"""Latency-shaped scheduling: speculative decode, chunked prefill, preemption.

Covers self-speculative greedy bit-identity against target-only decode for
all four model families, the perfect-draft tick bound, per-request sampling
determinism under co-batching, chunked-prefill output equality + decode
interleaving, requeue-with-backoff under a full pool, preemption/swap-out
round trips, SLO-class admission ordering, and stripe-constrained
``PrefixCache.evict_one`` eviction.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serving.engine import (SLO_RANK, BlockAllocator, Engine,
                                  PagedEngine, PrefixCache)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")
KEY = jax.random.PRNGKey(0)


def _mixed(eng, n=4, **kw):
    prompts = [np.arange(1, 9), np.arange(3, 15), np.arange(1, 9),
               np.arange(2, 7)][:n]
    budgets = [6, 4, 7, 5][:n]
    return [eng.submit(p, max_tokens=mt, **kw)
            for p, mt in zip(prompts, budgets)]


# ------------------------------------------------- speculative bit-identity
@pytest.mark.parametrize("arch", [None, "gemma3-27b", "zamba2-7b",
                                  "rwkv6-3b"])
def test_spec_greedy_bit_identical_families(arch):
    """Greedy speculative decode == target-only decode, bitwise, for the
    uniform / grouped-local / hybrid / ssm families.  The draft is a
    *different* model (fresh init), so acceptance is low — bit-identity
    must hold regardless of what the draft proposes."""
    cfg = CFG if arch is None else get_smoke(arch)
    m = build_model(cfg)
    params = m.init(KEY)
    draft = m.init(jax.random.PRNGKey(7))
    et = PagedEngine(cfg, params, max_batch=2, capacity=48, block_size=8)
    es = PagedEngine(cfg, params, max_batch=2, capacity=48, block_size=8,
                     draft=draft, spec_k=3)
    rt, rs = _mixed(et), _mixed(es)
    et.run()
    es.run()
    for a, b in zip(rt, rs):
        assert a.done and b.done
        assert a.out == b.out, (a.rid, a.out, b.out)
    assert es.spec_drafted > 0


def test_spec_perfect_draft_accepts_everything():
    """draft == target means every proposal verifies: each tick emits
    spec_k + 1 tokens, so the run takes ~1/(spec_k+1) the ticks and the
    acceptance counter reflects full accepts."""
    m = build_model(CFG)
    params = m.init(KEY)
    et = PagedEngine(CFG, params, max_batch=1, capacity=64, block_size=8)
    es = PagedEngine(CFG, params, max_batch=1, capacity=64, block_size=8,
                     draft=params, spec_k=3)
    a = et.submit(np.arange(1, 9), max_tokens=13)
    b = es.submit(np.arange(1, 9), max_tokens=13)
    et.run()
    es.run()
    assert a.out == b.out
    # 12 post-admission tokens at 4/tick -> 3 ticks (vs 12 target-only)
    assert es.ticks <= -(-12 // 4) < et.ticks
    assert es.spec_accepted == es.spec_drafted > 0


def test_spec_rollback_frees_speculative_blocks():
    """Rejected draft tokens must not leak pool blocks: after a run with a
    disagreeing draft, every block is back in the free pool."""
    m = build_model(CFG)
    params = m.init(KEY)
    es = PagedEngine(CFG, params, max_batch=2, capacity=48, block_size=8,
                     draft=m.init(jax.random.PRNGKey(7)), spec_k=4,
                     share_prefixes=False)
    rs = _mixed(es)
    es.run()
    assert all(r.done for r in rs)
    assert es.alloc.blocks_in_use == 0


# --------------------------------------------- per-request sampling streams
def test_sampled_output_independent_of_cobatching():
    """A seeded temp>0 request must emit the same tokens whether it runs
    alone or co-batched with other traffic: draws are keyed by
    (request.seed, request.step), not by engine-global key splits."""
    m = build_model(CFG)
    params = m.init(KEY)
    prompt = np.arange(1, 9)

    solo = PagedEngine(CFG, params, max_batch=4, capacity=48, block_size=8)
    r_solo = solo.submit(prompt, max_tokens=8, temperature=0.8, seed=123)
    solo.run()

    busy = PagedEngine(CFG, params, max_batch=4, capacity=48, block_size=8)
    noise = [busy.submit(np.arange(2, 11), max_tokens=10, temperature=0.5,
                         seed=i) for i in range(3)]
    r_busy = busy.submit(prompt, max_tokens=8, temperature=0.8, seed=123)
    busy.run()

    assert r_solo.out == r_busy.out
    assert all(n.done for n in noise)
    # and the draw stream is genuinely seeded: a different seed diverges
    other = PagedEngine(CFG, params, max_batch=4, capacity=48, block_size=8)
    r_other = other.submit(prompt, max_tokens=8, temperature=0.8, seed=124)
    other.run()
    assert r_other.out != r_solo.out


def test_sampled_requests_reproduce_across_engines():
    """Default-seeded sampling reproduces across engine instances fed the
    same submit sequence (seed derives from (engine seed, rid))."""
    m = build_model(CFG)
    params = m.init(KEY)
    outs = []
    for _ in range(2):
        eng = PagedEngine(CFG, params, max_batch=2, capacity=48,
                          block_size=8, seed=5)
        rs = _mixed(eng, 3, temperature=0.7)
        eng.run()
        outs.append([r.out for r in rs])
    assert outs[0] == outs[1]


# ------------------------------------------------------------ chunked prefill
def test_chunked_prefill_matches_blocking():
    """A long prompt admitted chunk-by-chunk produces bit-identical output
    to blocking admission, and the chunks really are incremental."""
    m = build_model(CFG)
    params = m.init(KEY)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, CFG.vocab, size=46)
    eb = PagedEngine(CFG, params, max_batch=2, capacity=64, block_size=8)
    ec = PagedEngine(CFG, params, max_batch=2, capacity=64, block_size=8,
                     prefill_chunk=16)
    a = eb.submit(prompt, max_tokens=6)
    b = ec.submit(prompt, max_tokens=6)
    eb.run()
    ec.run()
    assert a.out == b.out, (a.out, b.out)
    assert ec.chunk_steps >= 3                    # 46 tokens / 16-chunks


def test_chunked_prefill_interleaves_decode():
    """A short interactive request submitted alongside a long prompt
    finishes *during* the long prompt's chunked prefill — the property
    blocking admission cannot provide."""
    m = build_model(CFG)
    params = m.init(KEY)
    rng = np.random.default_rng(4)
    long_p = rng.integers(1, CFG.vocab, size=48)
    eng = PagedEngine(CFG, params, max_batch=2, capacity=64, block_size=8,
                      prefill_chunk=16)
    r_long = eng.submit(long_p, max_tokens=4)
    r_short = eng.submit(np.arange(1, 7), max_tokens=2)
    eng.run()
    assert r_long.done and r_short.done
    # the short request's whole life fits before the long prompt's first
    # token: its decode ticks ran between prefill chunks
    assert r_short.token_times[-1] < r_long.token_times[0]
    assert eng.chunk_steps >= 3


def test_chunked_prefill_prefix_sharing_still_works():
    """Chunked admission registers the computed blocks: a second identical
    prompt skips its full blocks via the prefix cache."""
    m = build_model(CFG)
    params = m.init(KEY)
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, CFG.vocab, size=40)
    eng = PagedEngine(CFG, params, max_batch=1, capacity=64, block_size=8,
                      prefill_chunk=16)
    a = eng.submit(prompt, max_tokens=3)
    eng.run()
    b = eng.submit(prompt, max_tokens=3)
    eng.run()
    assert a.out == b.out
    assert eng.prefill_tokens_skipped > 0


# ----------------------------------------------- pool pressure: requeue path
def test_submit_under_full_pool_requeues_and_completes():
    """Two same-class requests against a pool that fits only one: the
    second is requeued with backoff (no RuntimeError escapes run()) and
    completes after the first retires."""
    m = build_model(CFG)
    params = m.init(KEY)
    # 5 usable blocks; each request needs 3 (17-token prompt + decode),
    # so only one fits at a time
    eng = PagedEngine(CFG, params, max_batch=2, capacity=32, block_size=8,
                      num_blocks=6, share_prefixes=False)
    rng = np.random.default_rng(6)
    a = eng.submit(rng.integers(1, CFG.vocab, size=17), max_tokens=6)
    b = eng.submit(rng.integers(1, CFG.vocab, size=17), max_tokens=6)
    eng.run()
    assert a.done and b.done
    assert eng.requeues >= 1
    assert eng.alloc.blocks_in_use == 0


# --------------------------------------------------- preemption / swap-out
def test_preemption_swap_roundtrip_bit_identical():
    """Decode growth under pool saturation swaps the batch-class slot out
    to host memory and resumes it later; its output must match an
    uncontended run bit-for-bit (the swap round trip is exact)."""
    m = build_model(CFG)
    params = m.init(KEY)
    rng = np.random.default_rng(8)
    p_batch = rng.integers(1, CFG.vocab, size=15)
    p_inter = rng.integers(1, CFG.vocab, size=15)

    free = PagedEngine(CFG, params, max_batch=2, capacity=32, block_size=8,
                       share_prefixes=False)
    fb = free.submit(p_batch, max_tokens=14, slo="batch")
    fi = free.submit(p_inter, max_tokens=14, slo="interactive")
    free.run()

    # 5 usable blocks; both requests grow to 29 positions = 4 blocks each
    tight = PagedEngine(CFG, params, max_batch=2, capacity=32, block_size=8,
                        num_blocks=6, share_prefixes=False)
    tb = tight.submit(p_batch, max_tokens=14, slo="batch")
    ti = tight.submit(p_inter, max_tokens=14, slo="interactive")
    tight.run()

    assert tb.out == fb.out, (tb.out, fb.out)
    assert ti.out == fi.out, (ti.out, fi.out)
    assert tight.preemptions >= 1                 # batch slot made way
    assert tight.swap_ins >= 1                    # and was resumed
    assert tight.alloc.blocks_in_use == 0


def test_preemption_prefers_batch_class():
    """The preemption victim is the batch-class slot even when the
    interactive slot was admitted more recently."""
    m = build_model(CFG)
    params = m.init(KEY)
    eng = PagedEngine(CFG, params, max_batch=2, capacity=32, block_size=8,
                      share_prefixes=False)
    rb = eng.submit(np.arange(1, 9), max_tokens=8, slo="batch")
    ri = eng.submit(np.arange(2, 10), max_tokens=8, slo="interactive")
    eng._admit()
    slot_of = {eng._slots[i].rid: i for i in range(2) if eng._slots[i]}
    assert eng._preempt_victim() == slot_of[rb.rid]
    # strictly-lower-priority filter: nothing preemptible at batch rank
    assert eng._preempt_victim(min_rank=SLO_RANK["batch"] + 1) is None


# ------------------------------------------------------ SLO-ordered admission
def test_slo_admission_order():
    """With one slot, a later-submitted interactive request is admitted
    before the earlier batch request (SLO order beats FIFO across
    classes), and both complete."""
    m = build_model(CFG)
    params = m.init(KEY)
    eng = PagedEngine(CFG, params, max_batch=1, capacity=32, block_size=8)
    rb = eng.submit(np.arange(1, 9), max_tokens=3, slo="batch")
    ri = eng.submit(np.arange(2, 10), max_tokens=3, slo="interactive")
    eng.run()
    assert rb.done and ri.done
    assert ri._admit_seq < rb._admit_seq


# --------------------------------------- PrefixCache.evict_one under stripes
def _chain(alloc, cache, prompt, stripe=0):
    """Simulate an admitted-and-retired request: allocate the chain's
    blocks on ``stripe``, register them, drop the request's own refs."""
    bs = cache.bs
    trow = np.full(8, -1, np.int32)
    for j in range(len(prompt) // bs):
        trow[j] = alloc.alloc(stripe)
    cache.insert(np.asarray(prompt, np.int32), trow, 0, len(prompt) // bs)
    for j in range(len(prompt) // bs):
        alloc.decref(int(trow[j]))
    return [int(b) for b in trow[trow >= 0]]


def test_evict_one_stripe_constrained():
    """evict_one(stripe=t) only reclaims blocks backed by partition t —
    the flash-path invariant: a stripe-t allocation failure must not be
    "fixed" by freeing another shard's slab."""
    alloc = BlockAllocator(8, 4, stripes=2)
    cache = PrefixCache(alloc, 4)
    b0 = _chain(alloc, cache, np.arange(100, 104), stripe=0)  # older LRU
    b1 = _chain(alloc, cache, np.arange(200, 204), stripe=1)
    assert alloc.stripe_of(b0[0]) == 0 and alloc.stripe_of(b1[0]) == 1
    # stripe-1 eviction must skip the older stripe-0 entry
    assert cache.evict_one(stripe=1)
    assert b1[0] in alloc.free[1] and b0[0] not in alloc.free[0]
    # stripe-0 then reclaims its own
    assert cache.evict_one(stripe=0)
    assert b0[0] in alloc.free[0]
    assert not cache.evict_one(stripe=0)          # nothing left anywhere
    assert not cache.evict_one(stripe=1)


def test_evict_one_leaf_first_under_stripes():
    """A parent block with a registered child is never evicted before the
    child, per stripe: eviction walks leaf-first so a surviving entry's
    whole prefix chain stays valid."""
    alloc = BlockAllocator(8, 4, stripes=2)
    cache = PrefixCache(alloc, 4)
    blocks = _chain(alloc, cache, np.arange(1, 9), stripe=1)   # 2-block chain
    assert len(blocks) == 2
    parent, child = blocks
    assert cache.evict_one(stripe=1)
    assert child in alloc.free[1]                 # leaf went first
    assert parent not in alloc.free[1]
    assert cache.evict_one(stripe=1)
    assert parent in alloc.free[1]


def test_evict_one_skips_live_blocks_per_stripe():
    """Entries whose block a live request still references (allocator
    refcount > 1) are not eviction candidates on any stripe."""
    alloc = BlockAllocator(8, 4, stripes=2)
    cache = PrefixCache(alloc, 4)
    bs = cache.bs
    trow = np.full(8, -1, np.int32)
    trow[0] = alloc.alloc(1)
    prompt = np.arange(50, 54, dtype=np.int32)
    cache.insert(prompt, trow, 0, 1)
    # the "request" still holds its ref -> refcount 2 -> not evictable
    assert not cache.evict_one(stripe=1)
    alloc.decref(int(trow[0]))                    # request retires
    assert cache.evict_one(stripe=1)
