"""Property tests (hypothesis) for packing, stats quantization, storage."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import qformat


@settings(max_examples=25, deadline=None)
@given(bits=st.sampled_from([1, 2, 3, 4, 8]),
       rows=st.integers(1, 8),
       cols=st.integers(1, 33),
       seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(bits, rows, cols, seed):
    rng = np.random.default_rng(seed)
    d_in = rows * 8                       # keep divisible by 8/bits
    q = jnp.asarray(rng.integers(0, 2 ** bits, (d_in, cols)), jnp.uint8)
    planes = qformat.pack(q, bits)
    q2 = qformat.unpack(planes, bits, d_in)
    assert (q == q2).all()
    # packed size is exactly bits/8 per value (plane bytes)
    total = sum(p.size for p in planes)
    assert total == d_in * cols * bits // 8


@settings(max_examples=20, deadline=None)
@given(g=st.integers(2, 40), cols=st.integers(1, 9),
       seed=st.integers(0, 2**31 - 1))
def test_stats_quant_bounded_error(g, cols, seed):
    rng = np.random.default_rng(seed)
    stats = jnp.asarray(rng.random((g, cols)).astype(np.float32)) + 0.05
    codes, s2, z2 = qformat.quantize_stats(stats, 3, 16)
    back = qformat.dequantize_stats(codes, s2, z2, g)
    # 3-bit grid over each block of 16: error <= half a step
    blk_span = float((stats.max() - stats.min()))
    assert float(jnp.abs(back - stats).max()) <= blk_span / 7 / 2 + 1e-5


def test_quantized_tensor_roundtrip_and_bits():
    rng = np.random.default_rng(0)
    d_in, d_out, gs, bits = 128, 48, 32, 2
    W = rng.normal(size=(d_in, d_out)).astype(np.float32) * 0.1
    from repro.core import quantizers as qz
    q, scales, zeros, w_hat = qz.rtn_quantize(jnp.asarray(W), bits, gs)
    cap = 16
    rows = jnp.arange(cap, dtype=jnp.int32)
    cols = jnp.zeros(cap, jnp.int32)
    vals = jnp.zeros(cap, jnp.float32)
    qt = qformat.make_quantized(q, scales, zeros, bits, gs, (d_in, d_out),
                                rows, cols, vals, dtype="float32")
    wd = qt.dequantize()
    # reconstruction matches fake-quant up to the (3-bit) stats quantization
    assert float(jnp.abs(wd - w_hat).max()) < float(scales.max()) * 2.5
    bits_eff = float(qt.storage_bits())
    assert 2.0 < bits_eff < 3.5, bits_eff  # tiny layer: stats padding visible


def test_avg_bits_at_paper_scale():
    """At realistic layer sizes the accounting lands near the paper's 2.09."""
    rng = np.random.default_rng(2)
    d_in, d_out, gs = 2048, 512, 64
    from repro.core import quantizers as qz
    W = jnp.asarray(rng.normal(size=(d_in, d_out)).astype(np.float32))
    q, s, z, _ = qz.rtn_quantize(W, 2, gs)
    cap = max(int(0.002 * d_in * d_out), 8)     # ~0.2% outliers
    zr = jnp.zeros(cap, jnp.int32)
    qt = qformat.make_quantized(q, s, z, 2, gs, (d_in, d_out), zr, zr,
                                jnp.zeros(cap, jnp.bfloat16))
    bits_eff = float(qt.storage_bits())
    assert 2.05 < bits_eff < 2.35, bits_eff


def test_abstract_matches_concrete_structure():
    import jax
    rng = np.random.default_rng(1)
    d_in, d_out, gs, bits = 256, 64, 64, 3
    from repro.core import quantizers as qz
    W = jnp.asarray(rng.normal(size=(d_in, d_out)).astype(np.float32))
    q, s, z, _ = qz.rtn_quantize(W, bits, gs)
    cap = max(int(0.005 * d_in * d_out), 8)
    zr = jnp.zeros(cap, jnp.int32)
    qt = qformat.make_quantized(q, s, z, bits, gs, (d_in, d_out), zr, zr,
                                jnp.zeros(cap, jnp.bfloat16))
    ab = qformat.abstract_quantized(d_in, d_out, bits, gs)
    got = jax.tree.map(lambda x: (x.shape, x.dtype), qt)
    want = jax.tree.map(lambda x: (x.shape, x.dtype), ab)
    assert jax.tree_util.tree_structure(got) == \
        jax.tree_util.tree_structure(want)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        assert a == b, (a, b)
