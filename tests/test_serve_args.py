"""launch/serve.py flag validation: combinations that would silently
no-op (--spec-k without a draft source, --prefill-chunk off the paged
engine, warmup flags without a checkpoint, --http on the static cohort)
must exit 2 with a clear error, and every legitimate combination must
parse.  Also pins the serve/client shared-prefix construction contract
the warmup CI path relies on."""
import numpy as np
import pytest

from repro.launch.serve import build_parser, validate_args

GOOD = [
    [],
    ["--engine", "paged"],
    ["--engine", "paged", "--draft", "rtn-w4"],
    ["--engine", "paged", "--draft", "rtn-w4", "--spec-k", "6"],
    ["--engine", "paged", "--prefill-chunk", "16"],
    ["--engine", "paged", "--kv-bits", "8"],
    ["--engine", "paged", "--capacity", "256", "--block-size", "16"],
    ["--ckpt", "d", "--check-quant", "rtn-w4"],
    ["--engine", "paged", "--ckpt", "d", "--warmup"],
    ["--engine", "paged", "--ckpt", "d", "--save-warmup",
     "--shared-prefix", "32"],
    ["--http", "0"],
    ["--http", "8080", "--engine", "paged", "--ckpt", "d", "--warmup"],
    ["--engine", "static"],
]

BAD = [
    ["--spec-k", "4"],                        # no draft source: no-op
    ["--engine", "paged", "--spec-k", "4"],   # still no draft
    ["--draft", "rtn-w4"],                    # wrong engine
    ["--engine", "static", "--draft", "rtn-w4"],
    ["--prefill-chunk", "16"],                # continuous engine ignores it
    ["--engine", "static", "--prefill-chunk", "16"],
    ["--kv-bits", "8"],                       # int8 pool is paged-only
    ["--check-quant", "rtn-w4"],              # needs --ckpt
    ["--ckpt", "d", "--quant", "rtn-w4"],     # conflicting weight sources
    ["--engine", "paged", "--capacity", "100", "--block-size", "16"],
    ["--warmup"],                             # wrong engine
    ["--engine", "paged", "--warmup"],        # no ckpt to read from
    ["--engine", "paged", "--save-warmup"],   # no ckpt to write to
    ["--http", "8080", "--engine", "static"],
    ["--http", "70000"],                      # not a port
    ["--http", "-1"],
    ["--http", "8080", "--ckpt", "d", "--check-quant", "rtn-w4"],
    ["--http", "8080", "--engine", "paged", "--ckpt", "d",
     "--save-warmup"],
    ["--http", "8080", "--tp", "2"],
]


@pytest.mark.parametrize("argv", GOOD, ids=" ".join)
def test_valid_flag_combinations_parse(argv):
    ap = build_parser()
    validate_args(ap, ap.parse_args(argv))


@pytest.mark.parametrize("argv", BAD, ids=" ".join)
def test_silent_noop_combinations_rejected(argv):
    ap = build_parser()
    with pytest.raises(SystemExit) as e:
        validate_args(ap, ap.parse_args(argv))
    assert e.value.code == 2


def test_spec_k_default_resolution():
    """--spec-k stays None when unset (so validation can tell 'typed' from
    'default'); the engine builder resolves None to 4."""
    ap = build_parser()
    args = validate_args(ap, ap.parse_args(
        ["--engine", "paged", "--draft", "rtn-w4"]))
    assert args.spec_k is None


def test_shared_prefix_contract():
    """serve's demo-prompt prefix and the client's reconstruction are the
    same token chain — the warmed-server CI path depends on it."""
    from repro.launch.client import shared_prefix
    from repro.launch.serve import _demo_prompts

    class Cfg:
        vocab = 64

    ap = build_parser()
    args = validate_args(ap, ap.parse_args(["--shared-prefix", "32"]))
    prompts = _demo_prompts(Cfg, args)
    want = np.asarray(shared_prefix(32, 64), np.int32)
    for p in prompts:
        np.testing.assert_array_equal(p[:32], want)
