"""Chunked matmul-form WKV vs the sequential-scan oracle (§Perf iteration 8),
plus numerical-safety properties of the pairwise-decay formulation."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.rwkv6 import _wkv_chunked, _wkv_scan

RNG = np.random.default_rng(0)


def _inputs(B, S, H, K, decay_lo=0.2, seed=0):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    w = jnp.asarray(rng.uniform(decay_lo, 0.9995,
                                size=(B, S, H, K)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, K)).astype(np.float32))
    S0 = jnp.asarray(rng.normal(size=(B, H, K, K)).astype(np.float32)) * 0.1
    return r, k, v, w, u, S0


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_matches_scan(chunk):
    r, k, v, w, u, S0 = _inputs(2, 64, 3, 8)
    y1, s1 = _wkv_scan(r, k, v, w, u, S0)
    y2, s2 = _wkv_chunked(r, k, v, w, u, S0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1),
                               rtol=1e-4, atol=1e-4)


def test_chunked_safe_under_extreme_decay():
    """Fast decay (w -> 0) explodes the factorized k/P_j form; the pairwise
    form's exponents are <= 0, so outputs must stay finite."""
    r, k, v, w, u, S0 = _inputs(1, 32, 2, 4, decay_lo=1e-4, seed=3)
    y, s = _wkv_chunked(r, k, v, w, u, S0, chunk=16)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(s).all())
    y1, s1 = _wkv_scan(r, k, v, w, u, S0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y1),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), s_exp=st.integers(3, 6))
def test_chunked_property_random_shapes(seed, s_exp):
    S = 2 ** s_exp
    r, k, v, w, u, S0 = _inputs(1, S, 2, 8, seed=seed)
    y1, s1 = _wkv_scan(r, k, v, w, u, S0)
    y2, s2 = _wkv_chunked(r, k, v, w, u, S0)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
