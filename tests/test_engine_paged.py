"""Paged KV cache: block tables, the block allocator, prefix sharing.

Covers paged-vs-dense greedy bit-identity on mixed prompt lengths for all
four model families, allocator unit behavior (alloc/free/refcount/COW),
prefix-sharing reuse counters on a shared-system-prompt workload, the
over-length admission reject, and the bucketed-prefill jit-cache bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import build_model
from repro.serving.engine import (BlockAllocator, Engine, PagedEngine,
                                  PrefixCache)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")
KEY = jax.random.PRNGKey(0)


def _mixed_workload(eng, n=5):
    prompts = [np.arange(1, 9), np.arange(3, 15), np.arange(1, 9),
               np.arange(2, 7), np.arange(4, 12)][:n]
    budgets = [5, 3, 7, 4, 6][:n]
    return [eng.submit(p, max_tokens=mt) for p, mt in zip(prompts, budgets)]


# ----------------------------------------------------- paged cache, unit level
def test_paged_decode_matches_dense_bitwise():
    """Linear paged addressing + block gather == the dense ring (no wrap):
    same values at the same positions, identical masks, exact-zero padding
    in the softmax -> bitwise-equal decode output."""
    B, cap, KV, Dh, bs = 3, 32, 2, 8, 8
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = jax.random.normal(k1, (B, 1, 4, Dh))
    kall = jax.random.normal(k2, (B, 6, KV, Dh))
    kn = jax.random.normal(k3, (B, 1, KV, Dh))
    vn = jax.random.normal(k4, (B, 1, KV, Dh))
    pos = jnp.asarray([6, 3, 5])

    dc = A.init_cache(B, cap, KV, Dh, dtype=jnp.float32)
    dc = A.cache_prefill(dc, kall, kall)
    dc = A.cache_write(dc, kn, vn, pos)
    ref = A.decode_attention(q, dc, pos)

    mb = cap // bs
    pc = A.init_paged_cache(B, B * mb + 1, bs, mb, KV, Dh,
                            dtype=jnp.float32)
    bt = np.full((B, mb), -1, np.int32)
    bt[:, 0] = [1, 2, 3]                       # block 0 reserved scratch
    pc = pc._replace(block_tables=jnp.asarray(bt))
    pad = jnp.zeros((B, bs - 6, KV, Dh))
    kp = jnp.concatenate([kall, pad], 1)
    pc = A.cache_prefill(pc, kp, kp)
    pc = A.cache_write(pc, kn, vn, pos)
    got = A.decode_attention(q, pc, pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_paged_write_unmapped_row_hits_scratch_only():
    """A row whose target block is unmapped must not corrupt any live
    block: its append lands in the reserved scratch block 0."""
    B, bs, mb, KV, Dh = 2, 4, 2, 2, 8
    pc = A.init_paged_cache(B, 5, bs, mb, KV, Dh, dtype=jnp.float32)
    bt = np.full((B, mb), -1, np.int32)
    bt[0, 0] = 1                                # row 0 mapped, row 1 free
    pc = pc._replace(block_tables=jnp.asarray(bt))
    kn = jnp.ones((B, 1, KV, Dh))
    pc2 = A.cache_write(pc, kn, 2 * kn, jnp.asarray([0, 0]))
    k = np.asarray(pc2.k)
    assert (k[1, 0] == 1).all()                 # row 0's block written
    assert (k[2:] == 0).all()                   # no other block touched


# --------------------------------------------------------------- allocator
def test_allocator_alloc_free_refcount():
    al = BlockAllocator(8, 4)
    assert 0 in al.reserved                     # scratch never handed out
    blocks = [al.alloc() for _ in range(7)]
    assert None not in blocks and 0 not in blocks
    assert al.alloc() is None                   # exhausted
    al.incref(blocks[0])
    al.decref(blocks[0])
    assert al.blocks_in_use == 7                # still held (ref 1)
    al.decref(blocks[0])
    assert al.blocks_in_use == 6
    b = al.alloc()
    assert b == blocks[0]                       # freed block reused
    assert al.refcount[b] == 1


def test_allocator_stripes():
    al = BlockAllocator(8, 4, stripes=2)
    assert al.reserved == {0, 4}
    for _ in range(3):
        assert al.stripe_of(al.alloc(stripe=1)) == 1
    assert al.alloc(stripe=1) is None           # stripe 1 exhausted
    assert al.alloc(stripe=0) is not None       # stripe 0 untouched


def test_prefix_cache_insert_match_evict():
    al = BlockAllocator(16, 4)
    pc = PrefixCache(al, 4)
    prompt = np.arange(1, 13).astype(np.int32)  # 3 full blocks
    row = np.asarray([al.alloc(), al.alloc(), al.alloc()], np.int32)
    pc.insert(prompt, row, 0, 3)
    n, blocks = pc.match(prompt)
    assert n == 3 and blocks == list(row)
    # a different chain shares only the first block
    other = np.concatenate([prompt[:4], np.arange(90, 98)]).astype(np.int32)
    n2, b2 = pc.match(other)
    assert n2 == 1 and b2 == [int(row[0])]
    # requests released their refs -> cache holds the only ref; eviction is
    # leaf-first: the chain's deepest block goes before its parents
    for b in row:
        al.decref(int(b))
    assert pc.evict_one()
    assert prompt[:12].tobytes() not in pc.entries
    assert prompt[:8].tobytes() in pc.entries


def test_cow_private_copy_on_shared_write_target():
    """_ensure_block must copy-on-write when a slot's write block is
    shared: fresh block, contents preserved, refcount moved."""
    m = build_model(CFG)
    params = m.init(KEY)
    eng = PagedEngine(CFG, params, max_batch=1, capacity=32, block_size=8)
    r = eng.submit(np.arange(1, 10), max_tokens=2)      # S=9: blocks 0,1
    eng._admit()
    shared = int(eng._tables[0, 1])                     # holds pos 8 (tail)
    eng.alloc.incref(shared)                            # simulate a sharer
    before = np.asarray(eng._cache["kv"].k[:, shared]).copy()
    eng._ensure_block(0, int(eng._pos[0]))              # write target pos 9
    assert eng.cow_copies == 1
    fresh = int(eng._tables[0, 1])
    assert fresh != shared
    np.testing.assert_array_equal(
        np.asarray(eng._cache["kv"].k[:, fresh]), before)
    assert eng.alloc.refcount[shared] == 1              # our ref dropped
    eng.alloc.decref(shared)


# ------------------------------------------------------- engine bit-identity
@pytest.mark.parametrize("arch", ["gemma3-27b", "zamba2-7b", "rwkv6-3b"])
def test_paged_matches_dense_greedy_bitwise_families(arch):
    """Greedy outputs bit-identical to the dense continuous engine on a
    mixed-length workload for grouped-local / hybrid / ssm (the uniform
    dense family runs in the faster toy test below)."""
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(KEY)
    ec = Engine(cfg, params, max_batch=2, capacity=48)
    ep = PagedEngine(cfg, params, max_batch=2, capacity=48, block_size=8)
    rc, rp = _mixed_workload(ec, 4), _mixed_workload(ep, 4)
    ec.run()
    ep.run()
    for a, b in zip(rc, rp):
        assert a.done and b.done
        assert a.out == b.out, (a.rid, a.out, b.out)


def test_paged_matches_dense_greedy_bitwise_uniform():
    m = build_model(CFG)
    params = m.init(KEY)
    ec = Engine(CFG, params, max_batch=2, capacity=48)
    ep = PagedEngine(CFG, params, max_batch=2, capacity=48, block_size=8)
    rc, rp = _mixed_workload(ec), _mixed_workload(ep)
    ec.run()
    ep.run()
    for a, b in zip(rc, rp):
        assert a.out == b.out, (a.rid, a.out, b.out)
    # every retired slot returned its blocks; only prefix-cache refs remain
    assert ep.alloc.blocks_in_use == len(ep.prefix.entries)


# ----------------------------------------------------------- prefix sharing
def test_prefix_sharing_counters_and_identity():
    """Shared-system-prompt workload: full prefix blocks are prefilled
    once, later admissions skip them (counters prove it) and stay
    bit-identical to the dense engine that recomputes everything."""
    m = build_model(CFG)
    params = m.init(KEY)
    rng = np.random.default_rng(0)
    sysp = rng.integers(1, CFG.vocab, size=24).astype(np.int32)  # 3 blocks
    tails = [rng.integers(1, CFG.vocab, size=3 + i).astype(np.int32)
             for i in range(6)]

    def submit_all(eng):
        return [eng.submit(np.concatenate([sysp, t]), max_tokens=6)
                for t in tails]

    ec = Engine(CFG, params, max_batch=3, capacity=64)
    ep = PagedEngine(CFG, params, max_batch=3, capacity=64, block_size=8)
    rc, rp = submit_all(ec), submit_all(ep)
    ec.run()
    ep.run()
    for a, b in zip(rc, rp):
        assert a.out == b.out, (a.rid, a.out, b.out)
    # request 0 computes the 24-token prefix; the other 5 reuse all 3
    # blocks: 5 * 24 = 120 prefill tokens skipped, 15 block hits
    assert ep.prefill_tokens_skipped == 5 * 24
    assert ep.shared_block_hits == 5 * 3
    assert ec.prefill_tokens_skipped == 0
    # >= 30% prefill reduction on this workload (the acceptance bar)
    total = ep.prefill_tokens_skipped + ep.prefill_tokens_computed
    assert ep.prefill_tokens_skipped / total >= 0.30
    # retirement freed every request-held block back to the pool
    assert ep.alloc.blocks_in_use == len(ep.prefix.entries)


def test_prefix_sharing_off_still_bitwise():
    m = build_model(CFG)
    params = m.init(KEY)
    ec = Engine(CFG, params, max_batch=2, capacity=48)
    ep = PagedEngine(CFG, params, max_batch=2, capacity=48, block_size=8,
                     share_prefixes=False)
    rc, rp = _mixed_workload(ec), _mixed_workload(ep)
    ec.run()
    ep.run()
    for a, b in zip(rc, rp):
        assert a.out == b.out
    assert ep.prefill_tokens_skipped == 0


def test_admission_failure_releases_blocks_and_requeues():
    """When the pool cannot cover an admission, the partial acquisitions
    are released (no leak) and the request is requeued with backoff —
    admission does NOT raise (pool saturation is scheduling pressure, not
    an error)."""
    m = build_model(CFG)
    params = m.init(KEY)
    eng = PagedEngine(CFG, params, max_batch=1, capacity=32, block_size=8,
                      num_blocks=3)                 # 2 usable blocks
    r = eng.submit(np.arange(1, 18), max_tokens=2)  # needs 3 blocks
    eng._admit()                                    # must not raise
    assert eng.alloc.blocks_in_use == 0             # nothing leaked
    assert eng.queue and eng.queue[0] is r          # requeued
    assert eng.requeues == 1 and r._backoff >= 1    # backoff engaged
    assert r._not_before > eng._admit_clock         # gated, not hot-spun


def test_pool_eviction_reclaims_cached_prefixes():
    """An undersized pool evicts prefix-cache entries instead of dying:
    13 usable blocks serve a workload whose chains would pin more."""
    m = build_model(CFG)
    params = m.init(KEY)
    eng = PagedEngine(CFG, params, max_batch=2, capacity=32, block_size=8,
                      num_blocks=14)
    rng = np.random.default_rng(1)
    rs = [eng.submit(rng.integers(1, CFG.vocab, size=17), max_tokens=4)
          for _ in range(6)]
    eng.run()
    assert all(r.done for r in rs)
    assert eng.peak_blocks_in_use <= 13


# ------------------------------------------------------- admission hygiene
@pytest.mark.parametrize("cls", [Engine, PagedEngine])
def test_over_length_prompt_rejected_not_truncated(cls):
    m = build_model(CFG)
    params = m.init(KEY)
    eng = cls(CFG, params, max_batch=2, capacity=32)
    with pytest.raises(ValueError, match="does not fit"):
        eng.submit(np.arange(40), max_tokens=4)         # > capacity
    with pytest.raises(ValueError, match="does not fit"):
        eng.submit(np.arange(31), max_tokens=4)         # == capacity - 1
    assert not eng.queue                                 # nothing enqueued
    r = eng.submit(np.arange(1, 9), max_tokens=3)       # engine still runs
    eng.run()
    assert r.done and len(r.out) == 3


def test_bucketed_prefill_compile_cache_log_bound():
    """17 distinct prompt lengths must land in O(log L) prefill compiles
    (one per power-of-two bucket), not one per length."""
    m = build_model(CFG)
    params = m.init(KEY)
    eng = Engine(CFG, params, max_batch=2, capacity=64)
    lens = list(range(3, 20))
    rs = [eng.submit(np.arange(1, S + 1), max_tokens=2) for S in lens]
    eng.run()
    assert all(r.done for r in rs)
    buckets = {eng._bucket(S) for S in lens}
    assert eng._prefill._cache_size() <= len(buckets)
    assert eng._prefill._cache_size() < len(lens)
