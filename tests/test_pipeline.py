"""Algorithm-1 pipeline: OAC ordering claims at toy scale + fault tolerance."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import pipeline
from repro.data import SyntheticCorpus, make_calib_set
from repro.models import build_model

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  vocab=128, n_heads=4, n_kv_heads=2, head_dim=16,
                  d_ff=128, mlp="swiglu", norm="rmsnorm", pos="rope")


@pytest.fixture(scope="module")
def trained():
    """A briefly-trained tiny LM (structure matters for Hessian tests)."""
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=48, seed=3)
    from repro.train import optimizer as opt
    state = opt.adamw_init(params)
    sched = opt.warmup_cosine(3e-3, 5, 60)
    step = jax.jit(lambda p, s, b: opt.adamw_update(
        jax.grad(m.loss)(p, b), s, p, lr_sched=sched)[:2])
    for i in range(60):
        b = {"tokens": jnp.asarray(corpus.batch("train", i, 16)["tokens"])}
        params, state = step(params, state, b)
    calib = {"tokens": jnp.asarray(
        make_calib_set(corpus, 8)["tokens"])}
    test = {"tokens": jnp.asarray(corpus.batch("test", 0, 16)["tokens"])}
    return m, params, calib, test


def _ce(m, params, batch):
    return float(m.loss(params, batch))


def test_oac_beats_rtn_and_l2(trained):
    """The paper's headline ordering at 2 bits: OAC <= SpQR-l2 <= RTN in
    output-CE distortion (Table 1 direction, toy scale).  alpha follows the
    paper's per-method tuning (App. C.2: OAC best at alpha=1)."""
    m, params, calib, test = trained
    base = _ce(m, params, test)
    results = {}
    for name, q in {
        "rtn": QuantConfig(wbits=2, group_size=32, method="rtn"),
        "l2": QuantConfig(wbits=2, group_size=32, method="spqr",
                          hessian="l2", alpha=0.1),
        "oac": QuantConfig(wbits=2, group_size=32, method="spqr",
                           hessian="oac", alpha=1.0),
    }.items():
        qp, _ = pipeline.quantize_model(m, params, calib, q,
                                        log=lambda *a: None)
        results[name] = _ce(m, qp, test) - base
    assert results["oac"] <= results["l2"] * 1.10, results
    assert results["l2"] < results["rtn"], results
    assert results["oac"] < results["rtn"], results


def test_pipeline_resume(tmp_path, trained):
    """Killing the pipeline mid-run and restarting must produce the same
    quantized model (per-layer checkpoints)."""
    m, params, calib, _ = trained
    q = QuantConfig(wbits=3, group_size=32, method="optq", hessian="oac")
    full, _ = pipeline.quantize_model(m, params, calib, q,
                                      log=lambda *a: None)

    ck = str(tmp_path / "pipe")
    calls = {"n": 0}
    orig = pipeline._calibrate_kernel

    def bomb(*a, **k):
        calls["n"] += 1
        if calls["n"] == 4:
            raise RuntimeError("simulated preemption")
        return orig(*a, **k)

    pipeline._calibrate_kernel = bomb
    try:
        with pytest.raises(RuntimeError):
            pipeline.quantize_model(m, params, calib, q, ckpt_dir=ck,
                                    log=lambda *a: None)
    finally:
        pipeline._calibrate_kernel = orig
    resumed, _ = pipeline.quantize_model(m, params, calib, q, ckpt_dir=ck,
                                         log=lambda *a: None)
    for a, b in zip(jax.tree_util.tree_leaves(full),
                    jax.tree_util.tree_leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_sum_vs_mean_reduction_equivalent(trained):
    """Paper App. C.3: scaling the Hessian does not change calibration."""
    m, params, calib, test = trained
    qs = QuantConfig(wbits=2, group_size=32, method="optq", hessian="oac",
                     hessian_reduction="sum")
    qm = dataclasses.replace(qs, hessian_reduction="mean")
    ps, _ = pipeline.quantize_model(m, params, calib, qs, log=lambda *a: None)
    pm, _ = pipeline.quantize_model(m, params, calib, qm, log=lambda *a: None)
    assert abs(_ce(m, ps, test) - _ce(m, pm, test)) < 0.05


def test_pack_results_roundtrip(trained):
    """Packed QuantizedTensor params serve the same logits as fake-quant."""
    m, params, calib, test = trained
    q = QuantConfig(wbits=2, group_size=32, method="spqr", hessian="oac")
    fake, results = pipeline.quantize_model(m, params, calib, q,
                                            log=lambda *a: None)
    packed = pipeline.pack_results(fake, results, q)
    lf, _ = m.apply(fake, test)
    lp, _ = m.apply(packed, test)
    # identical up to the second-round (3-bit) stats quantization
    assert float(jnp.abs(lf - lp).mean()) < 0.2
