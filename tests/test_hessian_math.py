"""The paper's core math, validated against exact autograd quantities.

1. Fisher identity (paper eq. 12 / Appendix A): for a trained binomial
   logistic regression, E[(g g^T)] over y ~ P_w(y|x) equals the exact CE
   Hessian E[x pi(1-pi) x^T].
2. eq. 13/14: the aggregated row-wise Hessian sum_j G_j^T G_j equals G^T G.
3. GPTQ factor identities used by eq. 3/4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hessian as hess


def _logreg_data(n=4000, d=8, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(d,)) * 0.7)
    x = jnp.asarray(rng.normal(size=(n, d)))
    return w, x


def test_fisher_identity_logistic_regression():
    """E_y[g g^T] == x pi(1-pi) x^T exactly, per-sample (paper eq. 12)."""
    w, x = _logreg_data()
    pi = jax.nn.sigmoid(x @ w)

    def ce(w, xi, yi):
        p = jax.nn.sigmoid(xi @ w)
        return -(yi * jnp.log(p + 1e-12) + (1 - yi) * jnp.log(1 - p + 1e-12))

    # E_{y|x}[g g^T]: binary y has closed-form expectation
    g1 = jax.vmap(lambda xi: jax.grad(ce)(w, xi, 1.0))(x)   # (n,d)
    g0 = jax.vmap(lambda xi: jax.grad(ce)(w, xi, 0.0))(x)
    Egg = jnp.einsum("n,ni,nj->ij", pi, g1, g1) + \
        jnp.einsum("n,ni,nj->ij", 1 - pi, g0, g0)
    # exact Hessian sum_i x_i pi(1-pi) x_i^T (eq. 11/18)
    Hex = jnp.einsum("ni,n,nj->ij", x, pi * (1 - pi), x)
    np.testing.assert_allclose(np.asarray(Egg), np.asarray(Hex),
                               rtol=1e-4, atol=1e-4)


def test_fisher_sampled_converges():
    """Empirical (1/N) sum g g^T with sampled labels approaches the Hessian."""
    w, x = _logreg_data(n=60000, d=6, seed=1)
    rng = np.random.default_rng(2)
    pi = jax.nn.sigmoid(x @ w)
    y = jnp.asarray(rng.random(x.shape[0]) < np.asarray(pi), jnp.float32)
    g = x * (pi - y)[:, None]                     # eq. 10
    H_emp = (g.T @ g) / x.shape[0]
    H_exact = jnp.einsum("ni,n,nj->ij", x, pi * (1 - pi), x) / x.shape[0]
    rel = float(jnp.linalg.norm(H_emp - H_exact) / jnp.linalg.norm(H_exact))
    assert rel < 0.05, rel


def test_rowwise_aggregation_identity():
    """sum_j G_{j,:}^T G_{j,:} == G^T G (paper eq. 14 / Fig. 4)."""
    rng = np.random.default_rng(3)
    G = jnp.asarray(rng.normal(size=(12, 7)))
    agg = sum(jnp.outer(G[j], G[j]) for j in range(G.shape[0]))
    np.testing.assert_allclose(np.asarray(agg), np.asarray(G.T @ G),
                               rtol=1e-5, atol=1e-6)


def test_cholesky_inv_upper_identities():
    rng = np.random.default_rng(4)
    A = jnp.asarray(rng.normal(size=(16, 16)))
    H = A @ A.T + 0.5 * jnp.eye(16)
    U = hess.cholesky_inv_upper(H)
    Hinv = jnp.linalg.inv(H)
    # U upper triangular with H^-1 = U^T U
    np.testing.assert_allclose(np.asarray(jnp.tril(U, -1)), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(U.T @ U), np.asarray(Hinv),
                               rtol=1e-3, atol=1e-5)
    # [H^-1]_{00} == U[0,0]^2 (saliency denominator, eq. 4, first pivot)
    np.testing.assert_allclose(float(U[0, 0] ** 2), float(Hinv[0, 0]),
                               rtol=1e-4)


def test_regularize_eq21():
    H = jnp.diag(jnp.asarray([1.0, 3.0]))
    Hr = hess.regularize(H, 0.5)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(Hr)),
                               [1.0 + 1.0, 3.0 + 1.0])


def test_hinv_diag():
    rng = np.random.default_rng(5)
    A = jnp.asarray(rng.normal(size=(10, 10)))
    H = A @ A.T + jnp.eye(10)
    d = hess.hinv_diag(H, 0.0)
    np.testing.assert_allclose(np.asarray(d),
                               np.diag(np.linalg.inv(np.asarray(H))),
                               rtol=1e-3)
