"""OPTQ/SpQR solver invariants + the paper's ordering claims at kernel level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import hessian as hess
from repro.core import quantizers as qz
from repro.core import solver


def _problem(seed, d_in=64, d_out=48, n=256):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(d_in, d_out)).astype(np.float32)) * 0.2
    X = jnp.asarray(rng.normal(size=(n, d_in)).astype(np.float32))
    # correlated inputs make calibration matter
    mix = jnp.asarray(rng.normal(size=(d_in, d_in)).astype(np.float32)) * 0.3
    X = X + X @ mix
    return W, X, X.T @ X


def _l2(W, Wh, H):
    d = (Wh - W).astype(jnp.float32)
    return float(jnp.trace(d.T @ (H @ d)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), bits=st.sampled_from([2, 3, 4]))
def test_calibration_beats_rtn(seed, bits):
    """The OBS update (eq. 3) must not increase the quadratic loss vs RTN."""
    W, X, H = _problem(seed)
    rtn = solver.rtn_result(W, bits=bits, group_size=32)
    cal = solver.calibrate(W, H, bits=bits, group_size=32, alpha=0.01,
                           tau=1e30, outlier_capacity=1e-6)
    assert _l2(W, cal.w_hat, H) <= _l2(W, rtn.w_hat, H) * 1.02


def test_outliers_reduce_error():
    W, X, H = _problem(1)
    base = solver.calibrate(W, H, bits=2, group_size=32, alpha=0.01,
                            tau=1e30, outlier_capacity=1e-6)
    spqr = solver.calibrate(W, H, bits=2, group_size=32, alpha=0.01,
                            tau=0.3, outlier_capacity=0.01)
    assert _l2(W, spqr.w_hat, H) <= _l2(W, base.w_hat, H)
    assert int((spqr.out_vals != 0).sum()) > 0


def test_codes_reconstruct_w_hat():
    """w_hat == dequant(codes) + COO corrections (storage consistency)."""
    W, X, H = _problem(2)
    r = solver.calibrate(W, H, bits=2, group_size=32, alpha=0.05,
                         tau=1.0, outlier_capacity=0.01)
    grid = qz.Grid(jnp.repeat(r.scales, 32, 0), jnp.repeat(r.zeros, 32, 0), 2)
    w = qz.dequantize(r.q.astype(jnp.float32), grid)
    w = w.at[r.out_rows, r.out_cols].add(r.out_vals)
    np.testing.assert_allclose(np.asarray(w), np.asarray(r.w_hat),
                               rtol=1e-5, atol=1e-5)


def test_single_column_optimality():
    """With H=I the OBS update reduces to RTN (no cross terms)."""
    W, _, _ = _problem(3, d_in=32, d_out=8)
    H = jnp.eye(32)
    cal = solver.calibrate(W, H, bits=4, group_size=32, alpha=1e-9,
                           tau=1e30, outlier_capacity=1e-6)
    rtn = solver.rtn_result(W, bits=4, group_size=32)
    np.testing.assert_allclose(np.asarray(cal.w_hat), np.asarray(rtn.w_hat),
                               rtol=1e-3, atol=1e-4)


def test_act_order_not_worse():
    W, X, H = _problem(4)
    a = solver.calibrate(W, H, bits=2, group_size=32, alpha=0.01,
                         tau=1e30, outlier_capacity=1e-6, act_order=False)
    b = solver.calibrate(W, H, bits=2, group_size=32, alpha=0.01,
                         tau=1e30, outlier_capacity=1e-6, act_order=True)
    # act_order typically helps on correlated H; allow small regressions
    assert _l2(W, b.w_hat, H) <= _l2(W, a.w_hat, H) * 1.1


def test_oac_hessian_identity_matches_l2_on_linear_model():
    """For a LINEAR model with squared loss, G G^T ~ X^T X delta^2: the
    output-adaptive Hessian of a linear head reduces to the layer-wise one
    (sanity link between the two objectives)."""
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.normal(size=(200, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    y = X @ w + jnp.asarray(rng.normal(size=(200,)) * 0.1)

    def loss(w, i):
        return 0.5 * (X[i] @ w - y[i]) ** 2

    G = jax.vmap(lambda i: jax.grad(loss)(w, i))(jnp.arange(200))
    H_oac = G.T @ G
    resid2 = (X @ w - y) ** 2
    H_manual = jnp.einsum("ni,n,nj->ij", X, resid2, X)
    np.testing.assert_allclose(np.asarray(H_oac), np.asarray(H_manual),
                               rtol=1e-3, atol=1e-3)


def test_solver_matches_calib_kernel_blocks():
    """solver.calibrate inner loop == calib_update kernel ref per block."""
    from repro.kernels.calib_update import ref as kref
    W, X, H = _problem(6, d_in=32, d_out=16)
    Hr = hess.regularize(H, 0.05)
    U = hess.cholesky_inv_upper(Hr)
    r = solver.calibrate(W, H, bits=2, group_size=32, alpha=0.05,
                         tau=1e30, outlier_capacity=1e-6)
    grid = qz.fit_grid(W, 2)
    q, e, wh = kref.block_step_ref(W.astype(jnp.float32), U, grid.scale,
                                   grid.zero, jnp.zeros_like(W), 2)
    assert (q == r.q).all()
