"""Packed-checkpoint (`oac-qckpt`) tests: save/load round-trips across model
families, calibrated-OAC end-to-end, resume-then-pack, manifest rejection,
spec <-> code parity (docs/qformat.md), and tp=2 per-device plane bytes."""
import json
import os
import re
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.configs.base import ModelConfig, QuantConfig, reduce_cfg
from repro.core import pipeline, qformat
from repro.core.qformat import QuantizedTensor
from repro.models import build_model
from repro.serving.engine import StaticEngine
from repro.serving.qserve import ckpt as qckpt
from repro.serving.quantized import quantize_params_rtn

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
DOCS = os.path.join(os.path.dirname(__file__), "..", "docs", "qformat.md")
KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")


def _serve_greedy(cfg, tree):
    eng = StaticEngine(cfg, tree, max_batch=2, capacity=48)
    rs = [eng.submit(np.arange(1, 9), max_tokens=4),
          eng.submit(np.arange(3, 11), max_tokens=3)]
    eng.run()
    return [r.out for r in rs]


def _assert_trees_equal(a, b):
    fa, ta = jax.tree_util.tree_flatten(a)
    fb, tb = jax.tree_util.tree_flatten(b)
    assert str(ta) == str(tb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------- round trips
@pytest.mark.parametrize("arch", [None, "gemma3-27b", "zamba2-7b",
                                  "rwkv6-3b"])
def test_rtn_roundtrip_greedy_identical_families(tmp_path, arch):
    """save -> load must reproduce the in-memory packed tree bit-for-bit
    and serve bit-identical greedy tokens, for all four model families
    (dense / grouped-local / hybrid / ssm)."""
    cfg = CFG if arch is None else get_smoke(arch)
    params = build_model(cfg).init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    qckpt.save(str(tmp_path / "ck"), qp, cfg,
               QuantConfig(wbits=4, group_size=16))
    loaded = qckpt.load(str(tmp_path / "ck"))
    _assert_trees_equal(qp, loaded)
    assert _serve_greedy(cfg, qp) == _serve_greedy(cfg, loaded)


def test_oac_calibrated_ckpt_serves_end_to_end(tmp_path):
    """The acceptance loop: OAC-calibrate (Algorithm 1) -> pack_results ->
    ckpt.save -> ckpt.load -> greedy tokens bit-identical to serving the
    in-memory packed tree; manifest passes the dryrun shape verification
    and records the QuantConfig."""
    from repro.data import SyntheticCorpus, make_calib_set
    cfg = reduce_cfg(get_config("toy-llama"))
    m = build_model(cfg)
    params = m.init(KEY)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=48, seed=3)
    calib = {"tokens": jnp.asarray(make_calib_set(corpus, 4)["tokens"])}
    q = QuantConfig(wbits=3, group_size=32, method="spqr", hessian="oac")
    qp, results = pipeline.quantize_model(m, params, calib, q,
                                          log=lambda *a: None)
    packed = pipeline.pack_results(qp, results, q)
    d = str(tmp_path / "oac")
    qckpt.save(d, packed, cfg, q)
    loaded = qckpt.load(d)
    assert _serve_greedy(cfg, packed) == _serve_greedy(cfg, loaded)

    from repro.launch.dryrun import verify_ckpt
    rep = verify_ckpt(d, tp=2, verbose=False)
    assert rep["quantized"] > 0 and rep["bytes"]["total"] > 0
    assert rep["bytes_tp"]["ratio"] <= 0.75          # planes really shard
    qcfg = qckpt.quant_config(qckpt.load_manifest(d))
    assert (qcfg.method, qcfg.hessian, qcfg.wbits) == ("spqr", "oac", 3)


def test_resume_then_pack_matches_uninterrupted(tmp_path):
    """A pipeline killed mid-run and resumed must still pack — and pack to
    the same planes as the uninterrupted run (per-layer npz now persists
    the full CalibResult, not just w_hat)."""
    from repro.data import SyntheticCorpus, make_calib_set
    m = build_model(CFG)
    params = m.init(KEY)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=32, seed=3)
    calib = {"tokens": jnp.asarray(make_calib_set(corpus, 2)["tokens"])}
    q = QuantConfig(wbits=4, group_size=16, method="optq", hessian="identity")
    full, res_full = pipeline.quantize_model(m, params, calib, q,
                                             log=lambda *a: None)
    packed_full = pipeline.pack_results(full, res_full, q)

    ck = str(tmp_path / "pipe")
    calls = {"n": 0}
    orig = pipeline._calibrate_kernel

    def bomb(*a, **k):
        calls["n"] += 1
        if calls["n"] == 4:
            raise RuntimeError("preempted")
        return orig(*a, **k)

    pipeline._calibrate_kernel = bomb
    try:
        with pytest.raises(RuntimeError):
            pipeline.quantize_model(m, params, calib, q, ckpt_dir=ck,
                                    log=lambda *a: None)
    finally:
        pipeline._calibrate_kernel = orig
    qp2, res2 = pipeline.quantize_model(m, params, calib, q, ckpt_dir=ck,
                                        log=lambda *a: None)
    assert all(r.calib is not None for r in res2.values())
    _assert_trees_equal(packed_full, pipeline.pack_results(qp2, res2, q))


def test_resume_refuses_different_quant_config(tmp_path):
    """Re-running calibration into the same dir with a different
    QuantConfig must refuse, not silently re-pack stale results at the
    wrong bit-width."""
    from repro.data import SyntheticCorpus, make_calib_set
    m = build_model(CFG)
    params = m.init(KEY)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=32, seed=3)
    calib = {"tokens": jnp.asarray(make_calib_set(corpus, 2)["tokens"])}
    ck = str(tmp_path / "pipe")
    q4 = QuantConfig(wbits=4, group_size=16, method="rtn")
    pipeline.quantize_model(m, params, calib, q4, ckpt_dir=ck,
                            log=lambda *a: None)
    q2 = QuantConfig(wbits=2, group_size=16, method="rtn")
    with pytest.raises(ValueError, match="different QuantConfig"):
        pipeline.quantize_model(m, params, calib, q2, ckpt_dir=ck,
                                log=lambda *a: None)


def test_billm_residual_carrier_roundtrip(tmp_path):
    """BiLLM results ride the v1 residual planes: the packed carrier
    dequantizes to w_hat (bf16-exact) and round-trips through disk."""
    w = jax.random.normal(KEY, (64, 48)) * 0.1
    qt = qformat.make_residual_carrier(w, group_size=32)
    assert qt.resid_planes is not None
    back = qt.dequantize().astype(jnp.float32)
    ref = jnp.abs(w).astype(jnp.bfloat16).astype(jnp.float32) * jnp.sign(w)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(ref))
    tree = {"layers": {"a": {"kernel": qt}}}
    d = str(tmp_path / "bl")
    man = qckpt.save(d, tree, CFG, None)
    t = man["tensors"]["/layers/a/kernel"]
    assert "resid.0" in t["planes"] and "resid_scales" in t["planes"]
    loaded = qckpt.load(d)
    _assert_trees_equal(tree, loaded)


# -------------------------------------------------------------- rejection
def _small_ckpt(tmp_path):
    params = build_model(CFG).init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    d = str(tmp_path / "ck")
    qckpt.save(d, qp, CFG, None)
    return d


def test_draft_planes_roundtrip_and_speculative_serve(tmp_path):
    """``--draft`` co-packing: the draft planes ride the same planes.bin,
    load back bit-for-bit under ``which="draft"``, and the loaded pair
    serves speculatively with greedy output identical to target-only."""
    from repro.serving.engine import PagedEngine

    params = build_model(CFG).init(KEY)
    tq = QuantConfig(wbits=4, group_size=16, method="rtn")
    dq = QuantConfig(wbits=2, group_size=16, method="rtn")
    qp, _ = quantize_params_rtn(params, tq)
    dp, _ = quantize_params_rtn(params, dq)
    d = str(tmp_path / "ck")
    man = qckpt.save(d, qp, CFG, tq, draft=dp, draft_qcfg=dq)
    assert qckpt.has_draft(man)
    assert man["draft"]["qcfg"]["wbits"] == 2
    target = qckpt.load(d)
    draft = qckpt.load(d, which="draft")
    _assert_trees_equal(target, qp)
    _assert_trees_equal(draft, dp)

    def outs(dr):
        eng = PagedEngine(CFG, target, max_batch=2, capacity=48,
                          block_size=8, draft=dr, spec_k=3)
        rs = [eng.submit(np.arange(1, 9), max_tokens=6),
              eng.submit(np.arange(3, 11), max_tokens=5)]
        eng.run()
        return [r.out for r in rs]

    assert outs(draft) == outs(None)


def test_missing_draft_section_rejected(tmp_path):
    """Checkpoints without draft planes report has_draft False and raise
    the re-quantize hint on ``which="draft"``."""
    d = _small_ckpt(tmp_path)
    man = qckpt.load_manifest(d)
    assert not qckpt.has_draft(man)
    with pytest.raises(qckpt.CkptError, match="no draft planes"):
        qckpt.load(d, which="draft")


def test_version_mismatch_rejected(tmp_path):
    d = _small_ckpt(tmp_path)
    mpath = os.path.join(d, qckpt.MANIFEST_NAME)
    man = json.load(open(mpath))
    man["version"] = qformat.QFORMAT_VERSION + 1
    json.dump(man, open(mpath, "w"))
    with pytest.raises(qckpt.CkptError, match="version mismatch"):
        qckpt.load(d)


def test_corrupted_manifest_and_planes_rejected(tmp_path):
    d = _small_ckpt(tmp_path)
    ppath = os.path.join(d, qckpt.PLANES_NAME)
    with open(ppath, "r+b") as f:          # truncate the plane file
        f.truncate(os.path.getsize(ppath) - 100)
    with pytest.raises(qckpt.CkptError, match="truncated"):
        qckpt.load_manifest(d)

    d2 = _small_ckpt(tmp_path / "b")
    mpath = os.path.join(d2, qckpt.MANIFEST_NAME)
    with open(mpath, "w") as f:
        f.write("{not json")
    with pytest.raises(qckpt.CkptError, match="corrupt manifest"):
        qckpt.load_manifest(d2)

    d3 = _small_ckpt(tmp_path / "c")
    mpath = os.path.join(d3, qckpt.MANIFEST_NAME)
    man = json.load(open(mpath))
    man["format"] = "something-else"
    json.dump(man, open(mpath, "w"))
    with pytest.raises(qckpt.CkptError, match="not an oac-qckpt"):
        qckpt.load_manifest(d3)

    d4 = _small_ckpt(tmp_path / "d")    # a required plane dropped entirely
    mpath = os.path.join(d4, qckpt.MANIFEST_NAME)
    man = json.load(open(mpath))
    qt_path = next(p for p, t in man["tensors"].items()
                   if t["kind"] == "quantized")
    del man["tensors"][qt_path]["planes"]["q_scales"]
    json.dump(man, open(mpath, "w"))
    with pytest.raises(qckpt.CkptError, match="missing plane"):
        qckpt.load_manifest(d4)


def test_verify_ckpt_catches_shape_drift(tmp_path):
    cfg = reduce_cfg(get_config("toy-llama"))
    params = build_model(cfg).init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    d = str(tmp_path / "ck")
    qckpt.save(d, qp, cfg, None)
    mpath = os.path.join(d, qckpt.MANIFEST_NAME)
    man = json.load(open(mpath))
    qt_path = next(p for p, t in man["tensors"].items()
                   if t["kind"] == "quantized")
    # bits drives the packed code-plane shape: claiming w2 for planes
    # written at w4 must fail the abstract_quantized cross-check
    man["tensors"][qt_path]["meta"]["bits"] = 2
    json.dump(man, open(mpath, "w"))
    from repro.launch.dryrun import verify_ckpt
    with pytest.raises(AssertionError):
        verify_ckpt(d, verbose=False)

    # an incomplete checkpoint (param of the arch absent) must also fail
    man["tensors"][qt_path]["meta"]["bits"] = 4
    dense_path = next(p for p, t in man["tensors"].items()
                      if t["kind"] == "dense")
    del man["tensors"][dense_path]
    json.dump(man, open(mpath, "w"))
    with pytest.raises(AssertionError, match="missing"):
        verify_ckpt(d, verbose=False)


# ----------------------------------------------------- spec <-> code parity
def test_spec_plane_names_match_code_and_manifest(tmp_path):
    """docs/qformat.md's "Plane names" table must list exactly the entry
    names the code writes (qformat.ENTRY_NAMES + the dense `data` plane),
    and every plane a real manifest records must be spec'd."""
    text = open(DOCS).read()
    section = text.split("## Plane names")[1].split("\n## ")[0]
    spec = set()
    for line in section.splitlines():
        m = re.match(r"\|\s*`([^`]+)`", line)
        if m:
            spec.add(m.group(1))
    assert spec == set(qformat.ENTRY_NAMES) | {"data"}, spec

    # a manifest exercising every optional plane: bits=3 (two code planes)
    # + a residual carrier
    params = build_model(CFG).init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=3, group_size=16))
    qp["layers"]["carrier"] = {"kernel": qformat.make_residual_carrier(
        jax.random.normal(KEY, (32, 16)), group_size=16)}
    man = qckpt.save(str(tmp_path / "ck"), qp, CFG, None)
    used = {name for t in man["tensors"].values() for name in t["planes"]}
    assert used <= spec, used - spec
    assert {"codes.0", "codes.1", "resid.0", "resid_scales",
            "data"} <= used


def test_quantize_run_matches_in_memory_rtn(tmp_path):
    """launch/quantize.py's rtn path must serve bit-identically to the
    in-memory `--quant rtn-w4` tree (the CI ckpt-smoke contract)."""
    from repro.launch import quantize as ql
    cfg = reduce_cfg(get_config("toy-llama"))
    q = QuantConfig(wbits=4, group_size=32, method="rtn")
    ql.run(cfg, q, str(tmp_path / "ck"), n_calib=2, calib_seq=32,
           log=lambda *a: None)
    loaded = qckpt.load(str(tmp_path / "ck"))
    ref, _ = quantize_params_rtn(build_model(cfg).init(KEY),
                                 QuantConfig(wbits=4, group_size=32))
    assert _serve_greedy(cfg, loaded) == _serve_greedy(cfg, ref)


# ------------------------------------------------------------------ tp = 2
def test_tp2_per_device_bytes_match_report(tmp_path):
    """Under a (1, 2) mesh the loader must place plane shards so that the
    bytes actually resident per device equal the `packed_plane_bytes`
    prediction (planes sharded, not replicated) — and the checkpoint must
    still serve."""
    params = build_model(CFG).init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    d = str(tmp_path / "ck")
    qckpt.save(d, qp, CFG, None)
    eng = StaticEngine(CFG, qp, max_batch=1, capacity=32)
    ref = eng.submit(np.arange(1, 9), max_tokens=3)
    eng.run()
    code = f"""
        import jax, numpy as np
        from repro.configs.base import ModelConfig
        from repro.dist.sharding import make_plan
        from repro.serving.engine import StaticEngine
        from repro.serving.qserve import ckpt as qckpt
        from repro.serving.qserve.report import (device_plane_bytes,
                                                 packed_plane_bytes)

        CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                          d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        plan = make_plan(CFG, mesh)
        man = qckpt.load_manifest({d!r})
        sds = qckpt.abstract_params(man)
        rep = packed_plane_bytes(sds, plan.param_shardings(sds))
        assert rep["per_device"] * 2 == rep["total"], rep   # fully sharded
        with jax.set_mesh(mesh):
            loaded = qckpt.load({d!r}, plan)
            resident = device_plane_bytes(loaded)
            assert resident == rep["per_device"], (resident, rep)
            eng = StaticEngine(CFG, loaded, max_batch=1, capacity=32,
                               plan=plan)
            r = eng.submit(np.arange(1, 9), max_tokens=3)
            eng.run()
        assert r.done and r.out == {ref.out!r}, r.out   # == no-mesh greedy
        print("OK", resident, rep["total"])
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "OK" in r.stdout
