"""HLO analyzer fidelity: trip-count multipliers and collective parsing must
be exact on closed-form modules (the roofline table depends on this)."""
import subprocess
import sys
import os
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code, n=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_scan_flops_exact():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.roofline import hlo_parse
        def f(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
            return y
        x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
        comp = jax.jit(f).lower(x, ws).compile()
        r = hlo_parse.analyze(comp.as_text())
        exp = 2 * 128 * 256 * 256 * 12
        assert abs(r["flops"] - exp) / exp < 1e-6, (r["flops"], exp)
        print("EXACT")
    """)
    assert "EXACT" in out


def test_nested_scan_multiplies():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.roofline import hlo_parse
        def inner(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
            return y
        def outer(x, ws2):
            y, _ = jax.lax.scan(lambda c, ws: (inner(c, ws), None), x, ws2)
            return y
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws2 = jax.ShapeDtypeStruct((5, 3, 64, 64), jnp.float32)
        comp = jax.jit(outer).lower(x, ws2).compile()
        r = hlo_parse.analyze(comp.as_text())
        exp = 2 * 64 * 64 * 64 * 15
        assert abs(r["flops"] - exp) / exp < 1e-6, (r["flops"], exp)
        print("NESTED")
    """)
    assert "NESTED" in out


def test_collectives_counted_per_device():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline import hlo_parse
        mesh = jax.make_mesh((8,), ("d",))
        def f(x):
            return jax.lax.with_sharding_constraint(
                x.sum(0, keepdims=True) + 0.0,
                NamedSharding(mesh, P()))
        x = jax.ShapeDtypeStruct((8, 1024), jnp.float32)
        xs = NamedSharding(mesh, P("d", None))
        comp = jax.jit(f, in_shardings=(xs,)).lower(x).compile()
        r = hlo_parse.analyze(comp.as_text())
        # one all-reduce (or equivalent) of a (1,1024) f32 = 4096 B
        assert 0 < r["collective_bytes"] <= 4096 * 8, r
        print("COLL", r["collective_bytes"])
    """)
    assert "COLL" in out
