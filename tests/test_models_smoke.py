"""Per-arch reduced-config smoke tests: forward + one train step on CPU,
asserting output shapes and no NaNs — plus decode==apply consistency (the
serving-path correctness invariant)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_IDS, get_smoke
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.train import optimizer as opt

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, key=KEY):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    b = {"tokens": tok}
    if cfg.family == "vlm":
        F = cfg.n_frontend_tokens
        b = {"tokens": tok[:, : S - F],
             "frontend": jax.random.normal(key, (B, F, cfg.d_model)) * 0.02}
    if cfg.family == "audio":
        b["frontend"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", ASSIGNED_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(KEY)
    B, S = 2, 32
    logits, _ = m.apply(params, _batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ASSIGNED_IDS)
def test_one_train_step(arch):
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    loss0, grads = jax.value_and_grad(m.loss)(params, batch)
    assert not bool(jnp.isnan(loss0))
    gnorm = sum(float(jnp.abs(g).sum())
                for g in jax.tree_util.tree_leaves(grads))
    assert gnorm > 0, "gradients must flow to every family"
    state = opt.adamw_init(params)
    sched = opt.warmup_cosine(1e-3, 1, 10)
    params2, state, _ = opt.adamw_update(grads, state, params,
                                         lr_sched=sched)
    loss1 = m.loss(params2, batch)
    assert not bool(jnp.isnan(loss1))


@pytest.mark.parametrize("arch", ASSIGNED_IDS)
def test_decode_matches_apply(arch):
    cfg = get_smoke(arch)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    m = build_model(cfg)
    params = m.init(KEY)
    B, S = 2, 33
    tok = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0, cfg.vocab)
    fe = jax.random.normal(jax.random.fold_in(KEY, 2),
                           (B, S, cfg.d_model)) * 0.05
    F = cfg.n_frontend_tokens

    def mk(s):
        b = {"tokens": tok[:, :s]}
        if cfg.family == "vlm":
            b = {"tokens": tok[:, : s - F], "frontend": fe[:, :F]}
        if cfg.family == "audio":
            b["frontend"] = fe[:, :s]
        return b

    full, _ = m.apply(params, mk(S))
    cache = m.init_cache(B, S + 4, dtype=jnp.float32)
    _, cache, _ = m.prefill(params, mk(S - 1), cache)
    dec_in = fe[:, S - 1:S] if cfg.family == "audio" else (
        tok[:, S - 1 - F:S - F] if cfg.family == "vlm" else tok[:, S - 1:S])
    lg, _ = m.decode_step(params, dec_in, cache, jnp.asarray(S - 1))
    diff = float(jnp.abs(lg[:, 0] - full[:, -1]).max())
    assert diff < 1e-4, diff


def test_gemma_local_cache_is_windowed():
    """The grouped-local stack must allocate ring caches of window length."""
    cfg = get_smoke("gemma3-27b")
    m = build_model(cfg)
    cache = m.init_cache(2, 64, dtype=jnp.float32)
    assert cache["local"].k.shape[-3] == cfg.local_window
    assert cache["global"].k.shape[-3] == 64


def test_sliding_window_masks_old_tokens():
    """A local-attention model must ignore tokens beyond the window."""
    cfg = get_smoke("gemma3-27b")
    cfg = dataclasses.replace(cfg, n_layers=3, global_every=3, vocab=64,
                              local_window=4)
    m = build_model(cfg)
    params = m.init(KEY)
    tok = jax.random.randint(KEY, (1, 24), 0, 64)
    lg1, _ = m.apply(params, {"tokens": tok})
    # perturb a token far outside every window of the last position
    tok2 = tok.at[0, 2].set((tok[0, 2] + 7) % 64)
    lg2, _ = m.apply(params, {"tokens": tok2})
    # global layer still sees it -> logits differ; but if we make ALL layers
    # local, the last position must be unaffected
    cfg3 = dataclasses.replace(cfg, n_layers=2, global_every=3)
    m3 = build_model(cfg3)
    p3 = m3.init(KEY)
    a, _ = m3.apply(p3, {"tokens": tok})
    b, _ = m3.apply(p3, {"tokens": tok2})
    assert float(jnp.abs(a[0, -1] - b[0, -1]).max()) < 1e-5
