"""Continuous-batching engine: per-row cache clocks, slot-pool scheduling.

Covers the vector-clock cache contract at the attention level (per-row
validity masks), bit-identity of the continuous engine against the static
cohort baseline on mixed-length workloads, mid-flight admission into freed
slots, on-device sampling, and the dist train-step port's loss parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import build_model
from repro.serving.engine import Engine, StaticEngine

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")
KEY = jax.random.PRNGKey(0)


# ------------------------------------------------- per-row clock, unit level
def test_cache_write_vector_pos_matches_per_row_scalar():
    """A (B,) vector-clock write == B independent scalar-clock writes."""
    k1, k2 = jax.random.split(KEY)
    kn = jax.random.normal(k1, (3, 1, 2, 8))
    vn = jax.random.normal(k2, (3, 1, 2, 8))
    pos = jnp.asarray([5, 2, 7])
    cache = A.init_cache(3, 8, 2, 8, dtype=jnp.float32)
    got = A.cache_write(cache, kn, vn, pos)
    for b in range(3):
        row = A.init_cache(1, 8, 2, 8, dtype=jnp.float32)
        row = A.cache_write(row, kn[b:b + 1], vn[b:b + 1],
                            jnp.asarray(int(pos[b])))
        for g, r in zip(got, row):
            np.testing.assert_array_equal(np.asarray(g[b:b + 1]),
                                          np.asarray(r))


def test_decode_scores_mask_per_row():
    """Rows at different clocks mask different cache suffixes: a slot
    holding position p is valid for row b iff p <= pos[b]."""
    cap = 8
    cache = A.init_cache(2, cap, 2, 8, dtype=jnp.float32)
    k_all = jax.random.normal(KEY, (2, 6, 2, 8))
    cache = A.cache_prefill(cache, k_all, k_all)        # positions 0..5
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 1, 4, 8))
    s = A._decode_scores(q, cache, jnp.asarray([5, 2]), window=0)
    s = np.asarray(s)                                    # (B, KV, rep, cap)
    assert (s[0, ..., :6] > A.NEG_INF / 2).all()         # row 0 sees 0..5
    assert (s[1, ..., :3] > A.NEG_INF / 2).all()         # row 1 sees 0..2
    assert (s[1, ..., 3:6] <= A.NEG_INF / 2).all()       # ..but not 3..5
    assert (s[:, ..., 6:] <= A.NEG_INF / 2).all()        # empty slots masked


def test_decode_step_vector_pos_matches_scalar_rows():
    """decode_step under a (B,) clock == each row decoded alone at its own
    scalar clock (the lockstep fast path and the vector path agree)."""
    m = build_model(CFG)
    params = m.init(KEY)
    tok = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 12), 0,
                             CFG.vocab)
    lens = [10, 6]
    rows = []
    for b, S in enumerate(lens):
        rc = m.init_cache(1, 16, dtype=jnp.float32)
        _, rc, _ = m.prefill(params, {"tokens": tok[b:b + 1, :S]}, rc)
        rows.append(rc)
    # merge the two prefilled rows into one batched cache along the batch
    # axis of each leaf, via the engine's own structural discovery
    from repro.serving.engine import cache_batch_axes
    flat_r0 = jax.tree.leaves(rows[0])
    flat_r1 = jax.tree.leaves(rows[1])
    axes = cache_batch_axes(m, 16)
    merged = [jnp.concatenate([jnp.take(r0, jnp.asarray([0]), axis=ax),
                               jnp.take(r1, jnp.asarray([0]), axis=ax)],
                              axis=ax)
              for r0, r1, ax in zip(flat_r0, flat_r1, axes)]
    cache = jax.tree.unflatten(jax.tree.structure(rows[0]), merged)

    nxt = jnp.asarray([[3], [9]], jnp.int32)
    lg_vec, _ = m.decode_step(params, nxt, cache,
                              jnp.asarray(lens, jnp.int32))
    for b, S in enumerate(lens):
        lg_ref, _ = m.decode_step(params, nxt[b:b + 1], rows[b],
                                  jnp.asarray(S))
        np.testing.assert_array_equal(np.asarray(lg_vec[b:b + 1]),
                                      np.asarray(lg_ref))


# --------------------------------------------------------- engine vs static
def _mixed_workload(eng, n=5):
    prompts = [np.arange(1, 9), np.arange(3, 15), np.arange(1, 9),
               np.arange(2, 7), np.arange(4, 12)][:n]
    budgets = [5, 3, 7, 4, 6][:n]
    return [eng.submit(p, max_tokens=mt) for p, mt in zip(prompts, budgets)]


def test_continuous_matches_static_greedy_bitwise():
    """Greedy outputs bit-identical to the static-cohort engine on a
    mixed-prompt-length, uneven-budget workload."""
    m = build_model(CFG)
    params = m.init(KEY)
    ec = Engine(CFG, params, max_batch=2, capacity=48)
    es = StaticEngine(CFG, params, max_batch=2, capacity=48)
    rc, rs = _mixed_workload(ec), _mixed_workload(es)
    ec.run()
    es.run()
    for a, b in zip(rc, rs):
        assert a.done and b.done
        assert a.out == b.out, (a.rid, a.out, b.out)


def test_mid_flight_admission_reuses_freed_slot():
    """With 2 slots and 5 requests, later requests must be admitted on
    ticks > 0 (a retirement freed their slot mid-flight) — not in cohort
    waves — and every request still completes with its full budget."""
    m = build_model(CFG)
    params = m.init(KEY)
    eng = Engine(CFG, params, max_batch=2, capacity=48)
    rs = _mixed_workload(eng)
    eng.run()
    assert all(r.done for r in rs)
    admits = [r.admit_tick for r in rs]
    assert admits[0] == 0 and admits[1] == 0        # initial fill
    assert all(t > 0 for t in admits[2:]), admits   # admitted mid-flight
    # engine never burned a tick decoding a fully-retired pool
    assert all(len(r.out) == min(r.max_tokens, 64) for r in rs)
    # fewer ticks than the static engine's cohort-drain schedule would take:
    # total decode work is sum(out)-n first tokens spread over 2 slots
    assert eng.ticks <= sum(len(r.out) for r in rs)


def test_sampling_on_device_per_slot_temps():
    """Mixed greedy / temperature slots: sampling happens in the jit'd
    decode step, outputs stay in-vocab, and greedy rows are unaffected by
    hot rows sharing the batch."""
    m = build_model(CFG)
    params = m.init(KEY)
    eng = Engine(CFG, params, max_batch=2, capacity=48, seed=3)
    g = eng.submit(np.arange(1, 9), max_tokens=5)
    h = eng.submit(np.arange(1, 9), max_tokens=5, temperature=1.2)
    eng.run()
    ref = Engine(CFG, params, max_batch=2, capacity=48)
    g2 = ref.submit(np.arange(1, 9), max_tokens=5)
    ref.run()
    assert g.out == g2.out                          # greedy row undisturbed
    assert all(0 <= t < CFG.vocab for t in h.out)
    assert len(h.out) == 5


def test_eos_retires_slot():
    m = build_model(CFG)
    params = m.init(KEY)
    probe = Engine(CFG, params, max_batch=1, capacity=48)
    r0 = probe.submit(np.arange(1, 9), max_tokens=8)
    probe.run()
    eos = r0.out[2]                                  # force a known EOS hit
    eng = Engine(CFG, params, max_batch=1, capacity=48)
    r = eng.submit(np.arange(1, 9), max_tokens=8, eos=eos)
    eng.run()
    stop = r0.out.index(eos) + 1                     # first occurrence wins
    assert r.out == r0.out[:stop]                    # stopped at the EOS


# --------------------------------------------------------- train-step port
def test_dist_train_step_port_loss_parity(tmp_path):
    """launch/train's build_train_step path == the legacy single-host loop
    on the smoke config (float32 compute, trivial mesh)."""
    from repro.configs import get_smoke
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.data import DataIterator, SyntheticCorpus
    from repro.launch.train import dist_step_fn
    from repro.train.loop import train

    cfg = get_smoke("toy-llama")
    m = build_model(cfg)

    def tcfg(d):
        return TrainConfig(steps=3, lr=1e-3, ckpt_dir=str(d), ckpt_every=100,
                           compute_dtype="float32")

    def data():
        return DataIterator(
            SyntheticCorpus(vocab=cfg.vocab, seq_len=32, seed=7), "train", 4)

    params = m.init(KEY)
    _, legacy = train(m, params, data(), tcfg(tmp_path / "a"),
                      log=lambda *a: None)
    params = m.init(KEY)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with jax.set_mesh(mesh):
        step_fn, shard = dist_step_fn(cfg, tcfg(tmp_path / "b"),
                                      ShapeConfig("t", 32, 4, "train"), mesh)
        _, ported = train(m, shard(params), data(), tcfg(tmp_path / "b"),
                          step_fn=step_fn, log=lambda *a: None)
    np.testing.assert_allclose(legacy, ported, rtol=0, atol=1e-5)
