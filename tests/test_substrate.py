"""Data pipeline determinism, checkpoint atomicity/resume, fault-tolerant
training, gradient compression, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.data import DataIterator, SyntheticCorpus
from repro.models import build_model
from repro.serving.engine import Engine
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train.loop import train

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")


# ------------------------------------------------------------------- data
def test_data_shard_determinism():
    c = SyntheticCorpus(vocab=64, seq_len=16, seed=1)
    full = c.batch("train", 5, 8)["tokens"]
    # sharded fetches must tile the same global batch
    sh = [c.batch("train", 5, 8, shard_id=i, num_shards=2)["tokens"]
          for i in range(2)]
    assert sh[0].shape == (4, 16)
    # deterministic across calls
    again = c.batch("train", 5, 8)["tokens"]
    np.testing.assert_array_equal(full, again)
    # different steps/splits differ
    assert not np.array_equal(full, c.batch("train", 6, 8)["tokens"])
    assert not np.array_equal(full, c.batch("valid", 5, 8)["tokens"])


def test_iterator_state_restore():
    c = SyntheticCorpus(vocab=64, seq_len=16, seed=1)
    it = DataIterator(c, "train", 4)
    a = [next(it)["tokens"] for _ in range(3)]
    state = it.state
    b1 = next(it)["tokens"]
    it2 = DataIterator(c, "train", 4).restore(state)
    b2 = next(it2)["tokens"]
    np.testing.assert_array_equal(b1, b2)


def test_corpus_is_learnable():
    """Markov structure: bigram entropy must be far below unigram entropy."""
    c = SyntheticCorpus(vocab=64, seq_len=256, seed=0)
    toks = c.batch("train", 0, 8)["tokens"]
    # empirical check: successor sets are small
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    avg_branch = np.mean([len(v) for v in succ.values()])
    assert avg_branch < 40, avg_branch  # vocab 64, branching 24 + resets


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree, extra={"data": {"step": s}},
                  keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    got, meta = ckpt.restore(str(tmp_path), tree)
    assert meta["extra"]["data"]["step"] == 4
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    # gc kept only 2
    steps = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(steps) == 2


def test_train_resume_equals_uninterrupted(tmp_path):
    m = build_model(CFG)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=16, seed=2)

    def fresh():
        return m.init(jax.random.PRNGKey(0))

    tcfg = TrainConfig(steps=8, ckpt_every=3, ckpt_dir=str(tmp_path / "a"),
                       lr=1e-3, warmup=2)
    p_full, _ = train(m, fresh(), DataIterator(corpus, "train", 4), tcfg,
                      log=lambda *a: None)

    # interrupted run: preemption at step 5, then restart
    tcfg2 = TrainConfig(steps=8, ckpt_every=3, ckpt_dir=str(tmp_path / "b"),
                        lr=1e-3, warmup=2)

    class Boom(Exception):
        pass

    def injector(s):
        if s == 5:
            raise Boom()

    with pytest.raises(Boom):
        train(m, fresh(), DataIterator(corpus, "train", 4), tcfg2,
              log=lambda *a: None, fault_injector=injector)
    p_res, _ = train(m, fresh(), DataIterator(corpus, "train", 4), tcfg2,
                     log=lambda *a: None)
    for a, b in zip(jax.tree_util.tree_leaves(p_full),
                    jax.tree_util.tree_leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# -------------------------------------------------------------- compression
def test_int8_error_feedback_unbiased_over_steps():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    residual = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        (gq,), (residual,) = comp.ef_compress((g_true,), (residual,))
        acc = acc + gq
    # error feedback: accumulated compressed grads converge to the truth
    rel = float(jnp.linalg.norm(acc / 50 - g_true) /
                jnp.linalg.norm(g_true))
    assert rel < 0.02, rel


def test_int8_quant_roundtrip_bounded():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32)) * 3
    q, s = comp.quantize_int8(x)
    back = comp.dequantize_int8(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.51


# ------------------------------------------------------------------ serving
def test_engine_batches_and_finishes():
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(CFG, params, max_batch=3, capacity=48)
    rs = [eng.submit(np.arange(1, 9), max_tokens=4) for _ in range(4)]
    rs.append(eng.submit(np.arange(1, 5), max_tokens=3))
    eng.run()
    assert all(r.done for r in rs)
    assert all(len(r.out) >= 3 for r in rs)
    # greedy decode is deterministic given equal prompts
    assert rs[0].out == rs[1].out


def test_engine_matches_manual_greedy():
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    prompt = np.arange(1, 9)
    eng = Engine(CFG, params, max_batch=1, capacity=48)
    r = eng.submit(prompt, max_tokens=3)
    eng.run()
    # manual: full forward, greedy next token
    toks = list(prompt)
    for _ in range(3):
        lg, _ = m.apply(params, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(lg[0, -1])))
    assert r.out == toks[len(prompt):]
