"""No-mesh fallback contract: with no ambient DistCtx, every dist-aware
dispatch path must be EXACTLY the single-device computation — importing
``repro.dist`` cannot perturb numerics.  Runs on 1 CPU device."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.dist  # noqa: F401  (the import itself must be side-effect free)
from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.dist import ctx as dctx
from repro.dist.ctx import DistCtx
from repro.dist.sharding import make_plan
from repro.models import attention as A
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def test_default_ctx_is_none():
    assert dctx.get() is None


def test_use_nests_and_restores():
    mesh = jax.make_mesh((1,), ("data",))
    c1 = DistCtx(mesh=mesh, dp=("data",), tp="data", batch_spec=None)
    with dctx.use(c1):
        assert dctx.get() is c1
        with dctx.use(None):
            assert dctx.get() is None
        assert dctx.get() is c1
    assert dctx.get() is None
    # exception path restores too
    with pytest.raises(RuntimeError):
        with dctx.use(c1):
            raise RuntimeError()
    assert dctx.get() is None


def test_wsc_and_tp_if_are_identity_without_ctx():
    x = jnp.ones((4, 8))
    assert dctx.wsc(x, "b", None) is x
    assert dctx.tp_if(64) is None


def test_train_attention_matches_causal_bitwise():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 16, 4, 8))
    k = jax.random.normal(k2, (2, 16, 2, 8))
    v = jax.random.normal(k3, (2, 16, 2, 8))
    ref = A.causal_attention(q, k, v, window=0)
    got = A.train_attention(q, k, v, window=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_serve_attention_write_matches_dense_bitwise():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 1, 4, 8))
    kn = jax.random.normal(k2, (2, 1, 2, 8))
    vn = jax.random.normal(k3, (2, 1, 2, 8))
    cache = A.init_cache(2, 8, 2, 8, dtype=jnp.float32)
    pos = jnp.asarray(0)
    c2 = A.cache_write(cache, kn, vn, pos)
    ref = A.decode_attention(q, c2, pos)
    got, got_cache = A.serve_attention_write(q, kn, vn, cache, pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    for a, b in zip(got_cache, c2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "granite-moe-1b-a400m"])
def test_model_numerics_identical_under_trivial_mesh(arch):
    """apply/prefill/decode on a 1x1 mesh ctx == the no-ctx path exactly:
    sharding constraints on one device are layout no-ops."""
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(KEY)
    tok = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 17), 0,
                             cfg.vocab)

    logits0, _ = m.apply(params, {"tokens": tok})
    cache0 = m.init_cache(2, 24, dtype=jnp.float32)
    _, cache0, _ = m.prefill(params, {"tokens": tok[:, :16]}, cache0)
    dec0, _ = m.decode_step(params, tok[:, 16:17], cache0, jnp.asarray(16))

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = make_plan(cfg, mesh)
    c = plan.ctx(ShapeConfig("d", 24, 2, "decode"))
    assert dctx.get() is None
    with jax.set_mesh(mesh):
        with dctx.use(c):
            logits1, _ = m.apply(params, {"tokens": tok})
            cache1 = m.init_cache(2, 24, dtype=jnp.float32)
            _, cache1, _ = m.prefill(params, {"tokens": tok[:, :16]}, cache1)
            dec1, _ = m.decode_step(params, tok[:, 16:17], cache1,
                                    jnp.asarray(16))
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits0),
                               rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(dec1), np.asarray(dec0),
                               rtol=0, atol=0)


def test_plan_modes_single_device():
    """On a trivial mesh every arch must pick the no-collective modes."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("qwen2-1.5b", "nemotron-4-340b", "rwkv6-3b"):
        plan = make_plan(get_smoke(arch), mesh)
        c = plan.ctx(ShapeConfig("t", 32, 4, "train"))
        assert c.attn_train_mode == "grouped"
        assert c.attn_decode_mode == "dense"
        assert c.tp_size == 1 and c.dp_size == 1
