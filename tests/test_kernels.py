"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hessian as hess
from repro.core import qformat
from repro.kernels.calib_update import ops as cal_ops
from repro.kernels.calib_update import ref as cal_ref
from repro.kernels.dequant_matmul import kernel as dq_kernel
from repro.kernels.dequant_matmul import ops as dq_ops
from repro.kernels.dequant_matmul import ref as dq_ref
from repro.kernels.hessian_gg import ops as gg_ops
from repro.kernels.hessian_gg import ref as gg_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("shape", [(8, 128, 64), (16, 256, 128)])
def test_dequant_matmul_bits_sweep(bits, shape):
    M, K, N = shape
    gs = 64
    codes = jnp.asarray(RNG.integers(0, 2 ** bits, (K, N)), jnp.uint8)
    planes = qformat.pack(codes, bits)
    scales = jnp.asarray(RNG.random((K // gs, N), np.float32)) + 0.1
    zeros = jnp.asarray(
        RNG.integers(0, 2 ** bits, (K // gs, N)).astype(np.float32))
    x = jnp.asarray(RNG.normal(size=(M, K)).astype(np.float32))
    want = dq_ref.dequant_matmul_ref(x, codes, scales, zeros, gs)
    got = dq_kernel.dequant_matmul_kernel(
        x, planes, scales, zeros, bits=bits, group_size=gs,
        bm=8, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4,
        atol=float(jnp.abs(want).max()) * 1e-5)


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_dtypes(xdtype):
    M, K, N, gs, bits = 8, 128, 64, 64, 2
    codes = jnp.asarray(RNG.integers(0, 4, (K, N)), jnp.uint8)
    planes = qformat.pack(codes, bits)
    scales = jnp.asarray(RNG.random((K // gs, N), np.float32)) + 0.1
    zeros = jnp.ones((K // gs, N), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(M, K))).astype(xdtype)
    want = dq_ref.dequant_matmul_ref(x.astype(jnp.float32), codes, scales,
                                     zeros, gs)
    got = dq_kernel.dequant_matmul_kernel(
        x, planes, scales, zeros, bits=bits, group_size=gs,
        bm=8, bn=64, bk=64, interpret=True)
    tol = 1e-5 if xdtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=float(jnp.abs(want).max()) * tol)


def test_dequant_op_full_path_with_outliers():
    from repro.core import solver
    K, N, gs = 128, 96, 32
    W = jnp.asarray(RNG.normal(size=(K, N)).astype(np.float32)) * 0.1
    X = jnp.asarray(RNG.normal(size=(256, K)).astype(np.float32))
    r = solver.calibrate(W, X.T @ X, bits=2, group_size=gs, alpha=0.1,
                         tau=0.5, outlier_capacity=0.01)
    qt = qformat.make_quantized(r.q, r.scales, r.zeros, 2, gs, W.shape,
                                r.out_rows, r.out_cols, r.out_vals,
                                dtype="float32")
    x = jnp.asarray(RNG.normal(size=(4, K)).astype(np.float32))
    dense = x @ qt.dequantize()
    for path in ("fallback", "kernel"):
        got = dq_ops.dequant_matmul(x, qt, force_kernel=(path == "kernel"),
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape,bi", [((64, 32), 32), ((128, 96), 64),
                                      ((256, 64), 64), ((192, 48), 64)])
def test_hessian_gg_sweep(shape, bi):
    D, dout = shape
    G = jnp.asarray(RNG.normal(size=(D, dout)).astype(np.float32))
    H0 = jnp.asarray(RNG.normal(size=(D, D)).astype(np.float32))
    want = gg_ref.gg_ref(G, H0)
    got = gg_ops.gg_update(G, H0, force_kernel=True, interpret=True, bi=bi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_hessian_gg_triangle_decode():
    from repro.kernels.hessian_gg.kernel import _tri_ij
    # triangle index decoding must be exact for all t
    nI = 23
    t = 0
    for i in range(nI):
        for j in range(i + 1):
            ii, jj = _tri_ij(jnp.asarray(t))
            assert (int(ii), int(jj)) == (i, j), (t, i, j)
            t += 1


@pytest.mark.parametrize("B,N,bits", [(32, 64, 2), (64, 128, 3), (64, 256, 4)])
def test_calib_update_sweep(B, N, bits):
    W = jnp.asarray(RNG.normal(size=(B, N)).astype(np.float32))
    X = jnp.asarray(RNG.normal(size=(4 * B, B)).astype(np.float32))
    U = hess.cholesky_inv_upper(hess.regularize(X.T @ X, 0.1))
    scale = jnp.asarray(RNG.random(N).astype(np.float32)) * 0.2 + 0.05
    zero = jnp.asarray(
        RNG.integers(0, 2 ** bits, N).astype(np.float32))
    omask = jnp.asarray((RNG.random((B, N)) < 0.02).astype(np.float32))
    qr, er, hr = cal_ref.block_step_ref(W, U, scale, zero, omask, bits)
    qk, ek, hk = cal_ops.calib_block(W, U, scale, zero, omask, bits=bits,
                                     force_kernel=True, interpret=True)
    assert (qr == qk).all()
    np.testing.assert_allclose(np.asarray(ek), np.asarray(er), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=1e-4,
                               atol=1e-4)
