"""repro.obs: metrics registry, tracer, exporters, engine/pipeline wiring.

Pins the registry math (hand-computed histogram quantiles on both the
exact-sample and bucket-interpolation paths), the cardinality cap, the
snapshot/reset isolation contract, the Prometheus golden rendering, the
Perfetto export schema (nesting via args.parent, bounded buffer), the
zero-cost no-op mode (greedy decode bit-identical obs on/off), and the
instrumentation invariants the engines must keep: token_times length ==
emitted tokens even under speculative rollback, pool occupancy <= 1,
prefix hit rate in [0, 1], and per-layer calibration wall stamped into
the pipeline manifest so resumed runs report cumulative cost.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs.base import ModelConfig, QuantConfig
from repro.models import build_model
from repro.obs.metrics import CardinalityError, MetricsRegistry
from repro.obs.trace import Tracer
from repro.serving.engine import PagedEngine

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")
KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ registry math
def test_histogram_exact_quantiles():
    """While every observation fits in the sample buffer, quantiles are
    exact order statistics with linear interpolation between them."""
    m = MetricsRegistry()
    h = m.histogram("h_seconds", buckets=(1.0, 2.0, 4.0, 8.0, 16.0))
    for v in range(1, 11):                     # 1.0 .. 10.0
        h.observe(float(v))
    assert h.count == 10
    assert h.mean == pytest.approx(5.5)
    assert h.max == 10.0
    assert h.quantile(0.0) == 1.0
    assert h.quantile(1.0) == 10.0
    assert h.quantile(0.5) == pytest.approx(5.5)       # pos 4.5 in 1..10
    assert h.quantile(0.99) == pytest.approx(9.91)     # pos 8.91


def test_histogram_bucket_interpolation():
    """keep_samples=0 forces the Prometheus-style bucket path: linear
    within the target bucket, +Inf clamped to the top finite bound."""
    m = MetricsRegistry()
    h = m.histogram("h_seconds", buckets=(1.0, 2.0, 4.0, 8.0),
                    keep_samples=0)
    for v in (1.5, 3.0, 3.0, 6.0, 10.0):
        h.observe(v)
    # buckets: (<=1)=0 (<=2)=1 (<=4)=2 (<=8)=1 (+Inf)=1
    assert h.children()[()].bucket_counts == [0, 1, 2, 1, 1]
    # q=0.5 -> target 2.5 falls in the (2, 4] bucket holding obs 2..3:
    # 2 + (4-2) * (2.5-1)/2 = 3.5
    assert h.quantile(0.5) == pytest.approx(3.5)
    # q=0.9 -> target 4.5 runs off the finite buckets into +Inf -> clamp
    assert h.quantile(0.9) == 8.0
    # counts/sums still exact even without samples
    assert h.count == 5
    assert h.sum == pytest.approx(23.5)


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError):
        MetricsRegistry().histogram("h", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        MetricsRegistry().histogram("h", buckets=())


def test_cardinality_cap():
    m = MetricsRegistry(max_children=2)
    c = m.counter("c_total", labels=("rid",))
    c.labels(rid="a").inc()
    c.labels(rid="b").inc()
    c.labels(rid="a").inc()            # existing child: fine
    with pytest.raises(CardinalityError):
        c.labels(rid="c")


def test_counter_gauge_semantics():
    m = MetricsRegistry()
    c = m.counter("c_total")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = m.gauge("g")
    g.set(5)
    g.dec(2)
    assert g.value == 3
    # re-registering the same name+kind is idempotent; kind flips raise
    assert m.counter("c_total") is c
    with pytest.raises(ValueError):
        m.gauge("c_total")


def test_snapshot_reset_isolation():
    m = MetricsRegistry()
    c = m.counter("c_total", labels=("k",))
    c.labels(k="x").inc(3)
    h = m.histogram("h_seconds", buckets=(1.0, 2.0))
    h.observe(0.5)
    snap = m.snapshot()
    c.labels(k="x").inc(10)            # mutate after snapshot
    h.observe(1.5)
    assert snap["c_total"]["children"][("x",)]["value"] == 3
    assert snap["h_seconds"]["children"][()]["count"] == 1
    m.reset()
    # families and children survive a reset; values zero
    assert m.get("c_total").labels(k="x").value == 0
    assert m.get("h_seconds").count == 0
    assert m.get("h_seconds").quantile(0.99) == 0.0


def test_noop_registry_is_free():
    m = MetricsRegistry(enabled=False)
    c = m.counter("c_total", labels=("k",))
    c.inc()
    c.labels(k="x").inc(5)             # labels() returns the null object
    m.histogram("h_seconds").observe(1.0)
    m.gauge("g").set(2)
    assert m.families() == {}
    assert m.snapshot() == {}
    # every no-op instrument is the one shared null object
    assert m.counter("a") is m.gauge("b") is m.histogram("c")


# ----------------------------------------------------------------- renderer
def test_prometheus_golden():
    m = MetricsRegistry()
    c = m.counter("demo_requests_total", "requests served", labels=("slo",))
    c.labels(slo="batch").inc(3)
    c.labels(slo="interactive").inc()
    m.gauge("demo_occupancy", "pool occupancy").set(0.25)
    h = m.histogram("demo_latency_seconds", buckets=(0.1, 1.0),
                    help="request latency")
    for v in (0.25, 0.5, 2.0):
        h.observe(v)
    golden = os.path.join(os.path.dirname(__file__), "data",
                          "obs_golden.prom")
    with open(golden) as f:
        assert obs.prom.render(m) == f.read()


def test_prometheus_renders_childless_families():
    """An idle engine's full taxonomy is visible to scrapers: families
    with no children yet still emit HELP/TYPE."""
    m = MetricsRegistry()
    m.counter("idle_total", "never fired", labels=("k",))
    text = obs.prom.render(m)
    assert "# HELP idle_total never fired" in text
    assert "# TYPE idle_total counter" in text


# ------------------------------------------------------------------- tracer
def test_tracer_nesting_and_perfetto_schema():
    tr = Tracer()
    tr.name_process(1, "engine")
    root = tr.begin("req 0", pid=2, tid=0)
    child = tr.begin("prefill", pid=2, tid=0, parent=root)
    tr.end(child, args={"tokens": 8})
    tr.instant("preempt", pid=2, tid=0, args={"why": "pool"})
    tr.end(root)
    leak = tr.begin("open", pid=1)     # never ended: must still export
    doc = tr.export_chrome()
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["dropped_events"] == 0
    ev = {e["name"]: e for e in doc["traceEvents"]}
    assert ev["process_name"]["ph"] == "M"
    assert ev["process_name"]["args"]["name"] == "engine"
    x = ev["prefill"]
    assert x["ph"] == "X" and x["dur"] >= 0 and x["ts"] >= 0
    assert x["args"]["parent"] == root and x["args"]["tokens"] == 8
    assert ev["req 0"]["ph"] == "X"
    assert "incomplete" not in ev["req 0"]["args"]
    assert ev["preempt"]["ph"] == "i" and ev["preempt"]["s"] == "t"
    assert ev["open"]["args"]["incomplete"] is True
    assert leak is not None
    json.dumps(doc)                    # schema must be JSON-serializable


def test_tracer_bounded_buffer_and_noop():
    tr = Tracer(max_events=2)
    a = tr.begin("a")
    tr.instant("b")
    c = tr.begin("c")                  # over budget: dropped
    assert a is not None and c is None
    tr.end(c)                          # tolerated
    assert tr.dropped == 1
    assert tr.export_chrome()["otherData"]["dropped_events"] == 1
    off = Tracer(enabled=False)
    assert off.begin("x") is None
    with off.span("y"):
        pass
    off.instant("z")
    assert off.export_chrome()["traceEvents"] == []


def test_resolve_contract():
    ob = obs.Obs.make()
    assert obs.resolve(ob) is ob
    assert obs.resolve(None, default="off") is obs.OFF
    assert obs.resolve(None).enabled
    with pytest.raises(TypeError):
        obs.resolve(object())


# ------------------------------------------------------- engine instrument
def _reqs(eng, shared=True, n=4):
    base = np.arange(1, 25, dtype=np.int32)
    out = []
    for i in range(n):
        p = np.concatenate([base, np.asarray([30 + i], np.int32)]) \
            if shared else base + i
        out.append(eng.submit(p, max_tokens=5 + i,
                              slo="interactive" if i % 2 else "batch"))
    return out


def test_obs_on_off_greedy_bit_identical():
    """The no-op bundle must not change device math: same engine, same
    workload, obs on vs obs.OFF, bitwise-equal outputs."""
    params = build_model(CFG).init(KEY)

    def run(ob):
        eng = PagedEngine(CFG, params, max_batch=2, capacity=48,
                          block_size=8, obs=ob)
        hs = _reqs(eng)
        eng.run()
        return [list(r.out) for r in hs]

    assert run(obs.Obs.make()) == run(obs.OFF)


def test_token_times_match_out_under_spec_rollback():
    """Every decode path stamps token_times from the shared clock: under a
    rollback-heavy draft (fresh init), len(token_times) == len(out) and
    times are nondecreasing, ending before finish_wall."""
    m = build_model(CFG)
    params = m.init(KEY)
    draft = m.init(jax.random.PRNGKey(7))
    eng = PagedEngine(CFG, params, max_batch=2, capacity=48, block_size=8,
                      draft=draft, spec_k=3)
    hs = _reqs(eng, shared=False)
    eng.run()
    assert eng.spec_drafted > 0
    for r in hs:
        assert len(r.token_times) == len(r.out) > 0
        assert all(a <= b for a, b in zip(r.token_times, r.token_times[1:]))
        assert r.finish_wall >= r.token_times[-1] > 0


def test_engine_metric_sanity_and_lifecycle():
    """One shared-prefix run: counters agree with handles, gauges stay in
    range, and the trace holds >= 1 complete request lifecycle."""
    params = build_model(CFG).init(KEY)
    ob = obs.Obs.make()
    eng = PagedEngine(CFG, params, max_batch=2, capacity=48, block_size=8,
                      obs=ob)
    hs = _reqs(eng)
    eng.run()
    m = ob.metrics
    toks = sum(len(r.out) for r in hs)
    assert m.get("engine_tokens_total").value == toks
    assert m.get("engine_ticks_total").value > 0
    assert m.get("engine_run_seconds").value > 0
    assert 0.0 <= m.get("engine_block_pool_occupancy").value <= 1.0
    fin = m.get("engine_requests_finished_total")
    assert sum(c.value for c in fin.children().values()) == len(hs)
    pf = {k[0]: c.value for k, c in
          m.get("engine_prefix_cache_events_total").children().items()}
    hits, misses = pf.get("hit", 0), pf.get("miss", 0)
    assert 0.0 <= hits / max(1, hits + misses) <= 1.0
    assert hits > 0                    # shared prefix must actually share
    gap = m.get("engine_inter_token_seconds")
    assert sum(h.count for h in gap.children().values()) == \
        sum(max(0, len(r.out) - 1) for r in hs)
    # trace: each request row has a closed root span + phase spans
    spans = ob.tracer.spans()
    roots = [s for s in spans if s.name.startswith("req ") and s.pid == 2]
    assert len(roots) == len(hs)
    assert all(s.end_ns is not None for s in roots)
    phases = {s.name for s in spans if s.pid == 2}
    assert {"queued", "prefill", "decode"} <= phases
    # prometheus text of a live engine parses the full taxonomy
    text = obs.prom.render(m)
    for fam in ("engine_tick_seconds_bucket", "engine_queue_depth",
                "engine_block_pool_occupancy",
                "engine_prefix_cache_events_total"):
        assert fam in text


# ----------------------------------------------------------- pipeline wall
def test_pipeline_wall_stamped_and_resumed(tmp_path):
    """Per-layer solve walls land in pipeline.json; a resumed run restores
    every kernel, adds no new wall, and reports the prior cost."""
    from repro.core import pipeline
    from repro.data import SyntheticCorpus, make_calib_set
    import jax.numpy as jnp
    m = build_model(CFG)
    params = m.init(KEY)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=32, seed=3)
    calib = {"tokens": jnp.asarray(make_calib_set(corpus, 4)["tokens"])}
    q = QuantConfig(wbits=4, group_size=16, method="optq", hessian="l2",
                    alpha=0.1)
    ck = str(tmp_path / "pipe")
    ob = obs.Obs.make()
    pipeline.quantize_model(m, params, calib, q, ckpt_dir=ck,
                            log=lambda *a: None, obs=ob)
    with open(os.path.join(ck, "pipeline.json")) as f:
        man = json.load(f)
    assert man["wall"] and all(v > 0 for v in man["wall"].values())
    assert set(man["wall"]) == set(man["done"])
    assert m is not None
    walls = ob.metrics.get("pipeline_wall_seconds").value
    assert walls == pytest.approx(
        sum(man["wall"].values()) + man["hessian_wall"], rel=1e-3)
    # resume: all kernels restored, cumulative cost reported
    logs = []
    ob2 = obs.Obs.make()
    pipeline.quantize_model(m, params, calib, q, ckpt_dir=ck,
                            log=logs.append, obs=ob2)
    assert any("already paid" in s for s in logs)
    kern = ob2.metrics.get("pipeline_kernels_total")
    src = {k[0]: c.value for k, c in kern.children().items()}
    assert src.get("computed", 0) == 0 and src.get("restored", 0) > 0
