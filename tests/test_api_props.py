"""Property-based hardening of the serving bridge and the prefix cache.

Two invariants under randomized interleavings:

  * the API bridge never leaks blocks or slots: after any sequence of
    submit / partial-stream / disconnect / run-to-finish operations
    drains, every ``BlockAllocator`` refcount is explained by a live
    table mapping or a prefix-cache entry, and all slots are free;
  * ``PrefixCache`` insert/evict over random token chains keeps its
    parent/child ``kids`` counts exactly recomputable from the entry set
    and releases every block on evict-to-empty.

Both run twice: seeded-random deterministic sweeps that always execute,
and hypothesis-driven searches (shrinking, broader space) that skip
cleanly when hypothesis is not installed (per requirements-dev.txt).
The engine and bridge are module-level singletons reused across cases —
the interleavings shrink, not the engine geometry, and rebuilding the
jit'd engine per example would swamp the suite.
"""
import asyncio
import time
from collections import Counter

import jax
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAS_HYP = True
except ImportError:
    HAS_HYP = False

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serving.api import EngineBridge
from repro.serving.engine import BlockAllocator, PagedEngine, PrefixCache

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")

_STATE = {}


def _bridge():
    if not _STATE:
        params = build_model(CFG).init(jax.random.PRNGKey(0))
        eng = PagedEngine(CFG, params, max_batch=3, capacity=64,
                          block_size=8)
        _STATE["eng"] = eng
        _STATE["bridge"] = EngineBridge(eng, idle_wait=0.005).start()
    return _STATE["eng"], _STATE["bridge"]


def _assert_no_leaks(eng, bridge):
    """live == mapped: every allocator ref is a table mapping or a prefix
    entry, no slot is occupied, nothing queued."""
    with bridge.lock:
        assert not eng.queue
        assert all(s is None for s in eng._slots)
        refs = Counter()
        for row in eng._tables:
            for b in row[row >= 0]:
                refs[int(b)] += 1
        for b in eng.prefix.entries.values():
            refs[b] += 1
        assert dict(refs) == dict(eng.alloc.refcount)
        assert eng.alloc.blocks_in_use + eng.alloc.blocks_free \
            == eng.alloc.num_blocks - len(eng.alloc.reserved)


# ---------------------------------------------------------- bridge scenario
# one op = (prompt seed, prompt len, max_tokens, items to consume before
# disconnecting — None streams to completion)

async def _run_ops(bridge, ops):
    async def one(seed, plen, max_tokens, cut):
        prompt = [(seed * 7 + j) % CFG.vocab for j in range(plen)]
        h = await bridge.submit(prompt, max_tokens=max_tokens)
        seen = 0
        while True:
            kind, val = await asyncio.wait_for(h.queue.get(), timeout=60)
            if kind != "tok":
                return kind, val
            seen += 1
            if cut is not None and seen > cut:
                bridge.cancel(h.rid)      # simulated client disconnect
                cut = None                # keep draining to the terminal

    return await asyncio.gather(*(one(*op) for op in ops))


def _check_ops(ops):
    eng, bridge = _bridge()
    results = asyncio.run(_run_ops(bridge, ops))
    for kind, val in results:
        assert kind == "done", (kind, val)
        assert val in ("length", "stop", "cancelled")
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        with bridge.lock:
            if not eng.queue and all(s is None for s in eng._slots):
                break
        time.sleep(0.01)
    _assert_no_leaks(eng, bridge)


def test_bridge_interleavings_never_leak_seeded():
    rng = np.random.default_rng(42)
    for _ in range(8):
        n = int(rng.integers(1, 7))
        ops = [(int(rng.integers(0, 6)), int(rng.integers(1, 20)),
                int(rng.integers(1, 8)),
                None if rng.random() < 0.5 else int(rng.integers(0, 4)))
               for _ in range(n)]
        _check_ops(ops)


# ------------------------------------------------------ prefix-cache scenario
def _recompute_kids(cache, bs):
    kids = Counter()
    for key in cache.entries:
        if len(key) > bs * 4:
            kids[key[:-bs * 4]] += 1
    return {k: v for k, v in kids.items()}


def _check_chains(chains, evict_between):
    bs = 8
    alloc = BlockAllocator(64, bs)
    cache = PrefixCache(alloc, bs)
    for chain in chains:
        prompt = np.asarray(chain, np.int32)
        nb = len(prompt) // bs
        # simulate one admitted request: match shared blocks, own the rest
        n_shared, shared = cache.match(prompt)
        trow = np.full(16, -1, np.int32)
        for j, b in enumerate(shared):
            alloc.incref(b)
            trow[j] = b
        for j in range(n_shared, nb):
            b = alloc.alloc()
            if b is None:
                if not cache.evict_one():
                    break
                b = alloc.alloc()
            trow[j] = b
        cache.insert(prompt, trow, n_shared, int((trow >= 0).sum()))
        # retire: request drops its refs, cache entries keep theirs
        for b in trow[trow >= 0]:
            alloc.decref(int(b))
        if evict_between:
            cache.evict_one()
        # invariant: kids is exactly recomputable, every entry holds
        # exactly the cache's one ref
        assert _recompute_kids(cache, bs) == cache.kids
        for b in cache.entries.values():
            assert alloc.refcount[b] == 1
        assert len(set(cache.entries.values())) == len(cache.entries)
        assert alloc.blocks_in_use == len(cache.entries)
    while cache.evict_one():
        pass
    assert not cache.entries and not cache.kids and not cache.lru
    assert alloc.blocks_in_use == 0
    assert alloc.blocks_free == alloc.num_blocks - len(alloc.reserved)


def test_prefix_cache_refcounts_consistent_seeded():
    rng = np.random.default_rng(7)
    for case in range(20):
        chains = [list(rng.integers(0, CFG.vocab,
                                    size=int(rng.integers(8, 41))))
                  for _ in range(int(rng.integers(1, 9)))]
        # force shared prefixes in half the cases
        if case % 2:
            head = chains[0][:16]
            chains = [head + c[len(head):] if len(c) > len(head) else c
                      for c in chains]
        _check_chains(chains, evict_between=bool(case % 3 == 0))


# --------------------------------------------------- hypothesis-driven search
if HAS_HYP:
    OP = st.tuples(st.integers(0, 5), st.integers(1, 20),
                   st.integers(1, 8),
                   st.one_of(st.none(), st.integers(0, 4)))
    CHAIN = st.lists(st.integers(0, CFG.vocab - 1), min_size=8,
                     max_size=40)

    @settings(max_examples=12, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(ops=st.lists(OP, min_size=1, max_size=7))
    def test_bridge_interleavings_never_leak(ops):
        _check_ops(ops)

    @settings(max_examples=60, deadline=None)
    @given(chains=st.lists(CHAIN, min_size=1, max_size=8),
           evict_between=st.booleans())
    def test_prefix_cache_refcounts_consistent(chains, evict_between):
        _check_chains(chains, evict_between)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_bridge_interleavings_never_leak():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_prefix_cache_refcounts_consistent():
        pass
