"""repro.eval tests: metric math against hand-computed cross-entropy,
``PagedEngine.score`` bit-identity vs the dense teacher-forced reference
across all four model families, rival-calibrator (AdpQ / QuantEase)
checkpoint round-trips, calib/eval split disjointness, method provenance
stamps, and the quality scorecard tripwires."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig, QuantConfig
from repro.core import pipeline
from repro.core import quantizers as qz
from repro.data import SyntheticCorpus, make_calib_set, make_eval_set
from repro.eval import datasets as ds
from repro.eval import metrics as M
from repro.eval import runner, scorecard
from repro.models import build_model
from repro.serving.engine import Engine, StaticEngine
from repro.serving.qserve import ckpt as qckpt

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")


def _hand_nll(logits_row, target):
    """float64 log-sum-exp cross-entropy, written out by hand."""
    l = np.asarray(logits_row, np.float64)
    m = l.max()
    lse = m + np.log(np.exp(l - m).sum())
    return lse - l[int(target)]


# ----------------------------------------------------------------- metrics
def test_nll_greedy_matches_hand_computed():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(5, 17)) * 3.0, jnp.float32)
    targets = jnp.asarray(rng.integers(0, 17, size=5), jnp.int32)
    nll, greedy = jax.jit(M.nll_greedy)(logits, targets)
    ref = [_hand_nll(logits[i], targets[i]) for i in range(5)]
    np.testing.assert_allclose(np.asarray(nll), ref, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.argmax(np.asarray(logits), axis=-1))
    hand_ppl = float(np.exp(np.mean(ref)))
    assert abs(M.perplexity(nll) - hand_ppl) / hand_ppl < 1e-5


def test_choice_and_match_helpers():
    # rows score prompt(P=3) ++ choice(C=2): positions P-1..P span the choice
    nll = np.array([[9.0, 9.0, 1.0, 2.0],
                    [9.0, 9.0, 0.5, 0.5]])
    lp = M.choice_logprobs(nll, prompt_len=3)
    np.testing.assert_allclose(lp, [-3.0, -1.0])
    assert M.choice_accuracy(lp.reshape(1, 2), np.array([1])) == 1.0
    assert M.greedy_match_rate(np.array([1, 2, 3]), np.array([1, 0, 3])) \
        == pytest.approx(2 / 3)
    with pytest.raises(ValueError, match="shape mismatch"):
        M.greedy_match_rate(np.zeros(3), np.zeros(4))


def test_engine_ppl_matches_hand_cross_entropy():
    """Toy-model perplexity off the serving path == an independently
    hand-computed (float64 log-sum-exp over raw forward logits)
    cross-entropy, to 1e-5."""
    model = build_model(CFG)
    params = model.init(KEY)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=16, seed=7)
    toks = ds.ppl_stream(corpus, 2)
    eng = runner.make_engine(CFG, params, capacity=16, max_batch=2)
    ppl = M.perplexity(eng.score(toks)["nll"])

    step = jax.jit(model.decode_step)
    nll = []
    for i in range(2):
        cache = model.init_cache(1, 16, dtype=jnp.float32)
        logits, cache, _ = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray(toks[i:i + 1, :1])}, cache)
        nll.append(_hand_nll(np.asarray(logits)[0, 0], toks[i, 1]))
        for t in range(1, 15):
            logits, cache = step(params, jnp.asarray(toks[i:i + 1, t:t + 1]),
                                 cache, jnp.full((1,), t, jnp.int32))
            nll.append(_hand_nll(np.asarray(logits)[0, 0], toks[i, t + 1]))
    hand = float(np.exp(np.mean(nll)))
    assert abs(ppl - hand) / hand < 1e-5


# ---------------------------------------------------------- bit identity
@pytest.mark.parametrize("arch", [None, "gemma3-27b", "zamba2-7b",
                                  "rwkv6-3b"])
def test_score_bit_identical_to_dense_reference(arch):
    """Three contracts, per model family:

    1. ``PagedEngine(max_batch=1).score`` is fully bitwise (nll AND
       greedy) vs the per-row dense teacher-forced reference — the paged
       path adds zero numeric drift at matched decode batch.
    2. At production batch, paged and dense-slot engines stay bitwise
       identical to each other (block tables vs flat slots is pure
       storage).
    3. Greedy argmax is batch-invariant even for recurrent families,
       whose batched state math reassociates floats (~1e-6 nll drift).
    """
    cfg = CFG if arch is None else get_smoke(arch)
    params = build_model(cfg).init(KEY)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=12, seed=7)
    toks = ds.ppl_stream(corpus, 3)
    ref = runner.dense_reference_score(cfg, params, toks, capacity=16)

    o1 = runner.make_engine(cfg, params, capacity=16, max_batch=1).score(toks)
    np.testing.assert_array_equal(o1["nll"], ref["nll"])
    np.testing.assert_array_equal(o1["greedy"], ref["greedy"])

    op = runner.make_engine(cfg, params, capacity=16, max_batch=3).score(toks)
    od = Engine(cfg, params, max_batch=3, capacity=16).score(toks)
    np.testing.assert_array_equal(op["nll"], od["nll"])
    np.testing.assert_array_equal(op["greedy"], od["greedy"])
    np.testing.assert_array_equal(op["greedy"], ref["greedy"])
    np.testing.assert_allclose(op["nll"], ref["nll"], atol=2e-5)


def test_score_input_validation():
    eng = runner.make_engine(CFG, build_model(CFG).init(KEY), capacity=16)
    with pytest.raises(ValueError, match=r"\(B, S>=2\)"):
        eng.score(np.zeros((2, 1), np.int32))
    with pytest.raises(ValueError, match="exceeds the"):
        eng.score(np.zeros((1, 64), np.int32))


def test_score_leaves_engine_reusable():
    """score() must fully release its rows: a subsequent generate run and
    a second score() see a clean engine (paged blocks returned)."""
    params = build_model(CFG).init(KEY)
    eng = runner.make_engine(CFG, params, capacity=16, max_batch=2)
    toks = ds.ppl_stream(SyntheticCorpus(vocab=64, seq_len=12, seed=7), 3)
    a = eng.score(toks)
    r = eng.submit(np.arange(1, 6), max_tokens=3)
    eng.run()
    assert r.done and len(r.out) == 3
    b = eng.score(toks)
    np.testing.assert_array_equal(a["nll"], b["nll"])


def test_int8_kv_scoring_close_to_fp16_kv():
    params = build_model(CFG).init(KEY)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=16, seed=7)
    toks = ds.ppl_stream(corpus, 4)
    p16 = M.perplexity(runner.make_engine(
        CFG, params, capacity=16, kv_bits=16).score(toks)["nll"])
    p8 = M.perplexity(runner.make_engine(
        CFG, params, capacity=16, kv_bits=8).score(toks)["nll"])
    assert abs(p8 - p16) / p16 < 0.1, (p8, p16)


# ------------------------------------------------------------- eval sets
def test_calib_eval_splits_disjoint_and_deterministic():
    corpus = SyntheticCorpus(vocab=64, seq_len=32, seed=7)
    calib = make_calib_set(corpus, 8)["tokens"]
    ev = make_eval_set(corpus, 8)["tokens"]
    seen = {bytes(row.astype(np.int32).tobytes()) for row in calib}
    for row in ev:
        assert bytes(row.astype(np.int32).tobytes()) not in seen
    np.testing.assert_array_equal(ev, make_eval_set(corpus, 8)["tokens"])


def test_choice_set_shapes_and_gold():
    corpus = SyntheticCorpus(vocab=64, seq_len=32, seed=7)
    cs = ds.choice_set(corpus, 6, prompt_len=8, choice_len=4)
    assert cs.prompts.shape == (6, 8) and cs.choices.shape == (6, 4, 4)
    toks = make_eval_set(corpus, 6)["tokens"]
    for i in range(6):
        # the gold choice is the sequence's true continuation; distractors
        # all differ from it
        np.testing.assert_array_equal(cs.choices[i, cs.gold[i]],
                                      toks[i, 8:12])
        for k in range(4):
            if k != cs.gold[i]:
                assert not np.array_equal(cs.choices[i, k],
                                          cs.choices[i, cs.gold[i]])
    rows = cs.rows()
    assert rows.shape == (24, 12)
    np.testing.assert_array_equal(rows[5], np.concatenate(
        [cs.prompts[1], cs.choices[1, 1]]))
    with pytest.raises(ValueError, match="exceeds corpus seq_len"):
        ds.choice_set(corpus, 2, prompt_len=30, choice_len=4)


# ------------------------------------------------------- rival calibrators
def test_adpq_and_quantease_beat_rtn():
    """AdpQ must beat RTN in l2 (outliers reconstructed exactly);
    QuantEase must beat RTN on the Hessian-weighted objective it
    descends (starting from the RTN warm start, CD can only help)."""
    from repro.core.adpq import adpq_result
    from repro.core.quantease import quantease_result
    k1, k2 = jax.random.split(KEY)
    W = jax.random.normal(k1, (64, 48)) * 0.1
    spikes = jax.random.normal(k2, (12,)) * 2.0
    W = W.at[jnp.arange(12) * 5, jnp.arange(12) * 4 % 48].add(spikes)

    _, _, _, w_rtn = qz.rtn_quantize(W, 4, 16)
    rtn_l2 = float(jnp.sum((W - w_rtn) ** 2))
    r = adpq_result(W, bits=4, group_size=16, outlier_capacity=0.01)
    assert float(r.err_trace) < rtn_l2
    live = np.asarray(r.out_vals) != 0          # COO tail is zero-padded
    rows, cols = np.asarray(r.out_rows)[live], np.asarray(r.out_cols)[live]
    assert live.sum() >= 12                     # the planted spikes made it
    np.testing.assert_allclose(np.asarray(r.w_hat)[rows, cols],
                               np.asarray(W)[rows, cols], atol=1e-5)

    X = jax.random.normal(k2, (256, 64))
    H = X.T @ X / 256.0
    q = quantease_result(W, H, bits=4, group_size=16, cd_iters=3)
    Hn = H / jnp.mean(jnp.diag(H))

    def obj(w_hat):
        E = w_hat - W
        return float(jnp.trace(E.T @ Hn @ E))
    assert obj(q.w_hat) < obj(w_rtn)


@pytest.mark.parametrize("method,hessian", [("adpq", "identity"),
                                            ("quantease", "l2")])
def test_rival_calibrator_ckpt_roundtrip_greedy(tmp_path, method, hessian):
    """AdpQ / QuantEase results pack into the same oac-qckpt container:
    save -> load reproduces the tree bit-for-bit and serves bit-identical
    greedy tokens (mirror of test_ckpt's OAC round-trip)."""
    m = build_model(CFG)
    params = m.init(KEY)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=32, seed=3)
    calib = {"tokens": jnp.asarray(make_calib_set(corpus, 2)["tokens"])}
    q = QuantConfig(wbits=4, group_size=16, method=method, hessian=hessian,
                    alpha=0.1, cd_iters=2)
    qp, results = pipeline.quantize_model(m, params, calib, q,
                                          log=lambda *a: None)
    packed = pipeline.pack_results(qp, results, q)
    d = str(tmp_path / method)
    man = qckpt.save(d, packed, CFG, q)
    assert man["method"] == method
    loaded = qckpt.load(d)
    fa, ta = jax.tree_util.tree_flatten(packed)
    fb, tb = jax.tree_util.tree_flatten(loaded)
    assert str(ta) == str(tb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def greedy(tree):
        eng = StaticEngine(CFG, tree, max_batch=2, capacity=48)
        rs = [eng.submit(np.arange(1, 9), max_tokens=4),
              eng.submit(np.arange(3, 11), max_tokens=3)]
        eng.run()
        return [r.out for r in rs]
    assert greedy(packed) == greedy(loaded)


# -------------------------------------------------------- method stamping
def test_pipeline_stamps_method_and_refuses_mismatch(tmp_path):
    m = build_model(CFG)
    params = m.init(KEY)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=32, seed=3)
    calib = {"tokens": jnp.asarray(make_calib_set(corpus, 2)["tokens"])}
    ck = str(tmp_path / "pipe")
    q = QuantConfig(wbits=4, group_size=16, method="rtn")
    pipeline.quantize_model(m, params, calib, q, ckpt_dir=ck,
                            log=lambda *a: None)
    stored = json.load(open(os.path.join(ck, "pipeline.json")))
    assert stored["method"] == "rtn"
    q2 = QuantConfig(wbits=4, group_size=16, method="adpq")
    with pytest.raises(ValueError, match="refusing to resume with method"):
        pipeline.quantize_model(m, params, calib, q2, ckpt_dir=ck,
                                log=lambda *a: None)


# -------------------------------------------------------------- scorecard
def _row(method="rtn", wbits=4, ratio=1.01, **kw):
    r = {"arch": "t", "method": method, "wbits": wbits, "kv_bits": 16,
         "ppl": 10.0, "fp16_ppl": 10.0 / ratio, "ppl_ratio": ratio}
    r.update(kw)
    return r


def test_scorecard_upsert_replaces_and_sorts(tmp_path):
    p = str(tmp_path / "q.json")
    scorecard.upsert(p, _row("rtn", 4, ratio=1.02))
    scorecard.upsert(p, _row("adpq", 4))
    rows = scorecard.upsert(p, _row("rtn", 4, ratio=1.05))   # same key
    assert len(rows) == 2
    loaded = scorecard.load(p)
    assert [r["method"] for r in loaded] == ["adpq", "rtn"]   # key-sorted
    assert next(r for r in loaded if r["method"] == "rtn")["ppl_ratio"] \
        == 1.05
    with pytest.raises(ValueError, match="missing key fields"):
        scorecard.upsert(p, {"arch": "t", "method": "rtn"})
    with open(p, "w") as f:
        json.dump({"format": "other", "rows": []}, f)
    with pytest.raises(ValueError, match="not an oac-bench-quality"):
        scorecard.load(p)


def test_scorecard_tripwires():
    ok = [_row("rtn", 4, ratio=1.1), _row("spqr", 2, ratio=3.0),
          {"arch": "t", "method": "fp16", "wbits": 16, "kv_bits": 16,
           "ppl": 10.0}]                     # no ratio -> not tripwired
    assert scorecard.check(ok) == []
    bad = [_row("rtn", 4, ratio=2.0)]
    fails = scorecard.check(bad)
    assert len(fails) == 1 and "ppl_ratio 2.000" in fails[0]
    assert scorecard.check(bad, bounds={4: 3.0}) == []


# ------------------------------------------------------------- end to end
def test_evaluate_fp_self_identity(tmp_path):
    """The fp model scored against itself through two engine instances:
    ratio exactly 1.0, greedy match exactly 1.0 — and the resulting
    scorecard row passes the tripwires."""
    params = build_model(CFG).init(KEY)
    corpus = SyntheticCorpus(vocab=CFG.vocab, seq_len=32, seed=7)
    res = runner.evaluate(CFG, params, ref_params=params, corpus=corpus,
                          n_seq=2, n_choice_items=4, prompt_len=8,
                          choice_len=4, max_batch=2, log=lambda *a: None)
    assert res["ppl_ratio"] == 1.0
    assert res["greedy_match"] == 1.0
    assert res["choice_acc"] == res["fp16_choice_acc"]
    assert res["n_tokens"] == 2 * 31
    row = {"arch": CFG.name, "method": "rtn", "wbits": 4, "kv_bits": 16,
           "ppl": res["ppl"], "fp16_ppl": res["fp16_ppl"],
           "ppl_ratio": res["ppl_ratio"]}
    rows = scorecard.upsert(str(tmp_path / "q.json"), row)
    assert scorecard.check(rows) == []
