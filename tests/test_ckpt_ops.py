"""Fleet checkpoint ops: per-shard parallel save byte-identity and
prefix-cache warmup round trips.

The parallel writer must be a pure performance change — planes.bin and
manifest.json byte-identical to the streaming writer for dense, packed,
and draft-carrying trees.  A warmed PrefixCache must be indistinguishable
from a naturally-populated one: same prefill-skip counters on the
shared-prefix workload, bit-identical outputs, consistent allocator
refcounts, and clean rejection of warmup files from a different engine
geometry."""
import os

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, QuantConfig
from repro.models import build_model
from repro.serving.engine import PagedEngine
from repro.serving.quantized import quantize_params_rtn
from repro.serving.qserve import ckpt as qckpt

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def packed(params):
    p, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    return p


def _read(d, name):
    with open(os.path.join(d, name), "rb") as f:
        return f.read()


# ------------------------------------------------------------ parallel save
@pytest.mark.parametrize("workers", [2, 3, 8])
def test_parallel_save_byte_identical(tmp_path, packed, params, workers):
    a, b = str(tmp_path / "seq"), str(tmp_path / "par")
    qckpt.save(a, packed, CFG, QuantConfig(wbits=4, group_size=16),
               draft=params)
    qckpt.save(b, packed, CFG, QuantConfig(wbits=4, group_size=16),
               draft=params, workers=workers)
    assert _read(a, qckpt.PLANES_NAME) == _read(b, qckpt.PLANES_NAME)
    assert _read(a, qckpt.MANIFEST_NAME) == _read(b, qckpt.MANIFEST_NAME)


def test_parallel_save_loads_back(tmp_path, packed):
    d = str(tmp_path / "ck")
    qckpt.save(d, packed, CFG, QuantConfig(wbits=4, group_size=16),
               workers=4)
    loaded = qckpt.load(d)
    ref = jax.tree.leaves(packed)
    got = jax.tree.leaves(loaded)
    assert len(ref) == len(got)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))


def test_workers_one_is_stream_path(tmp_path, packed):
    """workers=1 (and 0) take the sequential writer; output matches."""
    a, b = str(tmp_path / "w0"), str(tmp_path / "w1")
    qckpt.save(a, packed, CFG, workers=0)
    qckpt.save(b, packed, CFG, workers=1)
    assert _read(a, qckpt.PLANES_NAME) == _read(b, qckpt.PLANES_NAME)


# ----------------------------------------------------------------- warmup
def _engine(params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("capacity", 64)
    kw.setdefault("block_size", 8)
    return PagedEngine(CFG, params, **kw)


def _shared_workload(eng, n=3, prefix=32, max_tokens=6):
    pre = (np.arange(1, prefix + 1) % CFG.vocab).astype(np.int32)
    rng = np.random.default_rng(0)
    prompts = [np.concatenate([pre, rng.integers(
        0, CFG.vocab, size=8).astype(np.int32)]) for _ in range(n)]
    rs = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
    eng.run()
    return rs


def test_warmup_matches_naturally_populated_cache(tmp_path, params):
    d = str(tmp_path)
    # naturally populate, persist, and measure a re-serve of the workload
    nat = _engine(params)
    out1 = [r.out for r in _shared_workload(nat)]
    qckpt.save_warmup(d, nat)
    base = nat.prefill_tokens_skipped
    out_nat = [r.out for r in _shared_workload(nat)]
    skipped_nat = nat.prefill_tokens_skipped - base

    # a warmed fresh replica must serve the same workload identically
    warm = _engine(params)
    assert qckpt.load_warmup(d, warm) == len(nat.prefix.entries)
    out_warm = [r.out for r in _shared_workload(warm)]
    assert warm.prefill_tokens_skipped == skipped_nat
    assert out_warm == out_nat == out1


def test_warmup_refcounts_consistent(tmp_path, params):
    d = str(tmp_path)
    nat = _engine(params)
    _shared_workload(nat)
    qckpt.save_warmup(d, nat)

    warm = _engine(params)
    n = qckpt.load_warmup(d, warm)
    assert n > 0
    # cache holds exactly one ref per seeded block, nothing else is live
    assert warm.alloc.blocks_in_use == n
    assert all(warm.alloc.refcount[b] == 1
               for b in warm.prefix.entries.values())
    # the seeded chain structure is evictable down to empty
    while warm.prefix.evict_one():
        pass
    assert not warm.prefix.entries and not warm.prefix.kids
    assert warm.alloc.blocks_in_use == 0


def test_warmup_top_n_keeps_hottest(tmp_path, params):
    d = str(tmp_path)
    nat = _engine(params)
    _shared_workload(nat)
    total = len(nat.prefix.entries)
    kept = qckpt.save_warmup(d, nat, top=2)
    assert kept == min(2, total)
    warm = _engine(params)
    assert qckpt.load_warmup(d, warm) <= kept


def test_warmup_idempotent_load(tmp_path, params):
    """Loading twice (restart with a stale in-memory cache) neither leaks
    blocks nor duplicates entries."""
    d = str(tmp_path)
    nat = _engine(params)
    _shared_workload(nat)
    qckpt.save_warmup(d, nat)
    warm = _engine(params)
    n = qckpt.load_warmup(d, warm)
    assert qckpt.load_warmup(d, warm) == 0
    assert warm.alloc.blocks_in_use == n


def test_warmup_geometry_mismatch_rejected(tmp_path, params):
    d = str(tmp_path)
    nat = _engine(params)
    _shared_workload(nat)
    qckpt.save_warmup(d, nat)
    other = _engine(params, block_size=16, capacity=64)
    with pytest.raises(qckpt.CkptError, match="block_size"):
        qckpt.load_warmup(d, other)
    with pytest.raises(qckpt.CkptError, match="no warmup"):
        qckpt.load_warmup(str(tmp_path / "nope"), nat)


def test_warmup_empty_cache_roundtrip(tmp_path, params):
    d = str(tmp_path)
    eng = _engine(params, share_prefixes=False)
    _shared_workload(eng)
    assert qckpt.save_warmup(d, eng) == 0
    warm = _engine(params)
    assert qckpt.load_warmup(d, warm) == 0
    assert warm.alloc.blocks_in_use == 0
