"""qserve: quantized serving subsystem.

Covers the RTN skip-list contract (misaligned projections reported, not
silently left fp), the hardened ``_is_quant_leaf`` predicate, the
``quantized_linear`` dispatch (bit-identical to the fused op off-mesh),
int8 KV quantization (roundtrip bound, model-level logit tolerance, engine
KV-bytes reduction), and greedy bit-identity of ``PagedEngine`` vs
``StaticEngine`` on RTN-w4 checkpoints across all four model families.
TP-sharded plane tests live in ``test_dist.py`` (they need virtual
devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ModelConfig, QuantConfig
from repro.core import qformat
from repro.core import quantizers as qz
from repro.core.qformat import QuantizedTensor
from repro.kernels.dequant_matmul import ops as dq_ops
from repro.models import build_model
from repro.serving.engine import PagedEngine, StaticEngine
from repro.serving.qserve import kvquant
from repro.serving.qserve.linear import quantized_linear
from repro.serving.quantized import _is_quant_leaf, quantize_params_rtn

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")
KEY = jax.random.PRNGKey(0)
# documented int8-KV serving contract: max-abs logit drift vs the fp paged
# pool (measured ~0.035 on the toy config; DESIGN.md §Quantized serving)
INT8_KV_LOGIT_TOL = 0.1


# ----------------------------------------------------------- leaf predicate
def test_is_quant_leaf_excludes_non_kernels():
    """A future param rename must not get packed by accident: only exact
    ``/kernel`` leaves qualify, and never 1-D leaves or norm scales."""
    ok = jnp.zeros((64, 64))
    vec = jnp.zeros((64,))
    assert _is_quant_leaf("/layers/attn/wq/kernel", ok)
    assert not _is_quant_leaf("/layers/attn/wq/kernel", vec)   # 1-D
    assert not _is_quant_leaf("/layers/ln1/scale", vec)        # norm scale
    assert not _is_quant_leaf("/layers/ln1/scale", ok)
    assert not _is_quant_leaf("/final_norm/kernel", ok)        # norm-named
    assert not _is_quant_leaf("/layers/mlp/wi/foo_kernel", ok)  # not /kernel
    assert not _is_quant_leaf("/embed/kernel", ok)
    assert not _is_quant_leaf("/lm_head/kernel", ok)
    assert not _is_quant_leaf("/layers/attn/wq/bias", vec)


def test_quantize_params_rtn_never_packs_vectors_or_norms():
    tree = {"a": {"kernel": jnp.zeros((64,))},          # 1-D, kernel-named
            "norm": {"kernel": jnp.ones((64, 64))},     # norm-pathed 2-D
            "b": {"kernel": jax.random.normal(KEY, (64, 64))}}
    qp, skipped = quantize_params_rtn(tree, QuantConfig(wbits=4,
                                                        group_size=16))
    assert not isinstance(qp["a"]["kernel"], QuantizedTensor)
    assert not isinstance(qp["norm"]["kernel"], QuantizedTensor)
    assert isinstance(qp["b"]["kernel"], QuantizedTensor)
    assert skipped == []        # exclusions are by policy, not alignment


# ------------------------------------------------------------- skip list
def test_skip_list_reports_misaligned_projections():
    """Odd head dims leave attention projections misaligned with the quant
    group — those kernels must be *reported*, not silently left fp."""
    odd = ModelConfig(name="odd", family="dense", n_layers=2, d_model=48,
                      vocab=64, n_heads=2, n_kv_heads=2, head_dim=24,
                      d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")
    m = build_model(odd)
    params = m.init(KEY)
    qp, skipped = quantize_params_rtn(params, QuantConfig(wbits=4,
                                                          group_size=32))
    # d_in=48 projections (wq/wk/wv from d_model, wo from 2*24 heads,
    # wi/wg from d_model) all misalign with group 32; the mlp wo (d_in=64)
    # packs
    assert any("wq/kernel" in p for p in skipped)
    assert any("attn/wo/kernel" in p for p in skipped)
    assert any("mlp/wi/kernel" in p for p in skipped)
    assert not any("mlp/wo" in p for p in skipped)
    from repro import utils
    flat, _ = jax.tree_util.tree_flatten_with_path(
        qp, is_leaf=lambda n: isinstance(n, QuantizedTensor))
    leaves = {utils.path_str(p): v for p, v in flat}
    for p in skipped:           # skipped kernels really stayed fp arrays
        assert not isinstance(leaves[p], QuantizedTensor), p
    assert isinstance(leaves["/layers/mlp/wo/kernel"], QuantizedTensor)
    # the skipped model still serves
    eng = StaticEngine(odd, qp, max_batch=2, capacity=32)
    r = eng.submit(np.arange(1, 9), max_tokens=3)
    eng.run()
    assert r.done and len(r.out) == 3


def test_aligned_config_has_empty_skip_list():
    m = build_model(CFG)
    params = m.init(KEY)
    _, skipped = quantize_params_rtn(params, QuantConfig(wbits=4,
                                                         group_size=16))
    assert skipped == []


# ------------------------------------------------------ dispatch layer
def test_quantized_linear_no_ctx_matches_fused_op():
    """Off-mesh the dispatch layer must be exactly the fused op (the
    engines' single-device fast path)."""
    rng = np.random.default_rng(0)
    K, N, gs = 128, 64, 32
    W = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32)) * 0.1
    q, s, z, _ = qz.rtn_quantize(W, 4, gs)
    zr = jnp.zeros((8,), jnp.int32)
    qt = qformat.make_quantized(q, s, z, 4, gs, W.shape, zr, zr,
                                jnp.zeros((8,), jnp.bfloat16),
                                dtype="float32")
    x = jnp.asarray(rng.normal(size=(4, K)).astype(np.float32))
    for kind in ("col", "row"):
        got = quantized_linear(x, qt, kind=kind)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(dq_ops.dequant_matmul(x, qt)))


def test_model_layers_route_quantized_kernels_through_qserve(monkeypatch):
    """models/layers.py must dispatch QuantizedTensor kernels to the qserve
    layer (the serve hot path), not dequantize a full fp weight."""
    import repro.serving.qserve.linear as ql
    calls = []
    orig = ql.quantized_linear
    monkeypatch.setattr(ql, "quantized_linear",
                        lambda *a, **k: calls.append(k) or orig(*a, **k))
    m = build_model(CFG)
    params = m.init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    cache = m.init_cache(2, 16, dtype=jnp.float32)
    m.decode_step(qp, jnp.ones((2, 1), jnp.int32), cache, jnp.asarray(0))
    assert calls, "decode never hit the qserve dispatch layer"
    assert any(k.get("kind") == "row" for k in calls)   # wo hinted row


# ------------------------------------------------------------ int8 KV
def test_kv_quant_roundtrip_bound():
    x = jax.random.normal(KEY, (5, 7, 16)) * 3.0
    q, s = kvquant.quantize_kv(x)
    assert q.dtype == jnp.int8 and s.dtype == kvquant.SCALE_DTYPE
    back = kvquant.dequantize_kv(q, s)
    # half-step of the per-vector grid plus bf16 scale rounding (~0.4%)
    bound = np.asarray(s.astype(jnp.float32))[..., None] * 0.5 \
        + np.abs(np.asarray(x)) * 5e-3 + 1e-6
    assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound).all()


def _teacher_forced_logits(m, params, toks, kv_bits, cap=32, bs=8):
    B, S = toks.shape
    cache = m.init_cache(B, cap, dtype=jnp.float32, paged=True,
                         block_size=bs, num_blocks=B * (cap // bs) + 1,
                         kv_bits=kv_bits)
    bt = np.arange(1, 1 + B * (cap // bs), dtype=np.int32)
    cache["kv"] = cache["kv"]._replace(
        block_tables=jnp.asarray(bt.reshape(B, cap // bs)))
    step = jax.jit(m.decode_step)
    lgs = []
    for t in range(S):
        lg, cache = step(params, toks[:, t:t + 1], cache, jnp.asarray(t))
        lgs.append(lg)
    return jnp.concatenate(lgs, axis=1)


def test_int8_paged_kv_logit_tolerance():
    """The int8 pool's serving contract: teacher-forced logits stay within
    INT8_KV_LOGIT_TOL max-abs of the fp paged pool, and greedy decisions
    are unchanged on the toy config."""
    m = build_model(CFG)
    params = m.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, CFG.vocab)
    fp = _teacher_forced_logits(m, params, toks, 16)
    i8 = _teacher_forced_logits(m, params, toks, 8)
    diff = float(jnp.abs(fp - i8).max())
    assert diff < INT8_KV_LOGIT_TOL, diff
    assert (jnp.argmax(fp, -1) == jnp.argmax(i8, -1)).all()


def test_int8_paged_engine_runs_and_halves_kv_bytes():
    import importlib.util, os
    spec = importlib.util.spec_from_file_location(
        "bench_serving", os.path.join(os.path.dirname(__file__), "..",
                                      "benchmarks", "bench_serving.py"))
    bench = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench)
    m = build_model(CFG)
    params = m.init(KEY)

    def run(kv_bits):
        eng = PagedEngine(CFG, params, max_batch=2, capacity=48,
                          block_size=8, kv_bits=kv_bits)
        rs = [eng.submit(np.arange(1, 10), max_tokens=4),
              eng.submit(np.arange(2, 14), max_tokens=3)]
        eng.run()
        assert all(r.done for r in rs)
        _, paged_bytes = bench.kv_bytes_split(eng)
        return paged_bytes, rs

    fp_bytes, fp_rs = run(16)
    i8_bytes, i8_rs = run(8)
    # >= 40% below the fp16-equivalent paged baseline (fp pool is f32)
    assert i8_bytes <= 0.6 * (fp_bytes / 2.0), (i8_bytes, fp_bytes)
    # toy-scale greedy outputs are unchanged (documented tolerance allows
    # drift at depth; here the margin is large)
    for a, b in zip(fp_rs, i8_rs):
        assert a.out == b.out, (a.out, b.out)


# --------------------------------------- rtn-w4 engine identity, 4 families
@pytest.mark.parametrize("arch", ["gemma3-27b", "zamba2-7b", "rwkv6-3b"])
def test_rtn_w4_paged_matches_static_greedy_families(arch):
    """Greedy serving of an RTN-w4 checkpoint through the paged engine must
    be bit-identical to the static-cohort baseline for grouped-local /
    hybrid / ssm (the uniform dense family runs in the toy test below)."""
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    prompts = [np.arange(1, 9), np.arange(3, 12), np.arange(2, 7)]
    budgets = [4, 3, 4]

    def submit(eng):
        return [eng.submit(p, max_tokens=b)
                for p, b in zip(prompts, budgets)]

    es = StaticEngine(cfg, qp, max_batch=2, capacity=48)
    ep = PagedEngine(cfg, qp, max_batch=2, capacity=48, block_size=8)
    rs, rp = submit(es), submit(ep)
    es.run()
    ep.run()
    for a, b in zip(rs, rp):
        assert a.done and b.done
        assert a.out == b.out, (arch, a.rid, a.out, b.out)


def test_rtn_w4_paged_matches_static_greedy_uniform():
    m = build_model(CFG)
    params = m.init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    prompts = [np.arange(1, 9), np.arange(3, 15), np.arange(2, 7),
               np.arange(4, 12)]
    budgets = [5, 3, 6, 4]

    def submit(eng):
        return [eng.submit(p, max_tokens=b)
                for p, b in zip(prompts, budgets)]

    es = StaticEngine(CFG, qp, max_batch=2, capacity=48)
    ep = PagedEngine(CFG, qp, max_batch=2, capacity=48, block_size=8)
    rs, rp = submit(es), submit(ep)
    es.run()
    ep.run()
    for a, b in zip(rs, rp):
        assert a.out == b.out, (a.rid, a.out, b.out)


# ------------------------------------------------------ packed accounting
def test_packed_plane_report_replicated_vs_sharded():
    from repro.dist.sharding import make_plan
    from repro.serving.qserve.report import abstract_tp_mesh, \
        packed_plane_bytes
    m = build_model(CFG)
    params = m.init(KEY)
    qp, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=16))
    plain = packed_plane_bytes(qp)
    assert plain["ratio"] == 1.0 and plain["total"] > 0
    plan = make_plan(CFG, abstract_tp_mesh(4))
    rep = packed_plane_bytes(qp, plan.param_shardings(qp))
    assert rep["total"] == plain["total"]
    # every toy kernel dim divides 4 -> fully sharded planes
    assert rep["per_device"] * 4 == rep["total"], rep
