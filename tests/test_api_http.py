"""HTTP serving front end, end-to-end over a real socket on the toy arch.

Pins the tentpole contracts of the API layer:
  * streamed SSE tokens are bit-identical to an in-process ``PagedEngine``
    greedy run for the same params/prompt on every request path — plain,
    self-speculative decode, chunked prefill;
  * concurrent mixed-SLO clients all complete;
  * a client disconnect mid-stream retires the slot and returns the
    request's blocks to the pool;
  * ``/metrics`` parses as Prometheus 0.0.4 text and the ``engine_*``
    families agree with the request counts;
  * malformed bodies and over-length prompts get a 4xx and the driver
    thread keeps serving.
"""
import http.client
import json
import re
import socket
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.launch import client as cl
from repro.models import build_model
from repro.serving.api import ApiServer, EngineBridge
from repro.serving.engine import PagedEngine

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                  d_ff=64, mlp="swiglu", norm="rmsnorm", pos="rope")

PROMPT = [3, 5, 7, 11, 13, 17, 19, 23]
LONG_PROMPT = [(5 * i + 1) % CFG.vocab for i in range(40)]


@pytest.fixture(scope="module")
def params():
    return build_model(CFG).init(jax.random.PRNGKey(0))


def _engine(params, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("capacity", 64)
    kw.setdefault("block_size", 8)
    return PagedEngine(CFG, params, **kw)


@pytest.fixture()
def serve(params):
    """Factory fixture: start a server over a fresh engine, tear down."""
    started = []

    def start(**engine_kw):
        eng = _engine(params, **engine_kw)
        bridge = EngineBridge(eng, idle_wait=0.01).start()
        server = ApiServer(bridge, model_info={"arch": CFG.name,
                                               "vocab": CFG.vocab})
        port = server.start()
        started.append((server, bridge))
        return port, eng

    yield start
    for server, bridge in started:
        server.stop()
        bridge.stop()


def _greedy_ref(params, prompt, max_tokens, **kw):
    eng = _engine(params, **kw)
    r = eng.submit(np.asarray(prompt), max_tokens=max_tokens)
    eng.run()
    return r.out


def _drain(eng, bridge, timeout=30.0):
    """Wait until the engine is fully idle (all slots retired)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        with bridge.lock:
            idle = not eng.queue and all(s is None for s in eng._slots) \
                and not eng._prefilling()
        if idle:
            return
        time.sleep(0.01)
    raise TimeoutError("engine did not drain")


# -------------------------------------------------------------- bit-identity
@pytest.mark.parametrize("path", ["plain", "spec", "chunked"])
def test_stream_bit_identical_to_inprocess(serve, params, path):
    engine_kw = {}
    prompt = PROMPT
    if path == "spec":
        # self-speculative with the target as its own draft: acceptance is
        # total but the code path (draft + scanned verify) is exercised
        engine_kw = {"draft": params, "spec_k": 3}
    elif path == "chunked":
        engine_kw = {"prefill_chunk": 16}
        prompt = LONG_PROMPT
    ref = _greedy_ref(params, prompt, 10, **engine_kw)
    port, _ = serve(**engine_kw)
    got = [t for t, _ in cl.complete(port, prompt, max_tokens=10)
           if t is not None]
    assert got == ref


def test_nonstream_matches_stream(serve, params):
    ref = _greedy_ref(params, PROMPT, 10)
    port, _ = serve()
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
    conn.request("POST", "/v1/completions", body=json.dumps(
        {"prompt": PROMPT, "max_tokens": 10, "stream": False}))
    resp = conn.getresponse()
    body = json.loads(resp.read())
    conn.close()
    assert resp.status == 200
    assert body["choices"][0]["token_ids"] == ref
    assert body["choices"][0]["finish_reason"] == "length"
    assert body["usage"]["completion_tokens"] == 10


def test_seeded_sampling_reproducible(serve):
    port, _ = serve()

    def run():
        return [t for t, _ in cl.complete(
            port, PROMPT, max_tokens=12, temperature=0.8, seed=123)
            if t is not None]

    a, b = run(), run()
    assert len(a) == 12 and a == b


# -------------------------------------------------------------- concurrency
def test_concurrent_mixed_slo_clients_complete(serve):
    port, eng = serve(max_batch=2)       # more clients than slots
    n = 6
    outs = [None] * n
    errs = []

    def one(i):
        try:
            slo = "interactive" if i % 2 == 0 else "batch"
            prompt = [(i + 2 + j) % CFG.vocab for j in range(6 + i)]
            outs[i] = [t for t, _ in cl.complete(
                port, prompt, max_tokens=5 + i % 3, slo=slo)
                if t is not None]
        except Exception as e:           # surface in the main thread
            errs.append((i, repr(e)))

    threads = [threading.Thread(target=one, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errs, errs
    for i, out in enumerate(outs):
        assert out is not None and len(out) == 5 + i % 3, (i, out)


# -------------------------------------------------------------- disconnect
def test_disconnect_mid_stream_frees_blocks(serve, params):
    # baseline occupancy 0 (no prefix cache); big capacity = long runway,
    # so the hang-up lands mid-generation, not after a natural finish
    port, eng = serve(share_prefixes=False, capacity=512)
    bridge = eng.on_token.__self__
    body = json.dumps({"prompt": PROMPT, "max_tokens": 4096}).encode()
    s = socket.create_connection(("127.0.0.1", port), timeout=30)
    s.sendall(b"POST /v1/completions HTTP/1.1\r\n"
              b"Host: x\r\nContent-Length: %d\r\n\r\n%s"
              % (len(body), body))
    # wait for the stream to actually start (first token on the wire)
    buf = b""
    while b"token_id" not in buf:
        chunk = s.recv(4096)
        assert chunk, f"stream closed early: {buf!r}"
        buf += chunk
    assert eng.alloc.blocks_in_use > 0
    s.close()                                 # hang up mid-generation
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        with bridge.lock:
            if eng.alloc.blocks_in_use == 0 and \
                    all(x is None for x in eng._slots):
                break
        time.sleep(0.02)
    else:
        raise AssertionError(
            f"{eng.alloc.blocks_in_use} blocks still live after disconnect")
    # the cancelled request is accounted a finished lifecycle
    with bridge.lock:
        assert any(r.cancelled for r in eng.finished.values())
    # and the driver still serves
    got = [t for t, _ in cl.complete(port, PROMPT, max_tokens=4)
           if t is not None]
    assert len(got) == 4


# ----------------------------------------------------------------- metrics
def _parse_prom(text):
    """Strict-enough Prometheus 0.0.4 parser: every non-comment line is
    ``name{labels} value``; HELP/TYPE precede their family."""
    samples = {}
    typed = set()
    for ln in text.splitlines():
        if not ln.strip():
            continue
        if ln.startswith("#"):
            m = re.match(r"^# (HELP|TYPE) ([a-zA-Z_:][a-zA-Z0-9_:]*) ", ln)
            assert m, f"bad comment line: {ln!r}"
            if m.group(1) == "TYPE":
                typed.add(m.group(2))
            continue
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
                     r"(\{[^{}]*\})? (-?[0-9.eE+]+|NaN)$", ln)
        assert m, f"unparsable sample line: {ln!r}"
        name = m.group(1)
        base = name[:-len("_bucket")] if name.endswith("_bucket") else name
        for suf in ("_sum", "_count"):
            if base.endswith(suf):
                base = base[:-len(suf)]
        assert base in typed or name in typed, f"untyped family: {name}"
        samples[(name, m.group(2) or "")] = float(m.group(3))
    return samples


def test_metrics_scrape_agrees_with_requests(serve):
    port, eng = serve()
    n = 3
    for i in range(n):
        toks = [t for t, _ in cl.complete(
            port, [(j + i) % CFG.vocab for j in range(6)], max_tokens=4)
            if t is not None]
        assert len(toks) == 4
    samples = _parse_prom(cl.scrape(port))
    fam = {k: v for k, v in samples.items()
           if k[0] == "engine_requests_finished_total"}
    assert sum(fam.values()) == n
    sub = {k: v for k, v in samples.items()
           if k[0] == "engine_requests_submitted_total"}
    assert sum(sub.values()) == n
    assert any(k[0].startswith("engine_") for k in samples)


def test_healthz_and_models(serve):
    port, _ = serve()
    h = cl.wait_ready(port)
    assert h["status"] == "ok" and h["capacity"] == 64
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=10)
    conn.request("GET", "/v1/models")
    resp = conn.getresponse()
    models = json.loads(resp.read())
    conn.close()
    assert resp.status == 200
    info = models["data"][0]
    assert info["arch"] == CFG.name and info["vocab"] == CFG.vocab


# -------------------------------------------------------------- bad inputs
def _post(port, body: bytes, path="/v1/completions"):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    conn.request("POST", path, body=body)
    resp = conn.getresponse()
    out = (resp.status, json.loads(resp.read()))
    conn.close()
    return out


def test_bad_requests_get_4xx_and_driver_survives(serve):
    port, eng = serve()
    cases = [
        (b"{oops", 400),                                  # not JSON
        (b"[1, 2]", 400),                                 # not an object
        (b"{}", 400),                                     # no prompt
        (json.dumps({"prompt": []}).encode(), 400),
        (json.dumps({"prompt": "hi"}).encode(), 400),     # no tokenizer
        (json.dumps({"prompt": [1, "x"]}).encode(), 400),
        (json.dumps({"prompt": [1, CFG.vocab]}).encode(), 400),
        (json.dumps({"prompt": [-1]}).encode(), 400),
        (json.dumps({"prompt": list(range(2)) * 40}).encode(), 400),
        (json.dumps({"prompt": [1], "max_tokens": 0}).encode(), 400),
        (json.dumps({"prompt": [1], "max_tokens": True}).encode(), 400),
        (json.dumps({"prompt": [1], "slo": "gold"}).encode(), 400),
        (json.dumps({"prompt": [1], "temperature": -1}).encode(), 400),
        (json.dumps({"prompt": [1], "seed": -5}).encode(), 400),
        (json.dumps({"prompt": [1], "stream": "yes"}).encode(), 400),
        (b"x" * (2 << 20), 413),                          # oversize body
    ]
    for body, want in cases:
        status, payload = _post(port, body)
        assert status == want, (body[:40], status, payload)
        assert "error" in payload
    status, _ = _post(port, b"{}", path="/nope")
    assert status == 404
    # GET on the completion route
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=10)
    conn.request("GET", "/v1/completions")
    assert conn.getresponse().status == 405
    conn.close()
    # after all that abuse the driver thread still serves correctly
    got = [t for t, _ in cl.complete(port, PROMPT, max_tokens=3)
           if t is not None]
    assert len(got) == 3
    h = cl.wait_ready(port)
    assert h["status"] == "ok" and h["queue_depth"] == 0
