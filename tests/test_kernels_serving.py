"""Kernel-vs-ref parity for the Pallas serving kernels (ISSUE 7).

Runs the TPU kernels in interpret mode on CPU against the pure-jnp oracles
and the XLA fallback lowerings.  Attention geometries (GQA ratio, head dim,
sliding window) are drawn from four assigned model families' smoke configs;
the MoE contraction sweeps bit-widths and family (d_model, d_ff) shapes.
Also covered: int8-KV decode tolerance vs the fp pool, w2 residual-carrier
bit-identity through ``dequant_matmul``, and the bounded-table contract
(narrowed live-width tables are output-identical to full-width ones).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import QuantConfig
from repro.core import qformat
from repro.kernels.dequant_matmul import ops as dq_ops
from repro.kernels.moe_dequant import ops as moe_ops
from repro.kernels.moe_dequant.ref import moe_dequant_matmul_ref
from repro.kernels.paged_attn import ops as pa_ops
from repro.kernels.paged_attn import ref as pa_ref
from repro.serving.qserve import kvquant as KQ

ARCHS = ["qwen2-1.5b", "gemma3-27b", "granite-moe-1b-a400m", "grok-1-314b"]
BS, MB = 8, 6        # block size, table width


def _geom(arch):
    cfg = get_smoke(arch)
    return cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, \
        cfg.local_window


def _paged_setup(arch, seed=0, dtype=jnp.float32, deepest=BS * MB - 1):
    """Pools + tables with per-row depths (and unmapped tail holes)."""
    H, KV, Dh, win = _geom(arch)
    rng = np.random.default_rng(seed)
    B = 3
    pos = np.array([5, deepest, 2 * BS + 3], np.int32)
    nb = 1 + B * MB
    kp = jnp.asarray(rng.normal(size=(nb, BS, KV, Dh)), dtype)
    vp = jnp.asarray(rng.normal(size=(nb, BS, KV, Dh)), dtype)
    tbl = np.full((B, MB), -1, np.int32)
    nxt = 1
    for b in range(B):
        for j in range(pos[b] // BS + 1):
            tbl[b, j] = nxt
            nxt += 1
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), dtype)
    return q, kp, vp, jnp.asarray(tbl), jnp.asarray(pos), win


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_attn_kernel_matches_ref(arch):
    """Interpret-mode kernel partials == two-pass oracle == XLA fallback,
    at the family's GQA/head geometry (and sliding window where set)."""
    q, kp, vp, bt, pos, win = _paged_setup(arch)
    for window in {0, win}:
        o_r, m_r, l_r = pa_ref.paged_decode_ref(q, kp, vp, bt, pos,
                                                window=window)
        o_k, m_k, l_k = pa_ops.paged_decode_partial(
            q, kp, vp, bt, pos, window=window,
            force_kernel=True, interpret=True)
        np.testing.assert_allclose(o_k, o_r, atol=1e-4)
        np.testing.assert_allclose(m_k, m_r, atol=1e-5)
        np.testing.assert_allclose(l_k, l_r, atol=1e-4)
        y_k = pa_ops.paged_decode(q, kp, vp, bt, pos, window=window,
                                  force_kernel=True, interpret=True)
        y_f = pa_ops.paged_decode(q, kp, vp, bt, pos, window=window)
        np.testing.assert_allclose(np.asarray(y_k, np.float32),
                                   np.asarray(y_f, np.float32), atol=1e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_attn_int8_kv(arch):
    """Fused int8 dequant in the score loop: kernel matches the fallback
    (which dequantizes the gathered view) tightly, and both stay within
    the documented int8 tolerance of the fp pool."""
    q, kp, vp, bt, pos, _ = _paged_setup(arch, seed=1)
    kq, ks = KQ.quantize_kv(kp)
    vq, vs = KQ.quantize_kv(vp)
    y_k = pa_ops.paged_decode(q, kq, vq, bt, pos, k_scale=ks, v_scale=vs,
                              force_kernel=True, interpret=True)
    y_f = pa_ops.paged_decode(q, kq, vq, bt, pos, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_f, np.float32), atol=1e-5)
    y_fp = pa_ops.paged_decode(q, kp, vp, bt, pos)
    err = np.abs(np.asarray(y_k, np.float32) - np.asarray(y_fp, np.float32))
    assert err.max() <= 0.05 * np.abs(np.asarray(y_fp)).max(), err.max()


def test_paged_attn_bounded_tables():
    """Slicing the table to the live width (the engine's bounded gather)
    is value-preserving: unmapped tail slots carry exactly zero softmax
    weight, so dropping them only shortens the contraction axis — outputs
    agree to reduction-order (ulp) level and greedy decode is unchanged
    (the engine bit-identity tests cover the token-level contract)."""
    q, kp, vp, bt, pos, _ = _paged_setup(ARCHS[0], seed=2,
                                         deepest=3 * BS + 1)
    live = int(np.asarray(pos).max()) // BS + 1
    assert live < bt.shape[1]                       # tail actually dropped
    y_full = pa_ops.paged_decode(q, kp, vp, bt, pos)
    y_live = pa_ops.paged_decode(q, kp, vp, bt[:, :live], pos)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_live),
                               atol=1e-6)
    o_k = pa_ops.paged_decode(q, kp, vp, bt[:, :live], pos,
                              force_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(y_full),
                               atol=1e-5)


def _stacked_qt(E, K, N, bits, gs, seed=0):
    from repro.serving.quantized import _quantize_leaf
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(E, K, N)).astype(np.float32))
    return _quantize_leaf(W, QuantConfig(wbits=bits, group_size=gs,
                                         method="rtn"))


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_moe_dequant_kernel_matches_ref(bits):
    """Interpret-mode fused kernel == per-expert scan fallback == dense
    reconstruction oracle, across bit-widths (3-bit = two planes)."""
    E, T, K, N, gs = 4, 8, 64, 48, 16
    qt = _stacked_qt(E, K, N, bits, gs, seed=bits)
    xe = jnp.asarray(np.random.default_rng(9).normal(size=(E, T, K)),
                     jnp.bfloat16)
    y_k = moe_ops.moe_dequant_matmul(xe, qt, force_kernel=True,
                                     interpret=True)
    y_s = moe_ops.moe_dequant_matmul(xe, qt)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_s, np.float32), atol=1e-2)
    y_r = moe_dequant_matmul_ref(xe, qt)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), atol=1e-1)


@pytest.mark.parametrize("arch", ARCHS)
def test_moe_dequant_family_geometries(arch):
    """Kernel-vs-scan parity at each family's smoke (d_model, d_ff) shape
    (the expert contraction is family-agnostic; shapes are not)."""
    cfg = get_smoke(arch)
    K = cfg.d_model
    N = cfg.moe.d_ff if cfg.moe is not None else cfg.d_ff
    gs = 16
    if K % gs or N % 8:
        pytest.skip(f"unaligned smoke geometry {K}x{N}")
    qt = _stacked_qt(4, K, N, 4, gs, seed=5)
    xe = jnp.asarray(np.random.default_rng(6).normal(size=(4, 8, K)),
                     jnp.bfloat16)
    y_k = moe_ops.moe_dequant_matmul(xe, qt, force_kernel=True,
                                     interpret=True)
    y_s = moe_ops.moe_dequant_matmul(xe, qt)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_s, np.float32), atol=1e-2)


def test_resid_carrier_kernel_bit_identity():
    """BiLLM w2 residual-carrier planes through the fused kernel must be
    bit-identical to the blockwise fallback: same unpack, same residual
    add, same dot (single K/N block at this geometry)."""
    rng = np.random.default_rng(11)
    w_hat = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))
    qt = qformat.make_residual_carrier(w_hat, group_size=16)
    assert qt.resid_planes is not None
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.bfloat16)
    y_k = dq_ops.dequant_matmul(x, qt, force_kernel=True, interpret=True)
    y_f = dq_ops.dequant_matmul(x, qt)
    np.testing.assert_array_equal(np.asarray(y_k, np.float32),
                                  np.asarray(y_f, np.float32))
