"""Distribution-layer tests run in subprocesses with 8 virtual devices
(XLA_FLAGS must precede jax import, hence the isolation)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig, TrainConfig
        from repro.dist.steps import build_train_step
        from repro.dist.sharding import make_plan
        from repro.models import build_model
        from repro.train import optimizer as opt
        from repro import utils

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke("qwen2-1.5b")
        shape = ShapeConfig("t", 32, 8, "train")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)}

        # single-device reference FIRST (the step donates its args, and
        # device_put may alias buffers whose sharding already matches)
        sched = opt.warmup_cosine(TrainConfig().lr, TrainConfig().warmup,
                                  TrainConfig().steps)
        pb = utils.cast_tree(params, jnp.bfloat16)
        loss_ref = float(m.loss(pb, batch))

        with jax.set_mesh(mesh):
            plan = make_plan(cfg, mesh)
            step, _, _ = build_train_step(cfg, shape, plan)
            o = opt.adamw_init(params)
            o = opt.AdamState(o.step, utils.cast_tree(o.m, jnp.bfloat16),
                              utils.cast_tree(o.v, jnp.bfloat16))
            # lay out args per the plan (committed arrays must match jit
            # in_shardings)
            ps = plan.param_shardings(params)
            params_s = jax.device_put(params, ps)
            from jax.sharding import NamedSharding, PartitionSpec as P
            o_s = opt.AdamState(
                jax.device_put(o.step, NamedSharding(mesh, P())),
                jax.device_put(o.m, ps), jax.device_put(o.v, ps))
            b_s = jax.device_put(batch, plan.batch_spec(batch, 8))
            p2, o2, loss_sharded = step(params_s, o_s, b_s)

        d = abs(float(loss_sharded) - loss_ref)
        print("LOSSDIFF", d)
        assert d < 5e-2, (float(loss_sharded), loss_ref)
    """)
    assert "LOSSDIFF" in out


def test_flash_decode_matches_dense():
    out = run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.dist import ctx as dctx
        from repro.dist.sharding import make_plan
        from repro.models import build_model

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke("nemotron-4-340b")   # kv=2 < 4 -> flash mode
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab)

        # dense single-device reference
        cache = m.init_cache(4, 24, dtype=jnp.float32)
        _, cache, _ = m.prefill(params, {"tokens": tok[:, :16]}, cache)
        ref, _ = m.decode_step(params, tok[:, 16:17], cache, jnp.asarray(16))

        with jax.set_mesh(mesh):
            plan = make_plan(cfg, mesh)
            shape = dataclasses.replace(
                __import__("repro.configs.base", fromlist=["x"]).ShapeConfig(
                    "d", 24, 4, "decode"))
            c = plan.ctx(shape)
            assert c.attn_decode_mode == "flash", c
            cache2 = m.init_cache(4, 24, dtype=jnp.float32)
            with dctx.use(dataclasses.replace(c, attn_decode_mode="dense")):
                _, cache2, _ = jax.jit(m.prefill)(params,
                                                  {"tokens": tok[:, :16]},
                                                  cache2)
            with dctx.use(c):
                got, _ = jax.jit(m.decode_step)(params, tok[:, 16:17],
                                                cache2, jnp.asarray(16))
        err = float(jnp.abs(got - ref).max())
        print("FLASHDIFF", err)
        assert err < 1e-3, err
    """)
    assert "FLASHDIFF" in out


def test_flash_decode_vector_clock_matches_dense():
    """Per-row (B,) cache clocks through the KV-length-sharded flash decode
    path must match the dense per-row reference (TP continuous serving)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import ctx as dctx
        from repro.dist.ctx import DistCtx
        from repro.models import attention as A

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, cap, KV, H, Dh = 4, 16, 2, 4, 8
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(k1, (B, 1, H, Dh))
        kn = jax.random.normal(k2, (B, 1, KV, Dh))
        vn = jax.random.normal(k3, (B, 1, KV, Dh))
        cache = A.init_cache(B, cap, KV, Dh, dtype=jnp.float32)
        kall = jax.random.normal(k4, (B, 6, KV, Dh))
        cache = A.cache_prefill(cache, kall, kall)
        pos = jnp.asarray([6, 3, 5, 2])          # per-row clocks

        c2 = A.cache_write(cache, kn, vn, pos)
        ref = A.decode_attention(q, c2, pos)

        ctx = DistCtx(mesh=mesh, dp=("data",), tp="model", batch_spec=None,
                      attn_decode_mode="flash")
        with jax.set_mesh(mesh):
            with dctx.use(ctx):
                got, got_cache = jax.jit(
                    lambda *a: A.serve_attention_write(*a))(
                    q, kn, vn, cache, pos)
        err = float(jnp.abs(got - ref).max())
        for a, b in zip(got_cache, c2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("FLASHVEC", err)
        assert err < 1e-5, err
    """)
    assert "FLASHVEC" in out


def test_paged_flash_decode_matches_unsharded():
    """Block-parallel flash decoding over a tp-sharded paged pool must
    match the unsharded paged reference, given stripe-invariant tables
    (logical block lb backed by pool partition lb // (max_blocks/T))."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import ctx as dctx
        from repro.dist.ctx import DistCtx
        from repro.models import attention as A

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, KV, H, Dh, bs, mb, T = 4, 2, 4, 8, 4, 8, 4
        nb = 32                               # 8 blocks per stripe
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(k1, (B, 1, H, Dh))
        kn = jax.random.normal(k2, (B, 1, KV, Dh))
        vn = jax.random.normal(k3, (B, 1, KV, Dh))
        pos = jnp.asarray([9, 3, 6, 0])       # rows at mixed clocks
        # stripe-invariant tables: lb -> partition lb // (mb/T); local
        # block 0 of each partition reserved as scratch
        bt = np.full((B, mb), -1, np.int32)
        nxt = {t: 1 for t in range(T)}
        for b in range(B):
            for lb in range(int(pos[b]) // bs + 1):
                t = lb // (mb // T)
                bt[b, lb] = t * (nb // T) + nxt[t]; nxt[t] += 1
        cache = A.init_paged_cache(B, nb, bs, mb, KV, Dh,
                                   dtype=jnp.float32)
        kall = jax.random.normal(k4, (B, mb * bs, KV, Dh))
        cache = A.PagedKVCache(
            cache.k, cache.v, jnp.asarray(bt))
        cache = A.cache_prefill(cache, kall, kall)   # mapped blocks filled

        ref_cache = A.cache_write(cache, kn, vn, pos)
        ref = A.decode_attention(q, ref_cache, pos)

        ctx = DistCtx(mesh=mesh, dp=("data",), tp="model", batch_spec=None,
                      attn_decode_mode="flash")
        with jax.set_mesh(mesh):
            with dctx.use(ctx):
                got, got_cache = jax.jit(
                    lambda *a: A.serve_attention_write(*a))(
                    q, kn, vn, cache, pos)
        err = float(jnp.abs(got - ref).max())
        # the pools must agree everywhere except the per-shard scratch
        # blocks (ids t * nb/T), which absorb non-owner writes
        scratch = [t * (nb // T) for t in range(T)]
        live = np.setdiff1d(np.arange(nb), scratch)
        for a, b in ((got_cache.k, ref_cache.k), (got_cache.v, ref_cache.v)):
            np.testing.assert_array_equal(np.asarray(a)[live],
                                          np.asarray(b)[live])
        print("PAGEDFLASH", err)
        assert err < 1e-5, err
    """)
    assert "PAGEDFLASH" in out


def test_quantized_linear_tp_matches_unsharded():
    """The qserve dispatch layer's col/row shard_maps over tp-sharded
    packed planes must match the whole-tensor fused op."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import qformat
        from repro.core import quantizers as qz
        from repro.dist import ctx as dctx
        from repro.dist.ctx import DistCtx
        from repro.kernels.dequant_matmul import ops as dq_ops
        from repro.serving.qserve.linear import quantized_linear

        rng = np.random.default_rng(0)
        K, N, gs = 128, 64, 16
        W = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32)) * 0.1
        q, s, z, _ = qz.rtn_quantize(W, 3, gs)     # 3-bit: two planes
        zr = jnp.zeros((8,), jnp.int32)
        qt = qformat.make_quantized(q, s, z, 3, gs, W.shape, zr, zr,
                                    jnp.zeros((8,), jnp.bfloat16),
                                    dtype="float32")
        x = jnp.asarray(rng.normal(size=(4, K)).astype(np.float32))
        ref = dq_ops.dequant_matmul(x, qt)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = DistCtx(mesh=mesh, dp=("data",), tp="model", batch_spec=None)
        with jax.set_mesh(mesh):
            with dctx.use(ctx):
                col = jax.jit(
                    lambda xx: quantized_linear(xx, qt, kind="col"))(x)
                row = jax.jit(
                    lambda xx: quantized_linear(xx, qt, kind="row"))(x)
        ec = float(jnp.abs(col - ref).max())
        er = float(jnp.abs(row - ref).max())
        print("QLINTP", ec, er)
        assert ec < 1e-5 and er < 1e-5, (ec, er)
    """)
    assert "QLINTP" in out


def test_quantized_paged_decode_cells_lower_with_sharded_planes():
    """The full qserve decode cell: packed params + int8 paged pool lower
    and compile under tp in both decode modes, with the QuantizedTensor
    planes actually sharded (per-device packed bytes ~ total/tp — the
    dryrun assertion, here on a virtual mesh)."""
    out = run_with_devices("""
        import jax
        from repro.configs import get_smoke
        from repro.configs.base import QuantConfig, ShapeConfig
        from repro.dist.sharding import make_plan
        from repro.dist.steps import build_step
        from repro.serving.quantized import abstract_quantized_params
        from repro.serving.qserve.report import PACKED_SHARD_SLACK, \\
            packed_plane_bytes

        qcfg = QuantConfig(wbits=4, group_size=16)
        shape = ShapeConfig("d", 256, 8, "decode")
        cells = [("gemma3-27b", (2, 2)),      # kv=2, tp=2 -> dense mode
                 ("qwen2-1.5b", (2, 4))]      # kv=2, tp=4 -> flash mode
        for arch, dims in cells:
            mesh = jax.make_mesh(dims, ("data", "model"))
            cfg = get_smoke(arch)
            qsds = abstract_quantized_params(cfg, qcfg)
            plan = make_plan(cfg, mesh)
            rep = packed_plane_bytes(qsds, plan.param_shardings(qsds))
            assert rep["ratio"] <= PACKED_SHARD_SLACK / plan.tp_size, rep
            with jax.set_mesh(mesh):
                jitted, args, ctx = build_step(
                    cfg, shape, mesh, quantized_params_sds=qsds,
                    paged=True, kv_bits=8)
                jitted.lower(*args).compile()
            print("QCELL", arch, ctx.attn_decode_mode,
                  round(rep["ratio"], 3))
    """)
    assert out.count("QCELL") == 2


def test_paged_flash_int8_matches_unsharded():
    """Block-parallel flash decoding over a tp-sharded *int8* paged pool
    (codes + scale planes striped together) must match the unsharded int8
    paged reference."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import ctx as dctx
        from repro.dist.ctx import DistCtx
        from repro.models import attention as A

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, KV, H, Dh, bs, mb, T = 4, 2, 4, 8, 4, 8, 4
        nb = 32
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(k1, (B, 1, H, Dh))
        kn = jax.random.normal(k2, (B, 1, KV, Dh))
        vn = jax.random.normal(k3, (B, 1, KV, Dh))
        pos = jnp.asarray([9, 3, 6, 0])
        bt = np.full((B, mb), -1, np.int32)
        nxt = {t: 1 for t in range(T)}
        for b in range(B):
            for lb in range(int(pos[b]) // bs + 1):
                t = lb // (mb // T)
                bt[b, lb] = t * (nb // T) + nxt[t]; nxt[t] += 1
        cache = A.init_paged_cache(B, nb, bs, mb, KV, Dh, kv_bits=8)
        cache = cache._replace(block_tables=jnp.asarray(bt))
        kall = jax.random.normal(k4, (B, mb * bs, KV, Dh))
        cache = A.cache_prefill(cache, kall, kall)

        ref_cache = A.cache_write(cache, kn, vn, pos)
        ref = A.decode_attention(q, ref_cache, pos)

        ctx = DistCtx(mesh=mesh, dp=("data",), tp="model", batch_spec=None,
                      attn_decode_mode="flash")
        with jax.set_mesh(mesh):
            with dctx.use(ctx):
                got, got_cache = jax.jit(
                    lambda *a: A.serve_attention_write(*a))(
                    q, kn, vn, cache, pos)
        err = float(jnp.abs(got - ref).max())
        scratch = [t * (nb // T) for t in range(T)]
        live = np.setdiff1d(np.arange(nb), scratch)
        for a, b in ((got_cache.k, ref_cache.k), (got_cache.v, ref_cache.v),
                     (got_cache.k_scale, ref_cache.k_scale),
                     (got_cache.v_scale, ref_cache.v_scale)):
            np.testing.assert_array_equal(np.asarray(a)[live],
                                          np.asarray(b)[live])
        print("PAGEDFLASHQ", err)
        assert err < 1e-5, err
    """)
    assert "PAGEDFLASHQ" in out


def test_paged_decode_cells_lower_and_compile():
    """build_step(paged=True) decode cells lower + compile under TP for
    both decode modes and a non-uniform family (the production 16x16 cell
    runs the same path via launch/dryrun.py --paged)."""
    out = run_with_devices("""
        import jax
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig
        from repro.dist.steps import build_step

        cells = [("qwen2-1.5b", (2, 4)),      # kv=2, tp=4 -> flash mode
                 ("gemma3-27b", (2, 2)),      # kv=2, tp=2 -> dense mode
                 ("zamba2-7b", (2, 2))]       # hybrid: paged kv + ssm state
        for arch, dims in cells:
            mesh = jax.make_mesh(dims, ("data", "model"))
            cfg = get_smoke(arch)
            shape = ShapeConfig("d", 256, 8, "decode")
            with jax.set_mesh(mesh):
                jitted, args, ctx = build_step(cfg, shape, mesh, paged=True)
                jitted.lower(*args).compile()
            print("PAGEDCELL", arch, ctx.attn_decode_mode)
    """)
    assert out.count("PAGEDCELL") == 3


def test_seq_shard_attention_matches_local():
    out = run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.dist import ctx as dctx
        from repro.dist.ctx import DistCtx
        from repro.models.attention import causal_attention, train_attention
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, S, H, KV, Dh = 4, 64, 6, 2, 16
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (B, S, H, Dh))
        k = jax.random.normal(k2, (B, S, KV, Dh))
        v = jax.random.normal(k3, (B, S, KV, Dh))
        ref = causal_attention(q, k, v)
        ctx = DistCtx(mesh=mesh, dp=("data",), tp="model", batch_spec=("data",),
                      attn_train_mode="seq_shard", attn_decode_mode="flash")
        with jax.set_mesh(mesh):
            with dctx.use(ctx):
                got = jax.jit(lambda *a: train_attention(*a))(q, k, v)
        err = float(jnp.abs(got - ref).max())
        print("SEQSHARD", err)
        assert err < 1e-4, err
    """)
    assert "SEQSHARD" in out


def test_elastic_restore_onto_different_mesh(tmp_path):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        ckpt.save(r"{tmp_path}", 1, tree)

        # restore onto a 4-way mesh (as if the job lost half its pods)
        mesh = jax.make_mesh((4,), ("data",))
        sh = {{"w": NamedSharding(mesh, P("data", None))}}
        got, _ = ckpt.restore(r"{tmp_path}", tree, shardings=sh)
        assert got["w"].sharding.spec == P("data", None)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
        print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out


def test_compressed_psum_matches_psum():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("d",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        def f(xs):
            return compressed_psum(xs, "d")

        with jax.set_mesh(mesh):
            got = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("d", None),
                                        out_specs=P("d", None)))(x)
        want = x.sum(0, keepdims=True).repeat(8, 0)
        rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
        print("PSUM", rel)
        assert rel < 0.02, rel
    """)
    assert "PSUM" in out
