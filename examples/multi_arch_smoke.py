"""Run a forward + train-step + decode for ALL 10 assigned architectures at
their reduced smoke shapes — the `--arch` surface in one sweep.

Run:  PYTHONPATH=src python examples/multi_arch_smoke.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402

from repro.configs import ASSIGNED_IDS, get_smoke   # noqa: E402
from repro.models import build_model                # noqa: E402

key = jax.random.PRNGKey(0)
for arch in ASSIGNED_IDS:
    cfg = get_smoke(arch)
    m = build_model(cfg)
    params = m.init(key)
    B, S = 2, 32
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.family == "vlm":
        F = cfg.n_frontend_tokens
        batch = {"tokens": tok[:, :S - F],
                 "frontend": jnp.zeros((B, F, cfg.d_model))}
    if cfg.family == "audio":
        batch["frontend"] = jnp.zeros((B, S, cfg.d_model))
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    cache = m.init_cache(B, S + 4, dtype=jnp.float32)
    _, cache, _ = m.prefill(params, batch, cache)
    dec = tok[:, :1] if cfg.family != "audio" else \
        jnp.zeros((B, 1, cfg.d_model))
    lg, _ = m.decode_step(params, dec, cache, jnp.asarray(S))
    print(f"{arch:24s} [{cfg.family:6s}] loss={float(loss):.3f} "
          f"decode_logits={tuple(lg.shape)}")
print("\nall 10 assigned architectures: train + serve paths OK")
