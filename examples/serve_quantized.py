"""Serve an OAC/RTN-quantized model: packed 2-bit weights, batched requests.

Shows the fused dequant-matmul path (Pallas kernel on TPU, blockwise jnp on
CPU), the storage win, and the full checkpoint loop: the packed tree is
written to disk (``serving.qserve.ckpt.save``), memmap-loaded back, and
served from the on-disk planes.

Run:  PYTHONPATH=src python examples/serve_quantized.py [--arch gemma3-27b]
(assigned archs run in their reduced smoke shapes on CPU)
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402
import numpy as np                                     # noqa: E402

from repro import utils                                # noqa: E402
from repro.configs import get_smoke                    # noqa: E402
from repro.configs.base import QuantConfig             # noqa: E402
from repro.core.qformat import QuantizedTensor         # noqa: E402
from repro.models import build_model                   # noqa: E402
from repro.serving.engine import Engine                # noqa: E402
from repro.serving.quantized import quantize_params_rtn  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--wbits", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    dense_bytes = utils.tree_size_bytes(params)

    qp, skipped = quantize_params_rtn(params, QuantConfig(wbits=args.wbits,
                                                          group_size=32))
    if skipped:
        print(f"left fp (misaligned/tiny): {skipped}")
    q_bytes = utils.tree_size_bytes(qp)
    n_packed = sum(1 for v in jax.tree_util.tree_leaves(
        qp, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        if isinstance(v, QuantizedTensor))
    print(f"arch={cfg.name}  packed {n_packed} kernel stacks to "
          f"w{args.wbits}: {dense_bytes / 1e6:.2f} MB -> "
          f"{q_bytes / 1e6:.2f} MB")

    # write the packed tree as an on-disk checkpoint and serve from it —
    # the same artifact `launch/serve.py --ckpt` consumes
    from repro.serving.qserve import ckpt as qckpt
    ckpt_dir = os.path.join(tempfile.mkdtemp(prefix="rtn_ckpt_"), "ckpt")
    qckpt.save(ckpt_dir, qp, cfg, QuantConfig(wbits=args.wbits,
                                              group_size=32, method="rtn"))
    loaded = qckpt.load(ckpt_dir)
    disk_bytes = os.path.getsize(os.path.join(ckpt_dir, qckpt.PLANES_NAME))
    print(f"checkpoint: {disk_bytes / 1e6:.2f} MB on disk -> {ckpt_dir}")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=10) for _ in range(3)]

    def serve(tree):
        eng = Engine(cfg, tree, max_batch=3, capacity=64)
        rs = [eng.submit(p, max_tokens=8) for p in prompts]
        eng.run()
        return rs

    rs, rs_disk = serve(qp), serve(loaded)
    for a, b in zip(rs, rs_disk):
        assert a.out == b.out, (a.rid, a.out, b.out)
        print(f"  req {a.rid} -> {a.out}")

    # quality summary: teacher-force the demo prompts + their continuations
    # through the scoring path (repro.eval).  The fused-dequant serve path
    # must be argmax-LOSSLESS against serving the same dequantized weights
    # as dense fp arrays — greedy-match-rate exactly 1.0 (asserted for the
    # rtn-w4 toy model, the CI contract).  The match against the
    # *unquantized* fp weights is the real quality number quantization
    # degrades; `launch/eval.py` tracks it per method in BENCH_quality.json.
    import dataclasses
    from repro.core.qformat import dequantize_any
    from repro.eval import metrics, runner
    fp_ref = jax.tree_util.tree_map(
        lambda v: dequantize_any(dataclasses.replace(v, dtype="float32"))
        if isinstance(v, QuantizedTensor) else v,
        qp, is_leaf=lambda v: isinstance(v, QuantizedTensor))
    rows = np.stack([np.concatenate([p, np.asarray(r.out)])
                     for p, r in zip(prompts, rs)]).astype(np.int32)
    o_pack = runner.make_engine(cfg, loaded, capacity=32,
                                max_batch=3).score(rows)
    o_deq = runner.make_engine(cfg, fp_ref, capacity=32,
                               max_batch=3).score(rows)
    o_fp = runner.make_engine(cfg, params, capacity=32,
                              max_batch=3).score(rows)
    lossless = metrics.greedy_match_rate(o_pack["greedy"], o_deq["greedy"])
    vs_fp = metrics.greedy_match_rate(o_pack["greedy"], o_fp["greedy"])
    print(f"quality: greedy-match {lossless:.3f} vs dequantized fp "
          f"(serve path lossless), {vs_fp:.3f} vs unquantized fp16, "
          f"ppl {metrics.perplexity(o_pack['nll']):.2f} "
          f"(fp16 {metrics.perplexity(o_fp['nll']):.2f})")
    if cfg.name.startswith("toy-llama") and args.wbits == 4:
        assert lossless == 1.0, lossless
    print("OK: batched decode through packed weights; on-disk checkpoint "
          "serves bit-identically.")


if __name__ == "__main__":
    main()
