"""Quickstart: quantize a linear layer with OAC vs the output-agnostic
baselines and see the error ordering (paper eq. 1 vs eq. 6 in 30 lines).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.core import solver   # noqa: E402

rng = np.random.default_rng(0)
d_in, d_out, n = 256, 192, 1024

# a linear layer inside a "model": y = softmax-ish readout of W x
W = jnp.asarray(rng.normal(size=(d_in, d_out)) * 0.15)
X = jnp.asarray(rng.normal(size=(n, d_in)))
X = X + X @ jnp.asarray(rng.normal(size=(d_in, d_in)) * 0.4)  # correlations
readout = jnp.asarray(rng.normal(size=(d_out, 32)) * 0.3)
targets = jnp.argmax((X @ W) @ readout, axis=-1)             # "labels"


def model_ce(Wq):
    logits = (X @ Wq) @ readout
    lp = jax.nn.log_softmax(logits, -1)
    return -jnp.take_along_axis(lp, targets[:, None], 1).mean()


# output-agnostic Hessian (OPTQ/SpQR): input second moment, eq. 1
H_l2 = X.T @ X

# output-adaptive Hessian (OAC): per-sample CE gradients, eq. 13/22
def per_sample_ce(Wq, i):
    logits = (X[i] @ Wq) @ readout
    lp = jax.nn.log_softmax(logits, -1)
    return -lp[targets[i]]

G = jax.vmap(lambda i: jax.grad(per_sample_ce)(W, i))(jnp.arange(n))
H_oac = jnp.einsum("nio,njo->ij", G, G)

base = float(model_ce(W))
for name, H in [("RTN (no H)", None), ("OPTQ/SpQR-l2", H_l2),
                ("OAC", H_oac)]:
    if H is None:
        r = solver.rtn_result(W, bits=2, group_size=64)
    else:
        r = solver.calibrate(W, H, bits=2, group_size=64, alpha=0.1,
                             tau=3.5, outlier_capacity=0.005)
    dce = float(model_ce(r.w_hat)) - base
    print(f"{name:14s}  2-bit ΔCE = {dce:+.4f}")
print("\nOAC uses the model OUTPUT loss to decide where precision matters.")
