"""End-to-end driver: train a ~6M-param LM a few hundred steps, quantize it
with the full OAC pipeline (Algorithm 1), pack to 2-bit storage, save the
packed checkpoint to disk (``serving.qserve.ckpt``), and serve it back from
the on-disk planes — the paper's workflow in miniature, ending in the same
artifact ``launch/serve.py --ckpt`` consumes.

Run:  PYTHONPATH=src python examples/quantize_llm.py [--steps 300]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro.configs.base import QuantConfig, TrainConfig  # noqa: E402
from repro.configs.paper_models import TOY_LM            # noqa: E402
from repro.core import pipeline                          # noqa: E402
from repro.data import (DataIterator, SyntheticCorpus,   # noqa: E402
                        make_calib_set)
from repro.models import build_model                     # noqa: E402
from repro.train.loop import train                       # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--wbits", type=int, default=2)
    args = ap.parse_args()

    cfg = TOY_LM
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=128, seed=7)

    print(f"== 1. train {cfg.name} for {args.steps} steps ==")
    tcfg = TrainConfig(steps=args.steps, lr=2e-3, warmup=30,
                       ckpt_dir="/tmp/oac_example_ckpt", ckpt_every=100)
    params, losses = train(m, params, DataIterator(corpus, "train", 16),
                           tcfg, log_every=50)

    calib = {"tokens": jnp.asarray(make_calib_set(corpus, 16)["tokens"])}
    test = {"tokens": jnp.asarray(corpus.batch("test", 0, 16)["tokens"])}
    base_ce = float(m.loss(params, test))
    print(f"\n== 2. quantize to {args.wbits}-bit (calib: 16 x 128 tokens) ==")

    rows = []
    for name, q in {
        "RTN": QuantConfig(wbits=args.wbits, group_size=32, method="rtn"),
        "SpQR (l2 H)": QuantConfig(wbits=args.wbits, group_size=32,
                                   method="spqr", hessian="l2"),
        "OAC (ours)": QuantConfig(wbits=args.wbits, group_size=32,
                                  method="spqr", hessian="oac"),
    }.items():
        qp, results = pipeline.quantize_model(m, params, calib, q,
                                              log=lambda *a: None)
        ce = float(m.loss(qp, test))
        rows.append((name, ce))
        print(f"  {name:12s} ppl {np.exp(ce):8.3f}  (ΔCE {ce - base_ce:+.4f})")
    print(f"  {'baseline':12s} ppl {np.exp(base_ce):8.3f}")

    print("\n== 3. pack OAC weights -> packed checkpoint -> serve from disk ==")
    q = QuantConfig(wbits=args.wbits, group_size=32, method="spqr",
                    hessian="oac")
    qp, results = pipeline.quantize_model(m, params, calib, q,
                                          log=lambda *a: None)
    packed = pipeline.pack_results(qp, results, q)
    from repro.core.qformat import QuantizedTensor
    bits = [v.storage_bits()
            for v in jax.tree_util.tree_leaves(
                packed, is_leaf=lambda x: isinstance(x, QuantizedTensor))
            if isinstance(v, QuantizedTensor)]
    from repro.launch.dryrun import verify_ckpt
    from repro.serving.engine import Engine
    from repro.serving.qserve import ckpt as qckpt

    def serve_one(tree):
        eng = Engine(cfg, tree, max_batch=1, capacity=64)
        r = eng.submit(np.arange(1, 12), max_tokens=8)
        eng.run()
        return r

    ckpt_dir = os.path.join(tempfile.mkdtemp(prefix="oac_ckpt_"), "ckpt")
    manifest = qckpt.save(ckpt_dir, packed, cfg, q)
    verify_ckpt(ckpt_dir, verbose=False)      # manifest-only shape check
    loaded = qckpt.load(ckpt_dir)
    r_mem, r_disk = serve_one(packed), serve_one(loaded)
    assert r_mem.out == r_disk.out, (r_mem.out, r_disk.out)
    avg_bits = float(np.mean(bits))
    pf = manifest["plane_file"]
    print(f"  packed layer stacks: avg bits {avg_bits:.2f} "
          f"({16.0 / avg_bits:.1f}x smaller than fp16)")
    print(f"  checkpoint: {pf['bytes'] / 1e6:.2f} MB planes -> {ckpt_dir}")
    print(f"  served continuation (from disk, == in-memory): {r_disk.out}")
    assert rows[-1][1] <= rows[0][1], "OAC must beat RTN"
    print("\nOK: OAC < RTN on held-out CE; saved checkpoint serves "
          "bit-identically to the in-memory packed tree.")


if __name__ == "__main__":
    main()
