"""Packed-weight byte accounting: proves planes are sharded, not replicated.

``packed_plane_bytes(params, shardings)`` walks every ``QuantizedTensor``
in a (concrete or abstract) param tree and returns the total packed-plane
bytes plus — when a matching shardings tree from
``ShardingPlan.param_shardings`` is given — the per-device bytes implied by
each plane's ``NamedSharding.shard_shape``.  A replicated layout reports
``per_device == total``; a properly tp-sharded layout reports
``per_device ~= total / tp``.  ``launch/dryrun.py`` asserts the latter for
quantized decode cells and ``benchmarks/bench_serving.py`` prints it as a
bench row (over an ``AbstractMesh``, so no devices are needed).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.core.qformat import QuantizedTensor

# tripwire headroom over the ideal per-device ratio of 1/tp: odd kernels
# whose dims don't divide the tp axis legitimately replicate, but anything
# past this means the planes as a whole are not sharded.  Shared by the
# dryrun assertion, the bench tripwire, and test_dist.
PACKED_SHARD_SLACK = 1.25


def _is_qt(n):
    return isinstance(n, QuantizedTensor)


def _plane_leaves(qt: QuantizedTensor):
    planes = list(qt.planes)
    if qt.resid_planes is not None:
        planes += list(qt.resid_planes)
    return planes


def packed_plane_bytes(params, shardings=None) -> dict:
    """-> {"total": int, "per_device": int, "n_tensors": int, "ratio": float}.

    ``total`` counts the uint8 code planes (incl. BiLLM residual planes) of
    every QuantizedTensor; ``per_device`` is the same count under the given
    shardings tree (equal to ``total`` when ``shardings is None``).
    ``ratio`` = per_device / total (1.0 = replicated, 1/tp = fully sharded).
    """
    p_nodes = [n for n in jax.tree.leaves(params, is_leaf=_is_qt)
               if _is_qt(n)]
    s_nodes = [None] * len(p_nodes)
    if shardings is not None:
        s_nodes = [n for n in jax.tree.leaves(shardings, is_leaf=_is_qt)
                   if _is_qt(n)]
        assert len(s_nodes) == len(p_nodes), (len(s_nodes), len(p_nodes))
    total = 0
    per_device = 0
    for qt, sh in zip(p_nodes, s_nodes):
        planes = _plane_leaves(qt)
        shards = _plane_leaves(sh) if sh is not None else [None] * len(planes)
        for plane, s in zip(planes, shards):
            n = int(np.prod(plane.shape))
            total += n
            if s is None:
                per_device += n
            else:
                per_device += int(np.prod(s.shard_shape(tuple(plane.shape))))
    return {"total": total, "per_device": per_device,
            "n_tensors": len(p_nodes),
            "ratio": per_device / total if total else 1.0}


def manifest_plane_bytes(manifest: dict, plan=None) -> dict:
    """``packed_plane_bytes`` straight from a checkpoint manifest — no
    plane reads, no model build.  The abstract tree is rebuilt from the
    manifest (``ckpt.abstract_params``); with a ``ShardingPlan`` (concrete
    or AbstractMesh) the per-device count reflects the exact layout the
    TP-aware loader will place."""
    from repro.serving.qserve import ckpt
    sds = ckpt.abstract_params(manifest)
    sh = plan.param_shardings(sds) if plan is not None else None
    return packed_plane_bytes(sds, sh)


def device_plane_bytes(params) -> int:
    """Max over devices of packed code-plane bytes *actually resident* on
    that device for a loaded (committed) tree — the ground truth the
    ``packed_plane_bytes`` shard-shape arithmetic predicts.  Used by the
    tp=2 checkpoint test and ``launch/serve.py --ckpt`` reporting."""
    per_dev: dict = {}
    for qt in jax.tree.leaves(params, is_leaf=_is_qt):
        if not _is_qt(qt):
            continue
        for plane in _plane_leaves(qt):
            for s in getattr(plane, "addressable_shards", []):
                per_dev[s.device] = per_dev.get(s.device, 0) + s.data.nbytes
    return max(per_dev.values(), default=0)


def abstract_tp_mesh(tp: int, dp: int = 1):
    """Device-free (dp, tp) AbstractMesh for layout-only accounting —
    ``make_plan``/``param_shardings``/``shard_shape`` all work on it."""
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", dp), ("model", tp)))
