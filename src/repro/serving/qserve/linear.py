"""Serve-time dispatch for quantized linear kernels (the fused-dequant path).

``models/layers.py::linear`` calls ``quantized_linear`` whenever a kernel
leaf is a packed ``QuantizedTensor``.  Without a distribution context (or on
a trivial mesh) this is exactly ``kernels.dequant_matmul.ops.dequant_matmul``
— Pallas kernel on TPU, blockwise jnp elsewhere; the fp weight never
materializes in HBM either way.

Under tensor parallelism the packed planes are *sharded* by
``ShardingPlan.param_shardings`` (packed ints along the same axis as the fp
kernel they replace, grouped scales/zeros along the group axis, outlier COO
buffers replicated), and this module runs the fused matmul inside a
shard_map so each shard touches only its local plane slab:

  * ``kind="col"`` (wq/wk/wv/wi/wg/...): the output dim N splits over tp —
    each shard computes ``x @ W_local`` with zero collectives, mirroring the
    fp column-parallel layout.
  * ``kind="row"`` (wo/out_proj/cm_value): the contraction dim K splits over
    tp (group-aligned) — each shard computes a partial product and one psum
    combines, mirroring the fp row-parallel "one all-reduce" contract.

BiLLM residual-carrier planes (1-bit sign + |w_hat|) ride the same sharded
fused path: they split along the same axis as the code planes (N for "col",
K for "row" — the sign plane packs along K, so its byte rows follow the K
split) and the kernel adds them during tile dequant, so w2 checkpoints no
longer drop to the whole-tensor unfused op.

The SpQR COO outlier correction uses global (row, col) indices and is
applied outside the shard_map on the assembled output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qformat import QuantizedTensor
from repro.dist import ctx as dctx
from repro.kernels.dequant_matmul import ops as dq_ops


def _row_aligned(qt: QuantizedTensor, T: int) -> bool:
    """Can the contraction dim split over T shards without breaking the
    packing bytes or the quant-group tiling?"""
    K = qt.shape[0]
    if K % T or (K // T) % qt.group_size:
        return False
    if not all(p.shape[0] % T == 0 for p in qt.planes):
        return False
    if qt.resid_planes is not None and \
            any(p.shape[0] % T for p in qt.resid_planes):
        return False
    return True


def _local_matmul(bits, group_size, resid):
    if resid:
        def local(xl, planes_l, s_l, z_l, rp_l, rs_l):
            return dq_ops.dequant_matmul_parts(
                xl, planes_l, s_l, z_l, bits=bits, group_size=group_size,
                resid_planes=rp_l, resid_scales=rs_l)
    else:
        def local(xl, planes_l, s_l, z_l):
            return dq_ops.dequant_matmul_parts(
                xl, planes_l, s_l, z_l, bits=bits, group_size=group_size)
    return local


def _resid_args(qt):
    if qt.resid_planes is None:
        return ()
    return (qt.resid_planes, qt.resid_scales)


def _col_sharded(x2, qt, scales, zeros, c):
    """N splits over tp; no collective (fp column-parallel analogue)."""
    from jax.sharding import PartitionSpec as P
    tp = c.tp
    rep = P(None, None)
    col = P(None, tp)
    resid = qt.resid_planes is not None
    in_specs = (rep, tuple(col for _ in qt.planes), col, col)
    if resid:
        in_specs += (tuple(col for _ in qt.resid_planes), col)
    return jax.shard_map(
        _local_matmul(qt.bits, qt.group_size, resid), mesh=c.mesh,
        in_specs=in_specs,
        out_specs=col)(x2, qt.planes, scales, zeros, *_resid_args(qt))


def _row_sharded(x2, qt, scales, zeros, c):
    """K splits over tp; partial products psum (fp row-parallel analogue)."""
    from jax.sharding import PartitionSpec as P
    tp = c.tp
    resid = qt.resid_planes is not None
    core = _local_matmul(qt.bits, qt.group_size, resid)

    def local(xl, planes_l, s_l, z_l, *rl):
        return jax.lax.psum(core(xl, planes_l, s_l, z_l, *rl), tp)

    rowx = P(None, tp)
    row = P(tp, None)
    in_specs = (rowx, tuple(row for _ in qt.planes), row, row)
    if resid:
        in_specs += (tuple(row for _ in qt.resid_planes), row)
    return jax.shard_map(
        local, mesh=c.mesh,
        in_specs=in_specs,
        out_specs=P(None, None))(x2, qt.planes, scales, zeros,
                                 *_resid_args(qt))


def quantized_linear(x, qt: QuantizedTensor, *, kind: str = "col"):
    """x (..., K) @ packed (K, N) -> (..., N) in x.dtype.

    ``kind`` names the fp-parallel layout of the kernel this tensor packs:
    "col" shards the output dim, "row" the contraction dim (the
    ``_ROW_SHARDED`` projections in ``dist/sharding.py``).  Non-divisible
    shapes fall back to the whole-tensor op — GSPMD then reshards as
    needed, so the fallback is a layout decision, never a correctness
    one."""
    c = dctx.get()
    if c is None or c.tp_size <= 1:
        return dq_ops.dequant_matmul(x, qt)
    lead = x.shape[:-1]
    K, N = qt.shape
    T = c.tp_size
    x2 = x.reshape(-1, K)
    scales, zeros = qt.scales_zeros()
    scales = scales.astype(jnp.float32)
    zeros = zeros.astype(jnp.float32)
    G = scales.shape[0]
    if kind == "col" and N % T == 0:
        y = _col_sharded(x2, qt, scales, zeros, c)
    elif kind == "row" and G % T == 0 and _row_aligned(qt, T):
        y = _row_sharded(x2, qt, scales, zeros, c)
    else:
        y = dq_ops.dequant_matmul_parts(
            x2, qt.planes, scales, zeros, bits=qt.bits,
            group_size=qt.group_size, resid_planes=qt.resid_planes,
            resid_scales=qt.resid_scales)
    y = dq_ops.outlier_correction(x2, qt, y)
    return y.reshape(*lead, N).astype(x.dtype)
