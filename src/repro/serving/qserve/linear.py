"""Serve-time dispatch for quantized linear kernels (the fused-dequant path).

``models/layers.py::linear`` calls ``quantized_linear`` whenever a kernel
leaf is a packed ``QuantizedTensor``.  Without a distribution context (or on
a trivial mesh) this is exactly ``kernels.dequant_matmul.ops.dequant_matmul``
— Pallas kernel on TPU, blockwise jnp elsewhere; the fp weight never
materializes in HBM either way.

Under tensor parallelism the packed planes are *sharded* by
``ShardingPlan.param_shardings`` (packed ints along the same axis as the fp
kernel they replace, grouped scales/zeros along the group axis, outlier COO
buffers replicated), and this module runs the fused matmul inside a
shard_map so each shard touches only its local plane slab:

  * ``kind="col"`` (wq/wk/wv/wi/wg/...): the output dim N splits over tp —
    each shard computes ``x @ W_local`` with zero collectives, mirroring the
    fp column-parallel layout.
  * ``kind="row"`` (wo/out_proj/cm_value): the contraction dim K splits over
    tp (group-aligned) — each shard computes a partial product and one psum
    combines, mirroring the fp row-parallel "one all-reduce" contract.

The SpQR COO outlier correction uses global (row, col) indices and is
applied outside the shard_map on the assembled output.  BiLLM residual
planes fall back to the whole-tensor path (their serve traffic is the w1
research config, not the production rtn/OAC fast path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qformat import QuantizedTensor
from repro.dist import ctx as dctx
from repro.kernels.dequant_matmul import ops as dq_ops


def _row_aligned(qt: QuantizedTensor, T: int) -> bool:
    """Can the contraction dim split over T shards without breaking the
    packing bytes or the quant-group tiling?"""
    K = qt.shape[0]
    if K % T or (K // T) % qt.group_size:
        return False
    return all(p.shape[0] % T == 0 for p in qt.planes)


def _local_matmul(bits, group_size):
    def local(xl, planes_l, s_l, z_l):
        return dq_ops.dequant_matmul_parts(
            xl, planes_l, s_l, z_l, bits=bits, group_size=group_size)
    return local


def _col_sharded(x2, qt, scales, zeros, c):
    """N splits over tp; no collective (fp column-parallel analogue)."""
    from jax.sharding import PartitionSpec as P
    tp = c.tp
    rep = P(None, None)
    col = P(None, tp)
    return jax.shard_map(
        _local_matmul(qt.bits, qt.group_size), mesh=c.mesh,
        in_specs=(rep, tuple(col for _ in qt.planes), col, col),
        out_specs=col)(x2, qt.planes, scales, zeros)


def _row_sharded(x2, qt, scales, zeros, c):
    """K splits over tp; partial products psum (fp row-parallel analogue)."""
    from jax.sharding import PartitionSpec as P
    tp = c.tp
    core = _local_matmul(qt.bits, qt.group_size)

    def local(xl, planes_l, s_l, z_l):
        return jax.lax.psum(core(xl, planes_l, s_l, z_l), tp)

    rowx = P(None, tp)
    row = P(tp, None)
    return jax.shard_map(
        local, mesh=c.mesh,
        in_specs=(rowx, tuple(row for _ in qt.planes), row, row),
        out_specs=P(None, None))(x2, qt.planes, scales, zeros)


def quantized_linear(x, qt: QuantizedTensor, *, kind: str = "col"):
    """x (..., K) @ packed (K, N) -> (..., N) in x.dtype.

    ``kind`` names the fp-parallel layout of the kernel this tensor packs:
    "col" shards the output dim, "row" the contraction dim (the
    ``_ROW_SHARDED`` projections in ``dist/sharding.py``).  Non-divisible
    shapes and BiLLM-residual tensors fall back to the whole-tensor op —
    GSPMD then reshards as needed, so the fallback is a layout decision,
    never a correctness one."""
    c = dctx.get()
    if c is None or c.tp_size <= 1 or qt.resid_planes is not None:
        return dq_ops.dequant_matmul(x, qt)
    lead = x.shape[:-1]
    K, N = qt.shape
    T = c.tp_size
    x2 = x.reshape(-1, K)
    scales, zeros = qt.scales_zeros()
    scales = scales.astype(jnp.float32)
    zeros = zeros.astype(jnp.float32)
    G = scales.shape[0]
    if kind == "col" and N % T == 0:
        y = _col_sharded(x2, qt, scales, zeros, c)
    elif kind == "row" and G % T == 0 and _row_aligned(qt, T):
        y = _row_sharded(x2, qt, scales, zeros, c)
    else:
        y = dq_ops.dequant_matmul_parts(
            x2, qt.planes, scales, zeros, bits=qt.bits,
            group_size=qt.group_size)
    y = dq_ops.outlier_correction(x2, qt, y)
    return y.reshape(*lead, N).astype(x.dtype)
