"""qserve: end-to-end quantized serving.

Makes packed ``QuantizedTensor`` checkpoints the first-class serving format:

* ``linear``  — the serve-time matmul dispatch layer: fused dequant matmul
  (Pallas kernel on TPU, blockwise jnp elsewhere) over tensor-parallel plane
  shards.  ``models/layers.py::linear`` routes every quantized kernel here.
* ``kvquant`` — int8 KV-cache quantization (per-token-per-head symmetric
  grids) used by the quantized paged block pool in ``models/attention.py``.
* ``report``  — packed-weight byte accounting (total vs per-device under a
  ``ShardingPlan``), consumed by ``launch/dryrun.py`` and
  ``benchmarks/bench_serving.py`` to prove planes are sharded, not
  replicated.

* ``ckpt``    — the on-disk packed-checkpoint format (JSON manifest +
  flat binary plane file): ``save`` persists calibrated
  ``pack_results``/RTN trees, ``load`` memmaps planes back zero-copy and,
  under a ``ShardingPlan``, places each plane shard directly per
  ``param_shardings``.  See docs/qformat.md for the byte-level spec.

The write side of plane sharding lives in ``dist/sharding.py``
(``ShardingPlan.param_shardings`` understands ``QuantizedTensor`` nodes);
this package is the read side plus the accounting.
"""
from repro.serving.qserve import ckpt
from repro.serving.qserve.kvquant import dequantize_kv, quantize_kv
from repro.serving.qserve.linear import quantized_linear
from repro.serving.qserve.report import packed_plane_bytes

__all__ = ["ckpt", "quantized_linear", "quantize_kv", "dequantize_kv",
           "packed_plane_bytes"]
