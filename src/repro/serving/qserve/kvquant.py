"""int8 KV-cache quantization: per-token-per-head symmetric grids.

The quantized paged pool stores KV as int8 codes plus one bf16 scale per
(token, kv-head) — the scale plane rides next to the code pool with the
same (num_blocks, block_size, KV) block layout, so block-table indexing,
scatter/gather, COW copies, and tp stripe sharding all treat codes and
scales uniformly.  Symmetric (zero-point-free) grids keep the decode
dequant to one fused multiply; per-token granularity means a new token's
write never rescales previously written entries (append-only contract of
the pool).

Storage per element: 1 byte + 2/head_dim bytes of scale — 0.56x fp16 at
the toy head_dim=16, 0.52x at head_dim=128.
"""
from __future__ import annotations

import jax.numpy as jnp

SCALE_DTYPE = jnp.bfloat16
_QMAX = 127.0


def quantize_kv(x):
    """x (..., Dh) fp -> (codes (..., Dh) int8, scale (...,) SCALE_DTYPE).

    Symmetric per-vector grid: ``x ~= codes * scale`` with
    ``scale = max|x| / 127`` over the head dim.  The scale is rounded to
    its bf16 storage form BEFORE the codes are fit, so codes and stored
    scale are consistent — dequant (which re-widens the stored scale to
    f32) lands exactly on the grid the codes were rounded to."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / _QMAX, 1e-8).astype(SCALE_DTYPE)
    q = jnp.round(x.astype(jnp.float32)
                  / scale.astype(jnp.float32)[..., None])
    return jnp.clip(q, -_QMAX, _QMAX).astype(jnp.int8), scale


def dequantize_kv(codes, scale, dtype=jnp.float32):
    """codes (..., Dh) int8, scale (...,) -> fp (..., Dh)."""
    return (codes.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)
