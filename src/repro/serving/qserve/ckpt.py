"""Packed-checkpoint format: calibrated QuantizedTensor trees on disk.

This is the bridge between the paper's calibration output
(``core.pipeline.quantize_model`` -> ``pack_results``) and the serving
stack (``PagedEngine`` + the qserve fused-dequant dispatch): one directory
holds

  * ``manifest.json`` — format/version tags, the model config name, the
    QuantConfig used, and one entry per param-tree leaf: dense leaves
    record a single ``data`` plane; ``QuantizedTensor`` leaves record
    their static meta (bits/group/shape/stats/outlier count) plus every
    array field as a named plane in the stable ``qformat.qt_entries``
    order.
  * ``planes.bin``    — all plane bytes concatenated, each plane aligned
    to ``ALIGN`` so a zero-copy ``np.memmap`` view exists for every entry.

Loading is lazy and TP-aware: ``load(dir)`` memmaps the plane file and,
given a ``ShardingPlan``, places each plane *per shard* via
``plan.param_shardings`` + ``plan.place`` — only the slices this host's
devices own are ever read, so a tp-sharded load never materializes the
full tree in host memory.  ``abstract_params(manifest)`` rebuilds the
ShapeDtypeStruct tree from the manifest alone (no plane reads) for
dry-run lowering and shape verification (``launch/dryrun.py --ckpt``).

Byte-level layout and the sharding contract are specified in
``docs/qformat.md`` so external tools can write compatible checkpoints.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import utils
from repro.core import qformat
from repro.core.qformat import QuantizedTensor

FORMAT_NAME = "oac-qckpt"
MANIFEST_NAME = "manifest.json"
PLANES_NAME = "planes.bin"
ALIGN = 64


class CkptError(RuntimeError):
    """Unloadable checkpoint: wrong format/version, truncated plane file,
    or a manifest whose entries don't describe the plane bytes on disk."""


def _is_qt(n):
    return isinstance(n, QuantizedTensor)


def _dtype_name(dt) -> str:
    return jnp.dtype(dt).name


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _tree_from_paths(entries):
    """{'/a/b': leaf} -> nested dicts (the only container the format
    supports; model param trees are pure dicts)."""
    root: dict = {}
    for path, leaf in entries:
        parts = path.strip("/").split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


# --------------------------------------------------------------------------
# save
# --------------------------------------------------------------------------

class _PlaneWriter:
    def __init__(self, f):
        self.f = f
        self.off = 0

    def write(self, arr) -> dict:
        arr = np.asarray(arr)
        pad = (-self.off) % ALIGN
        if pad:
            self.f.write(b"\0" * pad)
            self.off += pad
        entry = {"offset": self.off, "bytes": arr.nbytes,
                 "shape": list(arr.shape),
                 "dtype": _dtype_name(arr.dtype)}
        self.f.write(np.ascontiguousarray(arr).tobytes())
        self.off += arr.nbytes
        return entry


def _write_tree(w: _PlaneWriter, params) -> dict:
    """Append every leaf of ``params`` to the plane writer; returns the
    manifest ``tensors`` section describing them."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params, is_leaf=_is_qt)
    tensors = {}
    for p, leaf in flat:
        path = utils.path_str(p)
        if _is_qt(leaf):
            stack = list(leaf.planes[0].shape[:-2])
            tensors[path] = {
                "kind": "quantized",
                "meta": qformat.qt_meta(leaf),
                "stack": stack,
                "outlier_count": int(leaf.out_vals.shape[-1]),
                "planes": {name: w.write(arr)
                           for name, arr in qformat.qt_entries(leaf)},
            }
        else:
            tensors[path] = {"kind": "dense",
                             "planes": {"data": w.write(leaf)}}
    return tensors


def save(ckpt_dir: str, params, cfg, qcfg=None, *,
         extra: Optional[dict] = None, draft=None, draft_qcfg=None) -> dict:
    """Write ``params`` (dense leaves + packed QuantizedTensors) as a
    packed checkpoint under ``ckpt_dir``; returns the manifest dict.

    ``draft`` (optional) is a second param tree of the *same architecture*
    — typically a zero-calibration RTN pack of the target weights — whose
    planes land in the same ``planes.bin`` after the target's, described
    by a ``draft`` manifest section.  One checkpoint then serves both
    roles of self-speculative decoding: ``load(dir)`` gives the verify
    model, ``load(dir, which="draft")`` the proposer.

    The plane file is written first and the manifest is renamed into place
    last, so a directory with a readable manifest is always complete.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp_planes = os.path.join(ckpt_dir, PLANES_NAME + ".tmp")
    with open(tmp_planes, "wb") as f:
        w = _PlaneWriter(f)
        tensors = _write_tree(w, params)
        draft_tensors = _write_tree(w, draft) if draft is not None else None
    os.replace(tmp_planes, os.path.join(ckpt_dir, PLANES_NAME))

    manifest = {
        "format": FORMAT_NAME,
        "version": qformat.QFORMAT_VERSION,
        "arch": cfg.name,
        "plane_file": {"name": PLANES_NAME, "bytes": w.off},
        "qcfg": dataclasses.asdict(qcfg) if qcfg is not None else None,
        # top-level calibrator stamp: every method (oac/spqr, rtn, adpq,
        # quantease, billm) shares this v1 container, so tools that route
        # on provenance (eval scorecard, resume guards) read it without
        # parsing the full qcfg
        "method": qcfg.method if qcfg is not None else None,
        "tensors": tensors,
    }
    if draft_tensors is not None:
        manifest["draft"] = {
            "qcfg": dataclasses.asdict(draft_qcfg)
            if draft_qcfg is not None else None,
            "method": draft_qcfg.method if draft_qcfg is not None else None,
            "tensors": draft_tensors,
        }
    if extra:
        manifest["extra"] = extra
    tmp = os.path.join(ckpt_dir, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(ckpt_dir, MANIFEST_NAME))
    return manifest


# --------------------------------------------------------------------------
# manifest reading / validation
# --------------------------------------------------------------------------

def load_manifest(ckpt_dir: str) -> dict:
    """Read + validate ``manifest.json`` (format/version tags, every plane
    entry self-consistent and inside the plane file).  Raises CkptError."""
    mpath = os.path.join(ckpt_dir, MANIFEST_NAME)
    if not os.path.exists(mpath):
        raise CkptError(f"no {MANIFEST_NAME} under {ckpt_dir}")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CkptError(f"corrupt manifest {mpath}: {e}") from e
    if manifest.get("format") != FORMAT_NAME:
        raise CkptError(f"not an {FORMAT_NAME} checkpoint: "
                        f"format={manifest.get('format')!r}")
    if manifest.get("version") != qformat.QFORMAT_VERSION:
        raise CkptError(
            f"qformat version mismatch: checkpoint v{manifest.get('version')}"
            f" vs this build v{qformat.QFORMAT_VERSION} — re-quantize or "
            "use a matching build")
    pf = manifest.get("plane_file", {})
    ppath = os.path.join(ckpt_dir, pf.get("name", PLANES_NAME))
    if not os.path.exists(ppath):
        raise CkptError(f"missing plane file {ppath}")
    size = os.path.getsize(ppath)
    if size != pf.get("bytes"):
        raise CkptError(f"plane file truncated/corrupt: {size} B on disk "
                        f"vs {pf.get('bytes')} B in manifest")
    _validate_tensors(manifest.get("tensors", {}), size)
    if "draft" in manifest:
        try:
            _validate_tensors(manifest["draft"]["tensors"], size)
        except (KeyError, TypeError) as e:
            raise CkptError(f"malformed draft section: {e!r}") from e
    return manifest


def _validate_tensors(tensors: dict, size: int):
    """Validate one manifest ``tensors`` section against the plane-file
    size (every entry self-consistent and inside the file)."""
    for path, t in tensors.items():
        try:
            kind, planes = t["kind"], t["planes"]
            if kind not in ("dense", "quantized"):
                raise CkptError(f"{path}: unknown tensor kind {kind!r}")
            if kind == "quantized":
                t["meta"]["bits"], t["stack"], t["outlier_count"]
            for name, e in planes.items():
                n = int(np.prod(e["shape"])) * _np_dtype(e["dtype"]).itemsize
                if n != e["bytes"] or e["offset"] < 0 \
                        or e["offset"] + e["bytes"] > size:
                    raise CkptError(
                        f"bad plane entry {path}:{name}: {e} (file {size} B)")
                if kind == "quantized" and name not in qformat.ENTRY_NAMES:
                    raise CkptError(f"unknown plane name {name!r} at {path} "
                                    "(written by a newer qformat?)")
            missing = _required_planes(t) - set(planes)
            if missing:
                raise CkptError(f"{path}: missing plane(s) "
                                f"{sorted(missing)} (kind={kind})")
        except (KeyError, TypeError) as e:
            raise CkptError(
                f"malformed manifest entry {path}: {e!r}") from e


def _required_planes(t: dict) -> set:
    """The plane names a manifest tensor entry MUST carry (spec'd in
    docs/qformat.md): dense needs ``data``; quantized needs every
    non-optional ``qformat.ENTRY_NAMES`` entry for its bit-width, and the
    residual pair travels together."""
    if t["kind"] != "quantized":
        return {"data"}
    want = {"codes.0", "q_scales", "ss_scale", "ss_zero",
            "q_zeros", "zz_scale", "zz_zero",
            "out_rows", "out_cols", "out_vals"}
    if int(t["meta"]["bits"]) == 3:
        want.add("codes.1")
    if "resid.0" in t["planes"] or "resid_scales" in t["planes"]:
        want |= {"resid.0", "resid_scales"}
    return want


def resolve_config(manifest: dict):
    """Model config recorded in the manifest -> ModelConfig.  Reduced smoke
    configs round-trip through their ``<arch>-smoke`` name."""
    from repro.configs import REGISTRY, get_config, get_smoke
    name = manifest["arch"]
    if name in REGISTRY:
        return get_config(name)
    if name.endswith("-smoke") and name[:-len("-smoke")] in REGISTRY:
        return get_smoke(name[:-len("-smoke")])
    raise CkptError(f"checkpoint arch {name!r} is not in the config "
                    f"registry; available: {sorted(REGISTRY)}")


def quant_config(manifest: dict):
    """QuantConfig recorded in the manifest (None for hand-built trees)."""
    from repro.configs.base import QuantConfig
    if manifest.get("qcfg") is None:
        return None
    return QuantConfig(**manifest["qcfg"])


# --------------------------------------------------------------------------
# abstract tree (no plane reads)
# --------------------------------------------------------------------------

def has_draft(manifest: dict) -> bool:
    """True when the checkpoint packs draft planes beside the target."""
    return "draft" in manifest


def _tensor_section(manifest: dict, which: str) -> dict:
    if which == "target":
        return manifest["tensors"]
    if which == "draft":
        if "draft" not in manifest:
            raise CkptError("checkpoint has no draft planes (re-quantize "
                            "with --draft to pack a speculative drafter)")
        return manifest["draft"]["tensors"]
    raise ValueError(f"which must be 'target' or 'draft', got {which!r}")


def abstract_params(manifest: dict, which: str = "target"):
    """ShapeDtypeStruct tree of the checkpoint, from the manifest alone."""
    def one(t):
        sds = {name: jax.ShapeDtypeStruct(tuple(e["shape"]),
                                          _np_dtype(e["dtype"]))
               for name, e in t["planes"].items()}
        if t["kind"] == "dense":
            return sds["data"]
        return qformat.qt_from_entries(sds, t["meta"])
    return _tree_from_paths(
        [(path, one(t))
         for path, t in _tensor_section(manifest, which).items()])


# --------------------------------------------------------------------------
# load
# --------------------------------------------------------------------------

def _plane_view(mm, entry):
    """Zero-copy typed view of one plane inside the memmap."""
    off, nb = entry["offset"], entry["bytes"]
    return mm[off:off + nb].view(_np_dtype(entry["dtype"])) \
        .reshape(tuple(entry["shape"]))


def load(ckpt_dir: str, plan=None, *, manifest: Optional[dict] = None,
         which: str = "target"):
    """Load a packed checkpoint into a servable param tree.

    Without a plan every plane is copied once memmap -> default device.
    With a ``ShardingPlan`` each plane gets the sharding the plan assigns
    the corresponding fp kernel (``param_shardings`` over the abstract
    tree) and is built shard-by-shard via ``plan.place`` — per device only
    its own slice of the memmap is read.

    ``which="draft"`` loads the co-packed speculative-draft tree instead
    of the calibrated target (CkptError if the checkpoint has none).
    """
    manifest = manifest or load_manifest(ckpt_dir)
    tensors = _tensor_section(manifest, which)
    pf = manifest["plane_file"]
    mm = np.memmap(os.path.join(ckpt_dir, pf["name"]), dtype=np.uint8,
                   mode="r")

    shardings = {}
    if plan is not None:
        sds = abstract_params(manifest, which)
        sh_tree = plan.param_shardings(sds)
        flat, _ = jax.tree_util.tree_flatten_with_path(sh_tree,
                                                       is_leaf=_is_qt)
        for p, leaf in flat:
            shardings[utils.path_str(p)] = leaf

    def materialize(view, sharding):
        if plan is None or sharding is None:
            return jnp.asarray(view)
        return plan.place(sharding, view.shape, view.dtype,
                          lambda idx: view[idx])

    def one(path, t):
        if t["kind"] == "dense":
            return materialize(_plane_view(mm, t["planes"]["data"]),
                               shardings.get(path))
        sh = shardings.get(path)
        sh_by_name = dict(qformat.qt_entries(sh)) if sh is not None else {}
        arrays = {name: materialize(_plane_view(mm, e),
                                    sh_by_name.get(name))
                  for name, e in t["planes"].items()}
        return qformat.qt_from_entries(arrays, t["meta"])

    return _tree_from_paths(
        [(path, one(path, t)) for path, t in tensors.items()])
