"""Packed-checkpoint format: calibrated QuantizedTensor trees on disk.

This is the bridge between the paper's calibration output
(``core.pipeline.quantize_model`` -> ``pack_results``) and the serving
stack (``PagedEngine`` + the qserve fused-dequant dispatch): one directory
holds

  * ``manifest.json`` — format/version tags, the model config name, the
    QuantConfig used, and one entry per param-tree leaf: dense leaves
    record a single ``data`` plane; ``QuantizedTensor`` leaves record
    their static meta (bits/group/shape/stats/outlier count) plus every
    array field as a named plane in the stable ``qformat.qt_entries``
    order.
  * ``planes.bin``    — all plane bytes concatenated, each plane aligned
    to ``ALIGN`` so a zero-copy ``np.memmap`` view exists for every entry.

Loading is lazy and TP-aware: ``load(dir)`` memmaps the plane file and,
given a ``ShardingPlan``, places each plane *per shard* via
``plan.param_shardings`` + ``plan.place`` — only the slices this host's
devices own are ever read, so a tp-sharded load never materializes the
full tree in host memory.  ``abstract_params(manifest)`` rebuilds the
ShapeDtypeStruct tree from the manifest alone (no plane reads) for
dry-run lowering and shape verification (``launch/dryrun.py --ckpt``).

Byte-level layout and the sharding contract are specified in
``docs/qformat.md`` so external tools can write compatible checkpoints.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import utils
from repro.core import qformat
from repro.core.qformat import QuantizedTensor

FORMAT_NAME = "oac-qckpt"
MANIFEST_NAME = "manifest.json"
PLANES_NAME = "planes.bin"
ALIGN = 64


class CkptError(RuntimeError):
    """Unloadable checkpoint: wrong format/version, truncated plane file,
    or a manifest whose entries don't describe the plane bytes on disk."""


def _is_qt(n):
    return isinstance(n, QuantizedTensor)


def _dtype_name(dt) -> str:
    return jnp.dtype(dt).name


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _tree_from_paths(entries):
    """{'/a/b': leaf} -> nested dicts (the only container the format
    supports; model param trees are pure dicts)."""
    root: dict = {}
    for path, leaf in entries:
        parts = path.strip("/").split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


# --------------------------------------------------------------------------
# save
# --------------------------------------------------------------------------

class _PlaneLayout:
    """Pure layout pass: assigns each plane its ALIGN-aligned offset and
    records ``(offset, leaf)`` write jobs without touching the disk.
    Separating layout from I/O is what makes the parallel writer trivially
    byte-identical to the streaming one — offsets are fixed before either
    writes a byte, and the inter-plane gaps are zero either way."""

    def __init__(self):
        self.off = 0
        self.jobs: list = []            # (offset, array-like) in path order

    def write(self, arr) -> dict:
        shape = tuple(arr.shape)
        dtype = jnp.dtype(arr.dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        self.off += (-self.off) % ALIGN
        entry = {"offset": self.off, "bytes": nbytes,
                 "shape": list(shape), "dtype": _dtype_name(dtype)}
        self.jobs.append((self.off, arr))
        self.off += nbytes
        return entry


def _plane_bytes(arr) -> bytes:
    return np.ascontiguousarray(np.asarray(arr)).tobytes()


def _write_jobs_stream(path: str, jobs, total: int):
    """Single sequential writer: planes in offset order, zero-filled
    alignment gaps."""
    off = 0
    with open(path, "wb") as f:
        for o, arr in jobs:
            if o > off:
                f.write(b"\0" * (o - off))
            buf = _plane_bytes(arr)
            f.write(buf)
            off = o + len(buf)
        if total > off:
            f.write(b"\0" * (total - off))


def _write_jobs_parallel(path: str, jobs, total: int, workers: int):
    """Per-shard parallel writer mirroring the shard-by-shard reader:
    preallocate (``ftruncate`` zero-fills, matching the stream writer's
    explicit gap zeros), then ``workers`` threads ``pwrite`` disjoint
    plane extents at their layout offsets.  Threads suffice — the work is
    kernel I/O plus ``tobytes`` copies, both of which release the GIL."""
    import concurrent.futures

    fd = os.open(path, os.O_RDWR | os.O_CREAT | os.O_TRUNC, 0o644)
    try:
        os.ftruncate(fd, total)

        def shard(i: int):
            for o, arr in jobs[i::workers]:
                os.pwrite(fd, _plane_bytes(arr), o)

        with concurrent.futures.ThreadPoolExecutor(workers) as ex:
            # list() to re-raise the first worker failure
            list(ex.map(shard, range(workers)))
    finally:
        os.close(fd)


def _write_tree(w: _PlaneLayout, params) -> dict:
    """Append every leaf of ``params`` to the plane writer; returns the
    manifest ``tensors`` section describing them."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params, is_leaf=_is_qt)
    tensors = {}
    for p, leaf in flat:
        path = utils.path_str(p)
        if _is_qt(leaf):
            stack = list(leaf.planes[0].shape[:-2])
            tensors[path] = {
                "kind": "quantized",
                "meta": qformat.qt_meta(leaf),
                "stack": stack,
                "outlier_count": int(leaf.out_vals.shape[-1]),
                "planes": {name: w.write(arr)
                           for name, arr in qformat.qt_entries(leaf)},
            }
        else:
            tensors[path] = {"kind": "dense",
                             "planes": {"data": w.write(leaf)}}
    return tensors


def save(ckpt_dir: str, params, cfg, qcfg=None, *,
         extra: Optional[dict] = None, draft=None, draft_qcfg=None,
         workers: int = 0) -> dict:
    """Write ``params`` (dense leaves + packed QuantizedTensors) as a
    packed checkpoint under ``ckpt_dir``; returns the manifest dict.

    ``draft`` (optional) is a second param tree of the *same architecture*
    — typically a zero-calibration RTN pack of the target weights — whose
    planes land in the same ``planes.bin`` after the target's, described
    by a ``draft`` manifest section.  One checkpoint then serves both
    roles of self-speculative decoding: ``load(dir)`` gives the verify
    model, ``load(dir, which="draft")`` the proposer.

    ``workers`` > 1 writes the plane file with that many parallel
    ``pwrite`` threads over a preallocated file; the output is
    byte-identical to the default single streaming writer because the
    layout pass fixes every offset first (guarded by
    ``tests/test_ckpt_ops.py``).

    The plane file is written first and the manifest is renamed into place
    last, so a directory with a readable manifest is always complete.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    w = _PlaneLayout()
    tensors = _write_tree(w, params)
    draft_tensors = _write_tree(w, draft) if draft is not None else None
    tmp_planes = os.path.join(ckpt_dir, PLANES_NAME + ".tmp")
    if workers and workers > 1:
        _write_jobs_parallel(tmp_planes, w.jobs, w.off, int(workers))
    else:
        _write_jobs_stream(tmp_planes, w.jobs, w.off)
    os.replace(tmp_planes, os.path.join(ckpt_dir, PLANES_NAME))

    manifest = {
        "format": FORMAT_NAME,
        "version": qformat.QFORMAT_VERSION,
        "arch": cfg.name,
        "plane_file": {"name": PLANES_NAME, "bytes": w.off},
        "qcfg": dataclasses.asdict(qcfg) if qcfg is not None else None,
        # top-level calibrator stamp: every method (oac/spqr, rtn, adpq,
        # quantease, billm) shares this v1 container, so tools that route
        # on provenance (eval scorecard, resume guards) read it without
        # parsing the full qcfg
        "method": qcfg.method if qcfg is not None else None,
        "tensors": tensors,
    }
    if draft_tensors is not None:
        manifest["draft"] = {
            "qcfg": dataclasses.asdict(draft_qcfg)
            if draft_qcfg is not None else None,
            "method": draft_qcfg.method if draft_qcfg is not None else None,
            "tensors": draft_tensors,
        }
    if extra:
        manifest["extra"] = extra
    tmp = os.path.join(ckpt_dir, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(ckpt_dir, MANIFEST_NAME))
    return manifest


# --------------------------------------------------------------------------
# manifest reading / validation
# --------------------------------------------------------------------------

def load_manifest(ckpt_dir: str) -> dict:
    """Read + validate ``manifest.json`` (format/version tags, every plane
    entry self-consistent and inside the plane file).  Raises CkptError."""
    mpath = os.path.join(ckpt_dir, MANIFEST_NAME)
    if not os.path.exists(mpath):
        raise CkptError(f"no {MANIFEST_NAME} under {ckpt_dir}")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CkptError(f"corrupt manifest {mpath}: {e}") from e
    if manifest.get("format") != FORMAT_NAME:
        raise CkptError(f"not an {FORMAT_NAME} checkpoint: "
                        f"format={manifest.get('format')!r}")
    if manifest.get("version") != qformat.QFORMAT_VERSION:
        raise CkptError(
            f"qformat version mismatch: checkpoint v{manifest.get('version')}"
            f" vs this build v{qformat.QFORMAT_VERSION} — re-quantize or "
            "use a matching build")
    pf = manifest.get("plane_file", {})
    ppath = os.path.join(ckpt_dir, pf.get("name", PLANES_NAME))
    if not os.path.exists(ppath):
        raise CkptError(f"missing plane file {ppath}")
    size = os.path.getsize(ppath)
    if size != pf.get("bytes"):
        raise CkptError(f"plane file truncated/corrupt: {size} B on disk "
                        f"vs {pf.get('bytes')} B in manifest")
    _validate_tensors(manifest.get("tensors", {}), size)
    if "draft" in manifest:
        try:
            _validate_tensors(manifest["draft"]["tensors"], size)
        except (KeyError, TypeError) as e:
            raise CkptError(f"malformed draft section: {e!r}") from e
    return manifest


def _validate_tensors(tensors: dict, size: int):
    """Validate one manifest ``tensors`` section against the plane-file
    size (every entry self-consistent and inside the file)."""
    for path, t in tensors.items():
        try:
            kind, planes = t["kind"], t["planes"]
            if kind not in ("dense", "quantized"):
                raise CkptError(f"{path}: unknown tensor kind {kind!r}")
            if kind == "quantized":
                t["meta"]["bits"], t["stack"], t["outlier_count"]
            for name, e in planes.items():
                n = int(np.prod(e["shape"])) * _np_dtype(e["dtype"]).itemsize
                if n != e["bytes"] or e["offset"] < 0 \
                        or e["offset"] + e["bytes"] > size:
                    raise CkptError(
                        f"bad plane entry {path}:{name}: {e} (file {size} B)")
                if kind == "quantized" and name not in qformat.ENTRY_NAMES:
                    raise CkptError(f"unknown plane name {name!r} at {path} "
                                    "(written by a newer qformat?)")
            missing = _required_planes(t) - set(planes)
            if missing:
                raise CkptError(f"{path}: missing plane(s) "
                                f"{sorted(missing)} (kind={kind})")
        except (KeyError, TypeError) as e:
            raise CkptError(
                f"malformed manifest entry {path}: {e!r}") from e


def _required_planes(t: dict) -> set:
    """The plane names a manifest tensor entry MUST carry (spec'd in
    docs/qformat.md): dense needs ``data``; quantized needs every
    non-optional ``qformat.ENTRY_NAMES`` entry for its bit-width, and the
    residual pair travels together."""
    if t["kind"] != "quantized":
        return {"data"}
    want = {"codes.0", "q_scales", "ss_scale", "ss_zero",
            "q_zeros", "zz_scale", "zz_zero",
            "out_rows", "out_cols", "out_vals"}
    if int(t["meta"]["bits"]) == 3:
        want.add("codes.1")
    if "resid.0" in t["planes"] or "resid_scales" in t["planes"]:
        want |= {"resid.0", "resid_scales"}
    return want


def resolve_config(manifest: dict):
    """Model config recorded in the manifest -> ModelConfig.  Reduced smoke
    configs round-trip through their ``<arch>-smoke`` name."""
    from repro.configs import REGISTRY, get_config, get_smoke
    name = manifest["arch"]
    if name in REGISTRY:
        return get_config(name)
    if name.endswith("-smoke") and name[:-len("-smoke")] in REGISTRY:
        return get_smoke(name[:-len("-smoke")])
    raise CkptError(f"checkpoint arch {name!r} is not in the config "
                    f"registry; available: {sorted(REGISTRY)}")


def quant_config(manifest: dict):
    """QuantConfig recorded in the manifest (None for hand-built trees)."""
    from repro.configs.base import QuantConfig
    if manifest.get("qcfg") is None:
        return None
    return QuantConfig(**manifest["qcfg"])


# --------------------------------------------------------------------------
# abstract tree (no plane reads)
# --------------------------------------------------------------------------

def has_draft(manifest: dict) -> bool:
    """True when the checkpoint packs draft planes beside the target."""
    return "draft" in manifest


def _tensor_section(manifest: dict, which: str) -> dict:
    if which == "target":
        return manifest["tensors"]
    if which == "draft":
        if "draft" not in manifest:
            raise CkptError("checkpoint has no draft planes (re-quantize "
                            "with --draft to pack a speculative drafter)")
        return manifest["draft"]["tensors"]
    raise ValueError(f"which must be 'target' or 'draft', got {which!r}")


def abstract_params(manifest: dict, which: str = "target"):
    """ShapeDtypeStruct tree of the checkpoint, from the manifest alone."""
    def one(t):
        sds = {name: jax.ShapeDtypeStruct(tuple(e["shape"]),
                                          _np_dtype(e["dtype"]))
               for name, e in t["planes"].items()}
        if t["kind"] == "dense":
            return sds["data"]
        return qformat.qt_from_entries(sds, t["meta"])
    return _tree_from_paths(
        [(path, one(t))
         for path, t in _tensor_section(manifest, which).items()])


# --------------------------------------------------------------------------
# load
# --------------------------------------------------------------------------

def _plane_view(mm, entry):
    """Zero-copy typed view of one plane inside the memmap."""
    off, nb = entry["offset"], entry["bytes"]
    return mm[off:off + nb].view(_np_dtype(entry["dtype"])) \
        .reshape(tuple(entry["shape"]))


def load(ckpt_dir: str, plan=None, *, manifest: Optional[dict] = None,
         which: str = "target"):
    """Load a packed checkpoint into a servable param tree.

    Without a plan every plane is copied once memmap -> default device.
    With a ``ShardingPlan`` each plane gets the sharding the plan assigns
    the corresponding fp kernel (``param_shardings`` over the abstract
    tree) and is built shard-by-shard via ``plan.place`` — per device only
    its own slice of the memmap is read.

    ``which="draft"`` loads the co-packed speculative-draft tree instead
    of the calibrated target (CkptError if the checkpoint has none).
    """
    manifest = manifest or load_manifest(ckpt_dir)
    tensors = _tensor_section(manifest, which)
    pf = manifest["plane_file"]
    mm = np.memmap(os.path.join(ckpt_dir, pf["name"]), dtype=np.uint8,
                   mode="r")

    shardings = {}
    if plan is not None:
        sds = abstract_params(manifest, which)
        sh_tree = plan.param_shardings(sds)
        flat, _ = jax.tree_util.tree_flatten_with_path(sh_tree,
                                                       is_leaf=_is_qt)
        for p, leaf in flat:
            shardings[utils.path_str(p)] = leaf

    def materialize(view, sharding):
        if plan is None or sharding is None:
            return jnp.asarray(view)
        return plan.place(sharding, view.shape, view.dtype,
                          lambda idx: view[idx])

    def one(path, t):
        if t["kind"] == "dense":
            return materialize(_plane_view(mm, t["planes"]["data"]),
                               shardings.get(path))
        sh = shardings.get(path)
        sh_by_name = dict(qformat.qt_entries(sh)) if sh is not None else {}
        arrays = {name: materialize(_plane_view(mm, e),
                                    sh_by_name.get(name))
                  for name, e in t["planes"].items()}
        return qformat.qt_from_entries(arrays, t["meta"])

    return _tree_from_paths(
        [(path, one(path, t)) for path, t in tensors.items()])


# --------------------------------------------------------------------------
# prefix-cache warmup (persisted popular prompt-prefix KV blocks)
# --------------------------------------------------------------------------

WARMUP_FORMAT = "oac-warmup"
WARMUP_VERSION = 1
WARMUP_META_NAME = "warmup.json"
WARMUP_NPZ_NAME = "warmup.npz"


def _paged_nodes(engine):
    """(all cache nodes, indices of the paged ones) for the engine's live
    device cache."""
    from repro.serving.engine import PagedKVCache, _cache_nodes
    nodes, _ = _cache_nodes(engine._cache)
    return nodes, [j for j, n in enumerate(nodes)
                   if isinstance(n, PagedKVCache)]


def save_warmup(ckpt_dir: str, engine, *, top: Optional[int] = None) -> int:
    """Persist the engine's ``PrefixCache`` beside the weight planes.

    Each cache entry is one full KV block keyed by the exact token chain
    that produced it; the file stores the chains plus, per paged cache
    node, the pool block contents (and scale planes at ``kv_bits=8``)
    gathered in entry order.  ``top`` keeps only the N most recently
    touched chains — "popular" under the cache's own LRU clock.  Entries
    are written parents-first (shortest chain first) so a loader can
    rebuild the chain structure in one pass.  Returns the entry count.

    Layout: ``warmup.json`` (format/version/arch/block geometry) +
    ``warmup.npz`` (``chain_lens``, concatenated ``chain_tokens``,
    ``node{j}_k/v[/ks/vs]`` arrays), both renamed into place last.
    """
    cache = engine.prefix
    keys = list(cache.entries)
    if top is not None:
        keys.sort(key=lambda k: cache.lru[k], reverse=True)
        keys = keys[:top]
    keys.sort(key=lambda k: (len(k), k))          # parents before children
    ids = np.asarray([cache.entries[k] for k in keys], np.int32)
    chains = [np.frombuffer(k, np.int32) for k in keys]

    nodes, paged = _paged_nodes(engine)
    arrays = {
        "chain_lens": np.asarray([len(c) for c in chains], np.int32),
        "chain_tokens": (np.concatenate(chains) if chains
                         else np.zeros((0,), np.int32)),
    }
    quantized = []
    for j in paged:
        n = nodes[j]
        arrays[f"node{j}_k"] = np.asarray(n.k[:, ids])
        arrays[f"node{j}_v"] = np.asarray(n.v[:, ids])
        quantized.append(bool(n.quantized))
        if n.quantized:
            arrays[f"node{j}_ks"] = np.asarray(n.k_scale[:, ids])
            arrays[f"node{j}_vs"] = np.asarray(n.v_scale[:, ids])

    meta = {
        "format": WARMUP_FORMAT,
        "version": WARMUP_VERSION,
        "arch": engine.cfg.name,
        "block_size": engine.block_size,
        "kv_bits": engine.kv_bits,
        "entries": len(keys),
        "paged_nodes": paged,
        "quantized": quantized,
    }
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, WARMUP_NPZ_NAME + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, os.path.join(ckpt_dir, WARMUP_NPZ_NAME))
    tmp = os.path.join(ckpt_dir, WARMUP_META_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1)
    os.replace(tmp, os.path.join(ckpt_dir, WARMUP_META_NAME))
    return len(keys)


def has_warmup(ckpt_dir: str) -> bool:
    return os.path.exists(os.path.join(ckpt_dir, WARMUP_META_NAME))


def load_warmup(ckpt_dir: str, engine) -> int:
    """Pre-seed a freshly built engine's ``PrefixCache`` from a warmup
    file, so the first clients sharing the persisted prompt prefixes skip
    their prefill from tick one.  Returns the number of blocks seeded.

    Every chain allocates one pool block at its logical position (stripe
    correctness rides on ``engine._alloc_block``), the saved block
    contents scatter into the device pool in one batched update per cache
    node, and the entry registers into ``PrefixCache`` holding the usual
    single cache-owned allocator ref.  Chains whose parent block could
    not be seeded (pool exhausted) are dropped — the cache never holds an
    orphaned child.  Raises ``CkptError`` when the file does not match
    the engine's arch or block geometry.
    """
    mpath = os.path.join(ckpt_dir, WARMUP_META_NAME)
    if not os.path.exists(mpath):
        raise CkptError(f"no {WARMUP_META_NAME} under {ckpt_dir}")
    try:
        with open(mpath) as f:
            meta = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CkptError(f"corrupt warmup meta {mpath}: {e}") from e
    if meta.get("format") != WARMUP_FORMAT or \
            meta.get("version") != WARMUP_VERSION:
        raise CkptError(f"not an {WARMUP_FORMAT} v{WARMUP_VERSION} file: "
                        f"{meta.get('format')!r} v{meta.get('version')!r}")
    nodes, paged = _paged_nodes(engine)
    for field, want in (("arch", engine.cfg.name),
                        ("block_size", engine.block_size),
                        ("kv_bits", engine.kv_bits),
                        ("paged_nodes", paged)):
        if meta.get(field) != want:
            raise CkptError(f"warmup/engine mismatch on {field}: file has "
                            f"{meta.get(field)!r}, engine has {want!r}")
    if not meta["entries"]:
        return 0
    with np.load(os.path.join(ckpt_dir, WARMUP_NPZ_NAME)) as z:
        arrays = {k: z[k] for k in z.files}

    bs = engine.block_size
    lens = arrays["chain_lens"]
    offs = np.concatenate([[0], np.cumsum(lens)])
    chains = [arrays["chain_tokens"][offs[i]:offs[i + 1]]
              for i in range(len(lens))]

    # allocate pool blocks chain-by-chain (file order is parents-first);
    # a chain is only seeded if its parent made it in, and allocation
    # failure (pool smaller than the warmup set) stops cleanly
    seeded: dict = {}                    # key -> (row in file, pool block)
    for row, chain in enumerate(chains):
        if len(chain) % bs or not len(chain):
            raise CkptError(f"warmup chain {row} has {len(chain)} tokens "
                            f"(not a whole number of {bs}-token blocks)")
        key = chain.tobytes()
        lb = len(chain) // bs - 1
        if key in engine.prefix.entries:
            continue
        if lb > 0 and chain[:lb * bs].tobytes() not in \
                set(engine.prefix.entries) | set(seeded):
            continue                     # orphaned child: parent not seeded
        try:
            b = engine._alloc_block(lb)
        except RuntimeError:
            break                        # pool full: keep what fits
        seeded[key] = (row, b)
    if not seeded:
        return 0

    rows = np.asarray([r for r, _ in seeded.values()], np.int32)
    ids = jnp.asarray([b for _, b in seeded.values()])
    from repro.serving.engine import PagedKVCache, _cache_nodes
    nodes, td = _cache_nodes(engine._cache)
    out = list(nodes)
    for j in paged:
        n = nodes[j]
        sc = (None, None)
        if n.quantized:
            sc = (n.k_scale.at[:, ids].set(
                      jnp.asarray(arrays[f"node{j}_ks"][:, rows])),
                  n.v_scale.at[:, ids].set(
                      jnp.asarray(arrays[f"node{j}_vs"][:, rows])))
        out[j] = PagedKVCache(
            n.k.at[:, ids].set(jnp.asarray(arrays[f"node{j}_k"][:, rows])),
            n.v.at[:, ids].set(jnp.asarray(arrays[f"node{j}_v"][:, rows])),
            n.block_tables, *sc)
    engine._cache = jax.tree_util.tree_unflatten(td, out)

    trow = np.full(engine.max_blocks, -1, np.int32)
    for key, (row, b) in seeded.items():
        chain = chains[row]
        lb = len(chain) // bs - 1
        trow[lb] = b
        engine.prefix.insert(chain, trow, lb, lb + 1)
        trow[lb] = -1
        engine.alloc.decref(b)           # cache ref is the only holder now
    return len(seeded)
