"""Quantized-checkpoint serving: convert/abstract params with packed weights.

``quantize_params_rtn`` converts any arch's param tree (works on stacked
layer/expert kernels via vmap) — the zero-calibration path used to exercise
serving.  OAC/SpQR-calibrated packing goes through
``core.pipeline.pack_results``.  ``abstract_quantized_params`` builds the
ShapeDtypeStruct tree for dry-run lowering of w2/w3/w4 serve steps.
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import qformat
from repro.core import quantizers as qz
from repro.models import build_model

# keep these in fp16/bf16: embeddings, lm head (paper keeps them fp16),
# norm/gate scales, and anything that is not a 2-D matmul kernel
_SKIP = ("embed", "lm_head", "norm", "scale", "bias")


def _is_quant_leaf(path: str, leaf=None) -> bool:
    """True iff ``path``/``leaf`` is a packable matmul kernel.

    Requires the exact ``/kernel`` leaf name (a future ``foo_kernel``
    rename cannot match by accident), rejects anything on the skip list
    (embeddings, lm head, norms/scales/biases), and — when the leaf is
    given — rejects sub-2-D arrays outright: 1-D vectors (norm scales,
    biases) are never matmul kernels no matter what they are named."""
    if leaf is not None and getattr(leaf, "ndim", 0) < 2:
        return False
    return path.endswith("/kernel") and not any(s in path for s in _SKIP)


def _alignment_skip(d_in: int, qcfg: QuantConfig) -> str:
    """Why a kernel with contraction dim ``d_in`` stays fp ('' = packable)."""
    if d_in % qcfg.group_size:
        return f"d_in={d_in} not divisible by group_size={qcfg.group_size}"
    if d_in < 2 * qcfg.group_size:
        return f"d_in={d_in} < 2 groups of {qcfg.group_size}"
    return ""


def _quantize_leaf(w, qcfg: QuantConfig):
    """w (..., d_in, d_out) -> stacked QuantizedTensor (leading dims vmapped).
    Callers must pre-check alignment (``_alignment_skip``)."""
    if w.ndim > 2:
        fn = partial(_quantize_leaf, qcfg=qcfg)
        return jax.vmap(fn)(w)
    q, scales, zeros, _ = qz.rtn_quantize(w, qcfg.wbits, qcfg.group_size)
    cap = max(int(qcfg.outlier_capacity * w.size), 8)
    zr = jnp.zeros((cap,), jnp.int32)
    return qformat.make_quantized(
        q, scales, zeros, qcfg.wbits, qcfg.group_size, w.shape,
        zr, zr, jnp.zeros((cap,), jnp.bfloat16),
        stats_bits=qcfg.stats_bits, stats_group=qcfg.stats_group)


def quantize_params_rtn(params, qcfg: QuantConfig,
                        verbose: bool = False) -> Tuple[dict, List[str]]:
    """Replace every eligible kernel with a packed QuantizedTensor (RTN).

    Returns ``(params, skipped_paths)`` — the paths of quantization-eligible
    kernels left in full precision because their contraction dim is
    misaligned with (or too small for) the group size, so callers can see
    exactly which projections still cost fp bytes instead of discovering it
    from a serving-memory regression.  ``verbose`` prints the summary."""
    from repro import utils

    skipped: List[str] = []

    def convert(path, leaf):
        if not (_is_quant_leaf(path, leaf) and hasattr(leaf, "ndim")):
            return leaf
        why = _alignment_skip(leaf.shape[-2], qcfg)
        if why:
            skipped.append(path)
            if verbose:
                print(f"[quantize_params_rtn] skip {path}: {why}")
            return leaf
        return _quantize_leaf(leaf, qcfg)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = [convert(utils.path_str(p), v) for p, v in flat]
    if verbose and skipped:
        print(f"[quantize_params_rtn] {len(skipped)} kernels left fp "
              f"(misaligned/tiny): {skipped}")
    return jax.tree_util.tree_unflatten(treedef, leaves), skipped


def abstract_quantized_params(cfg: ModelConfig,
                              qcfg: QuantConfig = QuantConfig(wbits=2)):
    """ShapeDtypeStruct param tree with packed kernels (dry-run serving)."""
    model = build_model(cfg)
    sds = model.abstract_params(jnp.bfloat16)
    from repro import utils

    def convert(path, leaf):
        if not _is_quant_leaf(path, leaf):
            return leaf
        d_in, d_out = leaf.shape[-2:]
        if _alignment_skip(d_in, qcfg):
            return leaf
        qt = qformat.abstract_quantized(
            d_in, d_out, qcfg.wbits, qcfg.group_size,
            outlier_capacity=qcfg.outlier_capacity,
            stats_bits=qcfg.stats_bits, stats_group=qcfg.stats_group)
        stack = leaf.shape[:-2]
        if stack:
            def add_stack(x):
                return jax.ShapeDtypeStruct(stack + x.shape, x.dtype)
            qt = jax.tree.map(add_stack, qt)
        return qt

    flat, treedef = jax.tree_util.tree_flatten_with_path(sds)
    leaves = [convert(utils.path_str(p), v) for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


dequantize_any = qformat.dequantize_any
