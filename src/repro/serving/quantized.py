"""Quantized-checkpoint serving: convert/abstract params with packed weights.

``quantize_params_rtn`` converts any arch's param tree (works on stacked
layer/expert kernels via vmap) — the zero-calibration path used to exercise
serving.  OAC/SpQR-calibrated packing goes through
``core.pipeline.pack_results``.  ``abstract_quantized_params`` builds the
ShapeDtypeStruct tree for dry-run lowering of w2/w3/w4 serve steps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import qformat
from repro.core import quantizers as qz
from repro.models import build_model

# keep these in fp16/bf16: embeddings, lm head (paper keeps them fp16), and
# anything that is not a 2-D matmul kernel
_SKIP = ("embed", "lm_head")


def _is_quant_leaf(path: str) -> bool:
    return path.endswith("kernel") and not any(s in path for s in _SKIP)


def _quantize_leaf(w, qcfg: QuantConfig):
    """w (..., d_in, d_out) -> stacked QuantizedTensor (leading dims vmapped)."""
    if w.ndim > 2:
        fn = partial(_quantize_leaf, qcfg=qcfg)
        return jax.vmap(fn)(w)
    if w.shape[0] % qcfg.group_size or w.shape[0] < 2 * qcfg.group_size:
        return w  # tiny / misaligned projections stay high precision
    q, scales, zeros, _ = qz.rtn_quantize(w, qcfg.wbits, qcfg.group_size)
    cap = max(int(qcfg.outlier_capacity * w.size), 8)
    zr = jnp.zeros((cap,), jnp.int32)
    return qformat.make_quantized(
        q, scales, zeros, qcfg.wbits, qcfg.group_size, w.shape,
        zr, zr, jnp.zeros((cap,), jnp.bfloat16),
        stats_bits=qcfg.stats_bits, stats_group=qcfg.stats_group)


def quantize_params_rtn(params, qcfg: QuantConfig):
    """Replace every eligible kernel with a packed QuantizedTensor (RTN)."""
    from repro import utils

    def convert(path, leaf):
        if _is_quant_leaf(path) and hasattr(leaf, "ndim") and leaf.ndim >= 2:
            return _quantize_leaf(leaf, qcfg)
        return leaf

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = [convert(utils.path_str(p), v) for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_quantized_params(cfg: ModelConfig,
                              qcfg: QuantConfig = QuantConfig(wbits=2)):
    """ShapeDtypeStruct param tree with packed kernels (dry-run serving)."""
    model = build_model(cfg)
    sds = model.abstract_params(jnp.bfloat16)
    from repro import utils

    def convert(path, leaf):
        if not (_is_quant_leaf(path) and leaf.ndim >= 2):
            return leaf
        d_in, d_out = leaf.shape[-2:]
        if d_in % qcfg.group_size or d_in < 2 * qcfg.group_size:
            return leaf
        qt = qformat.abstract_quantized(
            d_in, d_out, qcfg.wbits, qcfg.group_size,
            outlier_capacity=qcfg.outlier_capacity,
            stats_bits=qcfg.stats_bits, stats_group=qcfg.stats_group)
        stack = leaf.shape[:-2]
        if stack:
            def add_stack(x):
                return jax.ShapeDtypeStruct(stack + x.shape, x.dtype)
            qt = jax.tree.map(add_stack, qt)
        return qt

    flat, treedef = jax.tree_util.tree_flatten_with_path(sds)
    leaves = [convert(utils.path_str(p), v) for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


dequantize_any = qformat.dequantize_any
