"""Batched serving engine: static batching with bulk prefill + lockstep decode.

Requests are grouped into cohorts of equal prompt length (padding-free),
prefilled in one jit'd bulk pass, then decoded in lockstep — one jit'd
decode_step advances the whole batch per tick; finished slots keep decoding
into a discard buffer until the cohort drains (the standard static-batching
serving pattern; per-slot-position continuous batching needs per-row cache
clocks and is noted as future work in DESIGN.md).

Works with dense or OAC-quantized params for every assigned architecture.
Pass a ``repro.dist`` ShardingPlan to run prefill/decode under a mesh
(tensor-parallel serving); without one the engine is single-device.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_tokens: int = 32
    temperature: float = 0.0
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.capacity = capacity
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.key = jax.random.PRNGKey(seed)
        self.ctx = None
        if plan is not None:
            from repro.configs.base import ShapeConfig
            c = plan.ctx(ShapeConfig("serve", capacity, max_batch, "decode"))
            # cohorts may come up smaller than max_batch, so keep the batch
            # replicated: only the params/cache layouts (tp) are pinned here
            self.ctx = dataclasses.replace(c, batch_spec=None)
            self.params = jax.device_put(params, plan.param_shardings(params))
        self._decode = jax.jit(self._with_ctx(self.model.decode_step))
        self._prefill = jax.jit(self._with_ctx(self.model.prefill))
        self._next_rid = 0

    def _with_ctx(self, fn):
        if self.ctx is None:
            return fn

        def wrapped(*args):
            from repro.dist import ctx as dctx
            with dctx.use(self.ctx):
                return fn(*args)
        return wrapped

    def submit(self, prompt, **kw) -> Request:
        r = Request(self._next_rid, np.asarray(prompt, np.int32), **kw)
        self._next_rid += 1
        self.queue.append(r)
        return r

    def _next_cohort(self) -> List[Request]:
        by_len = defaultdict(list)
        for r in self.queue:
            by_len[len(r.prompt)].append(r)
        best = max(by_len.values(), key=len)[:self.max_batch]
        # single-pass partition (repeated list.remove is O(n^2) in queue len)
        chosen = {id(r) for r in best}
        self.queue = [r for r in self.queue if id(r) not in chosen]
        return best

    def _run_cohort(self, cohort: List[Request]):
        B = len(cohort)
        S = len(cohort[0].prompt)
        prompts = jnp.asarray(np.stack([r.prompt for r in cohort]))
        cache = self.model.init_cache(B, self.capacity, dtype=jnp.float32)
        logits, cache, n = self._prefill(self.params,
                                         {"tokens": prompts}, cache)
        logits = logits[:, 0]
        pos = S
        budget = max(r.max_tokens for r in cohort)
        for _ in range(min(budget, self.capacity - S - 1)):
            nxt = np.zeros(B, np.int32)
            for i, r in enumerate(cohort):
                if r.done:
                    continue
                if r.temperature > 0:
                    self.key, sub = jax.random.split(self.key)
                    t = int(jax.random.categorical(
                        sub, logits[i] / r.temperature))
                else:
                    t = int(jnp.argmax(logits[i]))
                r.out.append(t)
                nxt[i] = t
                if (r.eos is not None and t == r.eos) or \
                        len(r.out) >= r.max_tokens:
                    r.done = True
            if all(r.done for r in cohort):
                break
            lg, cache = self._decode(self.params, jnp.asarray(nxt)[:, None],
                                     cache, jnp.asarray(pos))
            logits = lg[:, 0]
            pos += 1
        for r in cohort:
            r.done = True
            self.finished[r.rid] = r

    def run(self):
        while self.queue:
            self._run_cohort(self._next_cohort())
        return self
