"""Batched serving engines: paged + continuous batching + static cohorts.

``Engine`` is a vLLM-style slot-pool scheduler built on the per-row cache
clocks in ``models/attention.py``: the KV cache is one persistent batched
allocation with ``max_batch`` slots, each slot running at its own absolute
position (``pos`` is a (B,) vector through the jit'd decode step).  New
requests are admitted into free slots mid-flight — a B=1 jit'd prefill
(padded to a power-of-two bucket so the jit cache holds O(log L) entries,
not one per distinct prompt length) fills a fresh cache row which is
scattered into the slot's row of the batched cache — and slots retire
independently on EOS / token budget, so a finished request never burns
decode steps into a discard buffer and the next queued request takes its
slot on the same tick.  Sampling (argmax + per-slot-temperature
categorical) runs inside the jit'd decode step; the scheduler syncs
exactly one (B,) token vector per tick instead of issuing a per-request
``int(argmax)`` host round-trip.

``PagedEngine`` replaces the per-slot dense KV rings with a global block
pool (``models/attention.PagedKVCache``): slots hold block *tables*, a
host-side refcounted ``BlockAllocator`` hands out physical blocks on
demand, and a ``PrefixCache`` maps full prompt-prefix blocks (keyed by
their exact token chain) to pool blocks so identical system prompts are
prefilled and stored once — admission reuses full hits and computes only
the private tail (the copy-on-write boundary).  KV memory then scales
with *live tokens*, not ``max_batch x capacity`` worst case.

``StaticEngine`` keeps the old equal-length-cohort lockstep scheduler as
the comparison baseline (``benchmarks/bench_serving.py`` measures all
three).

All engines work with dense or OAC-quantized params for every assigned
architecture.  Pass a ``repro.dist`` ShardingPlan to run prefill/decode
under a mesh (tensor-parallel serving); without one the engine is
single-device.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.attention import KVCache, PagedKVCache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_tokens: int = 32
    temperature: float = 0.0
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # scheduler telemetry (continuous engine): tick of admission/retirement
    # and wall-clock completion offset from run() start (benchmarks).
    admit_tick: int = -1
    finish_tick: int = -1
    finish_wall: float = 0.0


def cache_batch_axes(model, capacity):
    """Per-leaf batch-axis indices for ``model``'s cache pytree, found
    structurally: the one axis whose size changes between init_cache(B=2)
    and init_cache(B=3).  This is what lets any architecture's cache (KV
    stacks, SSM/RWKV states, per-row slot clocks) scatter/gather batch
    rows through one code path."""
    s2 = model.init_cache(2, capacity, abstract=True)
    s3 = model.init_cache(3, capacity, abstract=True)
    return [next(i for i, (a, b) in enumerate(zip(x.shape, y.shape))
                 if a != b)
            for x, y in zip(jax.tree.leaves(s2), jax.tree.leaves(s3))]


def _serve_shape(capacity: int, max_batch: int):
    from repro.configs.base import ShapeConfig
    return ShapeConfig("serve", capacity, max_batch, "decode")


def _sample_tokens(logits, temps, key):
    """Batched on-device sampling: logits (B,V), temps (B,) -> (B,) int32.

    temp == 0 rows take the argmax (bit-identical to the host-side
    ``int(jnp.argmax(...))`` the static engine historically did); temp > 0
    rows draw from categorical(logits / temp) with a per-row key."""
    B = logits.shape[0]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = jax.random.split(key, B)
    safe_t = jnp.maximum(temps, 1e-6)[:, None]
    drawn = jax.vmap(jax.random.categorical)(keys, logits / safe_t)
    return jnp.where(temps > 0, drawn.astype(jnp.int32), greedy)


class BlockAllocator:
    """Host-side refcounted physical-block allocator for the paged pool.

    ``stripes`` > 1 enforces the flash-decode *stripe invariant*: the pool
    is split into ``stripes`` contiguous partitions (matching the tp shards
    of the block-sharded pool) and ``alloc(stripe=t)`` only hands out
    partition-t blocks, so logical block ``lb`` — which the attention
    shard_map assigns to shard ``lb // (max_blocks/T)`` — is always backed
    by that shard's local slab.  The first block of every partition is
    reserved as that shard's write scratch and never allocated.
    """

    def __init__(self, num_blocks: int, block_size: int, stripes: int = 1):
        assert num_blocks % stripes == 0, (num_blocks, stripes)
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.stripes = stripes
        per = num_blocks // stripes
        self.reserved = {t * per for t in range(stripes)}
        # LIFO free lists per stripe (hot blocks reused first)
        self.free = [[b for b in range(t * per, (t + 1) * per)
                      if b not in self.reserved][::-1]
                     for t in range(stripes)]
        self.refcount: Dict[int, int] = {}

    def stripe_of(self, block: int) -> int:
        return block // (self.num_blocks // self.stripes)

    def alloc(self, stripe: int = 0) -> Optional[int]:
        if not self.free[stripe]:
            return None
        b = self.free[stripe].pop()
        self.refcount[b] = 1
        return b

    def incref(self, block: int):
        self.refcount[block] += 1

    def decref(self, block: int):
        self.refcount[block] -= 1
        if self.refcount[block] == 0:
            del self.refcount[block]
            self.free[self.stripe_of(block)].append(block)

    @property
    def blocks_in_use(self) -> int:
        return len(self.refcount)

    @property
    def blocks_free(self) -> int:
        return sum(len(f) for f in self.free)


class PrefixCache:
    """Exact-match prompt-prefix cache: full block -> pool block id.

    An entry's key is the *entire token chain* up to and including that
    block (``prompt[:(j+1)*bs].tobytes()``), so a hit certifies the whole
    prefix matches — KV at position p depends only on tokens 0..p, making
    the cached block's contents bit-identical to a recompute.  The cache
    holds one allocator ref per entry (blocks outlive their requests);
    eviction is leaf-first (never orphan a child's parent chain) and only
    takes entries no live request references (allocator refcount == 1),
    oldest-touched first.
    """

    def __init__(self, alloc: BlockAllocator, block_size: int):
        self.alloc = alloc
        self.bs = block_size
        self.entries: Dict[bytes, int] = {}
        self.kids: Dict[bytes, int] = {}
        self.lru: Dict[bytes, int] = {}
        self._clock = 0

    def _touch(self, key: bytes):
        self._clock += 1
        self.lru[key] = self._clock

    def match(self, prompt: np.ndarray):
        """Longest chain of full-block hits -> (n_blocks, [block ids])."""
        blocks = []
        for j in range(len(prompt) // self.bs):
            key = prompt[:(j + 1) * self.bs].tobytes()
            b = self.entries.get(key)
            if b is None:
                break
            self._touch(key)
            blocks.append(b)
        return len(blocks), blocks

    def insert(self, prompt: np.ndarray, table_row: np.ndarray,
               n_from: int, n_to: int):
        """Register blocks [n_from, n_to) of this prompt's chain (each
        gains a cache-owned allocator ref)."""
        for j in range(n_from, n_to):
            key = prompt[:(j + 1) * self.bs].tobytes()
            b = int(table_row[j])
            if key in self.entries or b < 0:
                continue
            self.entries[key] = b
            self.alloc.incref(b)
            self._touch(key)
            if j > 0:
                pkey = prompt[:j * self.bs].tobytes()
                self.kids[pkey] = self.kids.get(pkey, 0) + 1

    def evict_one(self, stripe: Optional[int] = None) -> bool:
        cands = [(self.lru[k], k) for k, b in self.entries.items()
                 if self.kids.get(k, 0) == 0
                 and self.alloc.refcount.get(b) == 1
                 and (stripe is None or self.alloc.stripe_of(b) == stripe)]
        if not cands:
            return False
        _, key = min(cands)
        b = self.entries.pop(key)
        del self.lru[key]
        if len(key) > self.bs * 4:            # int32 tokens: 4 bytes each
            pkey = key[:-self.bs * 4]
            self.kids[pkey] -= 1
            if not self.kids[pkey]:
                del self.kids[pkey]
        self.alloc.decref(b)
        return True


class _EngineBase:
    """Shared queue/jit plumbing for both schedulers."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.capacity = capacity
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.key = jax.random.PRNGKey(seed)
        self.ctx = None
        if plan is not None:
            c = plan.ctx(_serve_shape(capacity, max_batch))
            # admission batches can be smaller than max_batch, so keep the
            # batch replicated: only the params/cache layouts (tp) are pinned
            self.ctx = dataclasses.replace(c, batch_spec=None)
            self.params = jax.device_put(params, plan.param_shardings(params))
        self._prefill = jax.jit(self._with_ctx(self.model.prefill))
        self._next_rid = 0

    def _with_ctx(self, fn):
        if self.ctx is None:
            return fn

        def wrapped(*args, **kwargs):
            from repro.dist import ctx as dctx
            with dctx.use(self.ctx):
                return fn(*args, **kwargs)
        return wrapped

    def submit(self, prompt, **kw) -> Request:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) >= self.capacity - 1:
            raise ValueError(
                f"prompt of {len(prompt)} tokens does not fit the "
                f"capacity-{self.capacity} cache with room to decode")
        r = Request(self._next_rid, prompt, **kw)
        self._next_rid += 1
        self.queue.append(r)
        return r


class Engine(_EngineBase):
    """Continuous-batching slot-pool scheduler (see module docstring).

    Slot state lives on the host (numpy vectors indexed by slot id); the
    batched cache and the per-row clock vector live on device.  One tick =
    one jit'd decode step over all ``max_batch`` rows; rows whose slot is
    free still flow through the math (their output is discarded and their
    clock does not advance) — with a persistent batched cache this is the
    standard padded-slot trade: the decode step stays one compiled
    executable for the engine's lifetime.
    """

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None):
        super().__init__(cfg, params, max_batch=max_batch, capacity=capacity,
                         seed=seed, plan=plan)
        B = max_batch
        self._slots: List[Optional[Request]] = [None] * B
        self._pos = np.zeros(B, np.int32)        # per-slot cache clock
        self._temps = np.zeros(B, np.float32)
        self._next_tok = np.zeros(B, np.int32)   # token each slot feeds next
        self.ticks = 0
        # bucketed admission keeps the prefill jit cache at O(log L)
        # entries; recurrent families (ssm/hybrid) thread state through
        # every position, so padding would poison their carried state —
        # they prefill at exact length (one compile per distinct length)
        self._bucketable = cfg.family not in ("ssm", "hybrid")
        self.prefill_tokens_computed = 0
        self.prefill_tokens_skipped = 0
        self._cache = self._init_device_cache()
        self._cache_sh = None
        if plan is not None:
            # pin the persistent cache to the plan's layout so per-slot
            # insertion updates in place instead of bouncing the whole
            # cache between layouts every admission
            self._cache_sh = plan.cache_shardings(self._abstract_cache(),
                                                  self.ctx)
            self._cache = jax.device_put(self._cache, self._cache_sh)
        self._insert = self._make_insert(self._cache_sh)
        # the cache is donated through every step so the persistent batched
        # allocation updates in place instead of being copied per tick
        # (same contract as dist.steps.build_step's decode cell)
        self._decode = jax.jit(self._make_decode(), donate_argnums=(2,))
        self._first = jax.jit(_sample_tokens)
        self._score_jit = None      # built lazily on the first score() call

    # ------------------------------------------------------------- jit fns
    def _init_device_cache(self):
        return self.model.init_cache(self.max_batch, self.capacity,
                                     dtype=jnp.float32)

    def _abstract_cache(self):
        return self.model.init_cache(self.max_batch, self.capacity,
                                     abstract=True)

    def _make_decode(self):
        model, with_ctx = self.model, self._with_ctx

        def step(params, tokens, cache, pos, temps, key):
            logits, cache = with_ctx(model.decode_step)(
                params, tokens, cache, pos)
            tok = _sample_tokens(logits[:, 0], temps, key)
            return tok, cache
        return step

    def _make_insert(self, cache_sh=None):
        """jit'd per-slot cache insertion: scatter a B=1 cache row into the
        batched cache at a (traced) slot index, along each leaf's
        structurally-found batch axis (``cache_batch_axes``)."""
        axes = cache_batch_axes(self.model, self.capacity)

        def insert(big, row, slot):
            flat, td = jax.tree.flatten(big)
            rows = jax.tree.leaves(row)
            out = [jax.lax.dynamic_update_slice_in_dim(
                b, r.astype(b.dtype), slot, axis=ax)
                for b, r, ax in zip(flat, rows, axes)]
            return jax.tree.unflatten(td, out)
        if cache_sh is None:
            return jax.jit(insert, donate_argnums=(0,))
        return jax.jit(insert, donate_argnums=(0,), out_shardings=cache_sh)

    # ----------------------------------------------------------- scheduler
    def _free_slots(self):
        return [i for i, s in enumerate(self._slots) if s is None]

    def _retire(self, i: int):
        r = self._slots[i]
        r.done = True
        r.finish_tick = self.ticks
        r.finish_wall = time.perf_counter() - self._t0
        self.finished[r.rid] = r
        self._slots[i] = None

    def _finished_by(self, r: Request, tok: int, pos: int) -> bool:
        return (r.eos is not None and tok == r.eos) or \
            len(r.out) >= r.max_tokens or pos >= self.capacity - 1

    def _bucket(self, S: int) -> int:
        """Power-of-two admission bucket (>= 8, clamped to capacity)."""
        return min(max(8, 1 << (S - 1).bit_length()), self.capacity)

    def _dense_row_prefill(self, r: Request):
        """B=1 prefill into a fresh dense cache row (bucket-padded when
        the family allows).  Returns (logits (1,1,V), row cache)."""
        S = len(r.prompt)
        row = self.model.init_cache(1, self.capacity, dtype=jnp.float32)
        if self._bucketable:
            Sp = self._bucket(S)
            toks = np.zeros((1, Sp), np.int32)
            toks[0, :S] = r.prompt
            logits, row, _ = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, row,
                jnp.asarray(S, jnp.int32))
        else:
            logits, row, _ = self._prefill(
                self.params, {"tokens": jnp.asarray(r.prompt[None])}, row)
        return logits, row

    def _admit_prefill(self, r: Request, i: int):
        """B=1 prefill + scatter the row into slot ``i`` of the batched
        cache.  Returns the (1,1,V) logits of the last prompt position."""
        logits, row = self._dense_row_prefill(r)
        self._cache = self._insert(self._cache, row, i)
        self.prefill_tokens_computed += len(r.prompt)
        return logits

    def _admit(self):
        """Fill free slots from the queue (FIFO): B=1 prefill, scatter the
        row into the batched cache, sample the first token on device."""
        for i in self._free_slots():
            if not self.queue:
                return
            r = self.queue.pop(0)
            S = len(r.prompt)
            logits = self._admit_prefill(r, i)
            self.key, sub = jax.random.split(self.key)
            t = int(self._first(logits[:, 0],
                                jnp.full((1,), r.temperature, jnp.float32),
                                sub)[0])
            r.out.append(t)
            r.admit_tick = self.ticks
            if self._finished_by(r, t, S):
                self._slots[i] = r
                self._retire(i)
                continue
            self._slots[i] = r
            self._pos[i] = S
            self._temps[i] = r.temperature
            self._next_tok[i] = t

    def _pre_tick(self, active):
        """Hook before the device step (paged engine maps write blocks)."""

    def _decode_extra_args(self):
        """Extra trailing args for the jit'd decode step (paged: tables)."""
        return ()

    def _tick(self):
        """One lockstep device step for every slot; one host sync."""
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return
        self._pre_tick(active)
        self.key, sub = jax.random.split(self.key)
        toks, self._cache = self._decode(
            self.params, jnp.asarray(self._next_tok[:, None]), self._cache,
            jnp.asarray(self._pos), jnp.asarray(self._temps), sub,
            *self._decode_extra_args())
        toks = np.asarray(toks)                  # the tick's single sync
        self.ticks += 1
        for i in active:
            r = self._slots[i]
            t = int(toks[i])
            r.out.append(t)
            self._pos[i] += 1
            self._next_tok[i] = t
            if self._finished_by(r, t, int(self._pos[i])):
                self._retire(i)

    def run(self):
        self._t0 = time.perf_counter()
        while self.queue or any(s is not None for s in self._slots):
            self._admit()
            self._tick()
        return self

    # ------------------------------------------------- teacher-forced score
    def _make_score(self):
        """jit'd teacher-forced step: decode through the engine's serving
        path (paged tables / int8 KV / fused dequant ride along via
        ``*extra``), then per-row NLL of the forced target + greedy
        argmax.  The metric math is shared with ``eval.metrics`` so the
        engine and the dense reference apply bit-identical ops."""
        from repro.eval.metrics import nll_greedy
        model, with_ctx = self.model, self._with_ctx

        def step(params, tokens, targets, cache, pos, *extra):
            logits, cache = with_ctx(model.decode_step)(
                params, tokens, cache, pos, *extra)
            nll, greedy = nll_greedy(logits[:, 0], targets)
            return nll, greedy, cache
        return step

    def _score_cleanup(self, n: int):
        """Reset slot state after a scoring chunk (paged: drop blocks)."""
        self._pos[:] = 0
        self._next_tok[:] = 0
        self._temps[:] = 0.0

    def score(self, tokens) -> Dict[str, np.ndarray]:
        """Teacher-forced scoring of ``tokens (B, S)`` through the *real*
        serving path: rows are admitted like requests (bucketed B=1
        prefill of the first token; the paged engine allocates pool
        blocks and, at ``kv_bits=8``, packs int8 KV) and then advanced in
        lockstep jit'd decode steps that feed the ground-truth token and
        return the NLL of the next one — so quality eval exercises paged
        KV, block tables, and the fused dequant decode cells exactly as
        production decode does, instead of a bare ``model.apply``.

        Returns ``{"nll": (B, S-1) float32, "greedy": (B, S-1) int32}``:
        ``nll[:, t]`` is -log p(tokens[:, t+1] | tokens[:, :t+1]) and
        ``greedy[:, t]`` the argmax prediction at that position.  The
        engine must be idle; rows are scored in chunks of ``max_batch``.
        """
        from repro.eval.metrics import nll_greedy
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 2 or tokens.shape[1] < 2:
            raise ValueError(f"score() takes (B, S>=2) tokens, "
                             f"got {tokens.shape}")
        B, S = tokens.shape
        if S > self.capacity:
            raise ValueError(f"sequence length {S} exceeds the "
                             f"capacity-{self.capacity} cache")
        if self.queue or any(s is not None for s in self._slots):
            raise RuntimeError("score() requires an idle engine "
                               "(no queued or in-flight requests)")
        if self._score_jit is None:
            self._score_jit = jax.jit(self._make_score(),
                                      donate_argnums=(3,))
            self._first_score = jax.jit(nll_greedy)
        nll = np.zeros((B, S - 1), np.float32)
        greedy = np.zeros((B, S - 1), np.int32)
        for c0 in range(0, B, self.max_batch):
            rows = list(range(c0, min(c0 + self.max_batch, B)))
            n = len(rows)
            # admit each row with a 1-token prompt through the standard
            # admission path (prefix sharing is a no-op at S=1, so the
            # score never reads another request's cached blocks)
            first = []
            for k, i in enumerate(rows):
                r = Request(rid=-(i + 1), prompt=tokens[i, :1])
                first.append(self._admit_prefill(r, k)[:, 0])
                self._pos[k] = 1
            nll0, g0 = self._first_score(jnp.concatenate(first, axis=0),
                                         jnp.asarray(tokens[rows, 1]))
            nll[rows, 0] = np.asarray(nll0)
            greedy[rows, 0] = np.asarray(g0)
            active = list(range(n))
            for t in range(1, S - 1):
                tok = np.zeros((self.max_batch, 1), np.int32)
                tok[:n, 0] = tokens[rows, t]
                tgt = np.zeros((self.max_batch,), np.int32)
                tgt[:n] = tokens[rows, t + 1]
                self._pre_tick(active)
                nll_t, g_t, self._cache = self._score_jit(
                    self.params, jnp.asarray(tok), jnp.asarray(tgt),
                    self._cache, jnp.asarray(self._pos),
                    *self._decode_extra_args())
                nll[rows, t] = np.asarray(nll_t)[:n]
                greedy[rows, t] = np.asarray(g_t)[:n]
                self._pos[:n] += 1
            self._score_cleanup(n)
        return {"nll": nll, "greedy": greedy}


def _cache_nodes(tree):
    """Flatten a model cache pytree at cache-node granularity (KVCache /
    PagedKVCache stay whole; SSM/RWKV states recurse to arrays)."""
    return jax.tree.flatten(
        tree, is_leaf=lambda n: isinstance(n, (KVCache, PagedKVCache)))


class PagedEngine(Engine):
    """Slot-pool scheduler over a paged KV pool with prefix sharing.

    Inherits the whole continuous-batching scheduler from ``Engine`` and
    swaps the storage layer: full-context KV lives in a global block pool,
    slots hold host-side block tables (passed into the jit'd decode step
    each tick, so allocation is pure host bookkeeping), and blocks are
    refcounted so identical prompt prefixes are stored once.

    Admission policy (uniform-attention families):
      1. hash the prompt's full blocks against the ``PrefixCache`` and take
         the longest chain of hits, capped at the last block boundary
         <= S-1 (at least one suffix token must run to produce the first
         logits);
      2. the shared blocks are mapped read-only into the slot's table
         (+1 ref each) and their prefill is *skipped entirely*;
      3. the remaining tail is computed by ``Model.prefill_suffix`` into
         freshly-owned blocks — the copy-on-write boundary: partial blocks
         are never shared in place, a private copy is always materialized
         (as a recompute, which is cheaper than copy + it is needed for
         the first-token logits anyway);
      4. the prompt's full blocks are registered back into the cache.
    Decode writes only ever touch private blocks (positions >= S land past
    every shared full block); ``_ensure_block`` still guards the invariant
    with a device block copy should a shared block become a write target.
    Grouped-local / hybrid / ssm families admit through the dense-row
    prefill and pack the row into pool blocks (their window rings and
    recurrent state are per-row and unshareable — see ``Model.init_cache``).
    Retirement drops one ref per mapped block; blocks whose refs hit zero
    return to the pool, so capacity is freed per-block, not per-slot.

    ``kv_bits=8`` stores the pool as int8 codes + per-(token, kv-head)
    scale planes (``qserve.kvquant``): admission packs quantize the fp
    dense-row KV, decode writes quantize per token, attention dequantizes
    on read — ~0.56x fp16 KV bytes/request with a documented logit
    tolerance (DESIGN.md §Quantized serving).
    """

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 share_prefixes: bool = True, kv_bits: int = 16):
        assert capacity % block_size == 0, (capacity, block_size)
        assert kv_bits in (16, 8), kv_bits
        self.kv_bits = kv_bits
        self.block_size = block_size
        self.max_blocks = capacity // block_size
        stripes = 1
        if plan is not None:
            shp = _serve_shape(capacity, max_batch)
            if plan.ctx(shp).attn_decode_mode == "flash":
                stripes = plan.tp_size
                assert self.max_blocks % stripes == 0, \
                    (self.max_blocks, stripes)
        if num_blocks is None:
            # safe default: worst case + one scratch per stripe (no memory
            # win — pass a smaller pool to oversubscribe; the benchmark
            # reports the blocks actually touched either way)
            num_blocks = max_batch * self.max_blocks + stripes
        num_blocks += (-num_blocks) % stripes
        self.num_blocks = num_blocks
        self.alloc = BlockAllocator(num_blocks, block_size, stripes=stripes)
        self.prefix = PrefixCache(self.alloc, block_size)
        self._tables = np.full((max_batch, self.max_blocks), -1, np.int32)
        self.shared_block_hits = 0
        self.cow_copies = 0
        self.peak_blocks_in_use = 0
        self.blocks_held_at_retire: List[int] = []
        super().__init__(cfg, params, max_batch=max_batch,
                         capacity=capacity, seed=seed, plan=plan)
        nodes, _ = _cache_nodes(self._abstract_cache())
        self._has_paged = any(isinstance(n, PagedKVCache) for n in nodes)
        self._share = (share_prefixes and self._has_paged
                       and cfg.family in ("dense", "moe")
                       and not self.model._grouped_local())
        self._sfx_jits: Dict[int, object] = {}
        self._copy_block = jax.jit(self._make_copy_block(),
                                   donate_argnums=(0,))

    # ------------------------------------------------------------- jit fns
    def _init_device_cache(self):
        return self.model.init_cache(
            self.max_batch, self.capacity, dtype=jnp.float32, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits)

    def _abstract_cache(self):
        return self.model.init_cache(
            self.max_batch, self.capacity, abstract=True, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits)

    def _make_decode(self):
        model, with_ctx = self.model, self._with_ctx

        def step(params, tokens, cache, pos, temps, key, block_tables):
            logits, cache = with_ctx(model.decode_step)(
                params, tokens, cache, pos, block_tables)
            tok = _sample_tokens(logits[:, 0], temps, key)
            return tok, cache
        return step

    def _make_copy_block(self):
        def copy_one(n, src, dst):
            sc = (None, None)
            if n.quantized:              # scale planes ride with the codes
                sc = (n.k_scale.at[:, dst].set(n.k_scale[:, src]),
                      n.v_scale.at[:, dst].set(n.v_scale[:, src]))
            return PagedKVCache(n.k.at[:, dst].set(n.k[:, src]),
                                n.v.at[:, dst].set(n.v[:, src]),
                                n.block_tables, *sc)

        def copy(cache, src, dst):
            nodes, td = _cache_nodes(cache)
            out = [copy_one(n, src, dst)
                   if isinstance(n, PagedKVCache) else n for n in nodes]
            return jax.tree.unflatten(td, out)
        return copy

    def _make_insert(self, cache_sh=None):
        """jit'd pack of a B=1 *dense-row* prefill into the paged cache:
        paged nodes scatter whole blocks into the pool via the slot's
        table (unmapped entries spill to the scratch block), dense nodes
        (local rings, recurrent state, row clocks) scatter along their
        structurally-found batch axis exactly as the dense engine does."""
        big2, _ = _cache_nodes(self.model.init_cache(
            2, self.capacity, abstract=True, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits))
        big3, _ = _cache_nodes(self.model.init_cache(
            3, self.capacity, abstract=True, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits))
        axes = [None if isinstance(a, PagedKVCache) else jax.tree.map(
            lambda x, y: next(i for i, (p, q) in
                              enumerate(zip(x.shape, y.shape)) if p != q),
            a, b) for a, b in zip(big2, big3)]
        bs, nblk = self.block_size, self.max_blocks

        def insert(big, row, slot, table_row):
            bn, td = _cache_nodes(big)
            rn, _ = _cache_nodes(row)
            safe = jnp.where(table_row >= 0, table_row, 0)
            out = []
            for node, rnode, ax in zip(bn, rn, axes):
                if isinstance(node, PagedKVCache):
                    def pack(pool, scplane, rowkv):
                        # pool (n, nb, bs, KV, hd); rowkv (n, 1, cap, KV, hd)
                        # unmapped blocks collapse onto the never-read
                        # scratch block: no read-back select needed; int8
                        # pools quantize the fp dense-row KV on the way in
                        n = pool.shape[0]
                        vals = rowkv[:, 0].reshape(
                            n, nblk, bs, *pool.shape[3:])
                        if scplane is None:
                            return pool.at[:, safe].set(
                                vals.astype(pool.dtype)), None
                        from repro.serving.qserve import kvquant as KQ
                        q, s = KQ.quantize_kv(vals)
                        return (pool.at[:, safe].set(q),
                                scplane.at[:, safe].set(s))
                    bt2 = node.block_tables.at[slot].set(table_row)
                    kq, ks = pack(node.k, node.k_scale, rnode.k)
                    vq, vs = pack(node.v, node.v_scale, rnode.v)
                    out.append(PagedKVCache(kq, vq, bt2, ks, vs))
                else:
                    out.append(jax.tree.map(
                        lambda b, r, a: jax.lax.dynamic_update_slice_in_dim(
                            b, r.astype(b.dtype), slot, axis=a),
                        node, rnode, ax))
            return jax.tree.unflatten(td, out)
        if cache_sh is None:
            return jax.jit(insert, donate_argnums=(0,))
        return jax.jit(insert, donate_argnums=(0,), out_shardings=cache_sh)

    def _sfx_jit(self, n_shared: int):
        """Per-``n_shared`` jit of the prefix-shared suffix prefill (the
        suffix pads to bucket lengths, so each (n_shared, bucket) pair
        compiles once)."""
        fn = self._sfx_jits.get(n_shared)
        if fn is None:
            model, with_ctx = self.model, self._with_ctx

            def sfx(params, tokens, cache, table_row, valid_len):
                return with_ctx(model.prefill_suffix)(
                    params, tokens, cache, table_row, valid_len,
                    n_shared=n_shared)
            kw = {} if self._cache_sh is None else \
                {"out_shardings": (None, self._cache_sh)}
            fn = jax.jit(sfx, donate_argnums=(2,), **kw)
            self._sfx_jits[n_shared] = fn
        return fn

    # ----------------------------------------------------- block management
    def _alloc_block(self, lb: int) -> int:
        stripe = 0 if self.alloc.stripes == 1 else \
            lb // (self.max_blocks // self.alloc.stripes)
        b = self.alloc.alloc(stripe)
        while b is None and self.prefix.evict_one(stripe):
            b = self.alloc.alloc(stripe)
        if b is None:
            raise RuntimeError(
                f"KV block pool exhausted ({self.num_blocks} blocks, "
                f"{self.alloc.blocks_in_use} live): admit fewer requests "
                f"or grow num_blocks (preemption is future work)")
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.alloc.blocks_in_use)
        return b

    def _ensure_block(self, i: int, pos: int):
        """Map the block that position ``pos`` will write this tick.
        Shared targets get a private copy first (copy-on-write) — by
        policy decode never writes a shared full block, but the refcount
        guard keeps the invariant local, not global."""
        lb = pos // self.block_size
        if lb >= self.max_blocks:
            return
        b = int(self._tables[i, lb])
        if b < 0:
            self._tables[i, lb] = self._alloc_block(lb)
        elif self.alloc.refcount[b] > 1:
            nb = self._alloc_block(lb)
            self._cache = self._copy_block(self._cache, jnp.asarray(b),
                                           jnp.asarray(nb))
            self.alloc.decref(b)
            self._tables[i, lb] = nb
            self.cow_copies += 1

    # ----------------------------------------------------------- scheduler
    def _release_row(self, trow):
        """Drop this row's ref on every mapped block (failed admission /
        retirement)."""
        for b in trow[trow >= 0]:
            self.alloc.decref(int(b))

    def _admit_prefill(self, r: Request, i: int):
        if not self._share:
            # dense-row prefill (bucketed when the family allows), then
            # pack the row's full-context KV into freshly-owned blocks
            S = len(r.prompt)
            logits, row = self._dense_row_prefill(r)
            trow = np.full(self.max_blocks, -1, np.int32)
            if self._has_paged:
                try:
                    for j in range(-(-S // self.block_size)):
                        trow[j] = self._alloc_block(j)
                except RuntimeError:
                    # release partial acquisitions and put the request
                    # back so a catcher can drain slots and retry
                    self._release_row(trow)
                    self.queue.insert(0, r)
                    raise
            self._cache = self._insert(self._cache, row, i,
                                       jnp.asarray(trow))
            self._tables[i] = trow
            self.prefill_tokens_computed += S
            return logits
        # ---- prefix-shared admission (uniform-attention families)
        bs = self.block_size
        S = len(r.prompt)
        n_shared, shared = self.prefix.match(r.prompt)
        n_shared = min(n_shared, (S - 1) // bs)   # >= 1 suffix token
        shared = shared[:n_shared]
        suffix = r.prompt[n_shared * bs:]
        Ssfx = len(suffix)
        # the suffix pads to a bucket for the jit cache, but only blocks
        # covering *real* tokens are allocated — prefill_suffix spills the
        # pad region's writes to the scratch block, and decode growth maps
        # later blocks on demand
        Sp = min(self._bucket(Ssfx), self.capacity - n_shared * bs)
        Sp += (-Sp) % bs                          # whole blocks
        trow = np.full(self.max_blocks, -1, np.int32)
        try:
            for j, b in enumerate(shared):
                self.alloc.incref(b)
                trow[j] = b
            for j in range(n_shared, n_shared + -(-Ssfx // bs)):
                trow[j] = self._alloc_block(j)
        except RuntimeError:
            self._release_row(trow)
            self.queue.insert(0, r)
            raise
        toks = np.zeros((1, Sp), np.int32)
        toks[0, :Ssfx] = suffix
        logits, self._cache = self._sfx_jit(n_shared)(
            self.params, jnp.asarray(toks), self._cache, jnp.asarray(trow),
            jnp.asarray(Ssfx, jnp.int32))
        self._tables[i] = trow
        # register this prompt's newly-computed full blocks for reuse
        self.prefix.insert(r.prompt, trow, n_shared, S // bs)
        self.prefill_tokens_skipped += n_shared * bs
        self.shared_block_hits += n_shared
        self.prefill_tokens_computed += Ssfx
        return logits

    def _retire(self, i: int):
        if self._has_paged:
            self.blocks_held_at_retire.append(
                int((self._tables[i] >= 0).sum()))
            self._release_row(self._tables[i])
            self._tables[i] = -1
        super()._retire(i)

    def _score_cleanup(self, n: int):
        if self._has_paged:
            for k in range(n):
                self._release_row(self._tables[k])
                self._tables[k] = -1
        super()._score_cleanup(n)

    def _pre_tick(self, active):
        if self._has_paged:
            for i in active:
                self._ensure_block(i, int(self._pos[i]))

    def _decode_extra_args(self):
        # Bound the per-tick table view to the live logical depth: the decode
        # gather touches max_blocks*block_size rows otherwise, even when every
        # sequence is ten tokens deep.  Width is bucketed to powers of two
        # (floor 4) so jit retraces O(log max_blocks) times, not per step; the
        # model stores the cache-resident full-width table back into the
        # returned cache (see transformer._paged_store_tables), so narrowing
        # never changes donated cache leaf shapes.  Flash-striped pools
        # (stripes > 1) keep the full table: the stripe invariant addresses
        # the whole logical range on every shard.
        tables = self._tables
        if self._has_paged and self.alloc.stripes == 1:
            live = np.flatnonzero((tables >= 0).any(axis=0))
            deep = int(live[-1]) + 1 if live.size else 1
            w = 4
            while w < deep:
                w *= 2
            tables = tables[:, :min(w, self.max_blocks)]
        return (jnp.asarray(tables),)


class StaticEngine(_EngineBase):
    """Static batching: equal-length cohorts, bulk prefill, lockstep decode.

    One jit'd decode_step advances the whole cohort per tick; finished slots
    keep decoding into a discard buffer until the cohort drains, and queued
    requests wait for the next cohort.  Kept as the baseline the continuous
    engine is measured against (and stays bit-identical to, for greedy)."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None):
        super().__init__(cfg, params, max_batch=max_batch, capacity=capacity,
                         seed=seed, plan=plan)
        self._decode = jax.jit(self._with_ctx(self.model.decode_step))

    def _next_cohort(self) -> List[Request]:
        by_len = defaultdict(list)
        for r in self.queue:
            by_len[len(r.prompt)].append(r)
        best = max(by_len.values(), key=len)[:self.max_batch]
        # single-pass partition (repeated list.remove is O(n^2) in queue len)
        chosen = {id(r) for r in best}
        self.queue = [r for r in self.queue if id(r) not in chosen]
        return best

    def _run_cohort(self, cohort: List[Request]):
        B = len(cohort)
        S = len(cohort[0].prompt)
        prompts = jnp.asarray(np.stack([r.prompt for r in cohort]))
        cache = self.model.init_cache(B, self.capacity, dtype=jnp.float32)
        logits, cache, n = self._prefill(self.params,
                                         {"tokens": prompts}, cache)
        logits = logits[:, 0]
        pos = S
        budget = max(r.max_tokens for r in cohort)
        for _ in range(min(budget, self.capacity - S - 1)):
            nxt = np.zeros(B, np.int32)
            for i, r in enumerate(cohort):
                if r.done:
                    continue
                if r.temperature > 0:
                    self.key, sub = jax.random.split(self.key)
                    t = int(jax.random.categorical(
                        sub, logits[i] / r.temperature))
                else:
                    t = int(jnp.argmax(logits[i]))
                r.out.append(t)
                nxt[i] = t
                if (r.eos is not None and t == r.eos) or \
                        len(r.out) >= r.max_tokens:
                    r.done = True
            if all(r.done for r in cohort):
                break
            lg, cache = self._decode(self.params, jnp.asarray(nxt)[:, None],
                                     cache, jnp.asarray(pos))
            logits = lg[:, 0]
            pos += 1
        now = time.perf_counter() - self._t0
        for r in cohort:
            r.done = True
            r.finish_wall = now
            self.finished[r.rid] = r

    def run(self):
        self._t0 = time.perf_counter()
        while self.queue:
            self._run_cohort(self._next_cohort())
        return self
