"""Batched serving engines: paged + continuous batching + static cohorts.

``Engine`` is a vLLM-style slot-pool scheduler built on the per-row cache
clocks in ``models/attention.py``: the KV cache is one persistent batched
allocation with ``max_batch`` slots, each slot running at its own absolute
position (``pos`` is a (B,) vector through the jit'd decode step).  New
requests are admitted into free slots mid-flight — a B=1 jit'd prefill
(padded to a power-of-two bucket so the jit cache holds O(log L) entries,
not one per distinct prompt length) fills a fresh cache row which is
scattered into the slot's row of the batched cache — and slots retire
independently on EOS / token budget, so a finished request never burns
decode steps into a discard buffer and the next queued request takes its
slot on the same tick.  Sampling (argmax + per-slot-temperature
categorical) runs inside the jit'd decode step; the scheduler syncs
exactly one (B,) token vector per tick instead of issuing a per-request
``int(argmax)`` host round-trip.

``PagedEngine`` replaces the per-slot dense KV rings with a global block
pool (``models/attention.PagedKVCache``): slots hold block *tables*, a
host-side refcounted ``BlockAllocator`` hands out physical blocks on
demand, and a ``PrefixCache`` maps full prompt-prefix blocks (keyed by
their exact token chain) to pool blocks so identical system prompts are
prefilled and stored once — admission reuses full hits and computes only
the private tail (the copy-on-write boundary).  KV memory then scales
with *live tokens*, not ``max_batch x capacity`` worst case.

``StaticEngine`` keeps the old equal-length-cohort lockstep scheduler as
the comparison baseline (``benchmarks/bench_serving.py`` measures all
three).

All engines work with dense or OAC-quantized params for every assigned
architecture.  Pass a ``repro.dist`` ShardingPlan to run prefill/decode
under a mesh (tensor-parallel serving); without one the engine is
single-device.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.models import build_model
from repro.models.attention import KVCache, PagedKVCache


# SLO classes order both admission and preemption: `interactive` admits
# first and is preempted last; `batch` makes way.  Lower rank = higher
# priority.
SLO_RANK = {"interactive": 0, "batch": 1}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_tokens: int = 32
    temperature: float = 0.0
    eos: Optional[int] = None
    # per-request sampling stream: temp > 0 draws are keyed by
    # fold_in(PRNGKey(seed), n_tokens_sampled) so the stream depends only
    # on this request, never on which other slots are co-batched.  None
    # derives a default from (engine seed, rid).
    seed: Optional[int] = None
    slo: str = "interactive"           # SLO class (see SLO_RANK)
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # set by Engine.cancel (client disconnect / shed): the request was
    # aborted before EOS/budget and its slot+blocks were released
    cancelled: bool = False
    # scheduler telemetry (continuous engine): tick of admission/retirement
    # and wall-clock completion offset from run() start (benchmarks).
    admit_tick: int = -1
    finish_tick: int = -1
    finish_wall: float = 0.0
    # wall-clock offset of every emitted token (inter-token latency bench)
    token_times: List[float] = dataclasses.field(default_factory=list)
    # scheduler-internal: admission backoff + preemption swap state
    _backoff: int = 0
    _not_before: int = 0               # admission-clock gate after requeue
    _admit_seq: int = 0                # admission order (preemption victim)
    _swap: Optional[tuple] = None      # host-side swapped-out cache state
    # open tracer span ids for this request's lifecycle timeline
    _spans: Dict = dataclasses.field(default_factory=dict, repr=False)


def cache_batch_axes(model, capacity):
    """Per-leaf batch-axis indices for ``model``'s cache pytree, found
    structurally: the one axis whose size changes between init_cache(B=2)
    and init_cache(B=3).  This is what lets any architecture's cache (KV
    stacks, SSM/RWKV states, per-row slot clocks) scatter/gather batch
    rows through one code path."""
    s2 = model.init_cache(2, capacity, abstract=True)
    s3 = model.init_cache(3, capacity, abstract=True)
    return [next(i for i, (a, b) in enumerate(zip(x.shape, y.shape))
                 if a != b)
            for x, y in zip(jax.tree.leaves(s2), jax.tree.leaves(s3))]


def _serve_shape(capacity: int, max_batch: int):
    from repro.configs.base import ShapeConfig
    return ShapeConfig("serve", capacity, max_batch, "decode")


def _sample_tokens(logits, temps, seeds, steps):
    """Batched on-device sampling: logits (B,V), temps (B,) -> (B,) int32.

    temp == 0 rows take the argmax (bit-identical to the host-side
    ``int(jnp.argmax(...))`` the static engine historically did); temp > 0
    rows draw from categorical(logits / temp) keyed by
    ``fold_in(PRNGKey(seeds[b]), steps[b])`` — the draw at a request's
    n-th sampled token is a pure function of (its seed, n), so sampled
    output is reproducible per request regardless of co-batching, tick
    count, or which engine instance serves it."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.maximum(temps, 1e-6)[:, None]

    def draw(seed, step, lg):
        return jax.random.categorical(
            jax.random.fold_in(jax.random.PRNGKey(seed), step), lg)

    drawn = jax.vmap(draw)(seeds, steps, logits / safe_t)
    return jnp.where(temps > 0, drawn.astype(jnp.int32), greedy)


class BlockAllocator:
    """Host-side refcounted physical-block allocator for the paged pool.

    ``stripes`` > 1 enforces the flash-decode *stripe invariant*: the pool
    is split into ``stripes`` contiguous partitions (matching the tp shards
    of the block-sharded pool) and ``alloc(stripe=t)`` only hands out
    partition-t blocks, so logical block ``lb`` — which the attention
    shard_map assigns to shard ``lb // (max_blocks/T)`` — is always backed
    by that shard's local slab.  The first block of every partition is
    reserved as that shard's write scratch and never allocated.
    """

    def __init__(self, num_blocks: int, block_size: int, stripes: int = 1,
                 metrics: Optional[Dict] = None):
        assert num_blocks % stripes == 0, (num_blocks, stripes)
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.stripes = stripes
        per = num_blocks // stripes
        self.reserved = {t * per for t in range(stripes)}
        # LIFO free lists per stripe (hot blocks reused first)
        self.free = [[b for b in range(t * per, (t + 1) * per)
                      if b not in self.reserved][::-1]
                     for t in range(stripes)]
        self.refcount: Dict[int, int] = {}
        # obs handles: {"alloc": Counter, "free": Counter,
        #               "in_use": Gauge, "occupancy": Gauge}
        self._m = metrics

    def _obs_pool(self):
        if self._m is not None:
            live = len(self.refcount)
            self._m["in_use"].set(live)
            self._m["occupancy"].set(
                live / max(1, self.num_blocks - len(self.reserved)))

    def stripe_of(self, block: int) -> int:
        return block // (self.num_blocks // self.stripes)

    def alloc(self, stripe: int = 0) -> Optional[int]:
        if not self.free[stripe]:
            return None
        b = self.free[stripe].pop()
        self.refcount[b] = 1
        if self._m is not None:
            self._m["alloc"].inc()
            self._obs_pool()
        return b

    def incref(self, block: int):
        self.refcount[block] += 1

    def decref(self, block: int):
        self.refcount[block] -= 1
        if self.refcount[block] == 0:
            del self.refcount[block]
            self.free[self.stripe_of(block)].append(block)
            if self._m is not None:
                self._m["free"].inc()
                self._obs_pool()

    @property
    def blocks_in_use(self) -> int:
        return len(self.refcount)

    @property
    def blocks_free(self) -> int:
        return sum(len(f) for f in self.free)


class PrefixCache:
    """Exact-match prompt-prefix cache: full block -> pool block id.

    An entry's key is the *entire token chain* up to and including that
    block (``prompt[:(j+1)*bs].tobytes()``), so a hit certifies the whole
    prefix matches — KV at position p depends only on tokens 0..p, making
    the cached block's contents bit-identical to a recompute.  The cache
    holds one allocator ref per entry (blocks outlive their requests);
    eviction is leaf-first (never orphan a child's parent chain) and only
    takes entries no live request references (allocator refcount == 1),
    oldest-touched first.
    """

    def __init__(self, alloc: BlockAllocator, block_size: int,
                 metrics: Optional[Dict] = None):
        self.alloc = alloc
        self.bs = block_size
        self.entries: Dict[bytes, int] = {}
        self.kids: Dict[bytes, int] = {}
        self.lru: Dict[bytes, int] = {}
        self._clock = 0
        # obs handles: {"hit", "miss", "insert", "evict"} counters.  hit
        # counts matched blocks, miss counts failed full-block lookups, so
        # hit / (hit + miss) is a rate in [0, 1].
        self._m = metrics

    def _touch(self, key: bytes):
        self._clock += 1
        self.lru[key] = self._clock

    def match(self, prompt: np.ndarray):
        """Longest chain of full-block hits -> (n_blocks, [block ids])."""
        blocks = []
        for j in range(len(prompt) // self.bs):
            key = prompt[:(j + 1) * self.bs].tobytes()
            b = self.entries.get(key)
            if b is None:
                if self._m is not None:
                    self._m["miss"].inc()
                break
            self._touch(key)
            blocks.append(b)
        if self._m is not None and blocks:
            self._m["hit"].inc(len(blocks))
        return len(blocks), blocks

    def insert(self, prompt: np.ndarray, table_row: np.ndarray,
               n_from: int, n_to: int):
        """Register blocks [n_from, n_to) of this prompt's chain (each
        gains a cache-owned allocator ref)."""
        for j in range(n_from, n_to):
            key = prompt[:(j + 1) * self.bs].tobytes()
            b = int(table_row[j])
            if key in self.entries or b < 0:
                continue
            self.entries[key] = b
            self.alloc.incref(b)
            self._touch(key)
            if self._m is not None:
                self._m["insert"].inc()
            if j > 0:
                pkey = prompt[:j * self.bs].tobytes()
                self.kids[pkey] = self.kids.get(pkey, 0) + 1

    def evict_one(self, stripe: Optional[int] = None) -> bool:
        cands = [(self.lru[k], k) for k, b in self.entries.items()
                 if self.kids.get(k, 0) == 0
                 and self.alloc.refcount.get(b) == 1
                 and (stripe is None or self.alloc.stripe_of(b) == stripe)]
        if not cands:
            return False
        _, key = min(cands)
        b = self.entries.pop(key)
        del self.lru[key]
        if len(key) > self.bs * 4:            # int32 tokens: 4 bytes each
            pkey = key[:-self.bs * 4]
            self.kids[pkey] -= 1
            if not self.kids[pkey]:
                del self.kids[pkey]
        self.alloc.decref(b)
        if self._m is not None:
            self._m["evict"].inc()
        return True


class _EngineBase:
    """Shared queue/jit plumbing for both schedulers."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None, obs=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.capacity = capacity
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.key = jax.random.PRNGKey(seed)
        # engines default to a fresh enabled bundle: benches and serve.py
        # read throughput/latency straight from it (pass obs=obs.OFF for
        # the pinned-bit-identical no-op mode)
        self.obs = obs_mod.resolve(obs)
        self._t0_ns = obs_mod.now_ns()     # run() resets; direct-driven
        self._init_obs()                   # engines still get valid offsets
        # serving-front-end hooks, fired on the thread driving the engine:
        # on_token(request, token) at the single emission point
        # (_emit_token), on_finish(request) once per retirement — the
        # HTTP bridge (serving/api) streams rides on these; None = no-op.
        self.on_token = None
        self.on_finish = None
        self.ctx = None
        if plan is not None:
            c = plan.ctx(_serve_shape(capacity, max_batch))
            # admission batches can be smaller than max_batch, so keep the
            # batch replicated: only the params/cache layouts (tp) are pinned
            self.ctx = dataclasses.replace(c, batch_spec=None)
            self.params = jax.device_put(params, plan.param_shardings(params))
        self._prefill = jax.jit(self._with_ctx(self.model.prefill))
        self._next_rid = 0

    # ------------------------------------------------------------ telemetry
    def _init_obs(self):
        """Register the engine_* metric families (idempotent per registry —
        engines sharing one bundle co-register) and name the trace rows."""
        M = self.obs.metrics
        self.m = {
            "tokens": M.counter(
                "engine_tokens_total", "tokens emitted across all requests"),
            "submitted": M.counter(
                "engine_requests_submitted_total", "requests submitted",
                labels=("slo",)),
            "finished": M.counter(
                "engine_requests_finished_total", "requests finished",
                labels=("slo",)),
            "ticks": M.counter(
                "engine_ticks_total", "scheduler decode ticks"),
            "tick_s": M.histogram(
                "engine_tick_seconds", obs_mod.SHORT_LATENCY_BUCKETS,
                "wall time of one decode tick"),
            "queue": M.gauge(
                "engine_queue_depth", "queued requests by SLO class",
                labels=("slo",)),
            "gap": M.histogram(
                "engine_inter_token_seconds", obs_mod.SHORT_LATENCY_BUCKETS,
                "gap between consecutive tokens of one request",
                labels=("slo",)),
            "latency": M.histogram(
                "engine_request_latency_seconds", obs_mod.LATENCY_BUCKETS,
                "request completion offset from run() start",
                labels=("slo",)),
            "prefill": M.counter(
                "engine_prefill_tokens_total",
                "prompt tokens by admission outcome (computed | skipped)",
                labels=("kind",)),
            "sched": M.counter(
                "engine_sched_events_total",
                "scheduler events (requeue | preempt | swap_in | chunk)",
                labels=("event",)),
            "swap_bytes": M.counter(
                "engine_swap_bytes_total",
                "bytes moved by preemption swaps", labels=("dir",)),
            "run_s": M.gauge(
                "engine_run_seconds", "wall time of the last run()"),
        }
        # pre-create the standard SLO children so an idle engine's
        # exposition already carries the queue-depth series
        for slo in SLO_RANK:
            self.m["queue"].labels(slo=slo)
        tr = self.obs.tracer
        tr.name_process(1, "engine")
        tr.name_process(2, "requests")

    def _now_off(self) -> float:
        """Wall offset (s) from the engine epoch on the shared trace clock
        — the one timebase token_times, spans, and histograms agree on."""
        return (obs_mod.now_ns() - self._t0_ns) * 1e-9

    def _queue_gauges(self):
        counts = {slo: 0 for slo in SLO_RANK}
        for r in self.queue:
            counts[r.slo] = counts.get(r.slo, 0) + 1
        for slo, n in counts.items():
            self.m["queue"].labels(slo=slo).set(n)

    def _emit_token(self, r: Request, tok: int, now_off: float):
        """The single token-emission bookkeeping point for every decode
        path: output list, unconditional token_times stamp, inter-token
        histogram, throughput counter."""
        if r.token_times:
            self.m["gap"].labels(slo=r.slo).observe(
                now_off - r.token_times[-1])
        r.out.append(tok)
        r.token_times.append(now_off)
        self.m["tokens"].inc()
        if self.on_token is not None:
            self.on_token(r, tok)

    def _trace_submit(self, r: Request):
        tr = self.obs.tracer
        root = tr.begin(f"req {r.rid}", cat="request", pid=2, tid=r.rid,
                        args={"slo": r.slo,
                              "prompt_tokens": len(r.prompt)})
        r._spans["root"] = root
        r._spans["phase"] = tr.begin("queued", cat="sched", pid=2,
                                     tid=r.rid, parent=root)

    def _trace_phase(self, r: Request, name: str, args=None):
        """Close the request's open lifecycle phase and enter ``name``
        (queued -> prefill -> decode, with swapped/queued re-entries)."""
        tr = self.obs.tracer
        tr.end(r._spans.pop("phase", None))
        r._spans["phase"] = tr.begin(name, cat="sched", pid=2, tid=r.rid,
                                     parent=r._spans.get("root"), args=args)

    def _trace_finish(self, r: Request):
        tr = self.obs.tracer
        tr.end(r._spans.pop("phase", None))
        tr.end(r._spans.pop("root", None),
               args={"tokens": len(r.out),
                     "finish_wall": round(r.finish_wall, 6)})

    def _with_ctx(self, fn):
        if self.ctx is None:
            return fn

        def wrapped(*args, **kwargs):
            from repro.dist import ctx as dctx
            with dctx.use(self.ctx):
                return fn(*args, **kwargs)
        return wrapped

    def submit(self, prompt, **kw) -> Request:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) >= self.capacity - 1:
            raise ValueError(
                f"prompt of {len(prompt)} tokens does not fit the "
                f"capacity-{self.capacity} cache with room to decode")
        r = Request(self._next_rid, prompt, **kw)
        self._next_rid += 1
        self.queue.append(r)
        self.m["submitted"].labels(slo=r.slo).inc()
        self._trace_submit(r)
        self._queue_gauges()
        return r


class Engine(_EngineBase):
    """Continuous-batching slot-pool scheduler (see module docstring).

    Slot state lives on the host (numpy vectors indexed by slot id); the
    batched cache and the per-row clock vector live on device.  One tick =
    one jit'd decode step over all ``max_batch`` rows; rows whose slot is
    free still flow through the math (their output is discarded and their
    clock does not advance) — with a persistent batched cache this is the
    standard padded-slot trade: the decode step stays one compiled
    executable for the engine's lifetime.
    """

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None, obs=None):
        super().__init__(cfg, params, max_batch=max_batch, capacity=capacity,
                         seed=seed, plan=plan, obs=obs)
        B = max_batch
        self._slots: List[Optional[Request]] = [None] * B
        self._pos = np.zeros(B, np.int32)        # per-slot cache clock
        self._temps = np.zeros(B, np.float32)
        self._next_tok = np.zeros(B, np.int32)   # token each slot feeds next
        self._seeds = np.zeros(B, np.int32)      # per-slot sampling seed
        self._steps = np.zeros(B, np.int32)      # per-slot tokens sampled
        self._engine_seed = seed
        self.ticks = 0
        self._admit_clock = 0                    # admission attempts (backoff)
        self.requeues = 0                        # admissions requeued w/ backoff
        self.preemptions = 0                     # slots swapped out / aborted
        self.swap_ins = 0                        # preempted slots resumed
        # bucketed admission keeps the prefill jit cache at O(log L)
        # entries; recurrent families (ssm/hybrid) thread state through
        # every position, so padding would poison their carried state —
        # they prefill at exact length (one compile per distinct length)
        self._bucketable = cfg.family not in ("ssm", "hybrid")
        self.prefill_tokens_computed = 0
        self.prefill_tokens_skipped = 0
        self._cache = self._init_device_cache()
        self._cache_sh = None
        if plan is not None:
            # pin the persistent cache to the plan's layout so per-slot
            # insertion updates in place instead of bouncing the whole
            # cache between layouts every admission
            self._cache_sh = plan.cache_shardings(self._abstract_cache(),
                                                  self.ctx)
            self._cache = jax.device_put(self._cache, self._cache_sh)
        self._insert = self._make_insert(self._cache_sh)
        # the cache is donated through every step so the persistent batched
        # allocation updates in place instead of being copied per tick
        # (same contract as dist.steps.build_step's decode cell)
        self._decode = jax.jit(self._make_decode(), donate_argnums=(2,))
        self._first = jax.jit(_sample_tokens)
        self._score_jit = None      # built lazily on the first score() call

    # ------------------------------------------------------------- jit fns
    def _init_device_cache(self):
        return self.model.init_cache(self.max_batch, self.capacity,
                                     dtype=jnp.float32)

    def _abstract_cache(self):
        return self.model.init_cache(self.max_batch, self.capacity,
                                     abstract=True)

    def _make_decode(self):
        model, with_ctx = self.model, self._with_ctx

        def step(params, tokens, cache, pos, temps, seeds, steps):
            logits, cache = with_ctx(model.decode_step)(
                params, tokens, cache, pos)
            tok = _sample_tokens(logits[:, 0], temps, seeds, steps)
            return tok, cache
        return step

    def _make_insert(self, cache_sh=None):
        """jit'd per-slot cache insertion: scatter a B=1 cache row into the
        batched cache at a (traced) slot index, along each leaf's
        structurally-found batch axis (``cache_batch_axes``)."""
        axes = cache_batch_axes(self.model, self.capacity)

        def insert(big, row, slot):
            flat, td = jax.tree.flatten(big)
            rows = jax.tree.leaves(row)
            out = [jax.lax.dynamic_update_slice_in_dim(
                b, r.astype(b.dtype), slot, axis=ax)
                for b, r, ax in zip(flat, rows, axes)]
            return jax.tree.unflatten(td, out)
        if cache_sh is None:
            return jax.jit(insert, donate_argnums=(0,))
        return jax.jit(insert, donate_argnums=(0,), out_shardings=cache_sh)

    # ----------------------------------------------------------- scheduler
    def _free_slots(self):
        return [i for i, s in enumerate(self._slots) if s is None]

    def _retire(self, i: int):
        r = self._slots[i]
        r.done = True
        r.finish_tick = self.ticks
        r.finish_wall = self._now_off()
        self.finished[r.rid] = r
        self._slots[i] = None
        self.m["finished"].labels(slo=r.slo).inc()
        self.m["latency"].labels(slo=r.slo).observe(r.finish_wall)
        self._trace_finish(r)
        if self.on_finish is not None:
            self.on_finish(r)

    # -------------------------------------------------------- cancellation
    def _cancel_slot(self, i: int):
        """Release slot ``i`` for a cancelled request (paged override also
        aborts an in-flight chunked prefill)."""
        self._retire(i)

    def _finish_cancelled_queued(self, r: Request):
        """Finish bookkeeping for a request cancelled before admission."""
        r.done = True
        r.finish_tick = self.ticks
        r.finish_wall = self._now_off()
        self.finished[r.rid] = r
        self.m["finished"].labels(slo=r.slo).inc()
        self._trace_finish(r)
        self._queue_gauges()
        if self.on_finish is not None:
            self.on_finish(r)

    def cancel(self, rid: int) -> bool:
        """Abort request ``rid``: drop it from the admission queue, or
        retire its live slot (the paged engine frees the slot's blocks, so
        a disconnected client's KV returns to the pool immediately).
        Returns True when the request was found live.  Must be called from
        the thread driving the engine — scheduler state is unlocked; the
        serving front end funnels cancels through its driver thread."""
        for idx, r in enumerate(self.queue):
            if r.rid == rid:
                self.queue.pop(idx)
                r.cancelled = True
                r._swap = None          # swap blobs hold no pool blocks
                self._finish_cancelled_queued(r)
                return True
        for i, s in enumerate(self._slots):
            if s is not None and s.rid == rid:
                s.cancelled = True
                self._cancel_slot(i)
                return True
        return False

    def _acct_prefill(self, computed: int = 0, skipped: int = 0):
        """Prompt-token accounting: legacy attributes (serve.py / tests
        read them) mirrored into engine_prefill_tokens_total{kind}."""
        if computed:
            self.prefill_tokens_computed += computed
            self.m["prefill"].labels(kind="computed").inc(computed)
        if skipped:
            self.prefill_tokens_skipped += skipped
            self.m["prefill"].labels(kind="skipped").inc(skipped)

    def _finished_by(self, r: Request, tok: int, pos: int) -> bool:
        return (r.eos is not None and tok == r.eos) or \
            len(r.out) >= r.max_tokens or pos >= self.capacity - 1

    def _bucket(self, S: int) -> int:
        """Power-of-two admission bucket (>= 8, clamped to capacity)."""
        return min(max(8, 1 << (S - 1).bit_length()), self.capacity)

    def _dense_row_prefill(self, r: Request):
        """B=1 prefill into a fresh dense cache row (bucket-padded when
        the family allows).  Returns (logits (1,1,V), row cache)."""
        S = len(r.prompt)
        row = self.model.init_cache(1, self.capacity, dtype=jnp.float32)
        if self._bucketable:
            Sp = self._bucket(S)
            toks = np.zeros((1, Sp), np.int32)
            toks[0, :S] = r.prompt
            logits, row, _ = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, row,
                jnp.asarray(S, jnp.int32))
        else:
            logits, row, _ = self._prefill(
                self.params, {"tokens": jnp.asarray(r.prompt[None])}, row)
        return logits, row

    def _admit_prefill(self, r: Request, i: int):
        """B=1 prefill + scatter the row into slot ``i`` of the batched
        cache.  Returns the (1,1,V) logits of the last prompt position."""
        logits, row = self._dense_row_prefill(r)
        self._cache = self._insert(self._cache, row, i)
        self._acct_prefill(computed=len(r.prompt))
        return logits

    def _eff_seed(self, r: Request) -> int:
        """The sampling seed a request's stream is keyed by: the explicit
        ``r.seed`` when given, else a (engine seed, rid) mix — rids follow
        submit order, so even default streams reproduce across engine
        instances fed the same request sequence."""
        if r.seed is not None:
            return int(r.seed) & 0x7FFFFFFF
        return (self._engine_seed * 1000003 + 7919 * r.rid + 12345) \
            & 0x7FFFFFFF

    def _pop_admittable(self) -> Optional[Request]:
        """Next request to admit: SLO-class order (interactive before
        batch), FIFO within a class, skipping requests still in admission
        backoff."""
        best = None
        for idx, r in enumerate(self.queue):
            if r._not_before > self._admit_clock:
                continue
            rank = SLO_RANK.get(r.slo, 1)
            if best is None or rank < best[0]:
                best = (rank, idx)
                if rank == 0:
                    break
        if best is None:
            return None
        return self.queue.pop(best[1])

    def _requeue_backoff(self, r: Request):
        """Admission failed and ``r`` is back in the queue: gate its next
        attempt behind an exponentially growing number of admission rounds
        so a request that cannot fit yet stops burning a retry per loop."""
        r._backoff = min(r._backoff + 1, 6)
        r._not_before = self._admit_clock + (1 << r._backoff)
        self.requeues += 1
        self.m["sched"].labels(event="requeue").inc()
        self._queue_gauges()

    def _finish_admission(self, r: Request, i: int, logits, S: int):
        """Common admission tail: sample the first token from the prefill
        logits (per-request stream, step 0) and activate — or immediately
        retire — the slot."""
        t = int(self._first(
            logits[:, 0], jnp.full((1,), r.temperature, jnp.float32),
            jnp.full((1,), self._eff_seed(r), jnp.int32),
            jnp.zeros((1,), jnp.int32))[0])
        self._emit_token(r, t, self._now_off())
        if r.admit_tick < 0:
            r.admit_tick = self.ticks
        r._admit_seq = self._admit_clock
        self._slots[i] = r
        if self._finished_by(r, t, S):
            self._retire(i)
            return
        self._trace_phase(r, "decode")
        self._pos[i] = S
        self._temps[i] = r.temperature
        self._next_tok[i] = t
        self._seeds[i] = self._eff_seed(r)
        self._steps[i] = 1

    def _try_admit(self, r: Request, i: int):
        """Admit ``r`` into free slot ``i`` (may raise RuntimeError on pool
        saturation — the paged override adds swap-in and chunked paths)."""
        self._trace_phase(r, "prefill")
        try:
            logits = self._admit_prefill(r, i)
        except RuntimeError:
            self._trace_phase(r, "queued")    # back in the queue (head)
            raise
        self._finish_admission(r, i, logits, len(r.prompt))

    # --- preemption hooks (no-ops for dense engines: their per-slot cache
    # rows are preallocated, admission cannot fail on capacity)
    def _preempt_victim(self, exclude=(), min_rank=0) -> Optional[int]:
        return None

    def _preempt(self, i: int):
        raise NotImplementedError

    def _admit_preempt_retry(self, r: Request, i: int) -> bool:
        """Admission hit pool saturation: preempt a strictly-lower-priority
        victim (batch makes way for interactive) and retry once.  Returns
        True when the failure was handled (admitted, or backed off after
        the retry also failed)."""
        v = self._preempt_victim(min_rank=SLO_RANK.get(r.slo, 1) + 1)
        if v is None:
            return False
        self._preempt(v)
        if self.queue and self.queue[0] is r:
            self.queue.pop(0)
        try:
            self._try_admit(r, i)
        except RuntimeError:
            self._requeue_backoff(r)
        return True

    def _admit(self):
        """Fill free slots from the queue (SLO-ordered, FIFO within class):
        B=1 prefill, scatter the row into the batched cache, sample the
        first token on device.  Pool saturation is not fatal: the request
        is requeued with backoff (after trying to preempt a lower-priority
        slot) and admission moves on."""
        self._admit_clock += 1
        free = self._free_slots()
        if not (free and self.queue):
            return
        with self.obs.tracer.span("admit", cat="engine", pid=1, tid=0):
            for i in free:
                r = self._pop_admittable()
                if r is None:
                    break
                try:
                    self._try_admit(r, i)
                except RuntimeError:
                    # the failing path reinserted r at the queue head with
                    # its partial block acquisitions released
                    if not self._admit_preempt_retry(r, i):
                        self._requeue_backoff(r)
        self._queue_gauges()

    def _pre_tick(self, active):
        """Hook before the device step (paged engine maps write blocks)."""

    def _decode_extra_args(self):
        """Extra trailing args for the jit'd decode step (paged: tables)."""
        return ()

    def _active_slots(self):
        """Slots that decode this tick (paged: excludes mid-chunk-prefill
        slots — they hold a slot but are not live in the batch yet)."""
        return [i for i, s in enumerate(self._slots) if s is not None]

    def _tick(self):
        """One lockstep device step for every slot; one host sync."""
        active = self._active_slots()
        if not active:
            return
        t_ns = obs_mod.now_ns()
        sid = self.obs.tracer.begin("tick", cat="engine", pid=1, tid=0,
                                    args={"slots": len(active)})
        self._pre_tick(active)
        active = self._active_slots()        # preemption may drop slots
        if not active:
            self.obs.tracer.end(sid)
            return
        toks, self._cache = self._decode(
            self.params, jnp.asarray(self._next_tok[:, None]), self._cache,
            jnp.asarray(self._pos), jnp.asarray(self._temps),
            jnp.asarray(self._seeds), jnp.asarray(self._steps),
            *self._decode_extra_args())
        toks = np.asarray(toks)                  # the tick's single sync
        now = self._now_off()
        self.ticks += 1
        self.m["ticks"].inc()
        for i in active:
            r = self._slots[i]
            t = int(toks[i])
            self._emit_token(r, t, now)
            self._pos[i] += 1
            self._next_tok[i] = t
            self._steps[i] += 1
            if self._finished_by(r, t, int(self._pos[i])):
                self._retire(i)
        self.m["tick_s"].observe((obs_mod.now_ns() - t_ns) * 1e-9)
        self.obs.tracer.end(sid)

    def _prefill_step(self):
        """Hook: advance in-flight chunked prefills (paged engine)."""

    def _prefilling(self) -> bool:
        return False

    def _busy(self) -> bool:
        return any(s is not None for s in self._slots)

    def serve_step(self) -> bool:
        """One scheduler iteration (admit + chunk prefills + decode tick)
        for callers that own the loop — the HTTP front end's driver thread
        runs this instead of ``run()`` so it can interleave submissions and
        cancellations between ticks.  Returns True while the engine has
        live or queued work (False = safe to idle until the next submit).
        Unlike ``run()``, admission stalls are the caller's to resolve
        (expire backoffs / shed the queue); this never raises on them."""
        self._admit()
        self._prefill_step()
        self._tick()
        return bool(self.queue) or self._busy() or self._prefilling()

    def run(self):
        self._t0_ns = obs_mod.now_ns()
        stalls = 0
        while self.queue or self._busy():
            done0 = len(self.finished)
            self._admit()
            self._prefill_step()
            self._tick()
            if self._busy() or self._prefilling() or \
                    len(self.finished) > done0:
                stalls = 0
            elif self.queue:
                # nothing is running, so ticks (and natural backoff expiry)
                # cannot advance: expire every backoff and retry.  If
                # repeated forced retries still admit nothing with an empty
                # engine, the queued work can never fit.
                stalls += 1
                for r in self.queue:
                    r._not_before = 0
                if stalls > 3:
                    raise RuntimeError(
                        "admission stalled: queued request(s) cannot fit "
                        "the block pool even with the engine idle")
        self.m["run_s"].set(self._now_off())
        return self

    # ------------------------------------------------- teacher-forced score
    def _make_score(self):
        """jit'd teacher-forced step: decode through the engine's serving
        path (paged tables / int8 KV / fused dequant ride along via
        ``*extra``), then per-row NLL of the forced target + greedy
        argmax.  The metric math is shared with ``eval.metrics`` so the
        engine and the dense reference apply bit-identical ops."""
        from repro.eval.metrics import nll_greedy
        model, with_ctx = self.model, self._with_ctx

        def step(params, tokens, targets, cache, pos, *extra):
            logits, cache = with_ctx(model.decode_step)(
                params, tokens, cache, pos, *extra)
            nll, greedy = nll_greedy(logits[:, 0], targets)
            return nll, greedy, cache
        return step

    def _score_cleanup(self, n: int):
        """Reset slot state after a scoring chunk (paged: drop blocks)."""
        self._pos[:] = 0
        self._next_tok[:] = 0
        self._temps[:] = 0.0

    def score(self, tokens) -> Dict[str, np.ndarray]:
        """Teacher-forced scoring of ``tokens (B, S)`` through the *real*
        serving path: rows are admitted like requests (bucketed B=1
        prefill of the first token; the paged engine allocates pool
        blocks and, at ``kv_bits=8``, packs int8 KV) and then advanced in
        lockstep jit'd decode steps that feed the ground-truth token and
        return the NLL of the next one — so quality eval exercises paged
        KV, block tables, and the fused dequant decode cells exactly as
        production decode does, instead of a bare ``model.apply``.

        Returns ``{"nll": (B, S-1) float32, "greedy": (B, S-1) int32}``:
        ``nll[:, t]`` is -log p(tokens[:, t+1] | tokens[:, :t+1]) and
        ``greedy[:, t]`` the argmax prediction at that position.  The
        engine must be idle; rows are scored in chunks of ``max_batch``.
        """
        from repro.eval.metrics import nll_greedy
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 2 or tokens.shape[1] < 2:
            raise ValueError(f"score() takes (B, S>=2) tokens, "
                             f"got {tokens.shape}")
        B, S = tokens.shape
        if S > self.capacity:
            raise ValueError(f"sequence length {S} exceeds the "
                             f"capacity-{self.capacity} cache")
        if self.queue or any(s is not None for s in self._slots):
            raise RuntimeError("score() requires an idle engine "
                               "(no queued or in-flight requests)")
        if self._score_jit is None:
            self._score_jit = jax.jit(self._make_score(),
                                      donate_argnums=(3,))
            self._first_score = jax.jit(nll_greedy)
        nll = np.zeros((B, S - 1), np.float32)
        greedy = np.zeros((B, S - 1), np.int32)
        for c0 in range(0, B, self.max_batch):
            rows = list(range(c0, min(c0 + self.max_batch, B)))
            n = len(rows)
            # admit each row with a 1-token prompt through the standard
            # admission path (prefix sharing is a no-op at S=1, so the
            # score never reads another request's cached blocks)
            first = []
            for k, i in enumerate(rows):
                r = Request(rid=-(i + 1), prompt=tokens[i, :1])
                first.append(self._admit_prefill(r, k)[:, 0])
                self._pos[k] = 1
            nll0, g0 = self._first_score(jnp.concatenate(first, axis=0),
                                         jnp.asarray(tokens[rows, 1]))
            nll[rows, 0] = np.asarray(nll0)
            greedy[rows, 0] = np.asarray(g0)
            active = list(range(n))
            for t in range(1, S - 1):
                tok = np.zeros((self.max_batch, 1), np.int32)
                tok[:n, 0] = tokens[rows, t]
                tgt = np.zeros((self.max_batch,), np.int32)
                tgt[:n] = tokens[rows, t + 1]
                self._pre_tick(active)
                nll_t, g_t, self._cache = self._score_jit(
                    self.params, jnp.asarray(tok), jnp.asarray(tgt),
                    self._cache, jnp.asarray(self._pos),
                    *self._decode_extra_args())
                nll[rows, t] = np.asarray(nll_t)[:n]
                greedy[rows, t] = np.asarray(g_t)[:n]
                self._pos[:n] += 1
            self._score_cleanup(n)
        return {"nll": nll, "greedy": greedy}


def _cache_nodes(tree):
    """Flatten a model cache pytree at cache-node granularity (KVCache /
    PagedKVCache stay whole; SSM/RWKV states recurse to arrays)."""
    return jax.tree.flatten(
        tree, is_leaf=lambda n: isinstance(n, (KVCache, PagedKVCache)))


class PagedEngine(Engine):
    """Slot-pool scheduler over a paged KV pool with prefix sharing.

    Inherits the whole continuous-batching scheduler from ``Engine`` and
    swaps the storage layer: full-context KV lives in a global block pool,
    slots hold host-side block tables (passed into the jit'd decode step
    each tick, so allocation is pure host bookkeeping), and blocks are
    refcounted so identical prompt prefixes are stored once.

    Admission policy (uniform-attention families):
      1. hash the prompt's full blocks against the ``PrefixCache`` and take
         the longest chain of hits, capped at the last block boundary
         <= S-1 (at least one suffix token must run to produce the first
         logits);
      2. the shared blocks are mapped read-only into the slot's table
         (+1 ref each) and their prefill is *skipped entirely*;
      3. the remaining tail is computed by ``Model.prefill_suffix`` into
         freshly-owned blocks — the copy-on-write boundary: partial blocks
         are never shared in place, a private copy is always materialized
         (as a recompute, which is cheaper than copy + it is needed for
         the first-token logits anyway);
      4. the prompt's full blocks are registered back into the cache.
    Decode writes only ever touch private blocks (positions >= S land past
    every shared full block); ``_ensure_block`` still guards the invariant
    with a device block copy should a shared block become a write target.
    Grouped-local / hybrid / ssm families admit through the dense-row
    prefill and pack the row into pool blocks (their window rings and
    recurrent state are per-row and unshareable — see ``Model.init_cache``).
    Retirement drops one ref per mapped block; blocks whose refs hit zero
    return to the pool, so capacity is freed per-block, not per-slot.

    ``kv_bits=8`` stores the pool as int8 codes + per-(token, kv-head)
    scale planes (``qserve.kvquant``): admission packs quantize the fp
    dense-row KV, decode writes quantize per token, attention dequantizes
    on read — ~0.56x fp16 KV bytes/request with a documented logit
    tolerance (DESIGN.md §Quantized serving).
    """

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 share_prefixes: bool = True, kv_bits: int = 16,
                 draft=None, spec_k: int = 4, prefill_chunk: int = 0,
                 obs=None):
        assert capacity % block_size == 0, (capacity, block_size)
        assert kv_bits in (16, 8), kv_bits
        # resolve the bundle before super().__init__ runs: the allocator
        # and prefix cache are built first and carry their handles directly
        obs = obs_mod.resolve(obs)
        M = obs.metrics
        self.kv_bits = kv_bits
        self.block_size = block_size
        # --- self-speculative decoding: `draft` is a cheap params tree of
        # the SAME architecture (typically an rtn-packed zero-calibration
        # quantization of the target weights) that greedily proposes
        # spec_k tokens per tick; one scanned target pass verifies them.
        self._draft = draft
        self.spec_k = int(spec_k)
        self.spec_drafted = 0
        self.spec_accepted = 0
        self._spec_jit = None
        # --- chunked prefill: prompts longer than `prefill_chunk` tokens
        # admit through fixed-size prefill_chunk-token chunks interleaved
        # with decode ticks (0 disables = blocking admission).
        if prefill_chunk:
            prefill_chunk += (-prefill_chunk) % block_size
        self.chunk_tokens = prefill_chunk
        self.chunk_steps = 0
        self._chunking: Dict[int, dict] = {}
        self._chunk_jits: Dict[int, object] = {}
        self.max_blocks = capacity // block_size
        stripes = 1
        if plan is not None:
            shp = _serve_shape(capacity, max_batch)
            if plan.ctx(shp).attn_decode_mode == "flash":
                stripes = plan.tp_size
                assert self.max_blocks % stripes == 0, \
                    (self.max_blocks, stripes)
        if num_blocks is None:
            # safe default: worst case + one scratch per stripe (no memory
            # win — pass a smaller pool to oversubscribe; the benchmark
            # reports the blocks actually touched either way)
            num_blocks = max_batch * self.max_blocks + stripes
        num_blocks += (-num_blocks) % stripes
        self.num_blocks = num_blocks
        pool_m = None
        prefix_m = None
        if M.enabled:
            pool_m = {
                "alloc": M.counter("engine_block_pool_allocs_total",
                                   "physical block allocations"),
                "free": M.counter("engine_block_pool_frees_total",
                                  "physical block frees"),
                "in_use": M.gauge("engine_blocks_in_use",
                                  "live physical blocks"),
                "occupancy": M.gauge(
                    "engine_block_pool_occupancy",
                    "live blocks / allocatable (non-reserved) blocks"),
            }
            pf = M.counter(
                "engine_prefix_cache_events_total",
                "prefix cache events (hit | miss | insert | evict)",
                labels=("event",))
            prefix_m = {k: pf.labels(event=k)
                        for k in ("hit", "miss", "insert", "evict")}
        self.alloc = BlockAllocator(num_blocks, block_size, stripes=stripes,
                                    metrics=pool_m)
        self.prefix = PrefixCache(self.alloc, block_size, metrics=prefix_m)
        self._tables = np.full((max_batch, self.max_blocks), -1, np.int32)
        self.shared_block_hits = 0
        self.cow_copies = 0
        self.peak_blocks_in_use = 0
        self.blocks_held_at_retire: List[int] = []
        super().__init__(cfg, params, max_batch=max_batch,
                         capacity=capacity, seed=seed, plan=plan, obs=obs)
        self.m["spec"] = self.obs.metrics.counter(
            "engine_spec_tokens_total",
            "speculative tokens (drafted | accepted)", labels=("kind",))
        nodes, _ = _cache_nodes(self._abstract_cache())
        self._has_paged = any(isinstance(n, PagedKVCache) for n in nodes)
        self._share = (share_prefixes and self._has_paged
                       and cfg.family in ("dense", "moe")
                       and not self.model._grouped_local())
        self._sfx_jits: Dict[int, object] = {}
        self._copy_block = jax.jit(self._make_copy_block(),
                                   donate_argnums=(0,))
        if self._draft is not None and plan is not None:
            self._draft = jax.device_put(
                self._draft, plan.param_shardings(self._draft))

    # ------------------------------------------------------------- jit fns
    def _init_device_cache(self):
        return self.model.init_cache(
            self.max_batch, self.capacity, dtype=jnp.float32, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits)

    def _abstract_cache(self):
        return self.model.init_cache(
            self.max_batch, self.capacity, abstract=True, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits)

    def _make_decode(self):
        model, with_ctx = self.model, self._with_ctx

        def step(params, tokens, cache, pos, temps, seeds, steps,
                 block_tables):
            logits, cache = with_ctx(model.decode_step)(
                params, tokens, cache, pos, block_tables)
            tok = _sample_tokens(logits[:, 0], temps, seeds, steps)
            return tok, cache
        return step

    def _make_copy_block(self):
        def copy_one(n, src, dst):
            sc = (None, None)
            if n.quantized:              # scale planes ride with the codes
                sc = (n.k_scale.at[:, dst].set(n.k_scale[:, src]),
                      n.v_scale.at[:, dst].set(n.v_scale[:, src]))
            return PagedKVCache(n.k.at[:, dst].set(n.k[:, src]),
                                n.v.at[:, dst].set(n.v[:, src]),
                                n.block_tables, *sc)

        def copy(cache, src, dst):
            nodes, td = _cache_nodes(cache)
            out = [copy_one(n, src, dst)
                   if isinstance(n, PagedKVCache) else n for n in nodes]
            return jax.tree.unflatten(td, out)
        return copy

    def _make_insert(self, cache_sh=None):
        """jit'd pack of a B=1 *dense-row* prefill into the paged cache:
        paged nodes scatter whole blocks into the pool via the slot's
        table (unmapped entries spill to the scratch block), dense nodes
        (local rings, recurrent state, row clocks) scatter along their
        structurally-found batch axis exactly as the dense engine does."""
        big2, _ = _cache_nodes(self.model.init_cache(
            2, self.capacity, abstract=True, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits))
        big3, _ = _cache_nodes(self.model.init_cache(
            3, self.capacity, abstract=True, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits))
        axes = [None if isinstance(a, PagedKVCache) else jax.tree.map(
            lambda x, y: next(i for i, (p, q) in
                              enumerate(zip(x.shape, y.shape)) if p != q),
            a, b) for a, b in zip(big2, big3)]
        # the swap-out/swap-in path reuses the same per-node batch axes to
        # gather/scatter one slot's dense rows (paged nodes move by block)
        self._node_axes = axes
        bs, nblk = self.block_size, self.max_blocks

        def insert(big, row, slot, table_row):
            bn, td = _cache_nodes(big)
            rn, _ = _cache_nodes(row)
            safe = jnp.where(table_row >= 0, table_row, 0)
            out = []
            for node, rnode, ax in zip(bn, rn, axes):
                if isinstance(node, PagedKVCache):
                    def pack(pool, scplane, rowkv):
                        # pool (n, nb, bs, KV, hd); rowkv (n, 1, cap, KV, hd)
                        # unmapped blocks collapse onto the never-read
                        # scratch block: no read-back select needed; int8
                        # pools quantize the fp dense-row KV on the way in
                        n = pool.shape[0]
                        vals = rowkv[:, 0].reshape(
                            n, nblk, bs, *pool.shape[3:])
                        if scplane is None:
                            return pool.at[:, safe].set(
                                vals.astype(pool.dtype)), None
                        from repro.serving.qserve import kvquant as KQ
                        q, s = KQ.quantize_kv(vals)
                        return (pool.at[:, safe].set(q),
                                scplane.at[:, safe].set(s))
                    bt2 = node.block_tables.at[slot].set(table_row)
                    kq, ks = pack(node.k, node.k_scale, rnode.k)
                    vq, vs = pack(node.v, node.v_scale, rnode.v)
                    out.append(PagedKVCache(kq, vq, bt2, ks, vs))
                else:
                    out.append(jax.tree.map(
                        lambda b, r, a: jax.lax.dynamic_update_slice_in_dim(
                            b, r.astype(b.dtype), slot, axis=a),
                        node, rnode, ax))
            return jax.tree.unflatten(td, out)
        if cache_sh is None:
            return jax.jit(insert, donate_argnums=(0,))
        return jax.jit(insert, donate_argnums=(0,), out_shardings=cache_sh)

    def _sfx_jit(self, n_shared: int):
        """Per-``n_shared`` jit of the prefix-shared suffix prefill (the
        suffix pads to bucket lengths, so each (n_shared, bucket) pair
        compiles once)."""
        fn = self._sfx_jits.get(n_shared)
        if fn is None:
            model, with_ctx = self.model, self._with_ctx

            def sfx(params, tokens, cache, table_row, valid_len):
                return with_ctx(model.prefill_suffix)(
                    params, tokens, cache, table_row, valid_len,
                    n_shared=n_shared)
            kw = {} if self._cache_sh is None else \
                {"out_shardings": (None, self._cache_sh)}
            fn = jax.jit(sfx, donate_argnums=(2,), **kw)
            self._sfx_jits[n_shared] = fn
        return fn

    # ----------------------------------------------------- block management
    def _alloc_block(self, lb: int) -> int:
        stripe = 0 if self.alloc.stripes == 1 else \
            lb // (self.max_blocks // self.alloc.stripes)
        b = self.alloc.alloc(stripe)
        while b is None and self.prefix.evict_one(stripe):
            b = self.alloc.alloc(stripe)
        if b is None:
            # not fatal: callers preempt a lower-priority slot and retry,
            # or requeue the request with backoff (see _admit / _pre_tick)
            raise RuntimeError(
                f"KV block pool exhausted ({self.num_blocks} blocks, "
                f"{self.alloc.blocks_in_use} live)")
        self.peak_blocks_in_use = max(self.peak_blocks_in_use,
                                      self.alloc.blocks_in_use)
        return b

    def _ensure_block(self, i: int, pos: int):
        """Map the block that position ``pos`` will write this tick.
        Shared targets get a private copy first (copy-on-write) — by
        policy decode never writes a shared full block, but the refcount
        guard keeps the invariant local, not global."""
        lb = pos // self.block_size
        if lb >= self.max_blocks:
            return
        b = int(self._tables[i, lb])
        if b < 0:
            self._tables[i, lb] = self._alloc_block(lb)
        elif self.alloc.refcount[b] > 1:
            nb = self._alloc_block(lb)
            self._cache = self._copy_block(self._cache, jnp.asarray(b),
                                           jnp.asarray(nb))
            self.alloc.decref(b)
            self._tables[i, lb] = nb
            self.cow_copies += 1

    # ----------------------------------------------------------- scheduler
    def _release_row(self, trow):
        """Drop this row's ref on every mapped block (failed admission /
        retirement)."""
        for b in trow[trow >= 0]:
            self.alloc.decref(int(b))

    def _admit_prefill(self, r: Request, i: int):
        if not self._share:
            # dense-row prefill (bucketed when the family allows), then
            # pack the row's full-context KV into freshly-owned blocks
            S = len(r.prompt)
            logits, row = self._dense_row_prefill(r)
            trow = np.full(self.max_blocks, -1, np.int32)
            if self._has_paged:
                try:
                    for j in range(-(-S // self.block_size)):
                        trow[j] = self._alloc_block(j)
                except RuntimeError:
                    # release partial acquisitions and put the request
                    # back so a catcher can drain slots and retry
                    self._release_row(trow)
                    self.queue.insert(0, r)
                    raise
            self._cache = self._insert(self._cache, row, i,
                                       jnp.asarray(trow))
            self._tables[i] = trow
            self._acct_prefill(computed=S)
            return logits
        # ---- prefix-shared admission (uniform-attention families)
        bs = self.block_size
        S = len(r.prompt)
        n_shared, shared = self.prefix.match(r.prompt)
        n_shared = min(n_shared, (S - 1) // bs)   # >= 1 suffix token
        shared = shared[:n_shared]
        suffix = r.prompt[n_shared * bs:]
        Ssfx = len(suffix)
        # the suffix pads to a bucket for the jit cache, but only blocks
        # covering *real* tokens are allocated — prefill_suffix spills the
        # pad region's writes to the scratch block, and decode growth maps
        # later blocks on demand
        Sp = min(self._bucket(Ssfx), self.capacity - n_shared * bs)
        Sp += (-Sp) % bs                          # whole blocks
        trow = np.full(self.max_blocks, -1, np.int32)
        try:
            for j, b in enumerate(shared):
                self.alloc.incref(b)
                trow[j] = b
            for j in range(n_shared, n_shared + -(-Ssfx // bs)):
                trow[j] = self._alloc_block(j)
        except RuntimeError:
            self._release_row(trow)
            self.queue.insert(0, r)
            raise
        toks = np.zeros((1, Sp), np.int32)
        toks[0, :Ssfx] = suffix
        logits, self._cache = self._sfx_jit(n_shared)(
            self.params, jnp.asarray(toks), self._cache, jnp.asarray(trow),
            jnp.asarray(Ssfx, jnp.int32))
        self._tables[i] = trow
        # register this prompt's newly-computed full blocks for reuse
        self.prefix.insert(r.prompt, trow, n_shared, S // bs)
        self._acct_prefill(computed=Ssfx, skipped=n_shared * bs)
        self.shared_block_hits += n_shared
        return logits

    def _retire(self, i: int):
        if self._has_paged:
            self.blocks_held_at_retire.append(
                int((self._tables[i] >= 0).sum()))
            self._release_row(self._tables[i])
            self._tables[i] = -1
        super()._retire(i)

    def _cancel_slot(self, i: int):
        # a cancelled mid-chunk prefill just stops: _retire releases the
        # blocks the finished chunks mapped
        self._chunking.pop(i, None)
        super()._cancel_slot(i)

    # ------------------------------------------------ preemption / swap-out
    def _preempt_victim(self, exclude=(), min_rank=0) -> Optional[int]:
        """Lowest-priority occupied slot: batch-class before interactive,
        most recently admitted first within a class; only slots whose SLO
        rank >= ``min_rank`` qualify (admission preempts strictly lower
        priority only; decode growth may preempt any other slot)."""
        best = None
        for i, r in enumerate(self._slots):
            if r is None or i in exclude:
                continue
            rank = SLO_RANK.get(r.slo, 1)
            if rank < min_rank:
                continue
            key = (rank, r._admit_seq)
            if best is None or key > best[0]:
                best = (key, i)
        return None if best is None else best[1]

    def _preempt(self, i: int):
        """Swap slot ``i`` out to host memory and requeue it at the queue
        head.  Mid-chunk-prefill slots are aborted instead (nothing decoded
        yet — recomputing the prefill is cheaper than paging out a prompt
        that produced no tokens)."""
        r = self._slots[i]
        if i in self._chunking:
            del self._chunking[i]
            self.obs.tracer.instant("preempt", cat="sched", pid=2,
                                    tid=r.rid,
                                    args={"aborted_prefill": True})
            self._trace_phase(r, "queued")
        else:
            # gather this row's live state: every mapped pool block plus
            # the slot's row of each dense leaf (rings, recurrent state,
            # clocks).  Block contents round-trip bit-exactly through host
            # numpy, so the resumed decode continues bit-identically.
            lbs = np.flatnonzero(self._tables[i] >= 0)
            ids = jnp.asarray(self._tables[i][lbs])
            nodes, _ = _cache_nodes(self._cache)
            blob = []
            for n, ax in zip(nodes, self._node_axes):
                if isinstance(n, PagedKVCache):
                    e = {"k": np.asarray(n.k[:, ids]),
                         "v": np.asarray(n.v[:, ids])}
                    if n.quantized:
                        e["ks"] = np.asarray(n.k_scale[:, ids])
                        e["vs"] = np.asarray(n.v_scale[:, ids])
                    blob.append(e)
                else:
                    blob.append(jax.tree.map(
                        lambda leaf, a: np.asarray(
                            jax.lax.index_in_dim(leaf, i, a, keepdims=True)),
                        n, ax))
            r._swap = (lbs, blob,
                       {"pos": int(self._pos[i]),
                        "next_tok": int(self._next_tok[i])})
            nbytes = sum(a.nbytes for a in jax.tree.leaves(blob))
            self.m["swap_bytes"].labels(dir="out").inc(nbytes)
            self.obs.tracer.instant("swap_out", cat="sched", pid=2,
                                    tid=r.rid, args={"bytes": nbytes})
            self._trace_phase(r, "swapped")
        self._release_row(self._tables[i])
        self._tables[i] = -1
        self._slots[i] = None
        self.queue.insert(0, r)
        self.preemptions += 1
        self.m["sched"].labels(event="preempt").inc()
        self._queue_gauges()

    def _admit_swapped(self, r: Request, i: int):
        """Swap a preempted slot back in: re-map its logical blocks onto
        freshly allocated physical ids, scatter the saved block contents
        and dense rows, and resume decode at the saved clock."""
        lbs, blob, st = r._swap
        trow = np.full(self.max_blocks, -1, np.int32)
        try:
            for lb in lbs:
                trow[lb] = self._alloc_block(int(lb))
        except RuntimeError:
            self._release_row(trow)
            self.queue.insert(0, r)
            raise
        ids = jnp.asarray(trow[lbs])
        nodes, td = _cache_nodes(self._cache)
        out = []
        for n, ax, e in zip(nodes, self._node_axes, blob):
            if isinstance(n, PagedKVCache):
                sc = (None, None)
                if n.quantized:
                    sc = (n.k_scale.at[:, ids].set(jnp.asarray(e["ks"])),
                          n.v_scale.at[:, ids].set(jnp.asarray(e["vs"])))
                out.append(PagedKVCache(
                    n.k.at[:, ids].set(jnp.asarray(e["k"])),
                    n.v.at[:, ids].set(jnp.asarray(e["v"])),
                    n.block_tables, *sc))
            else:
                out.append(jax.tree.map(
                    lambda leaf, a, row: jax.lax.dynamic_update_slice_in_dim(
                        leaf, jnp.asarray(row).astype(leaf.dtype), i, axis=a),
                    n, ax, e))
        self._cache = jax.tree.unflatten(td, out)
        self._tables[i] = trow
        self._slots[i] = r
        self._pos[i] = st["pos"]
        self._next_tok[i] = st["next_tok"]
        self._temps[i] = r.temperature
        self._seeds[i] = self._eff_seed(r)
        self._steps[i] = len(r.out)
        r._admit_seq = self._admit_clock
        r._swap = None
        self.swap_ins += 1
        self.m["sched"].labels(event="swap_in").inc()
        nbytes = sum(a.nbytes for a in jax.tree.leaves(blob))
        self.m["swap_bytes"].labels(dir="in").inc(nbytes)
        self.obs.tracer.instant("swap_in", cat="sched", pid=2, tid=r.rid,
                                args={"bytes": nbytes})
        self._trace_phase(r, "decode")

    # ------------------------------------------------------ chunked prefill
    def _begin_chunked(self, r: Request, i: int):
        """Claim slot ``i`` for an incremental long-prompt prefill: map the
        prefix-cache hits now, then compute the private tail chunk-by-chunk
        from ``_prefill_step`` between decode ticks."""
        bs = self.block_size
        S = len(r.prompt)
        n_shared, shared = self.prefix.match(r.prompt)
        n_shared = min(n_shared, (S - 1) // bs)
        trow = np.full(self.max_blocks, -1, np.int32)
        for j, b in enumerate(shared[:n_shared]):
            self.alloc.incref(b)
            trow[j] = b
        self._tables[i] = trow
        self._slots[i] = r
        w = 4
        while w < -(-S // bs):
            w *= 2
        self._chunking[i] = {"start": n_shared * bs, "n_shared": n_shared,
                             "w": min(w, self.max_blocks)}
        r.admit_tick = self.ticks
        r._admit_seq = self._admit_clock
        self._acct_prefill(skipped=n_shared * bs)
        self.shared_block_hits += n_shared
        self._trace_phase(r, "prefill", args={"chunked": True})

    def _chunk_jit(self, w: int):
        """Per-table-width jit of the chunk prefill (chunk length is fixed,
        so the jit cache holds O(log max_blocks) entries)."""
        fn = self._chunk_jits.get(w)
        if fn is None:
            model, with_ctx = self.model, self._with_ctx

            def chunk(params, tokens, cache, bt_row, start, valid_len):
                return with_ctx(model.prefill_chunk)(
                    params, tokens, cache, bt_row, start, valid_len)
            kw = {} if self._cache_sh is None else \
                {"out_shardings": (None, self._cache_sh)}
            fn = jax.jit(chunk, donate_argnums=(2,), **kw)
            self._chunk_jits[w] = fn
        return fn

    def _prefilling(self) -> bool:
        return bool(self._chunking)

    def _prefill_step(self):
        """Advance every in-flight chunked prefill by ONE chunk, then
        return — the run loop decodes a tick in between, so a long prompt
        costs the live batch one bounded chunk of latency per tick instead
        of its whole prefill."""
        for i in list(self._chunking):
            st = self._chunking.get(i)
            r = self._slots[i]
            if st is None or r is None:
                continue
            bs, C = self.block_size, self.chunk_tokens
            S = len(r.prompt)
            start = st["start"]
            n = min(C, S - start)
            try:
                for lb in range(start // bs, -(-(start + n) // bs)):
                    if self._tables[i, lb] < 0:
                        self._tables[i, lb] = self._alloc_block(lb)
            except RuntimeError:
                v = self._preempt_victim(exclude=(i,),
                                         min_rank=SLO_RANK.get(r.slo, 1))
                if v is not None:
                    self._preempt(v)
                else:
                    # no lower-priority victim: abort this prefill and
                    # requeue it behind a backoff
                    self._preempt(i)
                    self._requeue_backoff(r)
                continue
            toks = np.zeros((1, C), np.int32)
            toks[0, :n] = r.prompt[start:start + n]
            with self.obs.tracer.span(
                    "prefill_chunk", cat="sched", pid=2, tid=r.rid,
                    parent=r._spans.get("phase"),
                    args={"start": start, "tokens": n}):
                logits, self._cache = self._chunk_jit(st["w"])(
                    self.params, jnp.asarray(toks), self._cache,
                    jnp.asarray(self._tables[i, :st["w"]]),
                    jnp.asarray(start, jnp.int32),
                    jnp.asarray(n, jnp.int32))
            self._acct_prefill(computed=n)
            self.chunk_steps += 1
            self.m["sched"].labels(event="chunk").inc()
            st["start"] = start + n
            if st["start"] >= S:
                del self._chunking[i]
                self.prefix.insert(r.prompt, self._tables[i],
                                   st["n_shared"], S // bs)
                self._slots[i] = None      # _finish_admission re-occupies
                self._finish_admission(r, i, logits, S)

    def _try_admit(self, r: Request, i: int):
        if r._swap is not None:
            self._admit_swapped(r, i)
            return
        if self._share and self.chunk_tokens and \
                len(r.prompt) > self.chunk_tokens:
            self._begin_chunked(r, i)
            return
        super()._try_admit(r, i)

    def _active_slots(self):
        return [i for i, s in enumerate(self._slots)
                if s is not None and i not in self._chunking]

    def _score_cleanup(self, n: int):
        if self._has_paged:
            for k in range(n):
                self._release_row(self._tables[k])
                self._tables[k] = -1
        super()._score_cleanup(n)

    def _ensure_block_or_preempt(self, i: int, pos: int):
        """Map the block position ``pos`` writes, preempting until the
        allocation fits.  The victim is the globally lowest-priority slot
        (batch-class, most recent first) — which may be slot ``i``
        itself: a batch slot under pool pressure swaps *itself* out
        rather than evicting interactive work.  A genuinely unservable
        live set (a single slot that cannot grow) re-raises."""
        while True:
            try:
                self._ensure_block(i, pos)
                return
            except RuntimeError:
                v = self._preempt_victim()
                alone = all(s is None for j, s in enumerate(self._slots)
                            if j != i)
                if v is None or (v == i and alone):
                    # nothing else to free: this request's working set
                    # exceeds the pool outright — swapping it out would
                    # only readmit it into the same wall
                    raise
                self._preempt(v)
                if v == i:
                    return      # requester swapped out; row inactive now

    def _pre_tick(self, active):
        if self._has_paged:
            # speculation writes pos..pos+K this tick, plain decode just pos
            ahead = self.spec_k if self._draft is not None else 0
            for i in active:
                if self._slots[i] is None:
                    continue           # preempted by an earlier iteration
                p = int(self._pos[i])
                for q in range(p, min(p + ahead + 1, self.capacity)):
                    self._ensure_block_or_preempt(i, q)

    # ------------------------------------------------ speculative decoding
    def _rollback_blocks(self, i: int):
        """Free speculative blocks past the accepted frontier: the cache
        holds positions < pos[i], so any mapped block whose positions all
        lie at >= pos[i] carries only rejected draft writes.  (Prompt and
        shared-prefix blocks always start below pos, so only this tick's
        speculative growth is ever dropped.)"""
        keep = (int(self._pos[i]) - 1) // self.block_size
        trow = self._tables[i]
        for lb in np.flatnonzero(trow >= 0):
            if lb > keep:
                self.alloc.decref(int(trow[lb]))
                trow[lb] = -1

    def _make_spec(self):
        """The one-jit speculative tick: K greedy draft steps with the
        cheap params -> rewind the non-positional state -> one scanned
        target verify pass over the K+1 candidate tokens -> on-device
        accept counts + per-row state rollback.  Greedy rows emit
        accepts+1 tokens whose values are bit-identical to accepts+1
        sequential ``decode_step`` ticks (the verify scan IS decode_step's
        math, and position masking hides the draft's paged writes);
        sampled rows (temp > 0) fall back to one per-request-keyed draw
        from the verify pass's first logits."""
        model, with_ctx, K = self.model, self._with_ctx, self.spec_k
        # per-leaf batch axes of the rollback-sensitive state, found
        # structurally like cache_batch_axes
        s2 = model.spec_state(self.model.init_cache(
            2, self.capacity, abstract=True, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits))
        s3 = model.spec_state(self.model.init_cache(
            3, self.capacity, abstract=True, paged=True,
            block_size=self.block_size, num_blocks=self.num_blocks,
            kv_bits=self.kv_bits))
        spec_axes = [next(i for i, (a, b) in enumerate(zip(x.shape, y.shape))
                          if a != b) for x, y in zip(s2, s3)]

        def tick(pp, tokens, cache, pos, temps, seeds, steps, block_tables):
            params, draft = pp
            B = tokens.shape[0]
            state0 = model.spec_state(cache)

            def dstep(carry, _):
                tk, c, p = carry
                lg, c = with_ctx(model.decode_step)(draft, tk, c, p,
                                                    block_tables)
                nt = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)
                return (nt[:, None], c, p + 1), nt

            (_, cache, _), drafted = jax.lax.scan(
                dstep, (tokens, cache, jnp.asarray(pos)), None, length=K)
            drafted = jnp.moveaxis(drafted, 0, 1)             # (B, K)
            # rewind ring/recurrent state; paged pools rewind by clock
            cache = model.with_spec_state(cache, state0)
            seq = jnp.concatenate([tokens, drafted], axis=1)  # (B, K+1)
            lgs, cache, snaps = with_ctx(model.decode_steps)(
                params, seq, cache, pos, block_tables)
            greedy = jnp.argmax(lgs, axis=-1).astype(jnp.int32)
            # accepts = longest prefix where draft == target greedy;
            # sampled rows take the non-speculative one-token path
            eq = (drafted == greedy[:, :K]).astype(jnp.int32)
            acc = jnp.cumprod(eq, axis=1).sum(axis=1)
            acc = jnp.where(temps > 0, 0, acc)
            sampled = _sample_tokens(lgs[:, 0], temps, seeds, steps)
            bonus = jnp.where(
                temps > 0, sampled,
                jnp.take_along_axis(greedy, acc[:, None], axis=1)[:, 0])
            cols = jnp.arange(K + 1)[None, :]
            base = jnp.concatenate(
                [drafted, jnp.zeros_like(drafted[:, :1])], axis=1)
            tok_out = jnp.where(
                cols < acc[:, None], base,
                jnp.where(cols == acc[:, None], bonus[:, None], 0))
            # roll each rollback-sensitive leaf back to its accepted step:
            # snaps[t] is the state after consuming seq token t, so row b
            # keeps snapshot index acc[b]
            rows = jnp.arange(B)

            def sel(stack, ax):
                m = jnp.moveaxis(stack, ax + 1, 0)            # (B, K+1, ...)
                return jnp.moveaxis(m[rows, acc], 0, ax)
            cache = model.with_spec_state(
                cache, [sel(s, ax) for s, ax in zip(snaps, spec_axes)])
            return tok_out, acc, cache
        return tick

    def _tick(self):
        """Speculative tick when a draft is configured: one fused
        draft+verify dispatch emits 1..spec_k+1 tokens per live row, still
        with a single host sync; rejected speculative blocks are freed and
        the row clock rewinds to the accepted frontier."""
        if self._draft is None:
            return super()._tick()
        active = self._active_slots()
        if not active:
            return
        t_ns = obs_mod.now_ns()
        sid = self.obs.tracer.begin("tick", cat="engine", pid=1, tid=0,
                                    args={"slots": len(active),
                                          "spec": True})
        self._pre_tick(active)
        active = self._active_slots()
        if not active:
            self.obs.tracer.end(sid)
            return
        if self._spec_jit is None:
            self._spec_jit = jax.jit(self._make_spec(), donate_argnums=(2,))
        tok_out, acc, self._cache = self._spec_jit(
            (self.params, self._draft),
            jnp.asarray(self._next_tok[:, None]), self._cache,
            jnp.asarray(self._pos), jnp.asarray(self._temps),
            jnp.asarray(self._seeds), jnp.asarray(self._steps),
            *self._decode_extra_args())
        tok_out = np.asarray(tok_out)
        acc = np.asarray(acc)                    # one sync with tok_out
        now = self._now_off()
        self.ticks += 1
        self.m["ticks"].inc()
        for i in active:
            r = self._slots[i]
            a = int(acc[i])
            self.spec_drafted += self.spec_k
            self.spec_accepted += a
            self.m["spec"].labels(kind="drafted").inc(self.spec_k)
            self.m["spec"].labels(kind="accepted").inc(a)
            self.obs.tracer.instant("spec", cat="spec", pid=2, tid=r.rid,
                                    args={"drafted": self.spec_k,
                                          "accepted": a})
            for j in range(a + 1):
                t = int(tok_out[i, j])
                self._emit_token(r, t, now)
                self._pos[i] += 1
                self._next_tok[i] = t
                self._steps[i] += 1
                if self._finished_by(r, t, int(self._pos[i])):
                    self._retire(i)
                    break
            if self._slots[i] is not None and self._has_paged:
                self._rollback_blocks(i)
        self.m["tick_s"].observe((obs_mod.now_ns() - t_ns) * 1e-9)
        self.obs.tracer.end(sid)

    def _decode_extra_args(self):
        # Bound the per-tick table view to the live logical depth: the decode
        # gather touches max_blocks*block_size rows otherwise, even when every
        # sequence is ten tokens deep.  Width is bucketed to powers of two
        # (floor 4) so jit retraces O(log max_blocks) times, not per step; the
        # model stores the cache-resident full-width table back into the
        # returned cache (see transformer._paged_store_tables), so narrowing
        # never changes donated cache leaf shapes.  Flash-striped pools
        # (stripes > 1) keep the full table: the stripe invariant addresses
        # the whole logical range on every shard.
        tables = self._tables
        if self._has_paged and self.alloc.stripes == 1:
            live = np.flatnonzero((tables >= 0).any(axis=0))
            deep = int(live[-1]) + 1 if live.size else 1
            w = 4
            while w < deep:
                w *= 2
            tables = tables[:, :min(w, self.max_blocks)]
        return (jnp.asarray(tables),)


class StaticEngine(_EngineBase):
    """Static batching: equal-length cohorts, bulk prefill, lockstep decode.

    One jit'd decode_step advances the whole cohort per tick; finished slots
    keep decoding into a discard buffer until the cohort drains, and queued
    requests wait for the next cohort.  Kept as the baseline the continuous
    engine is measured against (and stays bit-identical to, for greedy)."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None, obs=None):
        super().__init__(cfg, params, max_batch=max_batch, capacity=capacity,
                         seed=seed, plan=plan, obs=obs)
        self._decode = jax.jit(self._with_ctx(self.model.decode_step))
        self.ticks = 0

    def _next_cohort(self) -> List[Request]:
        by_len = defaultdict(list)
        for r in self.queue:
            by_len[len(r.prompt)].append(r)
        best = max(by_len.values(), key=len)[:self.max_batch]
        # single-pass partition (repeated list.remove is O(n^2) in queue len)
        chosen = {id(r) for r in best}
        self.queue = [r for r in self.queue if id(r) not in chosen]
        return best

    def _run_cohort(self, cohort: List[Request]):
        B = len(cohort)
        S = len(cohort[0].prompt)
        self._queue_gauges()
        for r in cohort:
            self._trace_phase(r, "prefill")
        prompts = jnp.asarray(np.stack([r.prompt for r in cohort]))
        cache = self.model.init_cache(B, self.capacity, dtype=jnp.float32)
        logits, cache, n = self._prefill(self.params,
                                         {"tokens": prompts}, cache)
        self.m["prefill"].labels(kind="computed").inc(B * S)
        for r in cohort:
            self._trace_phase(r, "decode")
        logits = logits[:, 0]
        pos = S
        budget = max(r.max_tokens for r in cohort)
        for _ in range(min(budget, self.capacity - S - 1)):
            nxt = np.zeros(B, np.int32)
            now = self._now_off()
            for i, r in enumerate(cohort):
                if r.done:
                    continue
                if r.temperature > 0:
                    self.key, sub = jax.random.split(self.key)
                    t = int(jax.random.categorical(
                        sub, logits[i] / r.temperature))
                else:
                    t = int(jnp.argmax(logits[i]))
                self._emit_token(r, t, now)
                nxt[i] = t
                if (r.eos is not None and t == r.eos) or \
                        len(r.out) >= r.max_tokens:
                    r.done = True
            if all(r.done for r in cohort):
                break
            t_ns = obs_mod.now_ns()
            sid = self.obs.tracer.begin("tick", cat="engine", pid=1, tid=0,
                                        args={"slots": B})
            lg, cache = self._decode(self.params, jnp.asarray(nxt)[:, None],
                                     cache, jnp.asarray(pos))
            logits = lg[:, 0]
            pos += 1
            self.ticks += 1
            self.m["ticks"].inc()
            self.m["tick_s"].observe((obs_mod.now_ns() - t_ns) * 1e-9)
            self.obs.tracer.end(sid)
        now = self._now_off()
        for r in cohort:
            r.done = True
            r.finish_wall = now
            self.finished[r.rid] = r
            self.m["finished"].labels(slo=r.slo).inc()
            self.m["latency"].labels(slo=r.slo).observe(now)
            self._trace_finish(r)
            if self.on_finish is not None:
                self.on_finish(r)

    def run(self):
        self._t0_ns = obs_mod.now_ns()
        while self.queue:
            self._run_cohort(self._next_cohort())
        self.m["run_s"].set(self._now_off())
        self._queue_gauges()
        return self
