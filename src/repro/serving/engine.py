"""Batched serving engines: continuous batching (default) + static cohorts.

``Engine`` is a vLLM-style slot-pool scheduler built on the per-row cache
clocks in ``models/attention.py``: the KV cache is one persistent batched
allocation with ``max_batch`` slots, each slot running at its own absolute
position (``pos`` is a (B,) vector through the jit'd decode step).  New
requests are admitted into free slots mid-flight — a B=1 jit'd prefill
fills a fresh cache row which is scattered into the slot's row of the
batched cache — and slots retire independently on EOS / token budget, so a
finished request never burns decode steps into a discard buffer and the
next queued request takes its slot on the same tick.  Sampling (argmax +
per-slot-temperature categorical) runs inside the jit'd decode step; the
scheduler syncs exactly one (B,) token vector per tick instead of issuing
a per-request ``int(argmax)`` host round-trip.

``StaticEngine`` keeps the old equal-length-cohort lockstep scheduler as
the comparison baseline (``benchmarks/bench_serving.py`` measures both).

Both engines work with dense or OAC-quantized params for every assigned
architecture.  Pass a ``repro.dist`` ShardingPlan to run prefill/decode
under a mesh (tensor-parallel serving); without one the engine is
single-device.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_tokens: int = 32
    temperature: float = 0.0
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # scheduler telemetry (continuous engine): tick of admission/retirement
    # and wall-clock completion offset from run() start (benchmarks).
    admit_tick: int = -1
    finish_tick: int = -1
    finish_wall: float = 0.0


def cache_batch_axes(model, capacity):
    """Per-leaf batch-axis indices for ``model``'s cache pytree, found
    structurally: the one axis whose size changes between init_cache(B=2)
    and init_cache(B=3).  This is what lets any architecture's cache (KV
    stacks, SSM/RWKV states, per-row slot clocks) scatter/gather batch
    rows through one code path."""
    s2 = model.init_cache(2, capacity, abstract=True)
    s3 = model.init_cache(3, capacity, abstract=True)
    return [next(i for i, (a, b) in enumerate(zip(x.shape, y.shape))
                 if a != b)
            for x, y in zip(jax.tree.leaves(s2), jax.tree.leaves(s3))]


def _sample_tokens(logits, temps, key):
    """Batched on-device sampling: logits (B,V), temps (B,) -> (B,) int32.

    temp == 0 rows take the argmax (bit-identical to the host-side
    ``int(jnp.argmax(...))`` the static engine historically did); temp > 0
    rows draw from categorical(logits / temp) with a per-row key."""
    B = logits.shape[0]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = jax.random.split(key, B)
    safe_t = jnp.maximum(temps, 1e-6)[:, None]
    drawn = jax.vmap(jax.random.categorical)(keys, logits / safe_t)
    return jnp.where(temps > 0, drawn.astype(jnp.int32), greedy)


class _EngineBase:
    """Shared queue/jit plumbing for both schedulers."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.capacity = capacity
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.key = jax.random.PRNGKey(seed)
        self.ctx = None
        if plan is not None:
            from repro.configs.base import ShapeConfig
            c = plan.ctx(ShapeConfig("serve", capacity, max_batch, "decode"))
            # admission batches can be smaller than max_batch, so keep the
            # batch replicated: only the params/cache layouts (tp) are pinned
            self.ctx = dataclasses.replace(c, batch_spec=None)
            self.params = jax.device_put(params, plan.param_shardings(params))
        self._prefill = jax.jit(self._with_ctx(self.model.prefill))
        self._next_rid = 0

    def _with_ctx(self, fn):
        if self.ctx is None:
            return fn

        def wrapped(*args):
            from repro.dist import ctx as dctx
            with dctx.use(self.ctx):
                return fn(*args)
        return wrapped

    def submit(self, prompt, **kw) -> Request:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) >= self.capacity - 1:
            raise ValueError(
                f"prompt of {len(prompt)} tokens does not fit the "
                f"capacity-{self.capacity} cache with room to decode")
        r = Request(self._next_rid, prompt, **kw)
        self._next_rid += 1
        self.queue.append(r)
        return r


class Engine(_EngineBase):
    """Continuous-batching slot-pool scheduler (see module docstring).

    Slot state lives on the host (numpy vectors indexed by slot id); the
    batched cache and the per-row clock vector live on device.  One tick =
    one jit'd decode step over all ``max_batch`` rows; rows whose slot is
    free still flow through the math (their output is discarded and their
    clock does not advance) — with a persistent batched cache this is the
    standard padded-slot trade: the decode step stays one compiled
    executable for the engine's lifetime.
    """

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None):
        super().__init__(cfg, params, max_batch=max_batch, capacity=capacity,
                         seed=seed, plan=plan)
        B = max_batch
        self._slots: List[Optional[Request]] = [None] * B
        self._pos = np.zeros(B, np.int32)        # per-slot cache clock
        self._temps = np.zeros(B, np.float32)
        self._next_tok = np.zeros(B, np.int32)   # token each slot feeds next
        self.ticks = 0
        self._cache = self.model.init_cache(B, capacity, dtype=jnp.float32)
        cache_sh = None
        if plan is not None:
            # pin the persistent cache to the plan's layout so per-slot
            # insertion updates in place instead of bouncing the whole
            # cache between layouts every admission
            cache_sh = plan.cache_shardings(
                self.model.init_cache(B, capacity, abstract=True), self.ctx)
            self._cache = jax.device_put(self._cache, cache_sh)
        self._insert = self._make_insert(cache_sh)
        # the cache is donated through every step so the persistent batched
        # allocation updates in place instead of being copied per tick
        # (same contract as dist.steps.build_step's decode cell)
        self._decode = jax.jit(self._make_decode(), donate_argnums=(2,))
        self._first = jax.jit(_sample_tokens)

    # ------------------------------------------------------------- jit fns
    def _make_decode(self):
        model, with_ctx = self.model, self._with_ctx

        def step(params, tokens, cache, pos, temps, key):
            logits, cache = with_ctx(model.decode_step)(
                params, tokens, cache, pos)
            tok = _sample_tokens(logits[:, 0], temps, key)
            return tok, cache
        return step

    def _make_insert(self, cache_sh=None):
        """jit'd per-slot cache insertion: scatter a B=1 cache row into the
        batched cache at a (traced) slot index, along each leaf's
        structurally-found batch axis (``cache_batch_axes``)."""
        axes = cache_batch_axes(self.model, self.capacity)

        def insert(big, row, slot):
            flat, td = jax.tree.flatten(big)
            rows = jax.tree.leaves(row)
            out = [jax.lax.dynamic_update_slice_in_dim(
                b, r.astype(b.dtype), slot, axis=ax)
                for b, r, ax in zip(flat, rows, axes)]
            return jax.tree.unflatten(td, out)
        if cache_sh is None:
            return jax.jit(insert, donate_argnums=(0,))
        return jax.jit(insert, donate_argnums=(0,), out_shardings=cache_sh)

    # ----------------------------------------------------------- scheduler
    def _free_slots(self):
        return [i for i, s in enumerate(self._slots) if s is None]

    def _retire(self, i: int):
        r = self._slots[i]
        r.done = True
        r.finish_tick = self.ticks
        r.finish_wall = time.perf_counter() - self._t0
        self.finished[r.rid] = r
        self._slots[i] = None

    def _finished_by(self, r: Request, tok: int, pos: int) -> bool:
        return (r.eos is not None and tok == r.eos) or \
            len(r.out) >= r.max_tokens or pos >= self.capacity - 1

    def _admit(self):
        """Fill free slots from the queue (FIFO): B=1 prefill, scatter the
        row into the batched cache, sample the first token on device."""
        for i in self._free_slots():
            if not self.queue:
                return
            r = self.queue.pop(0)
            S = len(r.prompt)
            row = self.model.init_cache(1, self.capacity, dtype=jnp.float32)
            logits, row, _ = self._prefill(
                self.params, {"tokens": jnp.asarray(r.prompt[None])}, row)
            self._cache = self._insert(self._cache, row, i)
            self.key, sub = jax.random.split(self.key)
            t = int(self._first(logits[:, 0],
                                jnp.full((1,), r.temperature, jnp.float32),
                                sub)[0])
            r.out.append(t)
            r.admit_tick = self.ticks
            if self._finished_by(r, t, S):
                self._slots[i] = r
                self._retire(i)
                continue
            self._slots[i] = r
            self._pos[i] = S
            self._temps[i] = r.temperature
            self._next_tok[i] = t

    def _tick(self):
        """One lockstep device step for every slot; one host sync."""
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return
        self.key, sub = jax.random.split(self.key)
        toks, self._cache = self._decode(
            self.params, jnp.asarray(self._next_tok[:, None]), self._cache,
            jnp.asarray(self._pos), jnp.asarray(self._temps), sub)
        toks = np.asarray(toks)                  # the tick's single sync
        self.ticks += 1
        for i in active:
            r = self._slots[i]
            t = int(toks[i])
            r.out.append(t)
            self._pos[i] += 1
            self._next_tok[i] = t
            if self._finished_by(r, t, int(self._pos[i])):
                self._retire(i)

    def run(self):
        self._t0 = time.perf_counter()
        while self.queue or any(s is not None for s in self._slots):
            self._admit()
            self._tick()
        return self


class StaticEngine(_EngineBase):
    """Static batching: equal-length cohorts, bulk prefill, lockstep decode.

    One jit'd decode_step advances the whole cohort per tick; finished slots
    keep decoding into a discard buffer until the cohort drains, and queued
    requests wait for the next cohort.  Kept as the baseline the continuous
    engine is measured against (and stays bit-identical to, for greedy)."""

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 capacity: int = 512, seed: int = 0, plan=None):
        super().__init__(cfg, params, max_batch=max_batch, capacity=capacity,
                         seed=seed, plan=plan)
        self._decode = jax.jit(self._with_ctx(self.model.decode_step))

    def _next_cohort(self) -> List[Request]:
        by_len = defaultdict(list)
        for r in self.queue:
            by_len[len(r.prompt)].append(r)
        best = max(by_len.values(), key=len)[:self.max_batch]
        # single-pass partition (repeated list.remove is O(n^2) in queue len)
        chosen = {id(r) for r in best}
        self.queue = [r for r in self.queue if id(r) not in chosen]
        return best

    def _run_cohort(self, cohort: List[Request]):
        B = len(cohort)
        S = len(cohort[0].prompt)
        prompts = jnp.asarray(np.stack([r.prompt for r in cohort]))
        cache = self.model.init_cache(B, self.capacity, dtype=jnp.float32)
        logits, cache, n = self._prefill(self.params,
                                         {"tokens": prompts}, cache)
        logits = logits[:, 0]
        pos = S
        budget = max(r.max_tokens for r in cohort)
        for _ in range(min(budget, self.capacity - S - 1)):
            nxt = np.zeros(B, np.int32)
            for i, r in enumerate(cohort):
                if r.done:
                    continue
                if r.temperature > 0:
                    self.key, sub = jax.random.split(self.key)
                    t = int(jax.random.categorical(
                        sub, logits[i] / r.temperature))
                else:
                    t = int(jnp.argmax(logits[i]))
                r.out.append(t)
                nxt[i] = t
                if (r.eos is not None and t == r.eos) or \
                        len(r.out) >= r.max_tokens:
                    r.done = True
            if all(r.done for r in cohort):
                break
            lg, cache = self._decode(self.params, jnp.asarray(nxt)[:, None],
                                     cache, jnp.asarray(pos))
            logits = lg[:, 0]
            pos += 1
        now = time.perf_counter() - self._t0
        for r in cohort:
            r.done = True
            r.finish_wall = now
            self.finished[r.rid] = r

    def run(self):
        self._t0 = time.perf_counter()
        while self.queue:
            self._run_cohort(self._next_cohort())
        return self
