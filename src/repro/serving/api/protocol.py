"""Request/response schemas for the HTTP serving front end.

One place owns validation and JSON shapes, so the asyncio server stays a
transport layer and the integration tests can pin the schema without a
socket.  The completion API is OpenAI-style (``POST /v1/completions``)
with two repo-specific notes, both documented in ``docs/http_api.md``:

  * there is no tokenizer in this repo — ``prompt`` is a list of token
    ids, and streamed chunks carry ``token_id`` (with ``text`` rendered
    as the decimal id plus a space, so piping the stream through a real
    detokenizer is a drop-in swap);
  * ``slo`` ("interactive" | "batch") and ``seed`` map straight onto
    ``Engine.submit`` — SLO orders admission/preemption, seed keys the
    per-request sampling stream (temperature > 0 output is reproducible
    for a given seed regardless of co-batching).
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

from repro.serving.engine import SLO_RANK

#: hard cap on request bodies (a completion request is a few KB of token
#: ids; anything larger is a client bug or abuse, rejected 413 before parse)
MAX_BODY_BYTES = 1 << 20


class ApiError(Exception):
    """Client-visible request failure -> HTTP ``status`` + JSON error."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


@dataclasses.dataclass
class CompletionRequest:
    prompt: List[int]
    max_tokens: int = 32
    temperature: float = 0.0
    seed: Optional[int] = None
    slo: str = "interactive"
    eos: Optional[int] = None
    stream: bool = True

    def submit_kwargs(self) -> dict:
        return {"max_tokens": self.max_tokens,
                "temperature": self.temperature, "seed": self.seed,
                "slo": self.slo, "eos": self.eos}


def _field(body: dict, name: str, types, default, lo=None, hi=None):
    v = body.get(name, default)
    if v is None:
        return None
    if isinstance(v, bool) or not isinstance(v, types):
        want = getattr(types, "__name__", None) or "/".join(
            t.__name__ for t in types)
        raise ApiError(400, f"{name!r} must be {want}, "
                            f"got {type(v).__name__}")
    if lo is not None and v < lo:
        raise ApiError(400, f"{name!r} must be >= {lo}, got {v}")
    if hi is not None and v > hi:
        raise ApiError(400, f"{name!r} must be <= {hi}, got {v}")
    return v


def parse_completion(body_bytes: bytes, *, capacity: int,
                     vocab: int) -> CompletionRequest:
    """Validate a ``/v1/completions`` body.  Every failure is a 4xx
    ``ApiError`` raised *before* anything reaches the engine driver
    thread — a malformed or over-length request never wedges serving."""
    if len(body_bytes) > MAX_BODY_BYTES:
        raise ApiError(413, f"body of {len(body_bytes)} B exceeds the "
                            f"{MAX_BODY_BYTES} B limit")
    try:
        body = json.loads(body_bytes or b"null")
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ApiError(400, f"body is not valid JSON: {e}") from e
    if not isinstance(body, dict):
        raise ApiError(400, "body must be a JSON object")

    prompt = body.get("prompt")
    if not isinstance(prompt, list) or not prompt or \
            not all(isinstance(t, int) and not isinstance(t, bool)
                    for t in prompt):
        raise ApiError(400, "'prompt' must be a non-empty list of token "
                            "ids (this server has no tokenizer)")
    if any(t < 0 or t >= vocab for t in prompt):
        raise ApiError(400, f"prompt token out of range for vocab {vocab}")
    if len(prompt) >= capacity - 1:
        raise ApiError(400, f"prompt of {len(prompt)} tokens does not fit "
                            f"the capacity-{capacity} cache with room to "
                            "decode")

    slo = body.get("slo", "interactive")
    if slo not in SLO_RANK:
        raise ApiError(400, f"'slo' must be one of {sorted(SLO_RANK)}, "
                            f"got {slo!r}")
    stream = body.get("stream", True)
    if not isinstance(stream, bool):
        raise ApiError(400, "'stream' must be a boolean")
    temperature = _field(body, "temperature", (int, float), 0.0, lo=0.0)
    return CompletionRequest(
        prompt=prompt,
        max_tokens=_field(body, "max_tokens", int, 32, lo=1, hi=1 << 20),
        temperature=float(temperature),
        seed=_field(body, "seed", int, None, lo=0),
        slo=slo,
        eos=_field(body, "eos", int, None, lo=0),
        stream=stream)


# --------------------------------------------------------------- responses

def chunk_json(model: str, rid: int, token: int,
               finish_reason: Optional[str] = None) -> dict:
    """One streamed SSE chunk (or the final zero-token chunk carrying the
    finish reason)."""
    choice = {"index": 0,
              "text": f"{token} " if token is not None else "",
              "token_id": token,
              "finish_reason": finish_reason}
    return {"id": f"cmpl-{rid}", "object": "text_completion",
            "model": model, "choices": [choice]}


def completion_json(model: str, rid: int, prompt_tokens: int,
                    tokens: List[int], finish_reason: str) -> dict:
    """The non-streaming response body."""
    return {
        "id": f"cmpl-{rid}", "object": "text_completion", "model": model,
        "choices": [{"index": 0,
                     "text": "".join(f"{t} " for t in tokens),
                     "token_ids": tokens,
                     "finish_reason": finish_reason}],
        "usage": {"prompt_tokens": prompt_tokens,
                  "completion_tokens": len(tokens),
                  "total_tokens": prompt_tokens + len(tokens)},
    }


def error_json(status: int, message: str) -> dict:
    return {"error": {"code": status, "message": message}}


def finish_reason(r) -> str:
    """Map a finished ``Request`` to the wire finish_reason."""
    if r.cancelled:
        return "cancelled"
    if r.eos is not None and r.out and r.out[-1] == r.eos:
        return "stop"
    return "length"
