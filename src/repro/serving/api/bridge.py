"""Thread-safe bridge between asyncio HTTP handlers and the jit'd engine.

The engine is single-threaded by construction: its scheduler state (slot
vectors, block tables, the donated device cache) is unlocked, and every
jax dispatch must come from one thread.  ``EngineBridge`` therefore owns a
**driver thread** that runs the engine loop (``Engine.serve_step``: admit
-> chunk prefills -> decode tick) and funnels every mutation through it:

  * HTTP handlers never touch the engine.  ``await bridge.submit(...)``
    posts a command onto a thread-safe inbox and resolves once the driver
    has admitted the request into the engine queue; cancels (client
    disconnects) post the same way and retire the slot between ticks,
    returning its blocks to the pool.
  * Tokens flow the other way through the engine's ``on_token`` /
    ``on_finish`` hooks: the driver pushes ``("tok", t)`` /
    ``("done", reason)`` items into a per-request ``asyncio.Queue`` via
    ``loop.call_soon_threadsafe`` — the handler just drains its queue and
    frames SSE events.
  * ``/metrics`` renders the engine's live ``MetricsRegistry`` under the
    same mutex the driver holds across a step, so a scrape never races a
    half-updated family.

The driver idles on an event when the engine has no work (no busy-wait)
and wakes on the next submit.  Admission stalls — a queued request that
can never fit the block pool even with the engine idle — are shed back to
their clients as stream errors instead of wedging the thread, mirroring
``Engine.run``'s stall detection.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import queue as queue_mod
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs import prom
from repro.serving.api.protocol import finish_reason


class StreamHandle:
    """What a handler gets back from ``submit``: the engine request (rid,
    prompt, slo, ...) plus the asyncio queue its stream items land on.
    Items: ``("tok", token_id)``, ``("done", finish_reason)``,
    ``("error", message)`` — done/error are terminal."""

    __slots__ = ("request", "queue")

    def __init__(self, request, q: asyncio.Queue):
        self.request = request
        self.queue = q

    @property
    def rid(self) -> int:
        return self.request.rid


class EngineBridge:
    def __init__(self, engine, *, idle_wait: float = 0.05,
                 stall_limit: int = 3):
        self.engine = engine
        self.idle_wait = idle_wait
        self.stall_limit = stall_limit
        self.error: Optional[BaseException] = None
        self.started_ns: Optional[int] = None
        # lock: engine + metrics-registry mutations (driver) vs /metrics
        # renders and /healthz stat reads (handler threads)
        self.lock = threading.Lock()
        self._inbox: queue_mod.Queue = queue_mod.Queue()
        self._streams: Dict[int, Tuple[asyncio.AbstractEventLoop,
                                       asyncio.Queue]] = {}
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        engine.on_token = self._on_token
        engine.on_finish = self._on_finish

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "EngineBridge":
        assert self._thread is None, "bridge already started"
        self._thread = threading.Thread(target=self._drive,
                                        name="engine-driver", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0):
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    # ------------------------------------------------- handler-side surface
    async def submit(self, prompt, **submit_kwargs) -> StreamHandle:
        """Admit a request from an asyncio handler.  Raises whatever
        ``Engine.submit`` raises (e.g. ValueError on an over-capacity
        prompt) and RuntimeError if the driver thread is down."""
        if self.error is not None:
            raise RuntimeError(f"engine driver died: {self.error!r}")
        loop = asyncio.get_running_loop()
        fut: concurrent.futures.Future = concurrent.futures.Future()
        q: asyncio.Queue = asyncio.Queue()
        self._inbox.put(("submit", np.asarray(prompt, np.int32),
                         submit_kwargs, fut, loop, q))
        self._wake.set()
        request = await asyncio.wrap_future(fut)
        return StreamHandle(request, q)

    def cancel(self, rid: int):
        """Abort ``rid`` (thread-safe, non-blocking): the driver retires
        its slot between ticks and frees its blocks."""
        self._inbox.put(("cancel", rid))
        self._wake.set()

    def metrics_text(self) -> str:
        """The engine's registry as Prometheus 0.0.4 text exposition,
        rendered under the driver mutex."""
        with self.lock:
            return prom.render(self.engine.obs.metrics)

    def stats(self) -> dict:
        """Scheduler snapshot for ``/healthz`` (consistent under lock)."""
        with self.lock:
            eng = self.engine
            return {
                "status": "error" if self.error is not None else "ok",
                "error": repr(self.error) if self.error else None,
                "queue_depth": len(eng.queue),
                "active_slots": sum(s is not None for s in eng._slots),
                "max_batch": eng.max_batch,
                "capacity": eng.capacity,
                "ticks": eng.ticks,
                "requests_finished": len(eng.finished),
            }

    # ------------------------------------------------- engine-side (driver)
    def _post(self, loop, q, item):
        try:
            loop.call_soon_threadsafe(q.put_nowait, item)
        except RuntimeError:
            pass          # client's loop is gone; its cancel is in flight

    def _on_token(self, r, tok: int):
        s = self._streams.get(r.rid)
        if s is not None:
            self._post(*s, ("tok", int(tok)))

    def _on_finish(self, r):
        s = self._streams.pop(r.rid, None)
        if s is not None:
            self._post(*s, ("done", finish_reason(r)))

    def _push(self, rid: int, item):
        s = self._streams.get(rid)
        if s is not None:
            self._post(*s, item)

    def _drain_inbox(self):
        while True:
            try:
                cmd = self._inbox.get_nowait()
            except queue_mod.Empty:
                return
            if cmd[0] == "submit":
                _, prompt, kw, fut, loop, q = cmd
                with self.lock:
                    try:
                        r = self.engine.submit(prompt, **kw)
                    except Exception as e:          # over-length, bad kw
                        fut.set_exception(e)
                        continue
                    self._streams[r.rid] = (loop, q)
                fut.set_result(r)
            elif cmd[0] == "cancel":
                with self.lock:
                    self.engine.cancel(cmd[1])

    def _shed_queue(self):
        """Admission is stalled with an idle engine: every queued request
        exceeds what the pool can ever hold.  Error their streams and
        cancel them so the driver goes back to serving, instead of raising
        like ``Engine.run`` does."""
        eng = self.engine
        with self.lock:
            for r in list(eng.queue):
                self._push(r.rid, (
                    "error", f"request {r.rid} cannot be scheduled: its "
                             "working set exceeds the KV block pool"))
                eng.cancel(r.rid)

    def _drive(self):
        from repro import obs as obs_mod
        self.started_ns = obs_mod.now_ns()
        stalls = 0
        try:
            while not self._stop.is_set():
                self._drain_inbox()
                with self.lock:
                    eng = self.engine
                    done0 = len(eng.finished)
                    busy = eng.serve_step()
                    progressed = eng._busy() or eng._prefilling() or \
                        len(eng.finished) > done0
                    queued = bool(eng.queue)
                if progressed:
                    stalls = 0
                elif queued:
                    # nothing running: backoffs cannot expire naturally —
                    # force retries, then shed what still cannot fit
                    stalls += 1
                    with self.lock:
                        for r in eng.queue:
                            r._not_before = 0
                    if stalls > self.stall_limit:
                        self._shed_queue()
                        stalls = 0
                else:
                    stalls = 0
                if not busy:
                    self._wake.wait(self.idle_wait)
                    self._wake.clear()
        except BaseException as e:                  # pragma: no cover
            self.error = e
            for rid in list(self._streams):
                self._push(rid, ("error", f"engine driver died: {e!r}"))
            raise
