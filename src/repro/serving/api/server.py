"""Asyncio HTTP front end over the serving engine (stdlib-only).

``ApiServer`` binds an asyncio stream server (no framework — the repo
adds no deps) and speaks just enough HTTP/1.1 for the serving surface:

  ``POST /v1/completions``  OpenAI-style completion; ``"stream": true``
                            (default) frames each token as an SSE
                            ``data:`` event and ends with ``data: [DONE]``
  ``GET /metrics``          live Prometheus 0.0.4 exposition of the
                            engine's ``MetricsRegistry``
  ``GET /healthz``          scheduler liveness snapshot (queue depth,
                            active slots, ticks)
  ``GET /v1/models``        the served model: arch, quant method, wbits,
                            kv_bits from the checkpoint manifest

Every response closes its connection (``Connection: close``), which keeps
the framing trivial and is how the stream signals completion to clients
without chunked encoding.  Client disconnects are detected two ways —
EOF on the request socket (watched concurrently with the token queue) and
write failures — and both funnel into ``bridge.cancel``, so an abandoned
stream's slot and KV blocks return to the pool within a tick.

The server runs its own event loop on a background thread (``start()`` /
``stop()``), so the CLI, tests, and benchmarks share one entry point.
"""
from __future__ import annotations

import asyncio
import json
import threading
from typing import Optional

from repro.serving.api import protocol
from repro.serving.api.bridge import EngineBridge
from repro.serving.api.protocol import ApiError

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 413: "Payload Too Large",
            500: "Internal Server Error", 503: "Service Unavailable"}
_MAX_HEADER_BYTES = 32768


def _head(status: int, ctype: str, extra: str = "") -> bytes:
    return (f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}\r\n"
            f"Content-Type: {ctype}\r\n"
            f"Connection: close\r\n{extra}\r\n").encode()


def _response(status: int, body: bytes, ctype: str) -> bytes:
    return _head(status, ctype,
                 f"Content-Length: {len(body)}\r\n") + body


def _json_response(status: int, obj) -> bytes:
    return _response(status, json.dumps(obj).encode(), "application/json")


def _sse_event(obj) -> bytes:
    return b"data: " + json.dumps(obj).encode() + b"\n\n"


class ApiServer:
    def __init__(self, bridge: EngineBridge, *, model_info: Optional[dict]
                 = None, host: str = "127.0.0.1", port: int = 0):
        self.bridge = bridge
        self.model_info = dict(model_info or {})
        self.host = host
        self.port = port              # 0 = ephemeral; start() fills it in
        self._thread: Optional[threading.Thread] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop_ev: Optional[asyncio.Event] = None
        self._ready = threading.Event()
        self._startup_err: Optional[BaseException] = None

    @property
    def model_name(self) -> str:
        return str(self.model_info.get("arch", "repro"))

    # ----------------------------------------------------------- lifecycle
    def start(self) -> int:
        """Bind and serve on a background thread; returns the bound port."""
        assert self._thread is None, "server already started"
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._amain()),
            name="api-server", daemon=True)
        self._thread.start()
        self._ready.wait(timeout=30)
        if self._startup_err is not None:
            raise self._startup_err
        if not self._ready.is_set():
            raise RuntimeError("API server failed to start within 30s")
        return self.port

    def stop(self, timeout: float = 10.0):
        if self._loop is not None and self._stop_ev is not None:
            self._loop.call_soon_threadsafe(self._stop_ev.set)
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def join(self):
        """Block until the server thread exits (Ctrl-C to interrupt)."""
        while self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout=0.5)

    async def _amain(self):
        self._loop = asyncio.get_running_loop()
        self._stop_ev = asyncio.Event()
        try:
            server = await asyncio.start_server(self._handle, self.host,
                                                self.port)
        except OSError as e:
            self._startup_err = e
            self._ready.set()
            return
        self.port = server.sockets[0].getsockname()[1]
        self._ready.set()
        async with server:
            await self._stop_ev.wait()

    # ------------------------------------------------------------ plumbing
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        try:
            try:
                method, path, body = await self._read_request(reader)
            except ApiError as e:
                writer.write(_json_response(
                    e.status, protocol.error_json(e.status, e.message)))
                return
            await self._route(method, path, body, reader, writer)
        except (ConnectionError, asyncio.IncompleteReadError,
                asyncio.LimitOverrunError):
            pass                                   # client went away
        except Exception as e:                     # pragma: no cover
            try:
                writer.write(_json_response(
                    500, protocol.error_json(500, repr(e))))
            except Exception:
                pass
        finally:
            try:
                if writer.can_write_eof():
                    writer.write_eof()
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _read_request(self, reader):
        line = await reader.readline()
        parts = line.decode("latin-1").split()
        if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
            raise ApiError(400, f"malformed request line {line!r}")
        method, path = parts[0].upper(), parts[1]
        headers = {}
        total = len(line)
        while True:
            h = await reader.readline()
            total += len(h)
            if total > _MAX_HEADER_BYTES:
                raise ApiError(400, "header section too large")
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        body = b""
        try:
            n = int(headers.get("content-length", "0"))
        except ValueError:
            raise ApiError(400, "bad Content-Length") from None
        if n < 0 or n > protocol.MAX_BODY_BYTES:
            raise ApiError(413, f"Content-Length {n} out of range")
        if n:
            body = await reader.readexactly(n)
        return method, path, body

    async def _route(self, method, path, body, reader, writer):
        path = path.split("?", 1)[0]
        if path == "/v1/completions":
            if method != "POST":
                writer.write(_json_response(405, protocol.error_json(
                    405, "use POST /v1/completions")))
                return
            await self._completions(body, reader, writer)
        elif path == "/metrics" and method == "GET":
            writer.write(_response(
                200, self.bridge.metrics_text().encode(),
                "text/plain; version=0.0.4"))
        elif path == "/healthz" and method == "GET":
            st = self.bridge.stats()
            writer.write(_json_response(503 if st["status"] != "ok"
                                        else 200, st))
        elif path == "/v1/models" and method == "GET":
            writer.write(_json_response(200, {
                "object": "list",
                "data": [dict(self.model_info, id=self.model_name,
                              object="model")]}))
        else:
            writer.write(_json_response(404, protocol.error_json(
                404, f"no route for {method} {path}")))

    # --------------------------------------------------------- completions
    async def _completions(self, body, reader, writer):
        eng = self.bridge.engine
        try:
            req = protocol.parse_completion(
                body, capacity=eng.capacity, vocab=eng.cfg.vocab)
        except ApiError as e:
            writer.write(_json_response(
                e.status, protocol.error_json(e.status, e.message)))
            return
        try:
            handle = await self.bridge.submit(req.prompt,
                                              **req.submit_kwargs())
        except ValueError as e:
            writer.write(_json_response(400, protocol.error_json(400,
                                                                 str(e))))
            return
        except RuntimeError as e:
            writer.write(_json_response(503, protocol.error_json(503,
                                                                 str(e))))
            return
        # EOF on the request socket = client hung up; resolves while we
        # wait on the token queue so an abandoned stream cancels promptly
        watcher = asyncio.ensure_future(reader.read())
        try:
            if req.stream:
                await self._stream_response(req, handle, watcher, writer)
            else:
                await self._full_response(req, handle, watcher, writer)
        finally:
            watcher.cancel()

    async def _next_item(self, handle, watcher):
        """The next stream item, or None on client disconnect."""
        getter = asyncio.ensure_future(handle.queue.get())
        done, _ = await asyncio.wait(
            {getter, watcher}, return_when=asyncio.FIRST_COMPLETED)
        if getter in done:
            return getter.result()
        getter.cancel()
        return None

    async def _stream_response(self, req, handle, watcher, writer):
        writer.write(_head(200, "text/event-stream",
                           "Cache-Control: no-cache\r\n"))
        model = self.model_name
        while True:
            item = await self._next_item(handle, watcher)
            if item is None:                       # disconnect
                self.bridge.cancel(handle.rid)
                return
            kind, val = item
            if kind == "tok":
                writer.write(_sse_event(
                    protocol.chunk_json(model, handle.rid, val)))
            elif kind == "done":
                writer.write(_sse_event(
                    protocol.chunk_json(model, handle.rid, None,
                                        finish_reason=val)))
                writer.write(b"data: [DONE]\n\n")
                return
            else:                                  # terminal error
                writer.write(_sse_event(
                    {"error": {"message": val, "id": handle.rid}}))
                writer.write(b"data: [DONE]\n\n")
                return
            try:
                await writer.drain()
            except (ConnectionError, RuntimeError):
                self.bridge.cancel(handle.rid)
                return

    async def _full_response(self, req, handle, watcher, writer):
        tokens = []
        while True:
            item = await self._next_item(handle, watcher)
            if item is None:
                self.bridge.cancel(handle.rid)
                return
            kind, val = item
            if kind == "tok":
                tokens.append(val)
            elif kind == "done":
                writer.write(_json_response(200, protocol.completion_json(
                    self.model_name, handle.rid, len(req.prompt),
                    tokens, val)))
                return
            else:
                writer.write(_json_response(
                    503, protocol.error_json(503, val)))
                return
