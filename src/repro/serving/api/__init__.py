"""Asyncio HTTP serving front end over the paged engine.

Layers (one file each, composable without the others):

  ``protocol``  request validation + JSON/SSE wire shapes (no I/O)
  ``bridge``    the driver thread owning the jit'd engine loop and the
                thread-safe submit/stream/cancel surface
  ``server``    the asyncio stream server speaking HTTP/1.1 + SSE

``launch/serve.py --http PORT`` wires a loaded checkpoint into
``EngineBridge`` + ``ApiServer``; ``launch/client.py`` is the matching
reference client; ``docs/http_api.md`` specifies the wire format.
"""
from repro.serving.api.bridge import EngineBridge, StreamHandle
from repro.serving.api.protocol import (ApiError, CompletionRequest,
                                        parse_completion)
from repro.serving.api.server import ApiServer

__all__ = ["ApiServer", "EngineBridge", "StreamHandle", "ApiError",
           "CompletionRequest", "parse_completion"]
