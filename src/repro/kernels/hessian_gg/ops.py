"""Public op: H += G G^T using the triangular kernel on TPU.

The kernel fills the lower-triangular blocks; this wrapper mirrors them into
the full symmetric matrix and accumulates.  Non-TPU backends use the plain
einsum oracle (XLA's gemm is already optimal there and the dry-run counts
its FLOPs faithfully).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hessian_gg import kernel as _k
from repro.kernels.hessian_gg import ref as _r


def _mirror(L, bi):
    """Lower-block-triangular L -> full symmetric (diag blocks kept once)."""
    D = L.shape[0]
    mask = jnp.tril(jnp.ones((D, D), bool))
    Lt = jnp.where(mask, L, 0.0)
    return Lt + jnp.where(mask & ~jnp.eye(D, dtype=bool), Lt, 0.0).T


def gg_update(G, H=None, *, force_kernel=False, interpret=False, bi=256):
    on_tpu = jax.default_backend() == "tpu"
    if not (force_kernel or on_tpu):
        return _r.gg_ref(G, H)
    D = G.shape[0]
    bi = min(bi, D)
    while D % bi:
        bi //= 2
    tri = _k.gg_tri_kernel(G, bi=bi, interpret=interpret or not on_tpu)
    full = _mirror(tri, bi)
    return full if H is None else H + full
