"""Pure-jnp oracle for the symmetric Hessian accumulation H += G G^T."""
from __future__ import annotations

import jax.numpy as jnp


def gg_ref(G, H=None):
    """G (d_in, d_out) -> H (d_in, d_in) += G @ G^T (fp32)."""
    Gf = G.astype(jnp.float32)
    out = Gf @ Gf.T
    return out if H is None else H + out
