"""Pallas TPU kernel: symmetric rank-k update H += G G^T (OAC phase 1).

The output-adaptive Hessian (paper eq. 14/22) is symmetric, so only the
lower-triangular blocks need computing — the grid is the flattened triangle
T = I*(I+1)/2 of (bi x bi) output tiles, decoded back to (i, j) inside the
index maps.  This halves MXU work vs the naive d_in^2 d_out matmul; ops.py
mirrors the result.  The contraction (d_out) dim is the innermost
``arbitrary`` grid axis accumulating into the output VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.dist.compat  # noqa: F401  (aliases pltpu.CompilerParams on older jax)


def _tri_ij(t):
    """Triangle index t -> (i, j), j <= i, row-major over the triangle."""
    tf = t.astype(jnp.float32)
    i = jnp.floor((jnp.sqrt(8.0 * tf + 1.0) - 1.0) / 2.0).astype(jnp.int32)
    # guard float rounding at triangle boundaries
    base = (i * (i + 1)) // 2
    i = jnp.where(base > t, i - 1, i)
    i = jnp.where((i + 1) * (i + 2) // 2 <= t, i + 1, i)
    j = t - (i * (i + 1)) // 2
    return i, j


def _kernel(gi_ref, gj_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        gi_ref[...], gj_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bi", "bk", "interpret"))
def gg_tri_kernel(G, *, bi=256, bk=512, interpret=False):
    """G (D, d_out) -> lower-triangle blocks of G @ G^T, rest zeros."""
    D, d_out = G.shape
    bi = min(bi, D)
    bk = min(bk, d_out)
    assert D % bi == 0 and d_out % bk == 0, (D, d_out, bi, bk)
    nI = D // bi
    T = nI * (nI + 1) // 2
    grid = (T, d_out // bk)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bk), lambda t, k: (_tri_ij(t)[0], k)),
            pl.BlockSpec((bi, bk), lambda t, k: (_tri_ij(t)[1], k)),
        ],
        out_specs=pl.BlockSpec((bi, bi), lambda t, k: _tri_ij(t)),
        out_shape=jax.ShapeDtypeStruct((D, D), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(G, G)
