"""Public op: y = x @ W_quant (+ COO outlier correction).

Dispatch:
  * TPU: the Pallas kernel (packed planes stream HBM->VMEM, see kernel.py);
  * otherwise (CPU container, dry-run lowering): a BLOCKWISE jnp path that
    mirrors the kernel's tiling — each N-tile of W is unpacked transiently
    inside a scan body, so the bf16 weight matrix never materializes in HBM.
    This keeps the dry-run roofline honest about the packed-weight traffic.

``dequant_matmul_parts`` is the shard-shape-agnostic core: it takes raw
planes/scales/zeros (which may be a tp-local slice of a larger tensor) and
skips the outlier correction, so ``serving.qserve.linear`` can run it inside
a shard_map over tensor-parallel plane shards.  ``dequant_matmul`` is the
whole-tensor wrapper (core + COO outliers).

The SpQR outlier correction ``y[:, col] += x[:, row] * val`` is a fixed-
capacity COO scatter applied after the matmul (additive convention of
qformat).  Stacked QuantizedTensors (leading layer/expert dims) are handled
by the callers slicing before apply (scan) or vmapping.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qformat import QuantizedTensor, unpack
from repro.kernels.dequant_matmul import kernel as _k

_N_BLOCK = 1024


def outlier_correction(x2, qt: QuantizedTensor, y):
    """x2 (M, K); y (M, N) += scatter-add of COO corrections."""
    xa = x2[:, qt.out_rows]                         # (M, cap)
    upd = xa * qt.out_vals.astype(x2.dtype)[None, :]
    return y.at[:, qt.out_cols].add(upd.astype(y.dtype))


_outlier_correction = outlier_correction            # back-compat alias


def _jnp_blockwise(x2, planes, scales, zeros, *, bits, group_size,
                   resid_planes=None, resid_scales=None):
    K = x2.shape[1]
    N = scales.shape[-1]
    G = scales.shape[0]
    nb = max(N // _N_BLOCK, 1)
    while N % nb:
        nb -= 1
    bn = N // nb

    def block(_, bi):
        planes_b = tuple(
            jax.lax.dynamic_slice_in_dim(p, bi * bn, bn, axis=1)
            for p in planes)
        s_b = jax.lax.dynamic_slice_in_dim(scales, bi * bn, bn, axis=1)
        z_b = jax.lax.dynamic_slice_in_dim(zeros, bi * bn, bn, axis=1)
        codes = unpack(planes_b, bits, K).astype(jnp.float32)
        q = codes.reshape(G, group_size, bn)
        w = ((q - z_b[:, None, :]) * s_b[:, None, :]).reshape(K, bn)
        if resid_planes is not None:
            rb = unpack(tuple(
                jax.lax.dynamic_slice_in_dim(p, bi * bn, bn, axis=1)
                for p in resid_planes), 1, K).astype(jnp.float32)
            rs = jax.lax.dynamic_slice_in_dim(resid_scales, bi * bn, bn,
                                              axis=1)
            w = w + (rb * 2.0 - 1.0) * rs
        return None, x2 @ w.astype(x2.dtype)

    _, ys = jax.lax.scan(block, None, jnp.arange(nb))
    # ys (nb, M, bn) -> (M, N)
    return jnp.moveaxis(ys, 0, 1).reshape(x2.shape[0], N)


def dequant_matmul_parts(x2, planes, scales, zeros, *, bits, group_size,
                         resid_planes=None, resid_scales=None,
                         force_kernel: bool = False, interpret: bool = False):
    """Core x2 (M, K) @ deq(planes) (K, N) -> (M, N); no outlier correction.

    Shapes may be tp-local shards of a larger kernel: K/N are read off the
    operands, so a column (N/T) or row (K/T, group-aligned) slice lowers to
    the same kernel as the full tensor."""
    on_tpu = jax.default_backend() == "tpu"
    if force_kernel or on_tpu:
        M = x2.shape[0]
        bm = M if M < 128 else 128
        return _k.dequant_matmul_kernel(
            x2, planes, scales.astype(jnp.float32),
            zeros.astype(jnp.float32), resid_planes, resid_scales,
            bits=bits, group_size=group_size, bm=bm,
            interpret=interpret or not on_tpu)
    return _jnp_blockwise(x2, planes, scales, zeros, bits=bits,
                          group_size=group_size, resid_planes=resid_planes,
                          resid_scales=resid_scales)


def dequant_matmul(x, qt: QuantizedTensor, *, force_kernel: bool = False,
                   interpret: bool = False):
    """x (..., K) @ packed (K, N) -> (..., N) in x.dtype."""
    lead = x.shape[:-1]
    K, N = qt.shape
    x2 = x.reshape(-1, K)
    scales, zeros = qt.scales_zeros()
    y = dequant_matmul_parts(
        x2, qt.planes, scales, zeros, bits=qt.bits, group_size=qt.group_size,
        resid_planes=qt.resid_planes, resid_scales=qt.resid_scales,
        force_kernel=force_kernel, interpret=interpret)
    y = outlier_correction(x2, qt, y)
    return y.reshape(*lead, N).astype(x.dtype)
