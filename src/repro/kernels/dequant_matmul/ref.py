"""Pure-jnp oracle for the fused group-dequant matmul.

y = x @ dequant(codes, scales, zeros) with groups tiling the contraction dim.
Outlier COO correction is applied OUTSIDE the kernel (see ops.py) and is
therefore not part of this oracle.
"""
from __future__ import annotations

import jax.numpy as jnp


def dequant_ref(codes, scales, zeros, group_size: int):
    """codes (K, N) uint8 -> W (K, N) f32; scales/zeros (K//gs, N)."""
    K, N = codes.shape
    G = K // group_size
    q = codes.astype(jnp.float32).reshape(G, group_size, N)
    w = (q - zeros[:, None, :]) * scales[:, None, :]
    return w.reshape(K, N)


def dequant_matmul_ref(x, codes, scales, zeros, group_size: int):
    """x (M, K) @ dequant(codes) -> (M, N) f32."""
    w = dequant_ref(codes, scales, zeros, group_size)
    return x.astype(jnp.float32) @ w
