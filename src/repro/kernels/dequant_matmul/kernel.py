"""Pallas TPU kernel: fused group-dequant (2/3/4/8-bit packed) matmul.

The serving hot-spot for OAC-quantized checkpoints: streams packed uint8
code planes HBM->VMEM, unpacks to the MXU input dtype in VREGs, applies the
per-(group, column) scale/zero, and accumulates ``x @ W_deq`` on the MXU —
the bf16 weight tile never exists in HBM.

Tiling: grid (M/bm, N/bn, K/bk); K blocks are multiples of the quant group;
the f32 accumulator lives in the output VMEM block across the K loop
(``dimension_semantics=(parallel, parallel, arbitrary)``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.dist.compat  # noqa: F401  (aliases pltpu.CompilerParams on older jax)


def _unpack_block(refs, bits: int, bk: int):
    """uint8 plane block(s) -> (bk, bn) int32 codes."""
    if bits == 3:
        lo = _unpack_plane(refs[0][...], 2)
        hi = _unpack_plane(refs[1][...], 1)
        return lo + (hi << 2)
    return _unpack_plane(refs[0][...], bits)


def _unpack_plane(p, bits: int):
    """p (rows, bn) uint8, little-endian along rows -> (rows*8/bits, bn)."""
    per = 8 // bits
    rows, bn = p.shape
    x = p.astype(jnp.int32)                      # (rows, bn)
    shifts = (jnp.arange(per, dtype=jnp.int32) * bits)[None, :, None]
    vals = (x[:, None, :] >> shifts) & (2 ** bits - 1)
    return vals.reshape(rows * per, bn)


def _dequant_tile(planes, s_ref, z_ref, r_ref, rs_ref, *, bits, group_size,
                  bk, bn, in_dtype):
    """Unpack + dequantize one (bk, bn) weight tile in VREGs.

    ``r_ref``/``rs_ref`` (optional) are the BiLLM residual-carrier planes:
    a 1-bit sign plane and a per-element |w_hat| magnitude, added on top of
    the grouped grid exactly as ``QuantizedTensor.dequantize`` does."""
    codes = _unpack_block(planes, bits, bk).astype(jnp.float32)  # (bk, bn)
    gb = bk // group_size
    q = codes.reshape(gb, group_size, bn)
    w = (q - z_ref[...][:, None, :]) * s_ref[...][:, None, :]
    w = w.reshape(bk, bn)
    if r_ref is not None:
        rb = _unpack_plane(r_ref[...], 1).astype(jnp.float32)
        w = w + (rb * 2.0 - 1.0) * rs_ref[...].astype(jnp.float32)
    return w.astype(in_dtype)


def _kernel(x_ref, *refs, bits, group_size, resid, out_dtype):
    n_planes = 2 if bits == 3 else 1
    planes = refs[:n_planes]
    if resid:
        s_ref, z_ref, r_ref, rs_ref, o_ref = refs[n_planes:]
    else:
        s_ref, z_ref, o_ref = refs[n_planes:]
        r_ref = rs_ref = None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bk = x_ref.shape[1]
    bn = o_ref.shape[1]
    w = _dequant_tile(planes, s_ref, z_ref, r_ref, rs_ref, bits=bits,
                      group_size=group_size, bk=bk, bn=bn,
                      in_dtype=x_ref.dtype)
    o_ref[...] += jax.lax.dot(x_ref[...], w,
                              preferred_element_type=jnp.float32)


def _plane_rows(bits: int):
    if bits == 3:
        return (4, 8)     # 2-bit plane: 4 vals/byte; 1-bit plane: 8 vals/byte
    return (8 // bits,)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm",
                                             "bn", "bk", "interpret"))
def dequant_matmul_kernel(x, planes, scales, zeros, resid_planes=None,
                          resid_scales=None, *, bits, group_size,
                          bm=128, bn=256, bk=512, interpret=False):
    """x (M, K) x packed (K, N) -> (M, N) f32.

    planes: tuple of uint8 arrays ((K*b/8, N)) per qformat packing.
    scales/zeros: (K//gs, N) f32 (already double-dequantized).
    resid_planes/resid_scales (optional): BiLLM residual carrier — 1-bit
    sign plane (K/8, N) + per-element |w_hat| (K, N); fused into the tile
    dequant so residual checkpoints stay on the packed-stream path.
    """
    M, K = x.shape
    N = scales.shape[1]
    resid = resid_planes is not None
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    bk = max((bk // group_size) * group_size, group_size)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))]
    for per in _plane_rows(bits):
        in_specs.append(
            pl.BlockSpec((bk // per, bn), lambda i, j, k: (k, j)))
    gb = bk // group_size
    in_specs.append(pl.BlockSpec((gb, bn), lambda i, j, k: (k, j)))
    in_specs.append(pl.BlockSpec((gb, bn), lambda i, j, k: (k, j)))
    ins = [x, *planes, scales, zeros]
    if resid:
        in_specs.append(pl.BlockSpec((bk // 8, bn), lambda i, j, k: (k, j)))
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)))
        ins += [*resid_planes, resid_scales]

    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, group_size=group_size,
                          resid=resid, out_dtype=jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*ins)
