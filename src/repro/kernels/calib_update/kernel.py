"""Pallas TPU kernel: VMEM-resident OPTQ block calibration step.

GPTQ's sequential quantize -> error -> rank-1 update loop is memory-latency
bound on GPUs (the "lazy batch" trick exists to fight HBM churn).  TPU
adaptation (DESIGN.md §3): one quantization group (B consecutive contraction
rows) and a (bn)-wide tile of output columns are pinned in VMEM together
with the (B, B) local Cholesky block; the whole sequential loop runs
on-chip and writes Q / E / W_hat back once.  The grid is embarrassingly
parallel over output-column tiles; cross-block propagation (one MXU matmul
per block) happens in ops.py / solver.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.dist.compat  # noqa: F401  (aliases pltpu.CompilerParams on older jax)


def _kernel(w_ref, u_ref, s_ref, z_ref, m_ref, q_ref, e_ref, h_ref, *,
            bits: int):
    B, bn = w_ref.shape
    qmax = float(2 ** bits - 1)
    scale = s_ref[0, :]
    zero = z_ref[0, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (B, bn), 0)

    def body(i, W):
        w_i = W[i, :]
        q_i = jnp.clip(jnp.round(w_i / scale + zero), 0.0, qmax)
        dq = (q_i - zero) * scale
        o_i = m_ref[i, :] > 0
        dq_eff = jnp.where(o_i, w_i, dq)
        u_ii = u_ref[i, i]
        err = (w_i - dq_eff) / u_ii
        upd = u_ref[i, :][:, None] * err[None, :]
        W = W - jnp.where(rows > i, upd, 0.0)
        q_ref[i, :] = q_i.astype(jnp.float32)
        e_ref[i, :] = err
        h_ref[i, :] = dq_eff
        return W

    jax.lax.fori_loop(0, B, body, w_ref[...], unroll=False)


@functools.partial(jax.jit, static_argnames=("bits", "bn", "interpret"))
def calib_block_kernel(W, U, scale, zero, omask, *, bits, bn=256,
                       interpret=False):
    """One OPTQ group step.  W (B, N); U (B, B); scale/zero (N,); omask (B, N).

    Returns (Q (B,N) f32 codes, E (B,N) errors, W_hat (B,N)).
    """
    B, N = W.shape
    bn = min(bn, N)
    assert N % bn == 0, (N, bn)
    grid = (N // bn,)
    kern = functools.partial(_kernel, bits=bits)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bn), lambda j: (0, j)),
            pl.BlockSpec((B, B), lambda j: (0, 0)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
            pl.BlockSpec((B, bn), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((B, bn), lambda j: (0, j)),
            pl.BlockSpec((B, bn), lambda j: (0, j)),
            pl.BlockSpec((B, bn), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N), jnp.float32),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
            jax.ShapeDtypeStruct((B, N), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(W, U, scale[None, :], zero[None, :], omask)
