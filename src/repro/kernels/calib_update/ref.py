"""Pure-jnp oracle for one OPTQ block step (matches solver.calibrate's inner
loop): quantize B consecutive contraction rows of a (B, bn) tile with the
group grid, propagating the OBS error within the block.

Inputs:
  W   (B, bn)  current weight tile (one quant group)
  U   (B, B)   the local upper-Cholesky block of H^-1
  scale, zero (bn,) the group grid (precomputed, outliers excluded)
  omask (B, bn) 1.0 where the weight is an outlier (kept exact)
Outputs: (Q codes uint8, E errors, W_hat tile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_step_ref(W, U, scale, zero, omask, bits: int):
    B, bn = W.shape
    qmax = 2 ** bits - 1

    def col(carry, i):
        Wb, Q, E, Wh = carry
        w_i = Wb[i]
        q_i = jnp.clip(jnp.round(w_i / scale + zero), 0, qmax)
        dq = (q_i - zero) * scale
        o_i = omask[i] > 0
        dq_eff = jnp.where(o_i, w_i, dq)
        err = (w_i - dq_eff) / U[i, i]
        upd = U[i][:, None] * err[None, :]
        row_mask = (jnp.arange(B) > i)[:, None]
        Wb = Wb - jnp.where(row_mask, upd, 0.0)
        return (Wb, Q.at[i].set(q_i.astype(jnp.uint8)), E.at[i].set(err),
                Wh.at[i].set(dq_eff)), None

    init = (W, jnp.zeros((B, bn), jnp.uint8), jnp.zeros((B, bn), W.dtype),
            jnp.zeros((B, bn), W.dtype))
    (Wb, Q, E, Wh), _ = jax.lax.scan(col, init, jnp.arange(B))
    return Q, E, Wh
