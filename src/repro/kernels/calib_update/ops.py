"""Public op: one OPTQ group-block calibration step (kernel or oracle)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.calib_update import kernel as _k
from repro.kernels.calib_update import ref as _r


def calib_block(W, U, scale, zero, omask, *, bits, force_kernel=False,
                interpret=False):
    """Returns (Q uint8, E, W_hat) for one (B, N) group tile."""
    on_tpu = jax.default_backend() == "tpu"
    if force_kernel or on_tpu:
        q, e, h = _k.calib_block_kernel(
            W.astype(jnp.float32), U.astype(jnp.float32),
            scale.astype(jnp.float32), zero.astype(jnp.float32),
            omask.astype(jnp.float32), bits=bits,
            interpret=interpret or not on_tpu)
        return q.astype(jnp.uint8), e, h
    return _r.block_step_ref(W.astype(jnp.float32), U.astype(jnp.float32),
                             scale, zero, omask, bits)
