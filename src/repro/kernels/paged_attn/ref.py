"""Pure-jnp oracle for paged-attention decode.

Materializes the dense per-row view (the thing the kernel avoids) and runs
a two-pass softmax — the most literal possible statement of the math the
table-walking kernel must reproduce: position ``p`` of row ``b`` lives at
``(block_tables[b, p // bs], p % bs)`` in the pool, valid iff the logical
block is mapped and ``p <= pos[b]`` (and inside the sliding window when
``window > 0``).  int8 pools dequantize with the per-(token, kv-head)
scale planes exactly as ``qserve.kvquant.dequantize_kv`` does.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def paged_gather_ref(k_pool, block_tables, k_scale=None):
    """Dense (B, mb*bs, KV, Dh) f32 view of one pool + (B, mb*bs) mapped."""
    B, mb = block_tables.shape
    bs, KV, Dh = k_pool.shape[1:]
    safe = jnp.clip(block_tables, 0, k_pool.shape[0] - 1)
    k = k_pool[safe].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[safe].astype(jnp.float32)[..., None]
    mapped = jnp.repeat(block_tables >= 0, bs, axis=1)
    return k.reshape(B, mb * bs, KV, Dh), mapped


def paged_decode_ref(q, k_pool, v_pool, block_tables, pos, *, window=0,
                     k_scale=None, v_scale=None, pos_offset=0):
    """q (B,1,H,Dh) vs the paged pool -> (o_unnorm (B,H,Dh) f32, m, l).

    Returns flash-decoding partials (unnormalized out, row max, sumexp);
    normalize as ``o = o_unnorm / max(l, tiny)``.  ``pos_offset`` is the
    absolute position of the first table slot (tp stripe offset)."""
    B, _, H, Dh = q.shape
    KV = k_pool.shape[2]
    rep = H // KV
    k, mapped = paged_gather_ref(k_pool, block_tables, k_scale)
    v, _ = paged_gather_ref(v_pool, block_tables, v_scale)
    qg = (q[:, 0].astype(jnp.float32) * Dh ** -0.5).reshape(B, KV, rep, Dh)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg, k)
    posr = jnp.asarray(pos).reshape(B, 1)
    posn = pos_offset + jnp.arange(k.shape[1])[None]
    valid = mapped & (posn <= posr)
    if window:
        valid &= (posr - posn) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = s.max(axis=-1)
    e = jnp.exp(s - m[..., None])
    l = e.sum(axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", e, v)
    return (o.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))
