"""Public op: paged-attention decode over a block-table-addressed KV pool.

Dispatch:
  * TPU (or ``force_kernel``): the table-walking Pallas kernel (kernel.py)
    — walks ``block_tables`` via scalar prefetch, reads the pool in place,
    and fuses int8 dequant into the score loop.  Online-softmax partials,
    normalized here (or handed back raw for the flash psum combine).
  * otherwise (CPU container, dry-run lowering): the XLA block-gather
    fallback — gathers each row's blocks into a dense view and runs the
    exact pre-kernel lowering, so every committed bit-identity contract
    (paged vs dense greedy, flash stripe combine) is preserved verbatim.

Both paths share one addressing/masking contract: position ``p`` of row
``b`` lives at ``(block_tables[b, p // bs], p % bs)``, valid iff the
logical block is mapped (table entry >= 0) and ``p <= pos[b]`` (and inside
the sliding window when ``window > 0``).  Callers with a tp block stripe
(``_paged_flash_write``) pass stripe-local tables (foreign blocks -1) and
``pos_offset`` = the absolute position of table slot 0; masking is done in
int32 so the offset form is exact, not approximately equal.

``paged_view`` (the bounded gather) is exposed for the roofline byte
accounting of the unfused path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attn import kernel as _k

NEG_INF = -1e30


def paged_view(k_pool, block_tables, scale=None, dtype=None):
    """XLA gather: dense (B, mb*bs, KV, Dh) view of one pool.

    The gather is bounded by the table width callers pass — the serving
    engine slices tables to the live-block bucket, so the fallback stops
    paying for empty tail slots (ISSUE 7 satellite).  Unmapped entries
    clamp to physical block 0 (the reserved scratch block); the caller
    masks them via the returned ``mapped`` (B, mb*bs)."""
    B, mb = block_tables.shape
    bs, KV, Dh = k_pool.shape[1:]
    safe = jnp.clip(block_tables, 0, k_pool.shape[0] - 1)
    k = k_pool[safe]
    if scale is not None:
        from repro.serving.qserve import kvquant as KQ
        k = KQ.dequantize_kv(k, scale[safe])
    mapped = jnp.repeat(block_tables >= 0, bs, axis=1)
    return k.reshape(B, mb * bs, KV, Dh), mapped


def paged_scores(q, k, mapped, pos, window):
    """Masked (B, KV, rep, mb*bs) f32 scores — the pre-kernel lowering."""
    B, _, H, Dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = (q[:, 0] * Dh ** -0.5).reshape(B, KV, rep, Dh)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    posr = pos[:, None]
    posn = jnp.arange(k.shape[1])[None]
    valid = mapped & (posn <= posr)
    if window:
        valid &= (posr - posn) < window
    return jnp.where(valid[:, None, None], s, NEG_INF)


def _pos_eff(pos, pos_offset, B):
    """Stripe-local row clocks: integer shift keeps every mask bit exact."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (B,))
    return pos.astype(jnp.int32) - pos_offset


def paged_decode_partial(q, k_pool, v_pool, block_tables, pos, *, window=0,
                         k_scale=None, v_scale=None, pos_offset=0,
                         force_kernel=False, interpret=False):
    """Flash-decoding partials (o_unnorm (B,H,Dh) f32, m (B,H), l (B,H)).

    Combine across shards as ``psum(o*exp(m-M)) / psum(l*exp(m-M))`` with
    ``M = pmax(m)`` — the contract of ``decode_attention_partial``."""
    B = q.shape[0]
    posv = _pos_eff(pos, pos_offset, B)
    on_tpu = jax.default_backend() == "tpu"
    if force_kernel or on_tpu:
        return _k.paged_decode_kernel(
            q, k_pool, v_pool, block_tables, posv, k_scale, v_scale,
            window=window, interpret=interpret or not on_tpu)
    k, mapped = paged_view(k_pool, block_tables, k_scale)
    v, _ = paged_view(v_pool, block_tables, v_scale)
    s = paged_scores(q, k, mapped, posv, window)
    m = s.max(axis=-1)
    e = jnp.exp(s - m[..., None])
    l = e.sum(axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", e, v.astype(jnp.float32))
    B, _, H, Dh = q.shape
    return (o.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))


def paged_decode(q, k_pool, v_pool, block_tables, pos, *, window=0,
                 k_scale=None, v_scale=None, force_kernel=False,
                 interpret=False):
    """Normalized paged decode: q (B,1,H,Dh) -> (B,1,H,Dh).

    Output dtype follows the pre-kernel contract: fp pools return in the
    pool dtype (softmax weights are cast to it before the PV matmul);
    int8 pools compute in f32 and cast back to ``q.dtype``."""
    B, _, H, Dh = q.shape
    quant = k_scale is not None
    posv = _pos_eff(pos, 0, B)
    on_tpu = jax.default_backend() == "tpu"
    if force_kernel or on_tpu:
        o, m, l = _k.paged_decode_kernel(
            q, k_pool, v_pool, block_tables, posv, k_scale, v_scale,
            window=window, interpret=interpret or not on_tpu)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        o = o.astype(q.dtype if quant else k_pool.dtype)
        return o.reshape(B, 1, H, Dh)
    k, mapped = paged_view(k_pool, block_tables, k_scale)
    v, _ = paged_view(v_pool, block_tables, v_scale)
    s = paged_scores(q, k, mapped, posv, window)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p.astype(v.dtype), v)
    if quant:
        o = o.astype(q.dtype)
    return o.reshape(B, 1, H, Dh)
