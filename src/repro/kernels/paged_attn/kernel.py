"""Pallas TPU kernel: table-walking paged-attention decode.

One query token per row attends its whole paged KV history by *walking the
block table*: the grid is ``(B, max_blocks)`` and the pool BlockSpecs index
physical block ``block_tables[b, j]`` directly via scalar prefetch
(``PrefetchScalarGridSpec``) — the dense per-row KV view the XLA fallback
materializes never exists.  Unmapped table slots resolve to physical block
0 (the pool's reserved write scratch); consecutive repeats of the same
block index are not re-fetched by the pipeline emitter, so a row's empty
table tail costs ~one block DMA instead of ``max_blocks``.

int8 pools pass their ``k_scale``/``v_scale`` planes and the kernel fuses
dequant into the score loop (codes * scale in VREGs): quantized KV bytes
stream HBM->VMEM at 1B+scale per element and the bf16/f32 KV tile never
exists in HBM.

Softmax is the online (flash-decoding) recurrence: running (o_unnorm, m, l)
live in the output VMEM blocks across the ``j`` walk (block index depends
only on ``b``; ``dimension_semantics=(parallel, arbitrary)``), and the
caller normalizes or psum-combines — the same partials contract as
``models.attention.decode_attention_partial``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.dist.compat  # noqa: F401  (aliases pltpu.CompilerParams on older jax)

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, *rest, window, quant):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref = rest
    else:
        o_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    bs, KV, Dh = k_ref.shape[1:]
    H = q_ref.shape[1]
    rep = H // KV
    k = k_ref[0].astype(jnp.float32)                  # (bs, KV, Dh)
    v = v_ref[0].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0].astype(jnp.float32)[..., None]
        v = v * vs_ref[0].astype(jnp.float32)[..., None]
    qg = (q_ref[0].astype(jnp.float32) * Dh ** -0.5).reshape(KV, rep, Dh)
    # s[g, r, t] = sum_d qg[g, r, d] * k[t, g, d]
    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)
    posn = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    pr = pos_ref[b]
    valid = (bt_ref[b, j] >= 0) & (posn <= pr)
    if window:
        valid &= (pr - posn) < window
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    m_prev = m_ref[...].reshape(KV, rep)
    m_new = jnp.maximum(m_prev, s.max(-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l_ref[...].reshape(KV, rep) * alpha + p.sum(-1)
    # pv[g, r, d] = sum_t p[g, r, t] * v[t, g, d]
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)
    o_new = o_ref[...].reshape(KV, rep, Dh) * alpha[..., None] + pv
    o_ref[...] = o_new.reshape(1, H, Dh)
    m_ref[...] = m_new.reshape(1, H)
    l_ref[...] = l_new.reshape(1, H)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_kernel(q, k_pool, v_pool, block_tables, pos,
                        k_scale=None, v_scale=None, *, window=0,
                        interpret=False):
    """q (B,1,H,Dh) vs paged pools -> (o_unnorm (B,H,Dh) f32, m, l (B,H)).

    ``block_tables (B, mb)`` int32 (-1 unmapped), ``pos (B,)`` row clocks;
    position ``p`` lives at ``(block_tables[b, p // bs], p % bs)``.  Pass
    ``k_scale``/``v_scale (num_blocks, bs, KV)`` for int8 pools (fused
    dequant).  Partials combine exactly like ``decode_attention_partial``.
    """
    B, _, H, Dh = q.shape
    bs, KV = k_pool.shape[1:3]
    mb = block_tables.shape[1]
    quant = k_scale is not None
    q2 = q.reshape(B, H, Dh)

    def pool_blk(b, j, bt, pos_s):
        return (jnp.where(bt[b, j] >= 0, bt[b, j], 0), 0, 0, 0)

    def scale_blk(b, j, bt, pos_s):
        return (jnp.where(bt[b, j] >= 0, bt[b, j], 0), 0, 0)

    in_specs = [pl.BlockSpec((1, H, Dh), lambda b, j, bt, pos_s: (b, 0, 0)),
                pl.BlockSpec((1, bs, KV, Dh), pool_blk),
                pl.BlockSpec((1, bs, KV, Dh), pool_blk)]
    ins = [q2, k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, bs, KV), scale_blk)] * 2
        ins += [k_scale, v_scale]
    row = lambda b, j, bt, pos_s: (b, 0)              # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, mb),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, H, Dh),
                                lambda b, j, bt, pos_s: (b, 0, 0)),
                   pl.BlockSpec((1, H), row), pl.BlockSpec((1, H), row)])
    return pl.pallas_call(
        functools.partial(_kernel, window=window, quant=quant),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
                   jax.ShapeDtypeStruct((B, H), jnp.float32),
                   jax.ShapeDtypeStruct((B, H), jnp.float32)),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos.astype(jnp.int32), *ins)
