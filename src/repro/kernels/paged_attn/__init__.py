# Table-walking paged-attention decode kernel (see ops.py).
