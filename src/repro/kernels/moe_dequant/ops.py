"""Public op: batched y[e] = x[e] @ W_quant[e] over a stacked expert tensor.

Dispatch:
  * TPU (or ``force_kernel``): the fused Pallas kernel (kernel.py) — packed
    expert planes stream HBM->VMEM per tile; the dense ``(E, K, N)`` weight
    stack never materializes.
  * otherwise (CPU container, dry-run lowering): a scan over experts, each
    step running the whole-tensor ``dequant_matmul`` (itself blockwise) —
    peak transient memory is ONE expert's weight tile, not all ``E`` of
    them, which is the interim fix for ``moe_apply`` densely dequantizing
    every expert per layer.

The stacked ``QuantizedTensor`` is exactly what ``serving.quantized``
produces (vmapped quantization: every data leaf gains a leading ``E``) and
both paths consume the full reconstruction: grouped grid, BiLLM residual
carrier, and per-expert COO outlier correction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qformat import QuantizedTensor, dequantize_stats
from repro.kernels.dequant_matmul import ops as dq_ops
from repro.kernels.moe_dequant import kernel as _k


def stacked_scales_zeros(qt: QuantizedTensor):
    """Double-dequantized (E, G, N) stats of an expert-stacked tensor.

    ``QuantizedTensor.scales_zeros`` indexes ``[:, None]`` and is not
    stack-safe, so the second-level dequant is vmapped over the stack dim.
    """
    G = qt.n_groups
    dq = jax.vmap(dequantize_stats, in_axes=(0, 0, 0, None))
    scales = dq(qt.q_scales, qt.ss_scale, qt.ss_zero, G)
    zeros = dq(qt.q_zeros, qt.zz_scale, qt.zz_zero, G)
    return scales, zeros


def moe_dequant_matmul(xe, qt: QuantizedTensor, *, force_kernel: bool = False,
                       interpret: bool = False):
    """xe (E, T, K) x stacked packed (E, K, N) -> (E, T, N) in xe.dtype."""
    on_tpu = jax.default_backend() == "tpu"
    if force_kernel or on_tpu:
        T = xe.shape[1]
        scales, zeros = stacked_scales_zeros(qt)
        y = _k.moe_dequant_matmul_kernel(
            xe, qt.planes, scales.astype(jnp.float32),
            zeros.astype(jnp.float32), qt.resid_planes, qt.resid_scales,
            bits=qt.bits, group_size=qt.group_size,
            bm=T if T < 128 else 128, interpret=interpret or not on_tpu)
        y = jax.vmap(dq_ops.outlier_correction)(xe, qt, y)
        return y.astype(xe.dtype)

    def step(_, ev):
        x_e, qt_e = ev
        return None, dq_ops.dequant_matmul(x_e, qt_e)

    _, ys = jax.lax.scan(step, None, (xe, qt))
    return ys
