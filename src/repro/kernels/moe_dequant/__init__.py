# Fused stacked-expert dequant matmul (see ops.py).
