"""Pallas TPU kernel: fused stacked-expert group-dequant matmul.

The MoE serving hot-spot: expert weights live as one *stacked*
``QuantizedTensor`` (packed planes ``(E, K*b/8, N)``), and the dispatch
buffers are ``(E, T, K)`` routed-token stacks.  The grid walks
``(E, T/bm, N/bn, K/bk)``; each step streams one expert's packed tile
HBM->VMEM, unpacks + dequantizes it in VREGs (the same per-tile math as
``kernels.dequant_matmul``, including the BiLLM residual carrier), and
accumulates on the MXU — the dense ``(E, K, N)`` bf16 expert stack never
exists in HBM, which is the whole point: per decode step only the routed
experts' packed bytes move.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import repro.dist.compat  # noqa: F401  (aliases pltpu.CompilerParams on older jax)
from repro.kernels.dequant_matmul.kernel import _plane_rows, _unpack_plane


def _kernel(x_ref, *refs, bits, group_size, resid):
    n_planes = 2 if bits == 3 else 1
    planes = refs[:n_planes]
    if resid:
        s_ref, z_ref, r_ref, rs_ref, o_ref = refs[n_planes:]
    else:
        s_ref, z_ref, o_ref = refs[n_planes:]
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bk = x_ref.shape[2]
    bn = o_ref.shape[2]
    if bits == 3:
        codes = _unpack_plane(planes[0][0], 2) + \
            (_unpack_plane(planes[1][0], 1) << 2)
    else:
        codes = _unpack_plane(planes[0][0], bits)
    q = codes.astype(jnp.float32).reshape(bk // group_size, group_size, bn)
    w = (q - z_ref[0][:, None, :]) * s_ref[0][:, None, :]
    w = w.reshape(bk, bn)
    if resid:
        rb = _unpack_plane(r_ref[0], 1).astype(jnp.float32)
        w = w + (rb * 2.0 - 1.0) * rs_ref[0].astype(jnp.float32)
    w = w.astype(x_ref.dtype)
    o_ref[...] += jax.lax.dot(x_ref[0], w,
                              preferred_element_type=jnp.float32)[None]


def _fit(b, total, step=1):
    """Largest block <= b that is a multiple of ``step`` and divides total."""
    b = min(b, total)
    b = max((b // step) * step, step)
    while total % b:
        b -= step
    return b


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm",
                                             "bn", "bk", "interpret"))
def moe_dequant_matmul_kernel(xe, planes, scales, zeros, resid_planes=None,
                              resid_scales=None, *, bits, group_size,
                              bm=128, bn=256, bk=512, interpret=False):
    """xe (E, T, K) x stacked packed (E, K, N) -> (E, T, N) f32.

    planes: tuple of uint8 arrays ((E, K*b/8, N)); scales/zeros (E, K//gs, N)
    f32 (already double-dequantized, see ``ops.stacked_scales_zeros``).
    COO outliers are the caller's job (global indices, applied per expert
    outside the kernel).
    """
    E, T, K = xe.shape
    N = scales.shape[-1]
    resid = resid_planes is not None
    bm = _fit(bm, T)
    bn = _fit(bn, N)
    bk = _fit(bk, K, group_size)
    grid = (E, T // bm, N // bn, K // bk)

    in_specs = [pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k))]
    for per in _plane_rows(bits):
        in_specs.append(
            pl.BlockSpec((1, bk // per, bn), lambda e, i, j, k: (e, k, j)))
    gb = bk // group_size
    in_specs += [pl.BlockSpec((1, gb, bn), lambda e, i, j, k: (e, k, j))] * 2
    ins = [xe, *planes, scales, zeros]
    if resid:
        in_specs.append(
            pl.BlockSpec((1, bk // 8, bn), lambda e, i, j, k: (e, k, j)))
        in_specs.append(
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)))
        ins += [*resid_planes, resid_scales]

    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, group_size=group_size,
                          resid=resid),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, T, N), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*ins)
