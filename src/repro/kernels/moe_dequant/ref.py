"""Pure-jnp oracle for the stacked-expert dequant matmul.

Densely reconstructs every expert (the thing the fused kernel avoids) and
contracts — the most literal statement of the math: for each expert ``e``,
``y[e] = x[e] @ dequantize(W[e])`` with the full qformat reconstruction
(grouped grid, BiLLM residual carrier, COO outliers).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.qformat import dequantize_any


def moe_dequant_matmul_ref(xe, qt):
    """xe (E, T, K) x stacked packed (E, K, N) -> (E, T, N) in xe.dtype."""
    w = dequantize_any(qt).astype(xe.dtype)          # (E, K, N) dense
    return jnp.einsum("etk,ekn->etn", xe, w)
