"""Synthetic corpus + deterministic sharded data iterator.

Offline stand-in for the paper's C4/RedPajama/WikiText2 loaders (DESIGN.md §7).
The corpus is a Zipf-weighted first-order Markov chain with document
boundaries — enough structure that a toy LM trains to a meaningful
distribution, so quantization-distortion orderings (RTN vs OPTQ vs SpQR vs
OAC) are measurable.

Determinism contract (fault tolerance / elastic scaling):
  batch = f(seed, split, global_step, shard_id, num_shards)
with *stateless* indexing — any host can materialize any shard of any step,
so restarts/reshards never need a data-state exchange beyond the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

# "calib" and "eval" share a salt but live in disjoint step ranges (see
# SyntheticCorpus.batch): quantization calibration and quality eval draw
# from the same distribution but provably disjoint RNG streams, so
# perplexity is never measured on the sequences a method calibrated on.
_SPLIT_SALT = {"train": 0x1, "valid": 0x2, "calib": 0x3, "eval": 0x3,
               "test": 0x4}

# eval step k draws from base step 2**20 + k.  The seed mixer multiplies
# the step by an ODD constant (invertible mod 2**31 under the mask), so
# distinct base steps always yield distinct seeds: any calib set smaller
# than 2**20 batches is guaranteed disjoint from the eval stream, and the
# calib stream itself stays byte-identical to what it always was.
_EVAL_STEP_BASE = 1 << 20


@dataclasses.dataclass
class SyntheticCorpus:
    vocab: int
    seq_len: int
    seed: int = 0
    branching: int = 24     # out-degree of the Markov chain
    doc_len: int = 512      # expected document length (boundary resets)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab
        # Zipfian stationary-ish distribution
        ranks = np.arange(1, V + 1)
        self.unigram = (1.0 / ranks ** 1.1)
        self.unigram /= self.unigram.sum()
        # sparse random transition structure: each token -> `branching`
        # successors with Zipf-weighted probabilities
        self.succ = rng.integers(0, V, size=(V, self.branching))
        w = rng.dirichlet(np.ones(self.branching) * 0.5, size=V)
        self.succ_cum = np.cumsum(w, axis=1)

    def sample(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        V, S = self.vocab, self.seq_len
        toks = np.empty((batch, S), np.int64)
        cur = rng.choice(V, size=batch, p=self.unigram)
        boundary_p = 1.0 / self.doc_len
        for t in range(S):
            toks[:, t] = cur
            u = rng.random(batch)
            nxt_idx = (u[:, None] < self.succ_cum[cur]).argmax(axis=1)
            cur = self.succ[cur, nxt_idx]
            # document boundaries resample from the unigram
            reset = rng.random(batch) < boundary_p
            if reset.any():
                cur[reset] = rng.choice(V, size=int(reset.sum()),
                                        p=self.unigram)
        return toks.astype(np.int32)

    def batch(self, split: str, step: int, batch_size: int,
              shard_id: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
        assert batch_size % num_shards == 0
        per = batch_size // num_shards
        # calib/eval disjointness: see _EVAL_STEP_BASE.
        if split == "eval":
            step = _EVAL_STEP_BASE + step
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + _SPLIT_SALT[split]) ^
            (step * 2_654_435_761 + shard_id) & 0x7FFFFFFF)
        return {"tokens": self.sample(rng, per)}


@dataclasses.dataclass
class DataIterator:
    """Stateful view over the stateless corpus; `state` goes in checkpoints."""
    corpus: SyntheticCorpus
    split: str
    batch_size: int
    shard_id: int = 0
    num_shards: int = 1
    step: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        b = self.corpus.batch(self.split, self.step, self.batch_size,
                              self.shard_id, self.num_shards)
        self.step += 1
        return b

    @property
    def state(self):
        return {"step": self.step}

    def restore(self, state):
        self.step = int(state["step"])
        return self


def make_calib_set(corpus: SyntheticCorpus, n: int, batch: int = 1
                   ) -> Dict[str, np.ndarray]:
    """The paper's calibration set: n sequences stacked (n, seq_len)."""
    out = [corpus.batch("calib", i, batch)["tokens"] for i in range(n)]
    return {"tokens": np.concatenate(out, axis=0)}


def make_eval_set(corpus: SyntheticCorpus, n: int, batch: int = 1
                  ) -> Dict[str, np.ndarray]:
    """Held-out quality-eval sequences: same distribution as the calib
    set, guaranteed-disjoint RNG stream (see ``SyntheticCorpus.batch``)."""
    out = [corpus.batch("eval", i, batch)["tokens"] for i in range(n)]
    return {"tokens": np.concatenate(out, axis=0)}
