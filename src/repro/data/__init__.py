from repro.data.pipeline import (SyntheticCorpus, DataIterator,
                                 make_calib_set, make_eval_set)

__all__ = ["SyntheticCorpus", "DataIterator", "make_calib_set",
           "make_eval_set"]
