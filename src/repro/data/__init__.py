from repro.data.pipeline import (SyntheticCorpus, DataIterator, make_calib_set)

__all__ = ["SyntheticCorpus", "DataIterator", "make_calib_set"]
