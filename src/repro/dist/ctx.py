"""Ambient distribution context: ``DistCtx`` + module-level ``get``/``use``.

This is the read side of the distribution API.  Model code never receives
a mesh argument — it consults the ambient context at trace time:

    from repro.dist import ctx as dctx
    c = dctx.get()            # DistCtx or None (single-device fallback)
    if c is None: ...         # plain single-device math

``DistCtx`` is a frozen value object: the mesh, which axes carry data
parallelism (``dp``), which axis carries tensor parallelism (``tp``), the
PartitionSpec entry for batch dims (``batch_spec``), and the attention
dispatch modes picked by ``repro.dist.sharding.make_plan`` (see DESIGN.md
§4 for the mode table).  Because it is immutable, variants are cheap:
``dataclasses.replace(c, attn_decode_mode="dense")``.

The two sharding-constraint helpers keep model code terse:

  * ``wsc(x, *dims)`` — with_sharding_constraint with one token per dim:
    ``"b"`` -> the ctx batch spec, ``"tp"`` -> the tp axis, ``None`` ->
    replicated, anything else (an axis name, e.g. from ``tp_if``) passes
    through.  Tokens whose mesh-axis size does not divide the dim are
    dropped, and the whole call is the identity when no ctx is active —
    so model code needs no divisibility or single-device guards.
  * ``tp_if(dim)`` — the tp axis name when ``dim`` is divisible by
    ``tp_size`` (and a ctx is active), else None.  Used to build specs
    that shard "when the math lines up" (vocab, expert, head dims).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Any, Optional, Tuple

import jax

from repro.dist import compat  # noqa: F401  (installs jax API shims)


def _axis_size(mesh, axes) -> int:
    """Total size of one axis name or a tuple of axis names."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


@dataclasses.dataclass(frozen=True)
class DistCtx:
    """Immutable description of how the current computation is distributed."""
    mesh: Any
    dp: Tuple[str, ...] = ("data",)
    tp: str = "model"
    # PartitionSpec entry used for batch dims (usually ``dp`` as a tuple;
    # None -> batch replicated, e.g. when B does not divide dp_size).
    batch_spec: Any = ("data",)
    attn_train_mode: str = "grouped"    # grouped | repeated | seq_shard
    attn_decode_mode: str = "dense"     # dense | flash
    remat: bool = False
    hidden_seq_shard: bool = False

    @property
    def tp_size(self) -> int:
        return _axis_size(self.mesh, self.tp)

    @property
    def dp_size(self) -> int:
        return _axis_size(self.mesh, self.dp)


_current: Optional[DistCtx] = None


def get() -> Optional[DistCtx]:
    """The active DistCtx, or None (single-device fallback paths)."""
    return _current


@contextlib.contextmanager
def use(ctx: Optional[DistCtx]):
    """Make ``ctx`` the ambient context for the block (re-entrant)."""
    global _current
    prev = _current
    _current = ctx
    try:
        yield ctx
    finally:
        _current = prev


def _resolve(c: DistCtx, token, dim: int):
    """Token -> PartitionSpec entry, dropping non-divisible shardings."""
    if token == "b":
        token = c.batch_spec
    elif token == "tp":
        token = c.tp
    if token is None:
        return None
    if dim % _axis_size(c.mesh, token) != 0:
        return None
    return token


def wsc(x, *dims):
    """Sharding constraint on ``x``; one token per dim (identity w/o ctx)."""
    c = get()
    if c is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = [_resolve(c, t, x.shape[i]) for i, t in enumerate(dims[:x.ndim])]
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(c.mesh, P(*spec)))


def tp_if(dim: int) -> Optional[str]:
    """The tp axis name when ``dim`` shards evenly over it, else None."""
    c = get()
    if c is None or c.tp_size <= 1:
        return None
    return c.tp if dim % c.tp_size == 0 else None
