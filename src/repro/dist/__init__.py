"""``repro.dist`` — the single public distribution API.

Read side (model code): ``repro.dist.ctx`` — ambient ``DistCtx`` via
``get()``/``use()`` plus the ``wsc``/``tp_if`` constraint helpers; every
path degrades to single-device math when no context is active.

Write side (launchers/tests): ``repro.dist.sharding.make_plan(cfg, mesh)``
-> ``ShardingPlan`` (param/batch/cache layouts + attention-mode choices),
and ``repro.dist.steps`` for jit'd train/prefill/serve step builders.

Importing the package installs the jax compat shims; the heavier
submodules (steps pulls in the model zoo) resolve lazily so low-level
consumers (kernels, compression) can depend on ``repro.dist.compat``
without dragging the model stack into their import graph.

See DESIGN.md for the contract and the §4 attention dispatch table.
"""
import importlib

from repro.dist import compat  # noqa: F401  (installs jax API shims)

_EXPORTS = {
    "DistCtx": "repro.dist.ctx", "get": "repro.dist.ctx",
    "use": "repro.dist.ctx", "wsc": "repro.dist.ctx",
    "tp_if": "repro.dist.ctx",
    "ShardingPlan": "repro.dist.sharding", "make_plan": "repro.dist.sharding",
    "build_step": "repro.dist.steps", "build_train_step": "repro.dist.steps",
}

__all__ = ["compat"] + sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro.dist' has no attribute {name!r}")
