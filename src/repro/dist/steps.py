"""Step builders: jit'd train / prefill / serve steps under a ShardingPlan.

``build_train_step`` is the production train step (donated params +
optimizer state, bf16 compute over fp32 master params); ``build_step`` is
the generic entry the dry-run driver lowers for every (arch x shape) cell
— it dispatches on ``shape.kind`` and returns ``(jitted, abstract_args,
ctx)`` so callers can either execute the step or ``.lower()`` it with no
device allocation.

The plan's ``DistCtx`` is entered around the traced body (``dctx.use``),
so every mode dispatch and sharding constraint inside the model stack
resolves against the plan while tracing; at run time the context is
irrelevant (the decisions are baked into the jaxpr).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.dist import ctx as dctx
from repro.dist.sharding import ShardingPlan, make_plan
from repro.launch import specs
from repro.models import build_model
from repro.train import optimizer as opt


def _replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P())


def build_train_step(cfg: ModelConfig, shape: ShapeConfig,
                     plan: ShardingPlan, tcfg: Optional[TrainConfig] = None):
    """Donated-arg jit train step.

    ``step(params, opt_state, batch) -> (params', opt_state', loss)`` with
    in/out shardings pinned to the plan (callers ``device_put`` committed
    arrays with ``plan.param_shardings`` / ``plan.batch_spec`` so donation
    can alias buffers).  Loss/grads run in ``tcfg.compute_dtype`` (bf16 by
    default) over fp32 master params; ``compute_dtype="float32"`` skips the
    cast, matching the legacy host loop bit-for-bit on a trivial mesh.
    Returns ``(jitted, abstract_args, ctx)``.
    """
    tcfg = tcfg or TrainConfig()
    model = build_model(cfg)
    ctx = plan.ctx(shape)
    sched = opt.warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.steps)
    # honor the requested dtype exactly (jnp.dtype raises on typos rather
    # than silently computing in bf16)
    cast = None if tcfg.compute_dtype == "float32" \
        else jnp.dtype(tcfg.compute_dtype)

    def step(params, opt_state, batch):
        with dctx.use(ctx):
            def loss_fn(p):
                return model.loss(
                    utils.cast_tree(p, cast) if cast else p, batch)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params2, opt2, _ = opt.adamw_update(
                grads, opt_state, params, lr_sched=sched, b1=tcfg.b1,
                b2=tcfg.b2, eps=tcfg.eps, weight_decay=tcfg.weight_decay,
                grad_clip=tcfg.grad_clip)
        return params2, opt2, loss

    p_sds = model.abstract_params()
    ps = plan.param_shardings(p_sds)
    repl = _replicated(plan.mesh)
    o_sh = opt.AdamState(repl, ps, ps)
    batch_sds = specs.input_specs(cfg, shape)
    b_sh = plan.batch_spec(batch_sds, shape.global_batch)
    jitted = jax.jit(step, donate_argnums=(0, 1),
                     in_shardings=(ps, o_sh, b_sh),
                     out_shardings=(ps, o_sh, repl))
    o_sds = opt.AdamState(jax.ShapeDtypeStruct((), jnp.int32), p_sds, p_sds)
    return jitted, (p_sds, o_sds, batch_sds), ctx


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               quantized_params_sds=None, paged: bool = False,
               kv_bits: int = 16):
    """Generic (arch x shape) step for the dry-run driver and launchers.

    train   -> ``build_train_step`` under a fresh plan;
    prefill -> jit'd bulk prefill (cache donated);
    decode  -> jit'd serve step (cache donated), optionally over packed
               ``QuantizedTensor`` params (``quantized_params_sds`` — the
               plan TP-shards their code planes, so the cell's per-device
               packed bytes are ~total/tp) and/or a paged block-pool cache
               (``paged=True`` — the step reads block tables from the
               cache pytree, so its signature and the engine's per-tick
               override both lower from one build; ``kv_bits=8`` lowers
               the int8 pool + scale-plane layout).

    Returns ``(jitted, abstract_args, ctx)``.
    """
    plan = make_plan(cfg, mesh)
    if shape.kind == "train":
        return build_train_step(cfg, shape, plan)

    ctx = plan.ctx(shape)
    model = build_model(cfg)
    p_sds = quantized_params_sds if quantized_params_sds is not None \
        else model.abstract_params(jnp.bfloat16)
    ps = plan.param_shardings(p_sds)
    repl = _replicated(mesh)
    B = shape.global_batch

    if shape.kind == "prefill":
        batch_sds = specs.input_specs(cfg, shape)
        cache_sds = model.init_cache(B, shape.seq_len, dtype=jnp.bfloat16,
                                     abstract=True)

        def prefill_step(params, batch, cache):
            with dctx.use(ctx):
                return model.prefill(params, batch, cache)

        jitted = jax.jit(
            prefill_step, donate_argnums=(2,),
            in_shardings=(ps, plan.batch_spec(batch_sds, B),
                          plan.cache_shardings(cache_sds, ctx)))
        return jitted, (p_sds, batch_sds, cache_sds), ctx

    stripes = plan.tp_size if ctx.attn_decode_mode == "flash" else 1
    tok_sds, cache_sds, pos_sds = specs.decode_specs(cfg, shape, paged=paged,
                                                     stripes=stripes,
                                                     kv_bits=kv_bits)

    def serve_step(params, tokens, cache, pos):
        with dctx.use(ctx):
            return model.decode_step(params, tokens, cache, pos)

    # pos is the (B,) per-row cache clock — batch-sharded like the tokens
    jitted = jax.jit(
        serve_step, donate_argnums=(2,),
        in_shardings=(ps, plan.batch_spec(tok_sds, B),
                      plan.cache_shardings(cache_sds, ctx),
                      plan.batch_spec(pos_sds, B)))
    return jitted, (p_sds, tok_sds, cache_sds, pos_sds), ctx
