"""Sharding plans: per-architecture layout decisions for a concrete mesh.

``make_plan(cfg, mesh)`` inspects the model config against the mesh and
returns a ``ShardingPlan`` — the write side of the distribution API.  The
plan owns every layout decision so model code never sees a mesh:

  * attention dispatch modes (DESIGN.md §4): picked from how the head
    counts divide the tensor-parallel axis.  Training/prefill:
    ``grouped`` when KV heads divide tp, ``repeated`` when only Q heads
    do, ``seq_shard`` when neither does.  Decode: ``dense`` when KV
    heads divide tp, ``flash`` (KV-length-parallel flash-decoding)
    otherwise and for long-context cells.
  * ``param_shardings(params)`` — NamedSharding pytree for the params:
    matmul kernels TP-shard their output dim (input dim for ``wo``-style
    contractions so the activation all-reduce is the only collective)
    and FSDP-shard the complementary dim over the data axes; embeddings
    vocab-shard; norms/biases/small projections replicate.
  * ``batch_spec(batch, B)`` — batch pytree layout (leading dim over dp).
  * ``cache_shardings(cache, ctx)`` — KV/SSM cache layout matching the
    decode mode (KV heads for ``dense``, cache length for ``flash``).
  * ``ctx(shape)`` — the frozen ``DistCtx`` the model stack reads.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.ctx import DistCtx

# decode cells at/above this sequence length use flash decoding even when
# the KV heads divide tp: sharding the cache length bounds per-chip KV HBM.
LONG_CONTEXT_FLASH = 131072


def _size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


# kernels whose contraction (input) dim is the TP-sharded one: the matmul
# then produces partial sums and GSPMD inserts a single all-reduce, instead
# of all-gathering the (tp-sharded) activations first.
_ROW_SHARDED = ("wo", "out_proj", "cm_value")
# small projections kept replicated by design (see ssm_mamba2.py docstring).
_REPLICATED = ("in_B", "in_C", "in_dt", "router")


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    cfg: ModelConfig
    mesh: Any
    dp: Tuple[str, ...]
    tp: str
    attn_train_mode: str

    # ------------------------------------------------------------ derived
    @property
    def tp_size(self) -> int:
        return _size(self.mesh, self.tp)

    @property
    def dp_size(self) -> int:
        return _size(self.mesh, self.dp)

    @property
    def batch_entry(self):
        """PartitionSpec entry for batch dims (None when there is no dp)."""
        return self.dp if self.dp else None

    # ---------------------------------------------------------------- ctx
    def decode_mode(self, shape: Optional[ShapeConfig] = None) -> str:
        if self.tp_size <= 1:
            return "dense"        # trivial mesh: no-collective invariant
        kv = self.cfg.n_kv_heads
        if kv and kv % self.tp_size != 0:
            return "flash"
        if shape is not None and shape.kind == "decode" \
                and shape.seq_len >= LONG_CONTEXT_FLASH \
                and shape.seq_len % self.tp_size == 0:
            return "flash"
        return "dense"

    def ctx(self, shape: Optional[ShapeConfig] = None) -> DistCtx:
        kind = shape.kind if shape is not None else "train"
        b = self.batch_entry
        if shape is not None and shape.global_batch % self.dp_size != 0:
            b = None
        return DistCtx(
            mesh=self.mesh, dp=self.dp, tp=self.tp, batch_spec=b,
            attn_train_mode=self.attn_train_mode,
            attn_decode_mode=self.decode_mode(shape),
            remat=(kind == "train"),
            hidden_seq_shard=(kind != "decode"))

    # ------------------------------------------------------------- params
    def _fits(self, axes, dim: int) -> bool:
        return axes is not None and dim % _size(self.mesh, axes) == 0

    def _param_spec(self, path: str, shape) -> list:
        nd = len(shape)
        spec = [None] * nd
        if path.endswith("embed/table"):
            if self._fits(self.tp, shape[0]):
                spec[0] = self.tp          # vocab-sharded (see _logits)
            return spec
        name = path.split("/")[-2] if path.endswith("/kernel") else \
            path.split("/")[-1]
        if not (path.endswith("/kernel") or name in ("conv_x",)) or nd < 2:
            return spec                    # norms / biases / scalars
        if name in _REPLICATED:
            return spec
        if name == "conv_x":               # (..., K, d_in): head-aligned
            if self._fits(self.tp, shape[-1]):
                spec[-1] = self.tp
            return spec
        if "/moe/" in path and nd >= 3:
            # expert stacks (..., E, d, f): shard E over tp when divisible,
            # else the ffn dim; FSDP the model dim over dp (moe.py contract).
            e_ax, ff_ax = nd - 3, (nd - 1 if name != "wo" else nd - 2)
            d_ax = nd - 2 if name != "wo" else nd - 1
            if self._fits(self.tp, shape[e_ax]):
                spec[e_ax] = self.tp
            elif self._fits(self.tp, shape[ff_ax]):
                spec[ff_ax] = self.tp
            if self._fits(self.dp, shape[d_ax]):
                spec[d_ax] = self.dp
            return spec
        col, row = nd - 1, nd - 2
        tp_ax, dp_ax = (row, col) if name in _ROW_SHARDED else (col, row)
        if self._fits(self.tp, shape[tp_ax]):
            spec[tp_ax] = self.tp
        if self._fits(self.dp, shape[dp_ax]):
            spec[dp_ax] = self.dp          # FSDP over the data axes
        return spec

    def _qt_shardings(self, path: str, qt):
        """Shardings for one packed ``QuantizedTensor`` node (qserve).

        The packed code planes shard along the same logical axis as the fp
        kernel they replace (the plan's tp decision for ``path``); the
        grouped scale/zero stats follow along their group axis; the outlier
        COO buffers replicate (global indices).  Only the tp axis is
        honored — quantized params are the serving format, there is no
        optimizer state to FSDP, and replicating the (tiny) stats over the
        data axes keeps the decode cell collective-free."""
        import dataclasses as _dc
        from jax.sharding import NamedSharding, PartitionSpec as P
        stack = tuple(qt.planes[0].shape[:-2])
        ns = len(stack)
        base = self._param_spec(path, stack + tuple(qt.shape))
        # keep only tp entries (drop dp/FSDP for packed serving params)
        base = [e if e == self.tp else None for e in base]
        stack_spec = base[:ns]
        row_tp = base[ns] is not None        # contraction (d_in) axis
        col_tp = base[ns + 1] is not None    # output (d_out) axis

        def ns_of(arr, tail):
            """NamedSharding for one field: stack spec + ``tail`` entries
            for the trailing dims, dropping non-divisible axes."""
            if arr is None:
                return None
            spec = list(stack_spec) + list(tail)
            spec = [s if self._fits(s, d) else None
                    for s, d in zip(spec, arr.shape)]
            return NamedSharding(self.mesh, P(*spec))

        row = self.tp if row_tp else None
        col = self.tp if col_tp else None
        planes = tuple(ns_of(p, (row, col)) for p in qt.planes)
        rp = None
        if qt.resid_planes is not None:
            rp = tuple(ns_of(p, (row, col)) for p in qt.resid_planes)
        return _dc.replace(
            qt,
            planes=planes,
            # stats (GB, sg, d_out) / second-level (GB, d_out): the group-
            # block axis follows a row-sharded kernel, d_out a col-sharded
            q_scales=ns_of(qt.q_scales, (row, None, col)),
            ss_scale=ns_of(qt.ss_scale, (row, col)),
            ss_zero=ns_of(qt.ss_zero, (row, col)),
            q_zeros=ns_of(qt.q_zeros, (row, None, col)),
            zz_scale=ns_of(qt.zz_scale, (row, col)),
            zz_zero=ns_of(qt.zz_zero, (row, col)),
            out_rows=ns_of(qt.out_rows, (None,)),
            out_cols=ns_of(qt.out_cols, (None,)),
            out_vals=ns_of(qt.out_vals, (None,)),
            resid_planes=rp,
            resid_scales=ns_of(qt.resid_scales, (row, col)))

    def param_shardings(self, params):
        """NamedSharding pytree matching ``params`` (works on abstract or
        concrete trees).  Packed ``QuantizedTensor`` nodes shard their code
        planes along the fp kernel's tp axis and their grouped stats along
        the group axis (``_qt_shardings``); remaining unrecognized leaves
        replicate."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import utils
        from repro.core.qformat import QuantizedTensor
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=lambda n: isinstance(n, QuantizedTensor))
        out = []
        for p, leaf in flat:
            path = utils.path_str(p)
            if isinstance(leaf, QuantizedTensor):
                out.append(self._qt_shardings(path, leaf))
            else:
                spec = self._param_spec(path, leaf.shape)
                out.append(NamedSharding(self.mesh, P(*spec)))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------- loading
    def place(self, sharding, shape, dtype, read):
        """Build one committed array shard-by-shard (checkpoint load path).

        ``read(index)`` returns the numpy slice of the global array for one
        shard — typically a view into an ``np.memmap``, so only the bytes
        this host's devices actually own are pulled off disk.  This is how
        ``serving.qserve.ckpt.load`` places packed planes directly per
        ``param_shardings`` without ever materializing the full tree."""
        import numpy as np
        dtype = np.dtype(dtype)

        def cb(idx):
            a = np.ascontiguousarray(read(idx))
            assert a.dtype == dtype, (a.dtype, dtype)
            return a
        return jax.make_array_from_callback(tuple(shape), sharding, cb)

    # -------------------------------------------------------------- batch
    def batch_spec(self, batch, B: int):
        """NamedSharding pytree for a batch dict (leading dim over dp)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        b = self.batch_entry if B % self.dp_size == 0 else None

        def one(x):
            return NamedSharding(
                self.mesh, P(*([b] + [None] * (len(x.shape) - 1))))
        return jax.tree.map(one, batch)

    # -------------------------------------------------------------- cache
    def cache_shardings(self, cache, ctx: DistCtx):
        """NamedSharding pytree for a decode cache, matching the decode
        mode: ``dense`` shards KV heads, ``flash`` shards cache length.
        Paged pools follow the same modes: ``dense`` shards the pool's KV
        heads (tables replicated), ``flash`` shards the pool's *block* dim
        and the table's logical-block dim over tp (the contiguous-stripe
        layout ``attention._paged_flash_write`` assumes); pools carry no
        batch dim, so they replicate over dp."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.attention import KVCache, PagedKVCache
        b, tp = ctx.batch_spec, self.tp
        flash = ctx.attn_decode_mode == "flash"

        def kv_like(x):
            # (stack..., B, cap, KV, hd)
            nd = len(x.shape)
            spec = [None] * nd
            if self._fits(b, x.shape[nd - 4]):
                spec[nd - 4] = b
            if flash:
                spec[nd - 3] = tp if self._fits(tp, x.shape[nd - 3]) else None
            elif self._fits(tp, x.shape[nd - 2]):
                spec[nd - 2] = tp
            return NamedSharding(self.mesh, P(*spec))

        def pool_like(x):
            # (stack..., num_blocks, block_size, KV, hd)
            nd = len(x.shape)
            spec = [None] * nd
            if flash:
                spec[nd - 4] = tp if self._fits(tp, x.shape[nd - 4]) else None
            elif self._fits(tp, x.shape[nd - 2]):
                spec[nd - 2] = tp
            return NamedSharding(self.mesh, P(*spec))

        def scale_like(x):
            # (stack..., num_blocks, block_size, KV): int8-KV scale plane,
            # sharded like the code pool it annotates (block dim under
            # flash, KV heads under dense)
            if x is None:
                return None
            nd = len(x.shape)
            spec = [None] * nd
            if flash:
                spec[nd - 3] = tp if self._fits(tp, x.shape[nd - 3]) else None
            elif self._fits(tp, x.shape[nd - 1]):
                spec[nd - 1] = tp
            return NamedSharding(self.mesh, P(*spec))

        def one(node):
            if isinstance(node, PagedKVCache):
                # block_tables (B, max_blocks): batch over dp; the logical
                # dim over tp when flash (stripe invariant)
                bt = node.block_tables
                bt_spec = [None, None]
                if self._fits(b, bt.shape[0]):
                    bt_spec[0] = b
                if flash and self._fits(tp, bt.shape[1]):
                    bt_spec[1] = tp
                return PagedKVCache(pool_like(node.k), pool_like(node.v),
                                    NamedSharding(self.mesh, P(*bt_spec)),
                                    scale_like(node.k_scale),
                                    scale_like(node.v_scale))
            if isinstance(node, KVCache):
                # slot_pos (stack..., B, cap): batch over dp, cap over tp
                # when flash (matching the k/v length sharding)
                sp_spec = [None] * node.slot_pos.ndim
                if self._fits(b, node.slot_pos.shape[-2]):
                    sp_spec[-2] = b
                if flash and self._fits(tp, node.slot_pos.shape[-1]):
                    sp_spec[-1] = tp
                return KVCache(kv_like(node.k), kv_like(node.v),
                               NamedSharding(self.mesh, P(*sp_spec)))
            # SSM / RWKV state leaves: head- or channel-shard when aligned
            def leaf(x):
                nd = len(x.shape)
                spec = [None] * nd
                if nd >= 4 and self._fits(tp, x.shape[-3]):
                    spec[-3] = tp          # (.., B, nH, P, N) heads
                elif nd >= 3 and self._fits(tp, x.shape[-1]):
                    spec[-1] = tp          # (.., B, K-1, conv_ch) channels
                return NamedSharding(self.mesh, P(*spec))
            return jax.tree.map(leaf, node)

        return jax.tree.map(
            one, cache,
            is_leaf=lambda n: isinstance(n, (KVCache, PagedKVCache)))


def make_plan(cfg: ModelConfig, mesh) -> ShardingPlan:
    """Pick per-architecture layouts for ``cfg`` on ``mesh``."""
    names = tuple(mesh.axis_names)
    tp = "model" if "model" in names else names[-1]
    dp = tuple(a for a in names if a != tp)
    tp_size = _size(mesh, tp)
    kv, h = cfg.n_kv_heads, cfg.n_heads
    if tp_size <= 1 or not h or kv % tp_size == 0:
        train_mode = "grouped"
    elif h % tp_size == 0:
        train_mode = "repeated"
    else:
        train_mode = "seq_shard"
    return ShardingPlan(cfg=cfg, mesh=mesh, dp=dp, tp=tp,
                        attn_train_mode=train_mode)
