"""JAX version compatibility shims for the distribution layer.

The model stack and the dist tests target the modern top-level API
(``jax.shard_map``, ``jax.set_mesh``, ``jax.make_mesh``).  On older
jaxlib builds those live under ``jax.experimental`` / the ``Mesh``
context manager; importing this module installs equivalent top-level
aliases exactly once so the same source runs on both.

Shim semantics:
  * ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...)`` maps to
    ``jax.experimental.shard_map.shard_map`` with ``check_rep=False``
    (the old replication checker predates the varying-axis typing the
    attention kernels rely on and rejects valid programs).
  * ``jax.set_mesh(mesh)`` returns the mesh itself — ``Mesh`` has been a
    context manager since 0.4.x, so ``with jax.set_mesh(m):`` behaves the
    same way (sets the ambient resource env for the block).
"""
from __future__ import annotations

import jax


def install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh=None, in_specs=None, out_specs=None, **kw):
            kw.setdefault("check_rep", False)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = lambda mesh: mesh

    try:
        from jax.experimental.pallas import tpu as pltpu
        if not hasattr(pltpu, "CompilerParams") \
                and hasattr(pltpu, "TPUCompilerParams"):
            pltpu.CompilerParams = pltpu.TPUCompilerParams
    except ImportError:  # pallas not built into this jaxlib
        pass

    if not hasattr(jax, "make_mesh"):  # very old fallback
        import numpy as np
        from jax.experimental import mesh_utils
        from jax.sharding import Mesh

        def make_mesh(shape, axis_names):
            devs = mesh_utils.create_device_mesh(tuple(shape))
            return Mesh(np.asarray(devs).reshape(shape), axis_names)

        jax.make_mesh = make_mesh


install()
