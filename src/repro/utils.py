"""Small shared utilities: pytree paths, dtype helpers, timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def tree_paths(tree):
    """Return {'/a/b/c': leaf} for a nested pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        out[path_str(path)] = leaf
    return out


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):        # GetAttrKey (registered dataclasses)
            parts.append(str(p.name))
        else:
            parts.append(str(p).strip("."))
    return "/" + "/".join(parts)


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


class Stopwatch:
    def __init__(self):
        self.t0 = time.perf_counter()

    def lap(self) -> float:
        t = time.perf_counter()
        dt, self.t0 = t - self.t0, t
        return dt


def block_all(tree):
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, tree)
    return tree
