"""Structured span/event tracer with a Chrome-trace (Perfetto) exporter.

``Tracer`` records spans (begin/end pairs) and instant events on one
shared monotonic-nanosecond clock (``now_ns``, time.perf_counter_ns) —
the same clock the engine stamps ``Request.token_times`` from, so a
request's token latencies and its trace timeline agree by construction.

Spans carry explicit ids and parent ids (the internal model); the
exporter maps them onto the Chrome trace-event JSON that Perfetto and
``chrome://tracing`` load: complete (``ph: "X"``) events grouped by
``pid``/``tid`` rows, instants as ``ph: "i"``, with the parent id kept in
``args.parent`` for tooling that wants the explicit tree rather than the
timestamp-nesting Perfetto infers.

Per-request serving timelines use ``tid = request id`` on the ``requests``
process row and the engine's own tick/admit spans on ``tid = 0`` of the
``engine`` row — open the trace in https://ui.perfetto.dev and each
request renders as one horizontal lifecycle: queued → prefill (chunks) →
decode → finish, with preempt/swap instants overlaid.

The event buffer is bounded (``max_events``); once full, new events are
dropped and counted (``dropped``) instead of growing without limit — a
tracer left enabled on a long-running engine costs bounded memory.
``Tracer(enabled=False)`` records nothing and every call is a cheap
early-return (the no-op mode the obs-off bit-identity test pins).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

__all__ = ["Tracer", "Span", "now_ns"]


def now_ns() -> int:
    """The shared monotonic clock (ns)."""
    return time.perf_counter_ns()


class Span:
    __slots__ = ("sid", "name", "cat", "pid", "tid", "start_ns", "end_ns",
                 "parent", "args")

    def __init__(self, sid, name, cat, pid, tid, start_ns, parent, args):
        self.sid = sid
        self.name = name
        self.cat = cat
        self.pid = pid
        self.tid = tid
        self.start_ns = start_ns
        self.end_ns = None
        self.parent = parent
        self.args = args


class _SpanCtx:
    """Context-manager handle for ``Tracer.span``."""

    def __init__(self, tracer, sid):
        self.tracer = tracer
        self.sid = sid

    def __enter__(self):
        return self.sid

    def __exit__(self, *exc):
        self.tracer.end(self.sid)
        return False


class Tracer:
    def __init__(self, enabled: bool = True, max_events: int = 200_000):
        self.enabled = enabled
        self.max_events = max_events
        self.dropped = 0
        self._spans: List[Span] = []
        self._instants: List[dict] = []
        self._open: Dict[int, Span] = {}
        self._next_sid = 1
        self.epoch_ns = now_ns()
        self._process_names: Dict[int, str] = {}
        self._thread_names: Dict[tuple, str] = {}

    # ------------------------------------------------------------- naming
    def name_process(self, pid: int, name: str):
        if self.enabled:
            self._process_names[pid] = name

    def name_thread(self, pid: int, tid: int, name: str):
        if self.enabled:
            self._thread_names[(pid, tid)] = name

    # ------------------------------------------------------------- events
    def _full(self) -> bool:
        if len(self._spans) + len(self._instants) >= self.max_events:
            self.dropped += 1
            return True
        return False

    def begin(self, name: str, *, cat: str = "", pid: int = 1, tid: int = 0,
              parent: Optional[int] = None, args: Optional[dict] = None
              ) -> Optional[int]:
        """Open a span; returns its id (None when disabled/full)."""
        if not self.enabled or self._full():
            return None
        sid = self._next_sid
        self._next_sid += 1
        sp = Span(sid, name, cat, pid, tid, now_ns(), parent, args)
        self._spans.append(sp)
        self._open[sid] = sp
        return sid

    def end(self, sid: Optional[int], args: Optional[dict] = None):
        """Close span ``sid`` (tolerates None / already-closed ids so call
        sites need no branching on enabled-ness)."""
        if sid is None or not self.enabled:
            return
        sp = self._open.pop(sid, None)
        if sp is None:
            return
        sp.end_ns = now_ns()
        if args:
            sp.args = {**(sp.args or {}), **args}

    def span(self, name: str, **kw) -> _SpanCtx:
        """``with tracer.span("tick", tid=0): ...``"""
        return _SpanCtx(self, self.begin(name, **kw))

    def instant(self, name: str, *, cat: str = "", pid: int = 1,
                tid: int = 0, args: Optional[dict] = None):
        if not self.enabled or self._full():
            return
        self._instants.append({"name": name, "cat": cat, "pid": pid,
                               "tid": tid, "ts_ns": now_ns(), "args": args})

    # ------------------------------------------------------------ reading
    def spans(self) -> List[Span]:
        return list(self._spans)

    def reset(self):
        self._spans.clear()
        self._instants.clear()
        self._open.clear()
        self.dropped = 0
        self.epoch_ns = now_ns()

    # ---------------------------------------------------------- exporting
    def export_chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable).

        Open spans (begin without end) export with the current time as
        their end and ``args.incomplete = true`` — a crashed run's trace
        still loads.  Timestamps are microseconds relative to the tracer
        epoch (Chrome's ``ts`` unit).
        """
        ev = []
        for pid, name in sorted(self._process_names.items()):
            ev.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": name}})
        for (pid, tid), name in sorted(self._thread_names.items()):
            ev.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": name}})
        t_now = now_ns()
        for sp in self._spans:
            end = sp.end_ns if sp.end_ns is not None else t_now
            args = dict(sp.args or {})
            if sp.parent is not None:
                args["parent"] = sp.parent
            if sp.end_ns is None:
                args["incomplete"] = True
            args["sid"] = sp.sid
            ev.append({"name": sp.name, "cat": sp.cat or "span",
                       "ph": "X", "pid": sp.pid, "tid": sp.tid,
                       "ts": (sp.start_ns - self.epoch_ns) / 1e3,
                       "dur": max(end - sp.start_ns, 0) / 1e3,
                       "args": args})
        for i in self._instants:
            ev.append({"name": i["name"], "cat": i["cat"] or "instant",
                       "ph": "i", "s": "t", "pid": i["pid"],
                       "tid": i["tid"],
                       "ts": (i["ts_ns"] - self.epoch_ns) / 1e3,
                       "args": i["args"] or {}})
        return {"traceEvents": ev, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.export_chrome(), f)
        return path
