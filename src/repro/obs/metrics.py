"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

The registry is the single in-process source for every number the serving
engine, the calibration pipeline, and the benchmarks report: benches read
engine throughput/latency from the same instruments a live metrics
endpoint would render, instead of keeping parallel ``time.perf_counter()``
accounting.

Design constraints (and why):

  * **Labeled children, bounded cardinality.**  A family declares its
    label names up front (``labels=("slo",)``) and hands out one child per
    label-value tuple via ``.labels(slo="batch")``.  Children are capped
    (``max_children``, default 64) and exceeding the cap *raises* — an
    unbounded label (request id, prompt hash) is a memory leak wearing a
    metrics costume, and failing loudly at the instrumentation site beats
    OOMing the serving process.
  * **Fixed-bucket histograms with exact small-run quantiles.**  Buckets
    are fixed at creation (Prometheus-style cumulative ``le`` rendering);
    additionally the first ``keep_samples`` raw observations are retained
    so short benchmark runs compute *exact* percentiles (``quantile``
    falls back to linear interpolation inside the bucket bounds once the
    sample buffer is exhausted — the standard histogram_quantile
    estimate).
  * **Snapshot/reset isolation.**  ``snapshot()`` deep-copies into plain
    dicts (mutating the registry afterwards never mutates a snapshot);
    ``reset()`` zeroes values but keeps registered families and children,
    so a warmup pass can be discarded without re-plumbing instruments.
  * **Zero-cost no-op mode.**  ``MetricsRegistry(enabled=False)`` hands
    out shared null instruments whose methods are empty — instrumented
    code paths stay branch-free and the engine's device math is untouched
    either way (``tests/test_obs.py`` pins greedy bit-identity on vs off).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "CardinalityError", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BUCKETS", "SHORT_LATENCY_BUCKETS",
]

# generic wall-time buckets (seconds): spans ~0.1 ms .. 10 s, log-ish
LATENCY_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                   5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)
# tick / inter-token scale (seconds): ~10 us .. 1 s
SHORT_LATENCY_BUCKETS = (1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3,
                         2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 1.0)


class CardinalityError(ValueError):
    """A family exceeded its ``max_children`` label-cardinality cap."""


class _Child:
    """Base for one (family, label-values) time series."""

    __slots__ = ("labels",)

    def __init__(self, labels: Tuple[str, ...]):
        self.labels = labels


class Counter(_Child):
    """Monotonic counter.  ``inc`` with a negative amount raises."""

    __slots__ = ("value",)

    def __init__(self, labels=()):
        super().__init__(labels)
        self.value = 0.0

    def inc(self, amount: float = 1.0):
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        self.value += amount

    def _reset(self):
        self.value = 0.0

    def _snap(self):
        return {"value": self.value}


class Gauge(_Child):
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self, labels=()):
        super().__init__(labels)
        self.value = 0.0

    def set(self, value: float):
        self.value = float(value)

    def inc(self, amount: float = 1.0):
        self.value += amount

    def dec(self, amount: float = 1.0):
        self.value -= amount

    def _reset(self):
        self.value = 0.0

    def _snap(self):
        return {"value": self.value}


class Histogram(_Child):
    """Fixed-bucket histogram + bounded raw-sample buffer.

    ``bucket_counts[i]`` counts observations <= ``bounds[i]`` (non-
    cumulative storage; rendering cumulates).  The final implicit bucket
    is +Inf.  ``quantile(q)`` is exact while every observation is still
    in the sample buffer, and the standard intra-bucket linear
    interpolation afterwards.
    """

    __slots__ = ("bounds", "bucket_counts", "sum", "count", "max",
                 "_samples", "_keep")

    def __init__(self, bounds: Sequence[float], labels=(),
                 keep_samples: int = 4096):
        super().__init__(labels)
        b = tuple(float(x) for x in bounds)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing and non-empty: {bounds}")
        self.bounds = b
        self._keep = int(keep_samples)
        self._init_state()

    def _init_state(self):
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.max = 0.0
        self._samples: List[float] = []

    def observe(self, value: float):
        v = float(value)
        i = 0
        n = len(self.bounds)
        while i < n and v > self.bounds[i]:
            i += 1
        self.bucket_counts[i] += 1
        self.sum += v
        self.count += 1
        if v > self.max:
            self.max = v
        if len(self._samples) < self._keep:
            self._samples.append(v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """q in [0, 1] -> estimated quantile (exact while the raw-sample
        buffer holds every observation)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        if len(self._samples) == self.count:     # exact path
            s = sorted(self._samples)
            pos = q * (len(s) - 1)
            lo = int(math.floor(pos))
            hi = min(lo + 1, len(s) - 1)
            return s[lo] + (s[hi] - s[lo]) * (pos - lo)
        # bucket interpolation (Prometheus histogram_quantile semantics:
        # linear within the target bucket, lower edge of bucket 0 is 0,
        # the +Inf bucket clamps to the highest finite bound)
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.bucket_counts):
            if c == 0:
                continue
            lo = 0.0 if i == 0 else self.bounds[i - 1]
            if i >= len(self.bounds):            # +Inf bucket
                return self.bounds[-1]
            if cum + c >= target:
                return lo + (self.bounds[i] - lo) * (target - cum) / c
            cum += c
        return self.bounds[-1]

    def _reset(self):
        self._init_state()

    def _snap(self):
        return {"buckets": dict(zip(self.bounds + (math.inf,),
                                    self.bucket_counts)),
                "sum": self.sum, "count": self.count, "max": self.max,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99)}


class _Null:
    """Shared do-nothing instrument: every method is a no-op, every
    accessor a zero.  ``labels(...)`` returns itself so disabled-registry
    call sites are shape-identical to enabled ones."""

    bounds = ()
    value = 0.0
    sum = 0.0
    count = 0
    max = 0.0
    mean = 0.0

    def labels(self, **kw):
        return self

    def inc(self, amount=1.0):
        pass

    def dec(self, amount=1.0):
        pass

    def set(self, value):
        pass

    def observe(self, value):
        pass

    def quantile(self, q):
        return 0.0


_NULL = _Null()

_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric family: fixed label names, capped children."""

    def __init__(self, name: str, kind: str, help: str,
                 label_names: Tuple[str, ...], max_children: int, **kw):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = label_names
        self.max_children = max_children
        self._kw = kw
        self._children: Dict[Tuple[str, ...], _Child] = {}
        if not label_names:      # unlabeled family == its single child
            self._default = self._make(())
        else:
            self._default = None

    def _make(self, values: Tuple[str, ...]) -> _Child:
        if len(self._children) >= self.max_children:
            raise CardinalityError(
                f"metric family {self.name!r} exceeded its cardinality cap "
                f"({self.max_children} children); label values must be "
                f"bounded sets, not ids")
        c = _TYPES[self.kind](labels=values, **self._kw)
        self._children[values] = c
        return c

    def labels(self, **kv) -> _Child:
        if tuple(sorted(kv)) != tuple(sorted(self.label_names)):
            raise ValueError(
                f"family {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(kv)}")
        values = tuple(str(kv[n]) for n in self.label_names)
        c = self._children.get(values)
        return c if c is not None else self._make(values)

    def children(self):
        return dict(self._children)

    # -- unlabeled convenience: the family proxies its single child
    def _one(self) -> _Child:
        if self._default is None:
            raise ValueError(f"family {self.name!r} is labeled "
                             f"({self.label_names}); call .labels(...)")
        return self._default

    def inc(self, amount: float = 1.0):
        self._one().inc(amount)

    def dec(self, amount: float = 1.0):
        self._one().dec(amount)

    def set(self, value: float):
        self._one().set(value)

    def observe(self, value: float):
        self._one().observe(value)

    def quantile(self, q: float) -> float:
        return self._one().quantile(q)

    @property
    def value(self):
        return self._one().value

    @property
    def count(self):
        return self._one().count

    @property
    def sum(self):
        return self._one().sum

    @property
    def mean(self):
        return self._one().mean

    @property
    def max(self):
        return self._one().max

    def _reset(self):
        for c in self._children.values():
            c._reset()

    def _snap(self):
        return {"type": self.kind, "help": self.help,
                "labels": self.label_names,
                "children": {ls: c._snap()
                             for ls, c in sorted(self._children.items())}}


class MetricsRegistry:
    """Process-local registry.  Instrument registration is idempotent:
    re-requesting an existing (name, kind) returns the same family;
    requesting an existing name as a different kind raises."""

    def __init__(self, enabled: bool = True, max_children: int = 64):
        self.enabled = enabled
        self.max_children = max_children
        self._families: Dict[str, Family] = {}

    def _register(self, name: str, kind: str, help: str,
                  labels: Sequence[str], **kw) -> Family:
        if not self.enabled:
            return _NULL
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{fam.kind}, requested {kind}")
            return fam
        fam = Family(name, kind, help, tuple(labels), self.max_children,
                     **kw)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._register(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._register(name, "gauge", help, labels)

    def histogram(self, name: str, buckets: Sequence[float] =
                  LATENCY_BUCKETS, help: str = "",
                  labels: Sequence[str] = (),
                  keep_samples: int = 4096) -> Family:
        return self._register(name, "histogram", help, labels,
                              bounds=buckets, keep_samples=keep_samples)

    def get(self, name: str) -> Optional[Family]:
        return self._families.get(name)

    def families(self) -> Dict[str, Family]:
        return dict(self._families)

    def snapshot(self) -> Dict[str, dict]:
        """Plain-dict deep copy of every family (isolation: later registry
        mutations never alter a snapshot)."""
        return {n: f._snap() for n, f in sorted(self._families.items())}

    def reset(self):
        """Zero every child's values; families and children stay
        registered (warmup-pass discard without re-plumbing handles)."""
        for f in self._families.values():
            f._reset()
