"""``repro.obs`` — engine/pipeline/kernel telemetry.

One bundle, two halves:

  * ``MetricsRegistry`` (``obs.metrics``): counters / gauges /
    fixed-bucket histograms with labeled children, bounded cardinality,
    snapshot/reset, and a Prometheus text renderer (``obs.prom``).
  * ``Tracer`` (``obs.trace``): structured spans/events on a shared
    monotonic-ns clock with a Chrome-trace/Perfetto JSON exporter.

``Obs`` ties them together and is what instrumented components accept:

    ob = obs.Obs.make()              # metrics + bounded tracer, enabled
    eng = PagedEngine(cfg, params, obs=ob)
    ...
    obs.prom.write("metrics.prom", ob.metrics)
    ob.tracer.write("trace.json")    # open in https://ui.perfetto.dev

``obs.OFF`` is the shared zero-cost no-op bundle (every instrument method
is empty; device math is identical either way — pinned by tests).
Components that take ``obs=None`` default via ``resolve``: engines get a
fresh enabled bundle (their benches read throughput/latency from it), the
calibration pipeline defaults to OFF (its callers opt in).

Metric name taxonomy (DESIGN.md §Observability is the full glossary):

  engine_*    serving engine (ticks, queue, block pool, prefix cache,
              speculation, swaps, token latencies)
  pipeline_*  calibration pipeline (per-layer wall, hessian/solve split,
              quant error, resume progress)
  kernel_*    per-kernel roofline gauges (``roofline.analysis``
              achieved/predicted HBM bytes — the same numbers
              BENCH_kernels.json commits)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs import prom
from repro.obs.metrics import (CardinalityError, LATENCY_BUCKETS,
                               MetricsRegistry, SHORT_LATENCY_BUCKETS)
from repro.obs.trace import Span, Tracer, now_ns

__all__ = [
    "CardinalityError", "LATENCY_BUCKETS", "MetricsRegistry", "Obs", "OFF",
    "SHORT_LATENCY_BUCKETS", "Span", "Tracer", "now_ns", "prom", "resolve",
    "summary_table",
]


@dataclasses.dataclass
class Obs:
    """The telemetry bundle instrumented components accept."""

    metrics: MetricsRegistry
    tracer: Tracer

    @classmethod
    def make(cls, max_trace_events: int = 200_000) -> "Obs":
        return cls(MetricsRegistry(),
                   Tracer(max_events=max_trace_events))

    @classmethod
    def off(cls) -> "Obs":
        return cls(MetricsRegistry(enabled=False), Tracer(enabled=False))

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled


#: shared no-op bundle — pass ``obs=obs.OFF`` to disable instrumentation
OFF = Obs.off()


def resolve(obs: Optional[Obs], default: str = "on") -> Obs:
    """Normalize an ``obs=`` argument: an ``Obs`` passes through, ``None``
    takes the component default (``"on"`` -> fresh enabled bundle,
    ``"off"`` -> the shared no-op)."""
    if obs is not None:
        if not isinstance(obs, Obs):
            raise TypeError(f"obs must be an Obs bundle, got {type(obs)}")
        return obs
    return Obs.make() if default == "on" else OFF


def summary_table(registry: MetricsRegistry, prefix: str = "") -> str:
    """Human end-of-run summary: one aligned line per time series
    (counters/gauges: value; histograms: count, mean, p50, p99, max)."""
    rows = []
    for name, fam in sorted(registry.families().items()):
        if prefix and not name.startswith(prefix):
            continue
        for values, c in sorted(fam.children().items()):
            label = name + ("{" + ",".join(
                f"{k}={v}" for k, v in zip(fam.label_names, values)) + "}"
                if values else "")
            if fam.kind == "histogram":
                if not c.count:
                    continue
                rows.append((label, f"n={c.count}  mean={c.mean:.6g}  "
                             f"p50={c.quantile(.5):.6g}  "
                             f"p99={c.quantile(.99):.6g}  "
                             f"max={c.max:.6g}"))
            else:
                v = c.value
                rows.append((label, f"{v:.6g}" if v else "0"))
    if not rows:
        return "(no metrics recorded)"
    w = max(len(r[0]) for r in rows)
    return "\n".join(f"{label:<{w}}  {val}" for label, val in rows)
