"""Prometheus text-exposition renderer for ``MetricsRegistry``.

Renders format 0.0.4 (``text/plain; version=0.0.4``): ``# HELP`` /
``# TYPE`` per family, one line per child, cumulative ``_bucket`` lines
with ``le`` labels plus ``_sum``/``_count`` for histograms.  Families are
rendered even when they have no children yet (HELP/TYPE only), so a
scraper — or the CI obs-smoke assertion — sees the full metric taxonomy
of an idle engine, not just the families that happened to fire.

Output is deterministic (families and children sorted), which is what the
golden-file test in ``tests/test_obs.py`` pins.
"""
from __future__ import annotations

import math

from repro.obs.metrics import MetricsRegistry

__all__ = ["render", "write"]


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels(names, values, extra=()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _num(x: float) -> str:
    if x == math.inf:
        return "+Inf"
    f = float(x)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def render(registry: MetricsRegistry) -> str:
    """The full registry as Prometheus text exposition (version 0.0.4)."""
    out = []
    for name, fam in sorted(registry.families().items()):
        out.append(f"# HELP {name} {_escape(fam.help) or name}")
        out.append(f"# TYPE {name} {fam.kind}")
        for values, child in sorted(fam.children().items()):
            if fam.kind in ("counter", "gauge"):
                out.append(f"{name}{_labels(fam.label_names, values)} "
                           f"{_num(child.value)}")
                continue
            # histogram: cumulative le buckets + _sum/_count
            cum = 0
            for bound, cnt in zip(child.bounds + (math.inf,),
                                  child.bucket_counts):
                cum += cnt
                lbl = _labels(fam.label_names, values,
                              extra=(("le", _num(bound)),))
                out.append(f"{name}_bucket{lbl} {cum}")
            lbl = _labels(fam.label_names, values)
            out.append(f"{name}_sum{lbl} {_num(child.sum)}")
            out.append(f"{name}_count{lbl} {child.count}")
    return "\n".join(out) + ("\n" if out else "")


def write(path: str, registry: MetricsRegistry) -> str:
    text = render(registry)
    with open(path, "w") as f:
        f.write(text)
    return path
