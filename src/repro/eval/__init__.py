"""Quality-at-scale eval harness: datasets, metrics, engine-path runner,
and the per-(arch, method, bits, kv_bits) scorecard (BENCH_quality.json)."""
from repro.eval import datasets, metrics, runner, scorecard

__all__ = ["datasets", "metrics", "runner", "scorecard"]
