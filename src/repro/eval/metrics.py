"""Quality metrics: teacher-forced NLL/perplexity, multiple-choice
accuracy, and greedy-match-rate against a reference (fp16) model.

``nll_greedy`` is the single jnp kernel every scoring path shares —
``Engine.score`` jits it inside the serving decode step and the dense
reference loop (``runner.dense_reference_score``) applies it to bare
forward logits — so "bit-identical" comparisons between the paged
serving path and a dense forward compare the same floating-point ops,
not two reimplementations of log-softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def nll_greedy(logits, targets):
    """Per-row teacher-forced metrics from one step's logits.

    logits (B, V), targets (B,) int32 ->
      nll    (B,) float32: -log softmax(logits)[target]
      greedy (B,) int32:   argmax prediction
    """
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    greedy = jnp.argmax(lp, axis=-1).astype(jnp.int32)
    return nll, greedy


def perplexity(nll) -> float:
    """exp(mean token NLL) over an (B, T) or flat NLL array."""
    return float(np.exp(np.mean(np.asarray(nll, np.float64))))


def greedy_match_rate(greedy_a, greedy_b) -> float:
    """Fraction of positions where two models' greedy predictions agree —
    the serving-quality headline for a quantized model vs its fp16
    reference (1.0 = decoding is indistinguishable under argmax)."""
    a, b = np.asarray(greedy_a), np.asarray(greedy_b)
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch: {a.shape} vs {b.shape}")
    return float(np.mean(a == b))


def choice_logprobs(nll, prompt_len: int) -> np.ndarray:
    """Sum continuation log-probs from score() NLLs of prompt+choice rows.

    ``nll`` (N, P+C-1) scores sequences ``prompt (P) ++ choice (C)``;
    positions P-1 .. P+C-2 predict the choice tokens, so the choice's
    total log-prob is minus that slice's sum."""
    nll = np.asarray(nll, np.float64)
    return -nll[:, prompt_len - 1:].sum(axis=-1)


def choice_accuracy(logprobs, gold) -> float:
    """logprobs (n, K) per-choice totals, gold (n,) -> accuracy."""
    lp = np.asarray(logprobs)
    pred = lp.argmax(axis=-1)
    return float(np.mean(pred == np.asarray(gold)))
