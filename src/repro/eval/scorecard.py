"""Quality scorecard: per-(arch, method, bits, kv_bits) matrix on disk.

``BENCH_quality.json`` is the committed quality ledger the way
``BENCH.md`` is the speed one: each row is one end-to-end measurement —
an ``oac-qckpt`` checkpoint scored through the ``PagedEngine`` path
(``launch/eval.py``) — keyed by ``(arch, method, wbits, kv_bits)``.
``upsert`` replaces the row with the same key (re-running an eval updates
its cell, never duplicates it); rows stay sorted by key so diffs are
stable.

``check`` is the CI tripwire: every row's quantized-vs-fp16 perplexity
ratio must stay under the bound for its bit-width.  Bounds are loose
enough for run-to-run training noise but catch a broken calibrator or
dequant path (which shows up as 2-10x ppl, not 1.0x).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

FORMAT = "oac-bench-quality"
VERSION = 1
KEY_FIELDS = ("arch", "method", "wbits", "kv_bits")

# max quantized/fp16 ppl ratio per weight bit-width (CI tripwire).
# Measured on the trained toy-llama-smoke matrix (BENCH_quality.json):
# w4 lands at 1.01-1.03 across all methods, w2 at 1.43 (quantease) -
# 1.91 (rtn).  Bounds sit ~2x above the worst measured method so retrain
# noise passes while a broken calibrator or dequant path (2-10x ppl)
# fails hard.
PPL_RATIO_BOUNDS: Dict[int, float] = {
    1: 40.0, 2: 4.0, 3: 2.0, 4: 1.25, 8: 1.05, 16: 1.01,
}


def row_key(row: dict) -> tuple:
    return tuple(row[k] for k in KEY_FIELDS)


def load(path: str) -> List[dict]:
    """Rows of an existing scorecard ([] if the file doesn't exist)."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    if doc.get("format") != FORMAT:
        raise ValueError(f"{path} is not an {FORMAT} file "
                         f"(format={doc.get('format')!r})")
    return doc["rows"]


def save(path: str, rows: List[dict]) -> None:
    rows = sorted(rows, key=lambda r: [str(v) for v in row_key(r)])
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"format": FORMAT, "version": VERSION, "rows": rows},
                  f, indent=1)
        f.write("\n")
    os.replace(tmp, path)


def upsert(path: str, row: dict) -> List[dict]:
    """Insert ``row``, replacing any existing row with the same
    (arch, method, wbits, kv_bits) key; persists and returns all rows."""
    missing = [k for k in KEY_FIELDS if k not in row]
    if missing:
        raise ValueError(f"scorecard row missing key fields {missing}")
    rows = [r for r in load(path) if row_key(r) != row_key(row)]
    rows.append(row)
    save(path, rows)
    return rows


def check(rows: List[dict],
          bounds: Optional[Dict[int, float]] = None) -> List[str]:
    """Regression tripwires -> list of failure strings (empty = pass).

    A row fails when its ``ppl_ratio`` exceeds the bound for its
    ``wbits``; rows without a ratio (no fp16 reference recorded) are
    skipped — they carry absolute ppl only.
    """
    bounds = bounds or PPL_RATIO_BOUNDS
    fails = []
    for r in rows:
        ratio = r.get("ppl_ratio")
        if ratio is None:
            continue
        bound = bounds.get(int(r["wbits"]))
        if bound is None:
            continue
        if ratio > bound:
            fails.append(
                f"{r['arch']} {r['method']} w{r['wbits']} kv{r['kv_bits']}: "
                f"ppl_ratio {ratio:.3f} > bound {bound}")
    return fails
