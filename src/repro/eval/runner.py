"""Batched teacher-forced scoring through the real serving path.

The runner never calls ``model.apply`` for quality numbers: scoring goes
through ``PagedEngine.score`` (paged KV pool, block tables, optional int8
KV, fused dequant decode for packed weights), so every eval row exercises
the exact code production decode runs — a perplexity regression here is a
*serving* regression, not just a math one.  ``dense_reference_score`` is
the per-row dense-cache oracle tests compare the engine against
(bit-identity: same metric kernel, same bucketed first-token prefill,
dense KV instead of the paged pool).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.eval import datasets as ds
from repro.eval import metrics as M
from repro.models import build_model
from repro.serving.engine import PagedEngine


def make_engine(cfg, params, *, capacity: int, max_batch: int = 8,
                kv_bits: int = 16, block_size: int = 16,
                obs=None) -> PagedEngine:
    """The scoring engine: paged KV, capacity rounded up to whole blocks."""
    capacity += (-capacity) % block_size
    return PagedEngine(cfg, params, max_batch=max_batch, capacity=capacity,
                       block_size=block_size, kv_bits=kv_bits, obs=obs)


def score_choices(engine, cs: ds.ChoiceSet) -> np.ndarray:
    """(n, K) summed continuation log-probs via the engine scoring path."""
    rows = cs.rows()
    P = cs.prompts.shape[1]
    out = engine.score(rows)
    lp = M.choice_logprobs(out["nll"], P)
    n, K, _ = cs.choices.shape
    return lp.reshape(n, K)


def dense_reference_score(cfg, params, tokens, *,
                          capacity: int) -> Dict[str, np.ndarray]:
    """Per-row dense teacher-forced oracle for ``Engine.score``.

    Mirrors the engine's computation shape-for-shape — bucketed B=1
    first-token prefill (exact length for recurrent families), then a
    B=1 dense-cache ``decode_step`` per position — with no slot pool, no
    paged blocks, no batch padding.  ``PagedEngine.score`` reproduces
    this bit-for-bit at kv_bits=16 when decoding one row at a time
    (``max_batch=1``); at larger batches the paged and dense-slot engines
    remain bitwise-identical to *each other*, but recurrent families
    (ssm/hybrid) reassociate state math under batching (~1e-6 nll drift
    vs B=1 — greedy argmax is unaffected).  tests/test_eval.py pins all
    three contracts.
    """
    model = build_model(cfg)
    tokens = np.asarray(tokens, np.int32)
    B, S = tokens.shape
    bucketable = cfg.family not in ("ssm", "hybrid")
    prefill = jax.jit(model.prefill)
    first = jax.jit(M.nll_greedy)

    def _step(params, tok, tgt, cache, pos):
        logits, cache = model.decode_step(params, tok, cache, pos)
        nll, greedy = M.nll_greedy(logits[:, 0], tgt)
        return nll, greedy, cache
    step = jax.jit(_step, donate_argnums=(3,))

    nll = np.zeros((B, S - 1), np.float32)
    greedy = np.zeros((B, S - 1), np.int32)
    for i in range(B):
        cache = model.init_cache(1, capacity, dtype=jnp.float32)
        if bucketable:
            Sp = min(max(8, 1), capacity)        # Engine._bucket(1)
            toks = np.zeros((1, Sp), np.int32)
            toks[0, 0] = tokens[i, 0]
            logits, cache, _ = prefill(params, {"tokens": jnp.asarray(toks)},
                                       cache, jnp.asarray(1, jnp.int32))
        else:
            logits, cache, _ = prefill(
                params, {"tokens": jnp.asarray(tokens[i:i + 1, :1])}, cache)
        nll0, g0 = first(logits[:, 0], jnp.asarray(tokens[i:i + 1, 1]))
        nll[i, 0] = np.asarray(nll0)[0]
        greedy[i, 0] = np.asarray(g0)[0]
        for t in range(1, S - 1):
            nll_t, g_t, cache = step(
                params, jnp.asarray(tokens[i:i + 1, t:t + 1]),
                jnp.asarray(tokens[i:i + 1, t + 1]), cache,
                jnp.full((1,), t, jnp.int32))
            nll[i, t] = np.asarray(nll_t)[0]
            greedy[i, t] = np.asarray(g_t)[0]
    return {"nll": nll, "greedy": greedy}


def evaluate(cfg, params, *, ref_params=None, corpus=None, n_seq: int = 8,
             n_choice_items: int = 16, prompt_len: int = 24,
             choice_len: int = 8, kv_bits: int = 16, max_batch: int = 8,
             log=print, obs=None) -> Dict[str, object]:
    """Full quality eval of one param tree through the serving path.

    Scores the held-out perplexity stream and the multiple-choice set on
    a ``PagedEngine`` built from ``params``; with ``ref_params`` (the
    fp16 model) the same engine path scores the reference too, yielding
    the ppl ratio and the greedy-match-rate.  Returns a scorecard-ready
    dict plus the raw greedy arrays (for callers that chain comparisons).
    """
    corpus = corpus or ds.toy_corpus(cfg)
    stream = ds.ppl_stream(corpus, n_seq)
    cs = ds.choice_set(corpus, n_choice_items, prompt_len=prompt_len,
                       choice_len=choice_len)
    cap = max(corpus.seq_len, prompt_len + choice_len)
    eng = make_engine(cfg, params, capacity=cap, max_batch=max_batch,
                      kv_bits=kv_bits, obs=obs)
    out = eng.score(stream)
    ppl = M.perplexity(out["nll"])
    acc = M.choice_accuracy(score_choices(eng, cs), cs.gold)
    res: Dict[str, object] = {
        "ppl": ppl, "choice_acc": acc, "kv_bits": kv_bits,
        "n_tokens": int(out["nll"].size), "greedy": out["greedy"],
    }
    if ref_params is not None:
        reng = make_engine(cfg, ref_params, capacity=cap,
                           max_batch=max_batch, kv_bits=kv_bits, obs=obs)
        rout = reng.score(stream)
        res["fp16_ppl"] = M.perplexity(rout["nll"])
        res["ppl_ratio"] = ppl / res["fp16_ppl"]
        res["fp16_choice_acc"] = M.choice_accuracy(
            score_choices(reng, cs), cs.gold)
        res["greedy_match"] = M.greedy_match_rate(out["greedy"],
                                                  rout["greedy"])
    log(f"[eval] ppl {ppl:.3f}"
        + (f" (fp16 {res['fp16_ppl']:.3f}, x{res['ppl_ratio']:.3f})"
           if ref_params is not None else "")
        + f", choice acc {acc:.3f}"
        + (f", greedy match {res['greedy_match']:.3f}"
           if ref_params is not None else "")
        + f", {res['n_tokens']} tokens, kv{kv_bits}")
    return res
