"""Deterministic offline eval sets (no downloads — CI-safe).

Stand-ins for the paper's quality benchmarks, synthesized from the same
Zipf-Markov corpus the toy LM trains on (``benchmarks/prep_toy_lm.py``):

  * ``ppl_stream``  — a wikitext-style perplexity stream: held-out
    ``split="eval"`` sequences (guaranteed disjoint from the calibration
    split, see ``data.pipeline``), scored teacher-forced end to end.
  * ``choice_set``  — a tiny-MMLU-style multiple-choice set: each item is
    a prompt whose *true* Markov continuation is the gold answer and
    whose distractors are continuations lifted from other eval
    sequences.  A trained LM assigns the gold continuation higher
    likelihood than the distractors well above the 1/K chance floor, so
    choice accuracy degrades measurably with quantization error.

Everything is a pure function of (corpus seed, item count, shape
parameters) — any host regenerates the identical benchmark.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import SyntheticCorpus, make_eval_set

# the corpus the toy LM (benchmarks/prep_toy_lm.py, launch/quantize.py)
# trains on; eval draws from the same distribution's held-out split
TOY_CORPUS_SEED = 7


def toy_corpus(cfg, seq_len: int = 128,
               seed: int = TOY_CORPUS_SEED) -> SyntheticCorpus:
    """The corpus matching ``cfg``'s toy-LM training distribution."""
    return SyntheticCorpus(vocab=cfg.vocab, seq_len=seq_len, seed=seed)


def ppl_stream(corpus: SyntheticCorpus, n_seq: int) -> np.ndarray:
    """(n_seq, seq_len) held-out token windows for teacher-forced ppl."""
    return make_eval_set(corpus, n_seq)["tokens"]


@dataclasses.dataclass(frozen=True)
class ChoiceSet:
    prompts: np.ndarray      # (n, P) int32
    choices: np.ndarray      # (n, K, C) int32 — choices[i, gold[i]] is true
    gold: np.ndarray         # (n,) int64

    @property
    def n_choices(self) -> int:
        return self.choices.shape[1]

    def rows(self) -> np.ndarray:
        """(n*K, P+C) prompt++choice rows for ``Engine.score`` (row
        ``i*K + k`` is item i's k-th choice)."""
        n, K, C = self.choices.shape
        rep = np.repeat(self.prompts, K, axis=0)
        return np.concatenate([rep, self.choices.reshape(n * K, C)], axis=1)


def choice_set(corpus: SyntheticCorpus, n_items: int, *,
               prompt_len: int = 24, choice_len: int = 8,
               n_choices: int = 4, seed: int = 0) -> ChoiceSet:
    """Synthesize a deterministic multiple-choice set from the eval split.

    Item i's prompt is the first ``prompt_len`` tokens of eval sequence i;
    the gold choice is that sequence's actual continuation; the K-1
    distractors are the continuations of the *next* K-1 eval sequences
    (same marginal statistics, wrong context).  Gold positions are
    shuffled with a seeded RNG so position carries no signal.
    """
    if prompt_len + choice_len > corpus.seq_len:
        raise ValueError(f"prompt {prompt_len} + choice {choice_len} "
                         f"exceeds corpus seq_len {corpus.seq_len}")
    toks = make_eval_set(corpus, n_items)["tokens"]
    prompts = toks[:, :prompt_len].astype(np.int32)
    conts = toks[:, prompt_len:prompt_len + choice_len].astype(np.int32)
    rng = np.random.default_rng(corpus.seed * 7919 + seed)
    gold = rng.integers(0, n_choices, size=n_items)
    choices = np.empty((n_items, n_choices, choice_len), np.int32)
    for i in range(n_items):
        # distractor pool: other items' continuations, in deterministic
        # rotation so no two choices of one item coincide
        pool = [conts[(i + d) % n_items] for d in range(1, n_choices)]
        k_d = 0
        for k in range(n_choices):
            if k == gold[i]:
                choices[i, k] = conts[i]
            else:
                choices[i, k] = pool[k_d]
                k_d += 1
    return ChoiceSet(prompts, choices, gold)
