"""rwkv6-3b [ssm] — Finch, data-dependent decay; attention-free.

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 [arXiv:2404.05892; hf]
head_size 64 -> 40 wkv heads; decode state is O(1) in sequence length, so
this arch runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    vocab=65536,
    d_ff=8960,
    mlp="rwkv_channel_mix",
    norm="layernorm",
    pos="none",
    rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32),
    tie_embeddings=False,
    source="arXiv:2404.05892; hf",
    notes="Finch - data-dependent decay; attn-free -> runs long_500k",
)
