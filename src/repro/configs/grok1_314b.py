"""grok-1-314b [moe] — 8 experts top-2.

64L d_model=6144 48H (GQA kv=8) d_ff=32768(per expert) vocab=131072, MoE 8e top-2
[hf:xai-org/grok-1; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    vocab=131072,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    mlp="geglu",
    norm="rmsnorm",
    pos="rope",
    logit_softcap=30.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=32768, capacity_factor=1.25),
    tie_embeddings=False,
    source="hf:xai-org/grok-1; unverified",
    notes="8 experts top-2",
)
