"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000 [arXiv:2402.16819; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    vocab=256000,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    mlp="relu2",               # squared ReLU
    norm="layernorm",
    pos="rope",
    rope_theta=10000.0,
    tie_embeddings=False,
    source="arXiv:2402.16819; unverified",
    notes="GQA, squared-ReLU",
)
