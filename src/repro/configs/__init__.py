"""Architecture registry: ``--arch <id>`` -> ModelConfig.

``get_config(name)`` returns the exact published config; ``get_smoke(name)``
returns the reduced same-family config used by CPU smoke tests.
"""
from __future__ import annotations

from repro.configs.base import (LM_SHAPES, SHAPES_BY_NAME, ModelConfig,
                                MoEConfig, QuantConfig, RWKVConfig, SSMConfig,
                                ShapeConfig, TrainConfig, reduce_cfg)
from repro.configs import (gemma3_27b, granite_moe_1b, grok1_314b,
                           musicgen_large, nemotron4_340b, paper_models,
                           phi3_vision_4_2b, qwen2_1_5b, qwen2_5_32b,
                           rwkv6_3b, zamba2_7b)

ASSIGNED = (
    gemma3_27b.CONFIG,
    qwen2_1_5b.CONFIG,
    nemotron4_340b.CONFIG,
    qwen2_5_32b.CONFIG,
    phi3_vision_4_2b.CONFIG,
    zamba2_7b.CONFIG,
    granite_moe_1b.CONFIG,
    grok1_314b.CONFIG,
    rwkv6_3b.CONFIG,
    musicgen_large.CONFIG,
)

EXTRA = (paper_models.LLAMA7B, paper_models.OPT1B, paper_models.TOY_LM)

REGISTRY = {c.name: c for c in ASSIGNED + EXTRA}

ASSIGNED_IDS = tuple(c.name for c in ASSIGNED)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_smoke(name: str) -> ModelConfig:
    return reduce_cfg(get_config(name))


def cells(archs=None, shapes=None):
    """Yield every (arch_config, shape_config) dry-run cell, honoring skips.

    long_500k requires sub-quadratic decode state; it is skipped (with a
    reason) for pure full-attention archs per the assignment spec.
    """
    archs = archs or ASSIGNED_IDS
    shapes = shapes or [s.name for s in LM_SHAPES]
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            sc = SHAPES_BY_NAME[s]
            if sc.name == "long_500k" and not cfg.sub_quadratic:
                continue
            yield cfg, sc


def skipped_cells(archs=None):
    archs = archs or ASSIGNED_IDS
    out = []
    for a in archs:
        cfg = get_config(a)
        if not cfg.sub_quadratic:
            out.append((a, "long_500k",
                        "full-attention arch: O(S) KV growth per layer; "
                        "sub-quadratic shape reserved for ssm/hybrid"))
    return out


__all__ = [
    "ASSIGNED", "ASSIGNED_IDS", "REGISTRY", "LM_SHAPES", "SHAPES_BY_NAME",
    "ModelConfig", "MoEConfig", "SSMConfig", "RWKVConfig", "ShapeConfig",
    "QuantConfig", "TrainConfig", "get_config", "get_smoke", "reduce_cfg",
    "cells", "skipped_cells",
]
