"""qwen2.5-32b [dense] — GQA with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064 [hf:Qwen/Qwen2.5-0.5B family; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    vocab=152064,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    qkv_bias=True,
    d_ff=27648,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
    notes="GQA, QKV bias; 40 heads not divisible by model=16 -> KV-length-parallel decode attention",
)
