"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified]

81 Mamba2 layers; one weight-shared (attention + MLP) block is invoked after
every 6th Mamba2 layer with a per-invocation input projection (zamba2-style).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    vocab=32000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    shared_attn_every=6,
    tie_embeddings=True,
    source="arXiv:2411.15242; unverified",
    notes="Mamba2 + shared attn blocks; sub-quadratic -> runs long_500k",
)
