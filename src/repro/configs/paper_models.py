"""The paper's own model families (OPT / LLaMa) at proxy scales.

The paper quantizes OPT-1.3B..30B and LLaMa(-2)-7B..30B.  We register the real
shapes for dry-run purposes plus CPU-runnable proxies used by the quality
benchmarks (benchmarks/bench_table*.py reproduce the papers' orderings on a
*trained* toy model of the same family).
"""
from repro.configs.base import ModelConfig

# LLaMa-7B exact shape [arXiv:2302.13971] — the paper's main subject.
LLAMA7B = ModelConfig(
    name="llama-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    vocab=32000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    source="arXiv:2302.13971; hf",
    notes="paper's primary subject model",
)

# OPT-1.3B exact shape [arXiv:2205.01068].
OPT1B = ModelConfig(
    name="opt-1.3b",
    family="dense",
    n_layers=24,
    d_model=2048,
    vocab=50272,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    mlp="gelu",
    norm="layernorm",
    pos="sinusoidal",
    tie_embeddings=True,
    source="arXiv:2205.01068; hf",
    notes="paper's smallest OPT subject",
)

# CPU-trainable toy of the LLaMa family for the quality benchmarks.
TOY_LM = ModelConfig(
    name="toy-llama",
    family="dense",
    n_layers=4,
    d_model=256,
    vocab=512,
    n_heads=8,
    n_kv_heads=4,
    head_dim=32,
    d_ff=704,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    tie_embeddings=False,
    source="reduced llama family",
    notes="trained on the synthetic corpus for quality benchmarks",
)
