"""Config dataclasses for models, quantization, training, and workload shapes.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; the registry in ``__init__`` maps ``--arch <id>`` to it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff: int = 0              # per-expert hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # "dense"   : every expert on every token (only for tiny smoke configs)
    # "gather"  : capacity-based gather/scatter dispatch, tokens stay data-parallel
    moe_impl: str = "gather"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64         # mamba2 P (headdim)
    n_groups: int = 1
    chunk: int = 128           # SSD chunked-scan block length


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64       # rank of the data-dependent decay LoRA
    mix_lora: int = 32         # rank of the token-shift mix LoRA


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    # -- attention (unused for family == "ssm") --
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    # sliding-window pattern: `local_window > 0` makes every layer local except
    # each (global_every)-th one.  gemma3: 5 local : 1 global.
    local_window: int = 0
    global_every: int = 0
    # -- mlp --
    d_ff: int = 0
    mlp: str = "swiglu"        # swiglu | relu2 | geglu | gelu
    # -- misc --
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    pos: str = "rope"          # rope | sinusoidal | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # -- sub-configs --
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (zamba2): one shared attention block applied every k SSM layers
    shared_attn_every: int = 0
    # -- frontend stubs --
    frontend: str = "none"     # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0
    # -- provenance --
    source: str = ""
    notes: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state does not grow O(S) per *full-attention* layer."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "audio", "moe"):
            hd = self.resolved_head_dim
            qkv = d * hd * (self.n_heads + 2 * self.n_kv_heads)
            o = self.n_heads * hd * d
            attn = qkv + o
            if self.mlp in ("swiglu", "geglu"):
                mlp = 3 * d * self.d_ff
            else:
                mlp = 2 * d * self.d_ff
            if self.family == "moe":
                assert self.moe is not None
                if self.moe.top_k:
                    gmul = 3 if self.mlp in ("swiglu", "geglu") else 2
                    mlp = self.moe.n_experts * gmul * d * self.moe.d_ff
                    mlp += d * self.moe.n_experts  # router
            per_layer = attn + mlp + 2 * d
        elif self.family == "ssm":  # rwkv6
            assert self.rwkv is not None
            att = 4 * d * d + d * d  # r,k,v,g,o
            att += 6 * (self.rwkv.mix_lora * 2 * d) + self.rwkv.decay_lora * 2 * d
            ffn = 2 * d * self.d_ff + d * d  # key, value, receptance
            per_layer = att + ffn + 2 * d
        elif self.family == "hybrid":
            assert self.ssm is not None
            d_in = self.ssm.expand * d
            nh = d_in // self.ssm.head_dim
            zxbc = d * (2 * d_in + 2 * self.ssm.n_groups * self.ssm.d_state + nh)
            per_layer = zxbc + d_in * d + 2 * d  # + out proj + norms
            # shared attention block amortized over layers
            hd = self.resolved_head_dim
            shared = (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                      + self.n_heads * hd * d + 3 * d * self.d_ff)
            n_shared_inv = L // max(self.shared_attn_every, 1)
            per_inv_proj = 2 * d * d  # per-invocation input projection
            return emb + L * per_layer + shared + n_shared_inv * per_inv_proj
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe" or self.moe is None or not self.moe.top_k:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        gmul = 3 if self.mlp in ("swiglu", "geglu") else 2
        moe_all = L * self.moe.n_experts * gmul * d * self.moe.d_ff
        moe_active = L * self.moe.top_k * gmul * d * self.moe.d_ff
        return full - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


# The four LM shape cells shared by all assigned architectures.
LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    wbits: int = 2                 # 1 (binary) | 2 | 3 | 4 | 8 | 16 (off)
    group_size: int = 64
    # calibration method: rtn | optq | spqr | billm | adpq | quantease
    method: str = "spqr"
    # hessian source: oac (paper) | l2 (output-agnostic baseline) | identity
    hessian: str = "oac"
    alpha: float = 0.1             # Hessian regularization (paper eq. 21)
    outlier_threshold: float = 3.5 # SpQR tau (paper Table 8/9)
    outlier_capacity: float = 0.005  # max outlier fraction kept (fixed COO budget)
    stats_bits: int = 3            # SpQR second-round quantization of scales/zeros
    stats_group: int = 16
    act_order: bool = False
    grad_dtype: str = "float32"    # float32 | bfloat16 (App. C.1)
    hessian_reduction: str = "sum" # sum (eq. 22) | mean (eq. 14)
    # OAC phase-1 gradient source:
    #   precompute : one backward sweep of the full-precision model yields
    #                G for EVERY layer per sample (the paper's complexity
    #                reduction — N backwards total, and the Fisher is not
    #                polluted by the quantization noise of earlier blocks)
    #   sequential : per-block grads on the already-quantized prefix
    #                (GPTQ-style error propagation; N*L backwards)
    oac_grads: str = "precompute"
    n_calib: int = 128
    calib_seq: int = 2048
    solver_block: int = 128        # OPTQ column block size
    cd_iters: int = 3              # QuantEase coordinate-descent epochs


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    steps: int = 300
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    grad_compression: str = "none"  # none | int8_ef


def reduce_cfg(cfg: ModelConfig, **over) -> ModelConfig:
    """Build a reduced smoke-test config of the same family."""
    base = dict(
        n_layers=2,
        d_model=64,
        vocab=256,
        d_ff=128 if cfg.d_ff else 0,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
    )
    if cfg.local_window:
        base["local_window"] = 16
        base["global_every"] = 3
        base["n_layers"] = 7     # 2 groups of (2 local + 1 global) + 1 tail
    if cfg.moe is not None:
        base["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2, d_ff=64)
    if cfg.ssm is not None:
        base["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.rwkv is not None:
        base["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_size=16, decay_lora=8, mix_lora=8)
    if cfg.shared_attn_every:
        base["shared_attn_every"] = 2
        base["n_layers"] = 5
    base.update(over)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
