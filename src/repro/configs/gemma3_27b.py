"""gemma3-27b [dense] — 5:1 local:global sliding-window attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt family; unverified]
head_dim=128 (gemma3 uses a decoupled q/kv width: 32*128=4096 != d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    vocab=262144,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    mlp="geglu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=1_000_000.0,
    local_window=1024,
    global_every=6,            # 5 local : 1 global
    tie_embeddings=True,
    logit_softcap=30.0,
    source="hf:google/gemma-3-1b-pt; unverified",
    notes="5:1 local:global, 128k context",
)
