"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512(per expert) vocab=49155, MoE 32e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    vocab=49155,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    moe=MoEConfig(n_experts=32, top_k=8, d_ff=512, capacity_factor=1.25),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    notes="32 experts top-8",
)
