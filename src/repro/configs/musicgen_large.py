"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284; hf]

Backbone only per the assignment spec: the EnCodec frontend is a STUB whose
``input_specs()`` provides precomputed frame embeddings (the sum of the four
delayed-codebook embeddings); the LM head predicts codebook tokens (vocab 2048).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    vocab=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    mlp="gelu",
    norm="layernorm",
    pos="sinusoidal",
    tie_embeddings=False,
    frontend="audio_stub",
    n_frontend_tokens=0,       # frames *replace* tokens (pure continuation LM)
    source="arXiv:2306.05284; hf",
    notes="decoder-only over EnCodec tokens",
)
