"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

Per the assignment spec the vision frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (n_frontend_tokens, d_model) which the
backbone consumes as a prefix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    vocab=32064,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=10000.0,
    tie_embeddings=False,
    frontend="vision_stub",
    n_frontend_tokens=256,     # precomputed CLIP patch embeddings
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
    notes="phi3-mini + CLIP; frontend stubbed, CE on text positions only",
)
