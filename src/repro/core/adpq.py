"""AdpQ-style zero-shot calibration (arXiv 2405.13358).

AdpQ removes the calibration set entirely: instead of ranking weights by a
Hessian-weighted sensitivity (SpQR, eq. 4), it identifies outliers from the
weight distribution alone with an adaptive soft-threshold — the
adaptive-LASSO view of quantization: weights whose magnitude survives a
per-column shrinkage proportional to the quantization step are kept in
precision, everything else is round-to-nearest on a grid fitted to the
inliers.  No Hessian, no activations, no data — the whole "calibration" is
one pass over the kernel, which makes it the near-free rival baseline for
the OAC method matrix.

This implementation keeps the repo's fixed-COO-budget contract: the
shrinkage score ranks every weight, the top ``capacity * d_in * d_out``
survivors become additive COO corrections (exactly the
``solver.CalibResult`` / ``QuantizedTensor`` outlier encoding), so AdpQ
checkpoints pack into the same ``oac-qckpt`` container as OAC/SpQR and
serve through the identical fused-dequant path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantizers as qz
from repro.core import solver


def adpq_scores(W: jnp.ndarray, group_size: int, bits: int) -> jnp.ndarray:
    """Soft-threshold saliency: how far |w| overshoots its group's RTN step.

    The adaptive-LASSO threshold for a uniform grid of step ``s`` is
    proportional to ``s``; weights with ``|w| >> s`` carry most of the
    column's l2 mass and dominate the quantization error when clipped to
    the grid, so the score is the magnitude measured in *steps of its own
    group's grid* — scale-free across columns and groups.
    """
    d_in, d_out = W.shape
    G = d_in // group_size
    Wg = W.reshape(G, group_size, d_out)
    grid = qz.fit_grid(Wg, bits)                 # scale (G, d_out)
    step = grid.scale[:, None, :]                # broadcast over the group
    return (jnp.abs(Wg) / step).reshape(d_in, d_out)


def adpq_result(W: jnp.ndarray, *, bits: int, group_size: int,
                outlier_capacity: float = 0.005) -> solver.CalibResult:
    """Zero-shot AdpQ calibration of one kernel -> ``solver.CalibResult``.

    1. score every weight by grid-relative magnitude (``adpq_scores``);
    2. keep the global top ``capacity`` fraction as outliers (fixed COO
       budget, same shapes as SpQR so packing is uniform);
    3. refit each group's grid with outliers masked out — inliers get the
       full code range instead of being crushed by the outlier span;
    4. RTN-quantize everything on the refit grid; outlier positions store
       the additive correction ``w - dequant(code)``.
    """
    if W.ndim == 3:                               # stacked layer kernels
        fn = lambda w: adpq_result(w, bits=bits, group_size=group_size,
                                   outlier_capacity=outlier_capacity)
        return jax.vmap(fn)(W)
    W = W.astype(jnp.float32)
    d_in, d_out = W.shape
    assert d_in % group_size == 0, (d_in, group_size)
    G = d_in // group_size

    s = adpq_scores(W, group_size, bits)
    # adaptive threshold: relative to the mean score, like solver.detect_
    # outliers' relative tau — keeps the selection meaningful whether the
    # kernel is near-Gaussian (few outliers) or heavy-tailed (many)
    cap = max(int(outlier_capacity * d_in * d_out), 8)
    thresh = 2.0 * jnp.mean(s)
    flat = jnp.where(s > thresh, s, -jnp.inf).ravel()
    vals, idx = jax.lax.top_k(flat, cap)
    keep = jnp.isfinite(vals)
    rows = jnp.where(keep, idx // d_out, 0).astype(jnp.int32)
    cols = jnp.where(keep, idx % d_out, 0).astype(jnp.int32)
    omask = jnp.zeros((d_in, d_out), bool).at[rows, cols].set(keep)

    # grid refit with outliers excluded (they are stored exactly anyway)
    Wg = W.reshape(G, group_size, d_out)
    og = omask.reshape(G, group_size, d_out)
    grid = qz.fit_grid(Wg, bits, mask=1.0 - og.astype(W.dtype))
    g2 = qz.Grid(grid.scale[:, None], grid.zero[:, None], bits)
    q = qz.quantize(Wg, g2)
    w_grid = qz.dequantize(q, g2).reshape(d_in, d_out)

    o_vals = jnp.where(keep, W[rows, cols] - w_grid[rows, cols], 0.0)
    w_hat = w_grid.at[rows, cols].add(o_vals)
    err = jnp.sum((W - w_hat) ** 2)
    return solver.CalibResult(
        q.reshape(d_in, d_out).astype(jnp.uint8), grid.scale, grid.zero,
        rows, cols, o_vals, w_hat, err)
