"""Quantized-weight storage format: bit-packing, double-quantized stats, outliers.

Layout conventions (all relative to a linear kernel ``W`` of shape
``(d_in, d_out)`` applied as ``y = x @ W``):

* quantization groups tile the **contraction** axis (d_in), group size ``gs``;
  grid is asymmetric uniform: ``w ~= scale * (q - zero)``, ``q in [0, 2^b - 1]``.
* packing is little-endian along d_in:
    - b in {1, 2, 4, 8}: ``8/b`` values per byte -> packed ``(d_in*b/8, d_out)`` uint8
    - b == 3: two bit-planes (2-bit plane + 1-bit plane), ``q = lo2 + 4*hi1``
* first-level stats (scale, zero) per (group, d_out) are *themselves* quantized
  (SpQR second round, paper Fig. 3 step 7): ``stats_bits`` uniform grid over
  ``stats_group`` consecutive groups, fp second-level scale/zero.
* outliers: fixed-capacity COO ``(rows, cols, vals)``; ``vals`` are *additive*
  corrections on top of the dequantized grid (grid holds round(zero) there), so
  the fused matmul path is ``x @ deq(Q) + scatter_add``.

Everything here is pure jnp so it can run inside jit on any backend; the
Pallas kernels in ``repro.kernels.dequant_matmul`` consume the same layout.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PACKABLE = (1, 2, 3, 4, 8)

# Version tag of the packed layout (plane semantics + the on-disk entry
# ordering below).  Bump whenever a field is added/reordered or its meaning
# changes; ``serving.qserve.ckpt`` refuses manifests with a different tag.
QFORMAT_VERSION = 1

# Canonical per-tensor entry names in their stable on-disk order
# (docs/qformat.md "Plane names"): packed code planes first, grouped stats
# codes + their second-level fp stats, the COO outlier buffers, then the
# optional BiLLM residual planes.  ``codes.1`` exists only for bits == 3
# (the 1-bit hi plane); ``resid.*`` only when resid_planes is present.
ENTRY_NAMES = (
    "codes.0", "codes.1",
    "q_scales", "ss_scale", "ss_zero",
    "q_zeros", "zz_scale", "zz_zero",
    "out_rows", "out_cols", "out_vals",
    "resid.0", "resid_scales",
)


# --------------------------------------------------------------------------
# bit packing (jnp, vectorized)
# --------------------------------------------------------------------------

def _pack_plane(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack q (d_in, d_out) with values < 2**bits into uint8 along axis 0."""
    per = 8 // bits
    d_in, d_out = q.shape
    assert d_in % per == 0, f"d_in={d_in} not divisible by {per} (b={bits})"
    q = q.astype(jnp.uint8).reshape(d_in // per, per, d_out)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits)[None, :, None]
    return jnp.sum(q << shifts, axis=1).astype(jnp.uint8)


def _unpack_plane(p: jnp.ndarray, bits: int, d_in: int) -> jnp.ndarray:
    per = 8 // bits
    mask = jnp.uint8(2 ** bits - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits)[None, :, None]
    vals = (p[:, None, :] >> shifts) & mask
    return vals.reshape(per * p.shape[0], p.shape[-1])[:d_in]


def pack(q: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, ...]:
    """Pack integer codes -> tuple of uint8 planes."""
    assert bits in PACKABLE
    if bits == 3:
        lo = q & 0x3
        hi = (q >> 2) & 0x1
        return (_pack_plane(lo, 2), _pack_plane(hi, 1))
    return (_pack_plane(q, bits),)


def unpack(planes: Tuple[jnp.ndarray, ...], bits: int, d_in: int) -> jnp.ndarray:
    assert bits in PACKABLE
    if bits == 3:
        lo = _unpack_plane(planes[0], 2, d_in)
        hi = _unpack_plane(planes[1], 1, d_in)
        return (lo + (hi << 2)).astype(jnp.uint8)
    return _unpack_plane(planes[0], bits, d_in)


# --------------------------------------------------------------------------
# double-quantized statistics (SpQR second round)
# --------------------------------------------------------------------------

def quantize_stats(stats: jnp.ndarray, bits: int, group: int):
    """Quantize per-group stats (G, d_out) along axis 0 in blocks of ``group``.

    Returns (codes uint8, s2_scale, s2_zero) with block shape (G//group, d_out).
    """
    G, d_out = stats.shape
    pad = (-G) % group
    if pad:
        stats = jnp.concatenate(
            [stats, jnp.repeat(stats[-1:], pad, axis=0)], axis=0)
    blk = stats.reshape(-1, group, d_out)
    lo = blk.min(axis=1)
    hi = blk.max(axis=1)
    qmax = 2 ** bits - 1
    scale = jnp.maximum((hi - lo) / qmax, 1e-9)
    zero = -lo / scale
    codes = jnp.clip(jnp.round(blk / scale[:, None] + zero[:, None]), 0, qmax)
    return codes.astype(jnp.uint8), scale, zero


def dequantize_stats(codes, s2_scale, s2_zero, G: int):
    vals = (codes.astype(s2_scale.dtype) - s2_zero[:, None]) * s2_scale[:, None]
    return vals.reshape(-1, vals.shape[-1])[:G]


# --------------------------------------------------------------------------
# QuantizedTensor pytree
# --------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=["planes", "q_scales", "ss_scale", "ss_zero",
                      "q_zeros", "zz_scale", "zz_zero",
                      "out_rows", "out_cols", "out_vals",
                      "resid_planes", "resid_scales"],
         meta_fields=["bits", "group_size", "shape", "stats_bits",
                      "stats_group", "dtype"])
@dataclasses.dataclass
class QuantizedTensor:
    """Packed low-bit weight for a linear kernel (d_in, d_out)."""
    planes: Tuple[jnp.ndarray, ...]       # packed uint8 code planes
    q_scales: jnp.ndarray                 # (G//sg, sg-blocked) codes uint8
    ss_scale: jnp.ndarray                 # second-level scale for scales
    ss_zero: jnp.ndarray
    q_zeros: jnp.ndarray                  # codes for zeros
    zz_scale: jnp.ndarray
    zz_zero: jnp.ndarray
    out_rows: jnp.ndarray                 # (cap,) int32, d_in index
    out_cols: jnp.ndarray                 # (cap,) int32, d_out index
    out_vals: jnp.ndarray                 # (cap,) additive corrections
    resid_planes: Optional[Tuple[jnp.ndarray, ...]]  # BiLLM residual binary
    resid_scales: Optional[jnp.ndarray]
    bits: int
    group_size: int
    shape: Tuple[int, int]
    stats_bits: int
    stats_group: int
    dtype: str

    # -- reconstruction -----------------------------------------------------
    @property
    def n_groups(self) -> int:
        return self.shape[0] // self.group_size

    def scales_zeros(self):
        G = self.n_groups
        scales = dequantize_stats(self.q_scales, self.ss_scale, self.ss_zero, G)
        zeros = dequantize_stats(self.q_zeros, self.zz_scale, self.zz_zero, G)
        return scales, zeros

    def dequantize(self) -> jnp.ndarray:
        """Full-precision reconstruction W_hat (d_in, d_out)."""
        d_in, d_out = self.shape
        q = unpack(self.planes, self.bits, d_in).astype(jnp.float32)
        scales, zeros = self.scales_zeros()
        q = q.reshape(self.n_groups, self.group_size, d_out)
        w = (q - zeros[:, None, :]) * scales[:, None, :]
        w = w.reshape(d_in, d_out)
        if self.resid_planes is not None:
            rb = unpack(self.resid_planes, 1, d_in).astype(jnp.float32)
            w = w + (rb * 2.0 - 1.0) * self.resid_scales  # sign * alpha
        w = w.at[self.out_rows, self.out_cols].add(self.out_vals)
        return w.astype(self.dtype)

    def storage_bits(self) -> float:
        """Actual average bits per weight element (paper "Avg Bits").
        Works on layer/expert-stacked tensors (leading dims included)."""
        n = self.shape[0] * self.shape[1]
        for d in self.planes[0].shape[:-2]:     # stack dims
            n *= d
        total = 0
        for p in self.planes:
            total += p.size * 8
        for arr in (self.q_scales, self.q_zeros):
            total += arr.size * self.stats_bits     # logical 3-bit storage
        for arr in (self.ss_scale, self.ss_zero, self.zz_scale, self.zz_zero):
            total += arr.size * 16
        total += self.out_vals.size * (16 + 32)      # fp16 value + packed index
        if self.resid_planes is not None:
            for p in self.resid_planes:
                total += p.size * 8
            total += self.resid_scales.size * 16
        return total / n


def make_quantized(q_codes, scales, zeros, bits, group_size, shape,
                   out_rows, out_cols, out_vals, stats_bits=3, stats_group=16,
                   dtype="bfloat16", resid_signs=None, resid_scales=None
                   ) -> QuantizedTensor:
    """Assemble a QuantizedTensor from calibration outputs."""
    planes = pack(q_codes, bits)
    qs, ss, sz = quantize_stats(scales, stats_bits, stats_group)
    qz, zs, zz = quantize_stats(zeros, stats_bits, stats_group)
    # second-level stats are stored (and counted) as 16-bit floats
    ss, sz, zs, zz = (t.astype(jnp.bfloat16) for t in (ss, sz, zs, zz))
    rp = None
    if resid_signs is not None:
        rp = pack(((resid_signs > 0)).astype(jnp.uint8), 1)
    return QuantizedTensor(
        planes=planes, q_scales=qs, ss_scale=ss, ss_zero=sz,
        q_zeros=qz, zz_scale=zs, zz_zero=zz,
        out_rows=out_rows.astype(jnp.int32), out_cols=out_cols.astype(jnp.int32),
        out_vals=out_vals,
        resid_planes=rp, resid_scales=resid_scales,
        bits=bits, group_size=group_size, shape=tuple(shape),
        stats_bits=stats_bits, stats_group=stats_group, dtype=dtype)


def make_residual_carrier(w_hat, *, group_size: int, stats_bits=3,
                          stats_group=16, dtype="bfloat16") -> QuantizedTensor:
    """Pack an arbitrary fake-quant reconstruction exactly (in bf16) as a
    1-bit sign plane + per-element magnitude residual.

    BiLLM's per-element alpha choice (bell split / residual binarization)
    does not fit the grouped uniform grid, so its results ride the format's
    *residual* mechanism instead: the primary 1-bit grid is all-zero (zero
    scales -> contributes exactly 0) and ``resid_planes``/``resid_scales``
    carry ``sign(w_hat) * |w_hat|``.  This keeps BiLLM checkpoints in the
    same v1 container the sharded serving stack already understands (the
    fused matmuls add the residual per tile after the grouped dequant, on
    the unsharded and the tp col/row paths alike); storage
    accounting for the *method* stays with ``BinaryResult.avg_bits`` — the
    carrier's own ``storage_bits()`` reports the bf16-residual cost.
    """
    d_in, d_out = w_hat.shape
    assert d_in % group_size == 0, (d_in, group_size)
    G = d_in // group_size
    zg = jnp.zeros((G, d_out), jnp.float32)
    cap = 8
    zr = jnp.zeros((cap,), jnp.int32)
    return make_quantized(
        jnp.zeros((d_in, d_out), jnp.uint8), zg, zg, 1, group_size,
        (d_in, d_out), zr, zr, jnp.zeros((cap,), jnp.bfloat16),
        stats_bits=stats_bits, stats_group=stats_group, dtype=dtype,
        resid_signs=w_hat, resid_scales=jnp.abs(w_hat).astype(jnp.bfloat16))


def qt_entries(qt: QuantizedTensor):
    """The tensor's array fields as ``[(entry_name, array), ...]`` in the
    stable on-disk order (``ENTRY_NAMES``).  The checkpoint writer, the
    loader, and the byte accounting all iterate a QuantizedTensor through
    this single function so the layout cannot silently drift."""
    e = [(f"codes.{i}", p) for i, p in enumerate(qt.planes)]
    e += [("q_scales", qt.q_scales), ("ss_scale", qt.ss_scale),
          ("ss_zero", qt.ss_zero), ("q_zeros", qt.q_zeros),
          ("zz_scale", qt.zz_scale), ("zz_zero", qt.zz_zero),
          ("out_rows", qt.out_rows), ("out_cols", qt.out_cols),
          ("out_vals", qt.out_vals)]
    if qt.resid_planes is not None:
        e += [(f"resid.{i}", p) for i, p in enumerate(qt.resid_planes)]
        e += [("resid_scales", qt.resid_scales)]
    names = [n for n, _ in e]
    assert names == [n for n in ENTRY_NAMES if n in names], names
    return e


def qt_meta(qt: QuantizedTensor) -> dict:
    """JSON-serializable static metadata of one QuantizedTensor."""
    return {"bits": qt.bits, "group_size": qt.group_size,
            "shape": list(qt.shape), "stats_bits": qt.stats_bits,
            "stats_group": qt.stats_group, "dtype": qt.dtype}


def qt_from_entries(arrays: dict, meta: dict) -> QuantizedTensor:
    """Rebuild a QuantizedTensor from named entry arrays + static meta
    (inverse of ``qt_entries``/``qt_meta``; the checkpoint load path)."""
    bits = int(meta["bits"])
    planes = tuple(arrays[f"codes.{i}"]
                   for i in range(2 if bits == 3 else 1))
    rp, rs = None, None
    if "resid.0" in arrays:
        rp = (arrays["resid.0"],)
        rs = arrays["resid_scales"]
    return QuantizedTensor(
        planes=planes, q_scales=arrays["q_scales"],
        ss_scale=arrays["ss_scale"], ss_zero=arrays["ss_zero"],
        q_zeros=arrays["q_zeros"], zz_scale=arrays["zz_scale"],
        zz_zero=arrays["zz_zero"], out_rows=arrays["out_rows"],
        out_cols=arrays["out_cols"], out_vals=arrays["out_vals"],
        resid_planes=rp, resid_scales=rs,
        bits=bits, group_size=int(meta["group_size"]),
        shape=tuple(meta["shape"]), stats_bits=int(meta["stats_bits"]),
        stats_group=int(meta["stats_group"]), dtype=meta["dtype"])


def abstract_quantized(d_in: int, d_out: int, bits: int, group_size: int,
                       outlier_capacity: float = 0.005, stats_bits=3,
                       stats_group=16, dtype="bfloat16",
                       residual: bool = False,
                       outlier_count: Optional[int] = None) -> QuantizedTensor:
    """ShapeDtypeStruct skeleton of a QuantizedTensor (for dry-run lowering).

    ``outlier_count`` pins the COO capacity exactly (checkpoint-manifest
    verification); otherwise it is derived from ``outlier_capacity``."""
    sds = jax.ShapeDtypeStruct
    G = d_in // group_size
    GB = -(-G // stats_group)
    cap = outlier_count if outlier_count is not None else \
        max(int(outlier_capacity * d_in * d_out), 8)
    if bits == 3:
        planes = (sds((d_in // 4, d_out), jnp.uint8),
                  sds((d_in // 8, d_out), jnp.uint8))
    else:
        planes = (sds((d_in * bits // 8, d_out), jnp.uint8),)
    rp, rs = None, None
    if residual:
        rp = (sds((d_in // 8, d_out), jnp.uint8),)
        rs = sds((d_in, d_out), jnp.bfloat16)
    return QuantizedTensor(
        planes=planes,
        q_scales=sds((GB, stats_group, d_out), jnp.uint8),
        ss_scale=sds((GB, d_out), jnp.bfloat16),
        ss_zero=sds((GB, d_out), jnp.bfloat16),
        q_zeros=sds((GB, stats_group, d_out), jnp.uint8),
        zz_scale=sds((GB, d_out), jnp.bfloat16),
        zz_zero=sds((GB, d_out), jnp.bfloat16),
        out_rows=sds((cap,), jnp.int32),
        out_cols=sds((cap,), jnp.int32),
        out_vals=sds((cap,), jnp.bfloat16),
        resid_planes=rp, resid_scales=rs,
        bits=bits, group_size=group_size, shape=(d_in, d_out),
        stats_bits=stats_bits, stats_group=stats_group, dtype=dtype)


def dequantize_any(k):
    """Dense reconstruction of a (possibly layer/expert-stacked) tensor."""
    if not isinstance(k, QuantizedTensor):
        return k
    extra = k.planes[0].ndim - 2
    fn = QuantizedTensor.dequantize
    for _ in range(extra):
        fn = jax.vmap(fn)
    return fn(k)
