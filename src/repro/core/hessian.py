"""Hessian estimators for calibration (the paper's core contribution).

Two estimators, both producing a (d_in, d_in) matrix per linear kernel
``W (d_in, d_out)`` (our storage transposes the paper's ``W (d_row, d_col)``;
the Hessian lives on the contraction dim either way):

* **output-agnostic** (OPTQ/SpQR baseline, paper eq. 1):
    ``H_l2 = sum_i x_i x_i^T`` over calibration inputs of the layer.
* **output-adaptive** (OAC, paper eq. 13-14 / 22):
    ``H_oac = sum_i G[i] G[i]^T`` where ``G[i] = dL_CE/dW`` for calibration
    sample i — the Fisher-information approximation of the CE-loss Hessian
    aggregated over rows.  The *labels* enter through the gradient (eq. 12),
    which is what makes the method output-adaptive.

Reduction: paper defaults to the **sum** (eq. 22, better numerics); ``mean``
(eq. 14) is available for the App. C.3 ablation.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp

from repro import utils


def regularize(H: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """Paper eq. 21: H + diag(alpha * mean(diag(H)))."""
    d = H.shape[-1]
    lam = alpha * jnp.mean(jnp.diagonal(H, axis1=-2, axis2=-1), axis=-1)
    return H + lam[..., None, None] * jnp.eye(d, dtype=H.dtype)


def l2_hessian_update(H: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Accumulate sum_i x_i x_i^T; x (..., d_in) flattened over leading dims."""
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    return H + x2.T @ x2


def oac_hessian_update(H: jnp.ndarray, G: jnp.ndarray) -> jnp.ndarray:
    """Accumulate G G^T for one sample's weight gradient G (d_in, d_out)."""
    G = G.astype(jnp.float32)
    return H + G @ G.T


def is_quantizable(path: str, leaf) -> bool:
    """Linear kernels are the quantization targets (2-D 'kernel' leaves)."""
    return path.endswith("kernel") and hasattr(leaf, "ndim") and leaf.ndim == 2


def select_kernels(params, predicate: Optional[Callable[[str], bool]] = None
                   ) -> Dict[str, jnp.ndarray]:
    """{path: kernel} for every quantizable linear, optionally filtered."""
    out = {}
    for path, leaf in utils.tree_paths(params).items():
        if is_quantizable(path, leaf) and (predicate is None or predicate(path)):
            out[path] = leaf
    return out


def fisher_hessians(loss_fn, params, batches, *, predicate=None,
                    grad_dtype="float32", reduction="sum",
                    microbatch_loop: bool = True):
    """Output-adaptive Hessians for selected kernels (paper Alg. 1 phase 1).

    loss_fn(params, batch) -> scalar CE loss for ONE calibration sample
    (per-sample gradients are required by eq. 13: the sum of per-sample outer
    products is NOT the outer product of the summed gradient).

    batches: array pytree with leading dim N (calibration samples).
    Returns {path: H (d_in, d_in) float32}.
    """
    targets = select_kernels(params, predicate)
    paths = sorted(targets)

    cast = (lambda t: utils.cast_tree(t, jnp.bfloat16)) \
        if grad_dtype == "bfloat16" else (lambda t: t)

    def one_sample(H_acc, batch):
        grads = jax.grad(loss_fn)(cast(params), batch)
        gsel = utils.tree_paths(grads)
        new = {}
        for p in paths:
            new[p] = oac_hessian_update(H_acc[p], gsel[p])
        return new, None

    H0 = {p: jnp.zeros((targets[p].shape[0], targets[p].shape[0]),
                       jnp.float32) for p in paths}
    if microbatch_loop:
        H, _ = jax.lax.scan(one_sample, H0, batches)
    else:  # vmapped per-sample grads (faster, more memory)
        def per_sample(batch):
            g = jax.grad(loss_fn)(cast(params), batch)
            return {p: v for p, v in utils.tree_paths(g).items() if p in H0}
        G = jax.vmap(per_sample)(batches)
        H = {p: jnp.einsum("nio,njo->ij", G[p].astype(jnp.float32),
                           G[p].astype(jnp.float32)) for p in paths}
    n = jax.tree_util.tree_leaves(batches)[0].shape[0]
    if reduction == "mean":
        H = {p: v / n for p, v in H.items()}
    return H


def l2_hessians_from_capture(captured: Dict[str, jnp.ndarray],
                             reduction="sum", n: int = 1):
    """Finalize output-agnostic Hessians from model-forward captures.

    ``captured[path]`` already holds sum_i x_i x_i^T (models accumulate the
    per-layer Gram matrix when probing is enabled).
    """
    if reduction == "mean":
        return {p: v / n for p, v in captured.items()}
    return dict(captured)


def cholesky_inv_upper(H: jnp.ndarray) -> jnp.ndarray:
    """GPTQ's factor: upper-triangular U with ``H^-1 = U^T U``.

    Row i of U drives the OBS update (paper eq. 3): with columns processed in
    order, ``[H_F^-1]_{i,i:} = U[i,i] * U[i,i:]`` so
    ``delta = -(w_i - q_i)/U[i,i] * U[i,i:]`` and the saliency denominator
    (eq. 4) is ``U[i,i]**2``.
    """
    d = H.shape[-1]
    L = jnp.linalg.cholesky(H)                      # H = L L^T
    eye = jnp.eye(d, dtype=H.dtype)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    Hinv = Linv.T @ Linv                            # H^-1
    return jnp.linalg.cholesky(Hinv).T              # upper: Hinv = U^T U


def hinv_diag(H: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """diag(H^-1) used by the saliency rule (paper eq. 4)."""
    Hr = regularize(H, alpha)
    d = Hr.shape[-1]
    L = jnp.linalg.cholesky(Hr)
    Linv = jax.scipy.linalg.solve_triangular(L, jnp.eye(d, dtype=H.dtype),
                                             lower=True)
    return jnp.sum(Linv * Linv, axis=0)
