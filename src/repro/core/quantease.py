"""QuantEase layer-wise coordinate descent (arXiv 2309.01885).

QuantEase minimizes the same layer objective as OPTQ —
``||XW - X W_hat||^2 = tr((W_hat - W)^T H (W_hat - W))`` with
``H = sum x x^T`` (or any plug-in Hessian: OAC's ``sum G G^T`` works
unchanged) — but by cyclic coordinate descent over the contraction axis
instead of the one-shot Cholesky sweep.  Holding every row but ``k``
fixed, the objective is column-separable and quadratic in row ``k``; its
unconstrained minimizer is

    w*_kj = w_kj - (1/H_kk) * sum_{l != k} H_kl (w_hat_lj - w_lj)

and the constrained update projects ``w*`` onto the group's quantization
grid.  A few full epochs (``QuantConfig.cd_iters``) monotonically
decrease the objective; unlike OPTQ, already-quantized rows keep being
revisited, which is where QuantEase's accuracy edge at low bit-widths
comes from.

The grid (per-group scales/zeros) is fitted once by RTN and held fixed —
the descent is over the integer codes only — so the result packs into the
standard ``QuantizedTensor``/``oac-qckpt`` container with no outliers
(``solver.CalibResult`` with an empty COO budget) and serves through the
same fused-dequant path as every other method.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hessian as hess
from repro.core import quantizers as qz
from repro.core import solver


def quantease_result(W: jnp.ndarray, H: jnp.ndarray, *, bits: int,
                     group_size: int, alpha: float = 0.1,
                     cd_iters: int = 3) -> solver.CalibResult:
    """Coordinate-descent calibration of one kernel -> ``CalibResult``.

    ``H`` is whichever (d_in, d_in) Hessian the pipeline supplies (l2,
    OAC, or identity) — the solver is plug-in, like ``solver.calibrate``.
    """
    if W.ndim == 3:                               # stacked layer kernels
        fn = lambda w, h: quantease_result(
            w, h, bits=bits, group_size=group_size, alpha=alpha,
            cd_iters=cd_iters)
        return jax.vmap(fn)(W, H)
    W = W.astype(jnp.float32)
    d_in, d_out = W.shape
    assert d_in % group_size == 0, (d_in, group_size)

    # same Hessian conditioning as solver.calibrate: scale-normalize (the
    # objective is scale-invariant, the regularizer is not), then dampen
    H = H.astype(jnp.float32)
    H = H / (jnp.mean(jnp.diagonal(H)) + 1e-12)
    Hr = hess.regularize(H, alpha)
    hdiag = jnp.diagonal(Hr)

    # RTN warm start fixes the grid; descent moves only the codes
    q0, scales, zeros, w_hat0 = qz.rtn_quantize(W, bits, group_size)
    s_rows = jnp.repeat(scales, group_size, axis=0)   # (d_in, d_out)
    z_rows = jnp.repeat(zeros, group_size, axis=0)
    qmax = 2 ** bits - 1

    def row_update(k, carry):
        Q, E = carry                               # E = W_hat - W
        h_k = jax.lax.dynamic_slice(Hr, (k, 0), (1, d_in))[0]
        e_k = jax.lax.dynamic_slice(E, (k, 0), (1, d_out))[0]
        w_k = jax.lax.dynamic_slice(W, (k, 0), (1, d_out))[0]
        h_kk = jnp.take(hdiag, k)
        # unconstrained row minimizer, then project onto the fixed grid
        tgt = w_k - (h_k @ E - h_kk * e_k) / h_kk
        s_k = jax.lax.dynamic_slice(s_rows, (k, 0), (1, d_out))[0]
        z_k = jax.lax.dynamic_slice(z_rows, (k, 0), (1, d_out))[0]
        q_k = jnp.clip(jnp.round(tgt / s_k + z_k), 0, qmax)
        dq_k = (q_k - z_k) * s_k
        Q = jax.lax.dynamic_update_slice(Q, q_k[None].astype(jnp.uint8),
                                         (k, 0))
        E = jax.lax.dynamic_update_slice(E, (dq_k - w_k)[None], (k, 0))
        return Q, E

    Q, E = q0, w_hat0 - W
    for _ in range(cd_iters):
        Q, E = jax.lax.fori_loop(0, d_in, row_update, (Q, E))

    grid = qz.Grid(s_rows, z_rows, bits)
    w_hat = qz.dequantize(Q.astype(jnp.float32), grid)
    err = jnp.sum((w_hat - W) * (Hr @ (w_hat - W)))
    cap = 8
    z = jnp.zeros((cap,), jnp.int32)
    return solver.CalibResult(Q, scales, zeros, z, z,
                              jnp.zeros((cap,), jnp.float32), w_hat, err)
