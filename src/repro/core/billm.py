"""BiLLM-style binary PTQ with pluggable Hessian (paper Table 2: OAC_BiLLM).

Implements the three BiLLM ingredients on top of the same blocked OBS loop as
``solver.py``:
  1. structural (row-of-contraction-axis) salient selection by aggregated
     Hessian sensitivity,
  2. residual binarization for salient rows (two binary terms),
  3. bell-shaped magnitude splitting for non-salient rows (two alphas/group).

Supplying ``H = sum G G^T`` (OAC) instead of ``sum x x^T`` reproduces the
paper's OAC_BiLLM.  Results are fake-quant reconstructions + explicit storage
accounting (binary serving kernels are out of scope; see DESIGN.md).

Avg-bits accounting follows BiLLM's own convention (sign bits + alphas +
salient-extra; the bell-split membership bitmap is reported separately as
``physical_bits`` since it must be materialized for dequantization).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hessian as hess


class BinaryResult(NamedTuple):
    w_hat: jnp.ndarray
    salient_mask: jnp.ndarray   # (d_in,) bool
    err_trace: jnp.ndarray
    avg_bits: jnp.ndarray       # BiLLM-convention accounting
    physical_bits: jnp.ndarray  # including split membership bitmap


def _split_params(Wb, nonsal, n_splits=8):
    """Bell split: break point + (a_small, a_large) per column over non-salient
    rows of the block.  Wb (B, d_out); nonsal (B, 1)."""
    aw = jnp.abs(Wb)
    amax = (aw * nonsal).max(axis=0, keepdims=True)
    fracs = jnp.linspace(0.1, 0.9, n_splits)

    def stats(frac):
        p = amax * frac
        small = (aw <= p).astype(Wb.dtype) * nonsal
        large = (1.0 - (aw <= p).astype(Wb.dtype)) * nonsal
        a_s = (aw * small).sum(0) / jnp.maximum(small.sum(0), 1.0)
        a_l = (aw * large).sum(0) / jnp.maximum(large.sum(0), 1.0)
        sg = jnp.sign(Wb)
        w_hat = sg * jnp.where(small > 0, a_s[None], a_l[None])
        err = (((Wb - w_hat) ** 2) * nonsal).sum(0)
        return err, (p, a_s, a_l)

    errs, cands = jax.vmap(stats)(fracs)
    best = jnp.argmin(errs, axis=0)                      # (d_out,)
    pick = lambda arr: jnp.take_along_axis(
        arr, best[None, None, :] if arr.ndim == 3 else best[None, :], axis=0)[0]
    p = pick(cands[0])
    a_s = pick(cands[1])
    a_l = pick(cands[2])
    return p, a_s, a_l


def calibrate_binary(W, H, *, group_size=128, alpha=0.1,
                     salient_frac=0.05, n_splits=8) -> BinaryResult:
    W = W.astype(jnp.float32)
    d_in, d_out = W.shape
    B = group_size
    assert d_in % B == 0
    n_blocks = d_in // B

    Hr = hess.regularize(H.astype(jnp.float32), alpha)
    U = hess.cholesky_inv_upper(Hr)
    udiag_sq = jnp.diagonal(U) ** 2

    # 1) structural salient selection: aggregate sensitivity per d_in row
    sal_score = jnp.sum(W ** 2, axis=1) / udiag_sq
    n_sal = max(int(salient_frac * d_in), 1)
    thresh = jnp.sort(sal_score)[-n_sal]
    salient = sal_score >= thresh                        # (d_in,)

    col_idx = jnp.arange(d_in)

    def block_step(carry, b):
        W_cur, W_hat, err_tr = carry
        bs = b * B
        W_blk = jax.lax.dynamic_slice(W_cur, (bs, 0), (B, d_out))
        U_rows = jax.lax.dynamic_slice(U, (bs, 0), (B, d_in))
        U_loc = jax.lax.dynamic_slice(U, (bs, bs), (B, B))
        sal_blk = jax.lax.dynamic_slice(salient, (bs,), (B,))
        sal_col = sal_blk[:, None].astype(W.dtype)

        # residual-binarization alphas over salient rows of the block
        aw = jnp.abs(W_blk)
        a1 = (aw * sal_col).sum(0) / jnp.maximum(sal_col.sum(0), 1.0)
        r = W_blk - a1[None] * jnp.sign(W_blk)
        a2 = (jnp.abs(r) * sal_col).sum(0) / jnp.maximum(sal_col.sum(0), 1.0)
        # bell split over non-salient rows
        p, a_s, a_l = _split_params(W_blk, 1.0 - sal_col, n_splits)

        def col_step(inner, i):
            Wb, Hb, E, tr = inner
            w_i = Wb[i]
            sg = jnp.sign(w_i)
            # salient: residual binarization
            r_i = w_i - a1 * sg
            sal_hat = a1 * sg + a2 * jnp.sign(r_i)
            # non-salient: bell split
            nons_hat = sg * jnp.where(jnp.abs(w_i) <= p[0], a_s, a_l)
            w_hat_i = jnp.where(sal_blk[i], sal_hat, nons_hat)
            u_ii = U_loc[i, i]
            err = (w_i - w_hat_i) / u_ii
            row_mask = (jnp.arange(B) > i)[:, None]
            Wb = Wb - jnp.where(row_mask, U_loc[i][:, None] * err[None], 0.0)
            Hb = Hb.at[i].set(w_hat_i)
            E = E.at[i].set(err)
            tr = tr + jnp.sum((w_i - w_hat_i) ** 2) / (u_ii ** 2)
            return (Wb, Hb, E, tr), None

        init = (W_blk, jnp.zeros((B, d_out), W.dtype),
                jnp.zeros((B, d_out), W.dtype), err_tr)
        (_, H_blk, E, err_tr), _ = jax.lax.scan(col_step, init, jnp.arange(B))

        tail = (col_idx >= bs + B)[None, :]
        W_cur = W_cur - jnp.where(tail, U_rows, 0.0).T @ E
        W_hat = jax.lax.dynamic_update_slice(W_hat, H_blk, (bs, 0))
        return (W_cur, W_hat, err_tr), None

    init = (W, jnp.zeros_like(W), jnp.zeros((), jnp.float32))
    (_, w_hat, err_tr), _ = jax.lax.scan(block_step, init,
                                         jnp.arange(n_blocks))

    n = d_in * d_out
    f = n_sal / d_in
    group_alpha_bits = (2 * 16) / B          # a_s, a_l fp16 per group per col
    sal_bits = f * (2.0 + 2 * 16 / B)        # two sign planes + a1,a2
    nonsal_bits = (1 - f) * (1.0 + group_alpha_bits)
    avg = sal_bits + nonsal_bits + 16.0 / B  # + break point p per group
    phys = avg + (1 - f) * 1.0               # split membership bitmap
    return BinaryResult(w_hat, salient, err_tr,
                        jnp.asarray(avg), jnp.asarray(phys))
