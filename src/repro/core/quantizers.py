"""Grid quantizers: asymmetric uniform (RTN), binary, BiLLM split/residual binary.

All functions operate on blocks of a kernel ``W (d_in, d_out)`` with groups
tiling the contraction (d_in) axis, matching ``repro.core.qformat``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Grid(NamedTuple):
    scale: jnp.ndarray   # (..., d_out)
    zero: jnp.ndarray    # (..., d_out)
    bits: int

    @property
    def qmax(self):
        return 2 ** self.bits - 1


def fit_grid(w: jnp.ndarray, bits: int, mask=None) -> Grid:
    """Min/max asymmetric grid over axis -2 (the group axis).

    ``mask`` (same shape as w, 1=include) lets SpQR exclude detected outliers
    from the grid fit so inliers get full resolution.
    """
    if mask is None:
        lo = w.min(axis=-2)
        hi = w.max(axis=-2)
    else:
        big = jnp.asarray(jnp.finfo(w.dtype).max, w.dtype)
        lo = jnp.where(mask > 0, w, big).min(axis=-2)
        hi = jnp.where(mask > 0, w, -big).max(axis=-2)
        # all-outlier group: fall back to 0-span grid at 0
        none = (mask.sum(axis=-2) == 0)
        lo = jnp.where(none, 0.0, lo)
        hi = jnp.where(none, 0.0, hi)
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, 0.0)
    qmax = 2 ** bits - 1
    scale = jnp.maximum((hi - lo) / qmax, 1e-9)
    zero = jnp.clip(jnp.round(-lo / scale), 0, qmax)
    return Grid(scale.astype(jnp.float32), zero.astype(jnp.float32), bits)


def quantize(w: jnp.ndarray, grid: Grid) -> jnp.ndarray:
    """Round w onto the grid -> integer codes."""
    q = jnp.round(w / grid.scale + grid.zero)
    return jnp.clip(q, 0, grid.qmax)


def dequantize(q: jnp.ndarray, grid: Grid) -> jnp.ndarray:
    return (q - grid.zero) * grid.scale


def rtn_quantize(w: jnp.ndarray, bits: int, group_size: int):
    """Plain round-to-nearest with group quantization (paper baseline "RTN").

    Returns (codes (d_in,d_out) int, scales (G,d_out), zeros (G,d_out), w_hat).
    """
    d_in, d_out = w.shape
    G = d_in // group_size
    wg = w.reshape(G, group_size, d_out)
    grid = fit_grid(wg, bits)
    q = quantize(wg, Grid(grid.scale[:, None], grid.zero[:, None], bits))
    w_hat = dequantize(q, Grid(grid.scale[:, None], grid.zero[:, None], bits))
    return (q.reshape(d_in, d_out).astype(jnp.uint8), grid.scale, grid.zero,
            w_hat.reshape(d_in, d_out))


# --------------------------------------------------------------------------
# binary quantizers (BiLLM-style building blocks)
# --------------------------------------------------------------------------

def binary_alpha(w: jnp.ndarray, mask=None, axis=-2):
    """Optimal per-column binary scale alpha = mean |w| over the group."""
    aw = jnp.abs(w)
    if mask is None:
        return aw.mean(axis=axis)
    s = (aw * mask).sum(axis=axis)
    n = jnp.maximum(mask.sum(axis=axis), 1.0)
    return s / n


def residual_binarize(w: jnp.ndarray):
    """BiLLM residual approximation for salient weights: two binary terms.

    w ~= a1*sign(w) + a2*sign(w - a1*sign(w)).  Returns (w_hat, s1, a1, s2, a2).
    """
    a1 = binary_alpha(w)
    s1 = jnp.where(w >= 0, 1.0, -1.0)
    r = w - a1 * s1
    a2 = binary_alpha(r)
    s2 = jnp.where(r >= 0, 1.0, -1.0)
    return a1 * s1 + a2 * s2, s1, a1, s2, a2


def split_binarize(w: jnp.ndarray, n_splits: int = 16):
    """BiLLM bell-shaped splitting for non-salient weights.

    Searches a break point p* in |w| that splits the group into small/large
    magnitude sets, each binarized with its own alpha; minimizes l2 error.
    Returns (w_hat, best_p, alphas).  Shapes: w (..., group, d_out).
    """
    aw = jnp.abs(w)
    amax = aw.max(axis=-2, keepdims=True)
    # candidate break points: fractions of max |w|
    fracs = jnp.linspace(0.05, 0.95, n_splits)

    def err_for(frac):
        p = amax * frac
        small = (aw <= p).astype(w.dtype)
        a_s = binary_alpha(w, small)
        a_l = binary_alpha(w, 1.0 - small)
        sg = jnp.where(w >= 0, 1.0, -1.0)
        w_hat = sg * jnp.where(small > 0, a_s[..., None, :], a_l[..., None, :])
        return ((w - w_hat) ** 2).sum(axis=-2), frac

    errs = []
    for i in range(n_splits):
        e, _ = err_for(fracs[i])
        errs.append(e)
    errs = jnp.stack(errs)                      # (n_splits, ..., d_out)
    best = jnp.argmin(errs, axis=0)             # (..., d_out)
    best_frac = fracs[best]
    p = amax * best_frac[..., None, :]
    small = (aw <= p).astype(w.dtype)
    a_s = binary_alpha(w, small)
    a_l = binary_alpha(w, 1.0 - small)
    sg = jnp.where(w >= 0, 1.0, -1.0)
    w_hat = sg * jnp.where(small > 0, a_s[..., None, :], a_l[..., None, :])
    return w_hat, best_frac, (a_s, a_l)
