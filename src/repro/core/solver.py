"""Blocked column-wise Hessian calibration (OPTQ update + SpQR outliers).

Solves paper eq. (8): quantize ``W (d_in, d_out)`` iterating the contraction
axis, applying the OBS update (eq. 3) with whichever Hessian is supplied —
``H = sum x x^T`` reproduces OPTQ/SpQR; ``H = sum G G^T`` is OAC.  The solver
itself is Hessian-agnostic, exactly mirroring the paper's plug-in design
(Appendix I).

Structure (TPU adaptation of GPTQ's "lazy batch"): columns are processed in
VMEM-sized blocks equal to the quantization group; within a block the
sequential quantize -> error -> rank-1 update loop runs on a (B, d_out) tile,
and the cross-block correction is one matmul ``W -= U_blk^T E``.  The Pallas
kernel in ``repro.kernels.calib_update`` implements the inner tile loop; this
module is the pure-jnp reference implementation used on CPU and in tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hessian as hess
from repro.core import quantizers as qz


class CalibResult(NamedTuple):
    q: jnp.ndarray          # (d_in, d_out) uint8 codes
    scales: jnp.ndarray     # (G, d_out)
    zeros: jnp.ndarray      # (G, d_out)
    out_rows: jnp.ndarray   # (cap,) int32
    out_cols: jnp.ndarray   # (cap,) int32
    out_vals: jnp.ndarray   # (cap,) f32 additive corrections
    w_hat: jnp.ndarray      # (d_in, d_out) reconstruction
    err_trace: jnp.ndarray  # scalar tr(dW H dW^T)


def detect_outliers(W, U_diag_sq, bits, group_size, tau, capacity):
    """SpQR-style sensitivity outliers, paper eq. 4, with a fixed COO budget.

    s_ik = (W_ik - What_ik)^2 / [H^-1]_kk ; keep s > tau * mean(s) (the
    relative form keeps tau meaningful across Hessian scales — l2 and OAC
    Hessians differ by ~1e4x in magnitude), top-`cap` overall.
    Returns dense bool mask (d_in, d_out) plus the COO index arrays.
    """
    d_in, d_out = W.shape
    G = d_in // group_size
    Wg = W.reshape(G, group_size, d_out)
    grid = qz.fit_grid(Wg, bits)
    g2 = qz.Grid(grid.scale[:, None], grid.zero[:, None], bits)
    w_hat = qz.dequantize(qz.quantize(Wg, g2), g2).reshape(d_in, d_out)
    s = (W - w_hat) ** 2 / U_diag_sq[:, None]
    tau = tau * jnp.mean(s)
    cap = max(int(capacity * d_in * d_out), 8)
    flat = jnp.where(s > tau, s, -jnp.inf).ravel()
    vals, idx = jax.lax.top_k(flat, cap)
    keep = jnp.isfinite(vals)
    rows = jnp.where(keep, idx // d_out, 0)
    cols = jnp.where(keep, idx % d_out, 0)
    mask = jnp.zeros((d_in, d_out), bool).at[rows, cols].set(keep)
    return mask, rows.astype(jnp.int32), cols.astype(jnp.int32), keep


def calibrate(W, H, *, bits, group_size, alpha=0.1, tau=3.5,
              outlier_capacity=0.005, act_order=False) -> CalibResult:
    """Blocked OPTQ/SpQR calibration of one kernel with a supplied Hessian."""
    W = W.astype(jnp.float32)
    d_in, d_out = W.shape
    assert d_in % group_size == 0, (d_in, group_size)
    B = group_size                      # block == quant group (see module doc)
    n_blocks = d_in // B

    # normalize the Hessian scale: calibration is scale-invariant (paper
    # App. C.3) but the outlier threshold tau is NOT — without this, the
    # much-smaller-magnitude OAC Hessian selects no outliers at tau=3.5
    H = H.astype(jnp.float32)
    H = H / (jnp.mean(jnp.diagonal(H)) + 1e-12)
    Hr = hess.regularize(H, alpha)
    perm = inv_perm = None
    if act_order:
        perm = jnp.argsort(-jnp.diagonal(Hr))
        inv_perm = jnp.argsort(perm)
        W = W[perm]
        Hr = Hr[perm][:, perm]
    U = hess.cholesky_inv_upper(Hr)     # (d_in, d_in) upper, Hinv = U^T U
    udiag_sq = jnp.diagonal(U) ** 2

    omask, out_rows, out_cols, okeep = detect_outliers(
        W, udiag_sq, bits, group_size, tau, outlier_capacity)

    col_idx = jnp.arange(d_in)

    def block_step(carry, b):
        W_cur, Q, scales, zeros, err_tr = carry
        bs = b * B
        W_blk = jax.lax.dynamic_slice(W_cur, (bs, 0), (B, d_out))
        U_rows = jax.lax.dynamic_slice(U, (bs, 0), (B, d_in))
        U_loc = jax.lax.dynamic_slice(U, (bs, bs), (B, B))
        o_blk = jax.lax.dynamic_slice(omask, (bs, 0), (B, d_out))
        # grid for this group, outliers excluded from the fit (SpQR)
        grid = qz.fit_grid(W_blk, bits, mask=1.0 - o_blk.astype(W.dtype))

        def col_step(inner, i):
            Wb, Qb, E, tr = inner
            w_i = Wb[i]
            q_i = qz.quantize(w_i, grid)
            dq = qz.dequantize(q_i, grid)
            o_i = o_blk[i]
            dq_eff = jnp.where(o_i, w_i, dq)       # outliers: exact, no error
            u_ii = U_loc[i, i]
            err = (w_i - dq_eff) / u_ii
            upd = U_loc[i][:, None] * err[None, :]  # (B, d_out)
            row_mask = (jnp.arange(B) > i)[:, None]
            Wb = Wb - jnp.where(row_mask, upd, 0.0)
            Qb = Qb.at[i].set(q_i.astype(jnp.uint8))
            E = E.at[i].set(err)
            tr = tr + jnp.sum((w_i - dq_eff) ** 2) / (u_ii ** 2)
            return (Wb, Qb, E, tr), None

        init = (W_blk, jnp.zeros((B, d_out), jnp.uint8),
                jnp.zeros((B, d_out), W.dtype), err_tr)
        (W_blk2, Q_blk, E, err_tr), _ = jax.lax.scan(
            col_step, init, jnp.arange(B))

        # cross-block correction: W[be:, :] -= U[bs:be, be:]^T @ E
        tail_mask = (col_idx >= bs + B)[None, :]
        U_tail = jnp.where(tail_mask, U_rows, 0.0)
        W_cur = W_cur - U_tail.T @ E
        W_cur = jax.lax.dynamic_update_slice(W_cur, W_blk2, (bs, 0))
        Q = jax.lax.dynamic_update_slice(Q, Q_blk, (bs, 0))
        scales = jax.lax.dynamic_update_slice(scales, grid.scale[None], (b, 0))
        zeros = jax.lax.dynamic_update_slice(zeros, grid.zero[None], (b, 0))
        return (W_cur, Q, scales, zeros, err_tr), None

    init = (W, jnp.zeros((d_in, d_out), jnp.uint8),
            jnp.zeros((n_blocks, d_out), jnp.float32),
            jnp.zeros((n_blocks, d_out), jnp.float32),
            jnp.zeros((), jnp.float32))
    (W_fin, Q, scales, zeros, err_tr), _ = jax.lax.scan(
        block_step, init, jnp.arange(n_blocks))

    # reconstruct and collect outlier corrections
    grid_full = qz.Grid(jnp.repeat(scales, B, axis=0),
                        jnp.repeat(zeros, B, axis=0), bits)
    w_grid = qz.dequantize(Q.astype(jnp.float32), grid_full)
    # outlier value = (post-OBS-update w at quantize time) - grid value.
    # W_fin rows are final at their own position (only later rows get updated
    # after a row is processed), so W_fin[r, c] is the value that was kept.
    o_vals = jnp.where(okeep, W_fin[out_rows, out_cols]
                       - w_grid[out_rows, out_cols], 0.0)
    w_hat = w_grid.at[out_rows, out_cols].add(o_vals)

    if act_order:
        Q = Q[inv_perm]
        w_hat = w_hat[inv_perm]
        w_grid = w_grid[inv_perm]
        out_rows = inv_perm[out_rows]
        # scales/zeros remain in permuted-group order: act_order is a
        # fake-quant research mode; packing requires act_order=False.

    return CalibResult(Q, scales, zeros, out_rows, out_cols, o_vals,
                       w_hat, err_tr)


def rtn_result(W, *, bits, group_size) -> CalibResult:
    """RTN baseline in the same result format (no calibration)."""
    W = W.astype(jnp.float32)
    d_in, d_out = W.shape
    q, scales, zeros, w_hat = qz.rtn_quantize(W, bits, group_size)
    cap = 8
    z = jnp.zeros((cap,), jnp.int32)
    return CalibResult(q, scales, zeros, z, z, jnp.zeros((cap,), jnp.float32),
                       w_hat, jnp.zeros((), jnp.float32))
