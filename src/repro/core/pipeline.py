"""OAC pipeline (paper Algorithm 1): block-wise Hessian estimation + calibration.

  Phase 1: accumulate ``H_oac = sum_i G[i] G[i]^T`` per linear kernel
           (paper eq. 22).  Default (``oac_grads="precompute"``): ONE
           backward sweep of the full-precision model per calibration
           sample yields G for every layer at once — the paper's
           complexity reduction (N backwards total), and the Fisher is
           not polluted by the quantization noise of already-quantized
           blocks.  ``oac_grads="sequential"`` instead recomputes each
           block's grads on the current partially-quantized model
           (GPTQ-style error propagation; N*L backwards).
  Phase 2: calibrate each kernel with the chosen Hessian-based method
           (spqr / optq / billm / rtn), substituting H_oac (or the
           output-agnostic ``sum x x^T`` for the baselines).

Fault tolerance: with ``ckpt_dir`` set, each finished layer is persisted
(npz + manifest) and the pipeline resumes after preemption.

Real quantization: calibration runs on fake-quant weights (so later blocks
see the true quantized model, like the paper), and the packed
``QuantizedTensor`` stack is assembled at the end via ``pack_results``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro import utils
from repro.configs.base import QuantConfig
from repro.core import billm as bl
from repro.core import hessian as hess
from repro.core import qformat
from repro.core import solver
from repro.dist import ctx as dctx

# capture-key mapping for output-agnostic (l2) Hessians
L2_KEY = {
    "attn/wq": "attn_in", "attn/wk": "attn_in", "attn/wv": "attn_in",
    "attn/wo": "wo_in",
    "mlp/wi": "mlp_in", "mlp/wg": "mlp_in", "mlp/wo": "mlp_out_in",
}


def layer_kernel_paths(params) -> Dict[str, jnp.ndarray]:
    """{'attn/wq': stacked kernel (L, d_in, d_out), ...} under params['layers']."""
    out = {}
    for path, leaf in utils.tree_paths(params.get("layers", {})).items():
        if path.endswith("/kernel") and hasattr(leaf, "ndim") and leaf.ndim >= 3:
            out[path[1:-len("/kernel")]] = leaf
    return out


def _kernel_node(params, name):
    """The {'kernel': ...} dict for stacked kernel ``name`` under 'layers'."""
    node = params["layers"]
    for p in name.split("/"):
        node = node[p]
    return node


def _set_layer_kernel(params, name, j, value):
    node = _kernel_node(params, name)
    leaf = node["kernel"]
    node["kernel"] = leaf.at[j].set(value.astype(leaf.dtype))
    return params


def _get_layer_kernels(params, j):
    return {n: leaf[j] for n, leaf in layer_kernel_paths(params).items()}


def _sample_chunks(batches, dist_ctx):
    """Yield the calibration set in dp_size-sample chunks (1 without ctx)."""
    N = jax.tree_util.tree_leaves(batches)[0].shape[0]
    step = dist_ctx.dp_size if dist_ctx is not None else 1
    for i in range(0, N, step):
        yield jax.tree.map(lambda x: x[i:i + step], batches)


def _fisher_accumulate(loss_of, kernels0, batches, *, reduction, dist_ctx,
                       stacked):
    """Chunked ``H = sum_i G[i] G[i]^T`` over per-sample grads (eq. 22).

    ``kernels0`` are the kernels differentiated by ``loss_of`` —
    ``stacked=True`` when they carry a leading layer dim.  With
    ``dist_ctx`` samples are processed ``dp_size`` at a time with the
    sample axis sharded over the data axes — per-sample grads stay
    per-sample (vmap), only the outer-product sum crosses devices.
    """
    names = sorted(kernels0)
    # einsum over the sample axis, keyed on (stacked, has expert dim)
    base = 1 if stacked else 0
    specs = {base + 3: ("nio,njo->ij", "nlio,nljo->lij")[base],
             base + 4: ("neio,nejo->eij", "nleio,nlejo->leij")[base]}

    def per_sample(batch1):
        b = jax.tree.map(lambda x: x[None], batch1)
        return jax.grad(loss_of)(kernels0, b)

    @jax.jit
    def accumulate(H, chunk):
        with dctx.use(dist_ctx):
            if dist_ctx is not None:  # sample axis over dp
                chunk = jax.tree.map(lambda x: dctx.wsc(x, "b"), chunk)
            g = jax.vmap(per_sample)(chunk)
            for n in names:
                G = g[n].astype(jnp.float32)
                H[n] = H[n] + jnp.einsum(specs[G.ndim], G, G)
        return H

    H = {n: jnp.zeros(k.shape[:-1] + (k.shape[-2],), jnp.float32)
         for n, k in kernels0.items()}
    for b in _sample_chunks(batches, dist_ctx):
        H = accumulate(H, b)
    if reduction == "mean":
        N = jax.tree_util.tree_leaves(batches)[0].shape[0]
        H = {n: v / N for n, v in H.items()}
    return H


def _grad_cast(grad_dtype):
    return (lambda t: utils.cast_tree(t, jnp.bfloat16)) \
        if grad_dtype == "bfloat16" else (lambda t: t)


def oac_hessians_for_layer(model, params, batches, j, *,
                           grad_dtype="float32", reduction="sum",
                           dist_ctx=None):
    """Phase 1, sequential mode: per-sample grads of only block j's kernels
    on the current (partially quantized) model."""
    cast = _grad_cast(grad_dtype)

    def insert(p, kern):
        p = jax.tree.map(lambda x: x, p)  # shallow copy of dict structure
        for n, v in kern.items():
            _set_layer_kernel(p, n, j, v)
        return p

    def loss_of(kern, batch):
        return model.loss(insert(cast(params), cast(kern)), batch)

    return _fisher_accumulate(loss_of, _get_layer_kernels(params, j),
                              batches, reduction=reduction,
                              dist_ctx=dist_ctx, stacked=False)


def oac_hessians_all_layers(model, params, batches, *, grad_dtype="float32",
                            reduction="sum", dist_ctx=None):
    """Phase 1, precompute mode: all layers' Hessians from shared backwards.

    One backward pass per calibration sample gives the gradient of EVERY
    stacked kernel simultaneously; accumulating per-layer outer products
    costs nothing extra.  Returns {name: (L, d_in, d_in)} (experts:
    (L, E, d_in, d_in))."""
    cast = _grad_cast(grad_dtype)

    def insert_all(p, kern):
        p = jax.tree.map(lambda x: x, p)
        for n, v in kern.items():
            _kernel_node(p, n)["kernel"] = v
        return p

    def loss_of(kern, batch):
        return model.loss(insert_all(cast(params), cast(kern)), batch)

    return _fisher_accumulate(loss_of, layer_kernel_paths(params), batches,
                              reduction=reduction, dist_ctx=dist_ctx,
                              stacked=True)


def l2_hessians(model, params, batches, *, dist_ctx=None):
    """Output-agnostic Hessians for all layers via forward captures.

    The captured grams already sum over batch rows, so with ``dist_ctx``
    whole dp-sharded chunks go through one forward each."""
    @jax.jit
    def one(batch):
        with dctx.use(dist_ctx):
            if dist_ctx is not None:
                batch = jax.tree.map(lambda x: dctx.wsc(x, "b"), batch)
            _, aux = model.apply(params, batch, capture=True)
        return aux["xtx"]

    acc = None
    for b in _sample_chunks(batches, dist_ctx):
        x = one(b)
        acc = x if acc is None else jax.tree.map(jnp.add, acc, x)
    return acc  # {capture_key: (L, d, d)}


@dataclasses.dataclass
class LayerResult:
    name: str
    layer: int
    calib: Optional[solver.CalibResult]
    binary: Optional[bl.BinaryResult]
    w_hat: np.ndarray


def _save_layer_result(path_tmp, path, res, w_hat):
    """Persist one layer-kernel's full result (atomic rename).

    The npz stores every field of the Calib/BinaryResult, not just the
    fake-quant ``w_hat``, so a *resumed* run can still assemble the packed
    ``QuantizedTensor`` checkpoint at the end (``pack_results``) — resume
    and pack were previously mutually exclusive."""
    arrs = {"w_hat": np.asarray(w_hat)}
    if isinstance(res, solver.CalibResult):
        arrs.update({f"calib_{f}": np.asarray(getattr(res, f))
                     for f in solver.CalibResult._fields})
    elif isinstance(res, bl.BinaryResult):
        arrs.update({f"binary_{f}": np.asarray(getattr(res, f))
                     for f in bl.BinaryResult._fields})
    np.savez(path_tmp, **arrs)
    os.replace(path_tmp, path)


def _load_layer_result(path):
    """-> (w_hat ndarray, CalibResult | None, BinaryResult | None) from a
    layer npz.  Older checkpoints that stored only ``w_hat`` load with both
    results None (resumable but not packable)."""
    data = np.load(path, allow_pickle=False)
    calib = binary = None
    if "calib_q" in data:
        calib = solver.CalibResult(
            *(jnp.asarray(data[f"calib_{f}"])
              for f in solver.CalibResult._fields))
    elif "binary_w_hat" in data:
        binary = bl.BinaryResult(
            *(jnp.asarray(data[f"binary_{f}"])
              for f in bl.BinaryResult._fields))
    return data["w_hat"], calib, binary


# methods that never consume a Hessian (zero-shot / data-free)
HESSIAN_FREE = ("rtn", "adpq")


def _calibrate_kernel(W, H, qcfg: QuantConfig):
    if qcfg.method == "rtn":
        if W.ndim == 3:
            return jax.vmap(lambda w: solver.rtn_result(
                w, bits=qcfg.wbits, group_size=qcfg.group_size))(W)
        return solver.rtn_result(W, bits=qcfg.wbits, group_size=qcfg.group_size)
    if qcfg.method == "adpq":
        from repro.core import adpq
        return adpq.adpq_result(W, bits=qcfg.wbits,
                                group_size=qcfg.group_size,
                                outlier_capacity=qcfg.outlier_capacity)
    if qcfg.method == "quantease":
        from repro.core import quantease
        fn = lambda w, h: quantease.quantease_result(
            w, h, bits=qcfg.wbits, group_size=qcfg.group_size,
            alpha=qcfg.alpha, cd_iters=qcfg.cd_iters)
        return jax.vmap(fn)(W, H) if W.ndim == 3 else fn(W, H)
    if qcfg.method == "billm":
        fn = lambda w, h: bl.calibrate_binary(
            w, h, group_size=qcfg.group_size, alpha=qcfg.alpha)
        return jax.vmap(fn)(W, H) if W.ndim == 3 else fn(W, H)
    tau = qcfg.outlier_threshold if qcfg.method == "spqr" else 1e30
    cap = qcfg.outlier_capacity if qcfg.method == "spqr" else 1e-6
    fn = lambda w, h: solver.calibrate(
        w, h, bits=qcfg.wbits, group_size=qcfg.group_size, alpha=qcfg.alpha,
        tau=tau, outlier_capacity=cap, act_order=qcfg.act_order)
    return jax.vmap(fn)(W, H) if W.ndim == 3 else fn(W, H)


def quantize_model(model, params, batches, qcfg: QuantConfig, *,
                   sequential: bool = True, ckpt_dir: Optional[str] = None,
                   dist_ctx=None, log: Callable = print, obs=None):
    """Run Algorithm 1 over a uniform-stacked model.

    ``dist_ctx`` (optional ``repro.dist.ctx.DistCtx``) shards the Phase-1
    calibration forward/backward over the mesh's data axes; the per-kernel
    Phase-2 solves are unchanged (they are tiny relative to Phase 1).

    ``obs`` (optional ``repro.obs.Obs``) records pipeline_* metrics
    (per-layer wall split into hessian vs solve, per-kernel fake-quant
    MSE, resume progress) and layer/kernel trace spans; the ``log``
    callback is kept for BC and every message it receives is mirrored as
    a structured trace event.  Defaults to the no-op bundle.

    Returns (params with fake-quant weights, {(<layer>, <name>): LayerResult}).
    """
    if qcfg.oac_grads not in ("precompute", "sequential"):
        raise ValueError(f"unknown oac_grads {qcfg.oac_grads!r}; "
                         "expected 'precompute' or 'sequential'")
    ob = obs_mod.resolve(obs, default="off")
    M, tr = ob.metrics, ob.tracer
    tr.name_process(3, "pipeline")
    m_phase = M.histogram("pipeline_phase_seconds", obs_mod.LATENCY_BUCKETS,
                          "per-layer wall split (hessian | solve)",
                          labels=("phase",))
    m_err = M.gauge("pipeline_quant_error",
                    "latest per-kernel fake-quant MSE", labels=("kernel",))
    m_done = M.gauge("pipeline_layers_done", "layers fully calibrated")
    m_total = M.gauge("pipeline_layers_total", "layers to calibrate")
    m_kern = M.counter("pipeline_kernels_total",
                       "layer-kernels by source (computed | restored)",
                       labels=("source",))
    m_wall = M.gauge("pipeline_wall_seconds",
                     "cumulative calibration wall (incl. resumed runs)")

    def _log(msg):
        tr.instant("log", cat="pipeline", pid=3, args={"msg": msg})
        log(msg)

    def _secs(t0_ns):
        return (obs_mod.now_ns() - t0_ns) * 1e-9

    params = jax.tree.map(lambda x: x, params)
    names = sorted(layer_kernel_paths(params))
    n_layers = layer_kernel_paths(params)[names[0]].shape[0]
    results: Dict = {}
    m_total.set(n_layers)

    manifest_path = ckpt_dir and os.path.join(ckpt_dir, "pipeline.json")
    done = {}
    # per-kernel solve walls + cumulative hessian wall, stamped into the
    # manifest so a resumed run can report the calibration cost already
    # paid (and keeps accumulating its own)
    wall: Dict[str, float] = {}
    hessian_wall = 0.0
    qcfg_dict = dataclasses.asdict(qcfg)
    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
        if os.path.exists(manifest_path):
            stored = json.load(open(manifest_path))
            # manifest is {"qcfg": ..., "done": ...}; flat pre-qcfg-stamp
            # manifests (legacy) are the done-dict itself
            done = stored["done"] if "done" in stored else stored
            wall = dict(stored.get("wall", {})) if "done" in stored else {}
            hessian_wall = float(stored.get("hessian_wall", 0.0)) \
                if "done" in stored else 0.0
            # method mismatch gets its own refusal: two calibrators'
            # resume dirs must never silently collide (a half-finished
            # adpq dir re-run with --method oac would pack a chimera)
            stored_method = stored.get("method") or \
                (stored.get("qcfg") or {}).get("method")
            if stored_method is not None and stored_method != qcfg.method:
                raise ValueError(
                    f"calibration dir {ckpt_dir} holds {stored_method!r} "
                    f"results; refusing to resume with method "
                    f"{qcfg.method!r} — use a fresh ckpt_dir")
            # resuming under a different QuantConfig would silently pack
            # stale results (e.g. w4 codes re-packed at w2) — refuse
            if stored.get("qcfg") not in (None, qcfg_dict):
                diff = {k: (stored["qcfg"].get(k), qcfg_dict[k])
                        for k in qcfg_dict
                        if stored["qcfg"].get(k) != qcfg_dict[k]}
                raise ValueError(
                    f"calibration dir {ckpt_dir} was started with a "
                    f"different QuantConfig ({diff}); use a fresh ckpt_dir "
                    "or delete it to recalibrate")
            prior = sum(wall.values()) + hessian_wall
            _log(f"[pipeline] resuming: {len(done)} layer-kernels done"
                 + (f" ({prior:.1f}s of calibration already paid)"
                    if prior else ""))

    l2_caps = None
    H_all = None
    any_todo = any(f"{j}:{n}" not in done
                   for j in range(n_layers) for n in names)
    if qcfg.method not in HESSIAN_FREE and qcfg.hessian == "oac" \
            and any_todo and qcfg.oac_grads == "precompute":
        # precompute BEFORE any per-layer restore so a resumed run sees the
        # same (full-precision) model as the uninterrupted one; park the
        # (L, d, d) stacks in host memory — keeping every layer's Hessian
        # device-resident through Phase 2 is O(L d^2) of HBM
        t_ns = obs_mod.now_ns()
        with tr.span("hessian precompute", cat="pipeline", pid=3):
            H_all = jax.tree.map(np.asarray, oac_hessians_all_layers(
                model, params, batches, grad_dtype=qcfg.grad_dtype,
                reduction=qcfg.hessian_reduction, dist_ctx=dist_ctx))
        dt = _secs(t_ns)
        hessian_wall += dt
        m_phase.labels(phase="hessian").observe(dt)
    for j in range(n_layers):
        needs_h = qcfg.method not in HESSIAN_FREE
        H_blk = None
        todo = [n for n in names if f"{j}:{n}" not in done]
        layer_sid = tr.begin(f"layer {j}", cat="pipeline", pid=3,
                             args={"todo": len(todo)})
        t_ns = obs_mod.now_ns()
        if needs_h and qcfg.hessian == "oac" and todo:
            if H_all is not None:
                H_blk = {n: H_all[n][j] for n in names}
            else:
                with tr.span(f"hessian {j}", cat="pipeline", pid=3,
                             parent=layer_sid):
                    H_blk = oac_hessians_for_layer(
                        model, params, batches, j,
                        grad_dtype=qcfg.grad_dtype,
                        reduction=qcfg.hessian_reduction, dist_ctx=dist_ctx)
                dt = _secs(t_ns)
                hessian_wall += dt
                m_phase.labels(phase="hessian").observe(dt)
        if needs_h and qcfg.hessian == "l2" and todo and (
                sequential or l2_caps is None):
            # sequential error propagation: captures reflect the already-
            # quantized earlier blocks (SpQR/OPTQ-faithful)
            with tr.span(f"hessian {j}", cat="pipeline", pid=3,
                         parent=layer_sid):
                l2_caps = l2_hessians(model, params, batches,
                                      dist_ctx=dist_ctx)
            dt = _secs(t_ns)
            hessian_wall += dt
            m_phase.labels(phase="hessian").observe(dt)
        for n in names:
            key = f"{j}:{n}"
            W = _get_layer_kernels(params, j)[n]
            if key in done:
                w_np, calib, binary = _load_layer_result(
                    os.path.join(ckpt_dir, done[key]))
                w_hat = jnp.asarray(w_np)
                params = _set_layer_kernel(params, n, j, w_hat)
                results[(j, n)] = LayerResult(n, j, calib, binary, w_np)
                m_kern.labels(source="restored").inc()
                continue
            if needs_h:
                if qcfg.hessian == "oac":
                    H = H_blk[n]
                elif qcfg.hessian == "l2":
                    ck = L2_KEY.get(n)
                    if ck is None:
                        raise ValueError(f"no l2 capture for kernel {n}")
                    H = l2_caps[ck][j]
                else:  # identity
                    d = W.shape[-2]
                    H = jnp.eye(d, dtype=jnp.float32)
                    if W.ndim == 3:
                        H = jnp.broadcast_to(H, (W.shape[0], d, d))
            else:
                H = None
            t_solve = obs_mod.now_ns()
            with tr.span(f"solve {key}", cat="pipeline", pid=3,
                         parent=layer_sid):
                res = _calibrate_kernel(W, H, qcfg)
            w_hat = res.w_hat
            dt = _secs(t_solve)
            wall[key] = round(dt, 6)
            m_phase.labels(phase="solve").observe(dt)
            m_kern.labels(source="computed").inc()
            if ob.enabled:
                m_err.labels(kernel=n).set(float(jnp.mean(
                    (w_hat.astype(jnp.float32)
                     - W.astype(jnp.float32)) ** 2)))
            params = _set_layer_kernel(params, n, j, w_hat)
            lr = LayerResult(n, j,
                             res if isinstance(res, solver.CalibResult) else None,
                             res if isinstance(res, bl.BinaryResult) else None,
                             np.asarray(w_hat))
            results[(j, n)] = lr
            if ckpt_dir:
                fname = f"layer{j}_{n.replace('/', '_')}.npz"
                tmp = os.path.join(ckpt_dir, "tmp_" + fname)  # .npz suffix:
                _save_layer_result(                           # savez keeps it
                    tmp, os.path.join(ckpt_dir, fname), res, w_hat)
                done[key] = fname
                with open(manifest_path + ".tmp", "w") as f:
                    json.dump({"qcfg": qcfg_dict, "method": qcfg.method,
                               "done": done, "wall": wall,
                               "hessian_wall": round(hessian_wall, 6)}, f)
                os.replace(manifest_path + ".tmp", manifest_path)
        tr.end(layer_sid)
        m_done.set(j + 1)
        m_wall.set(sum(wall.values()) + hessian_wall)
        _log(f"[pipeline] layer {j + 1}/{n_layers} done "
             f"({qcfg.method}/{qcfg.hessian}, {qcfg.wbits}-bit)")
    return params, results


def pack_results(params, results, qcfg: QuantConfig):
    """Assemble packed QuantizedTensor stacks from per-layer results.

    Replaces each layers/<name>/kernel stack with a stacked QuantizedTensor
    (arrays gain a leading L dim; static meta shared).  ``CalibResult``
    layers (rtn/optq/spqr) pack to the grouped grid + COO outliers;
    ``BinaryResult`` layers (billm) ride the 1-bit residual carrier
    (``qformat.make_residual_carrier``) so OAC_BiLLM checkpoints live in
    the same on-disk format.  The result feeds ``serving.qserve.ckpt.save``
    directly."""
    if qcfg.act_order:
        raise ValueError(
            "pack_results requires act_order=False: act-order scales stay "
            "in permuted-group order (fake-quant research mode only)")
    names = sorted(layer_kernel_paths(params))
    n_layers = layer_kernel_paths(params)[names[0]].shape[0]
    params = jax.tree.map(lambda x: x, params)
    for n in names:
        per_layer = []
        for j in range(n_layers):
            lr = results[(j, n)]
            if np.asarray(lr.w_hat).ndim != 2:
                raise ValueError(
                    f"{j}:{n}: expert-stacked calibration results are "
                    "not packable yet (fused stacked-expert dequant is "
                    "a ROADMAP item)")
            r = lr.calib
            if r is not None:
                qt = qformat.make_quantized(
                    r.q, r.scales, r.zeros, qcfg.wbits, qcfg.group_size,
                    (r.q.shape[0], r.q.shape[1]), r.out_rows, r.out_cols,
                    r.out_vals.astype(jnp.bfloat16),
                    stats_bits=qcfg.stats_bits, stats_group=qcfg.stats_group)
            elif lr.binary is not None:
                qt = qformat.make_residual_carrier(
                    jnp.asarray(lr.w_hat), group_size=qcfg.group_size,
                    stats_bits=qcfg.stats_bits, stats_group=qcfg.stats_group)
            else:
                raise ValueError(
                    f"no packable result for {j}:{n} (resumed from a "
                    "pre-v1 layer checkpoint that stored only w_hat? "
                    "re-run calibration for this layer)")
            per_layer.append(qt)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
        _kernel_node(params, n)["kernel"] = stacked
    return params
