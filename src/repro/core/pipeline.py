"""OAC pipeline (paper Algorithm 1): block-wise Hessian estimation + calibration.

Per transformer block (= layer index in the scanned stack):
  Phase 1: forward the *current* model (earlier blocks already quantized) on N
           calibration samples, backprop the output CE loss, accumulate
           ``H_oac = sum_i G[i] G[i]^T`` for every linear kernel in the block
           (paper eq. 22).  Gradients are taken w.r.t. ONLY this block's
           kernels (others frozen), exactly as the paper batches per block.
  Phase 2: calibrate each kernel with the chosen Hessian-based method
           (spqr / optq / billm / rtn), substituting H_oac (or the
           output-agnostic ``sum x x^T`` for the baselines).

Fault tolerance: with ``ckpt_dir`` set, each finished layer is persisted
(npz + manifest) and the pipeline resumes after preemption.

Real quantization: calibration runs on fake-quant weights (so later blocks
see the true quantized model, like the paper), and the packed
``QuantizedTensor`` stack is assembled at the end via ``pack_results``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import utils
from repro.configs.base import QuantConfig
from repro.core import billm as bl
from repro.core import hessian as hess
from repro.core import qformat
from repro.core import solver

# capture-key mapping for output-agnostic (l2) Hessians
L2_KEY = {
    "attn/wq": "attn_in", "attn/wk": "attn_in", "attn/wv": "attn_in",
    "attn/wo": "wo_in",
    "mlp/wi": "mlp_in", "mlp/wg": "mlp_in", "mlp/wo": "mlp_out_in",
}


def layer_kernel_paths(params) -> Dict[str, jnp.ndarray]:
    """{'attn/wq': stacked kernel (L, d_in, d_out), ...} under params['layers']."""
    out = {}
    for path, leaf in utils.tree_paths(params.get("layers", {})).items():
        if path.endswith("/kernel") and hasattr(leaf, "ndim") and leaf.ndim >= 3:
            out[path[1:-len("/kernel")]] = leaf
    return out


def _set_layer_kernel(params, name, j, value):
    parts = name.split("/")
    node = params["layers"]
    for p in parts[:-1]:
        node = node[p]
    leaf = node[parts[-1]]["kernel"]
    node[parts[-1]]["kernel"] = leaf.at[j].set(value.astype(leaf.dtype))
    return params


def _get_layer_kernels(params, j):
    return {n: leaf[j] for n, leaf in layer_kernel_paths(params).items()}


def oac_hessians_for_layer(model, params, batches, j, *,
                           grad_dtype="float32", reduction="sum"):
    """Phase 1 for one block: per-sample grads of only block j's kernels."""
    names = sorted(layer_kernel_paths(params))

    def insert(p, kern):
        p = jax.tree.map(lambda x: x, p)  # shallow copy of dict structure
        for n, v in kern.items():
            _set_layer_kernel(p, n, j, v)
        return p

    block0 = _get_layer_kernels(params, j)
    cast = (lambda t: utils.cast_tree(t, jnp.bfloat16)) \
        if grad_dtype == "bfloat16" else (lambda t: t)

    def loss_of(kern, batch):
        return model.loss(insert(cast(params), cast(kern)), batch)

    @jax.jit
    def accumulate(H, batch):
        g = jax.grad(loss_of)(block0, batch)
        for n in names:
            G = g[n].astype(jnp.float32)
            if G.ndim == 2:
                H[n] = H[n] + G @ G.T
            else:  # (E, d_in, d_out) expert stack
                H[n] = H[n] + jnp.einsum("eio,ejo->eij", G, G)
        return H

    H = {}
    for n in names:
        k = block0[n]
        shp = (k.shape[0], k.shape[0]) if k.ndim == 2 else \
            (k.shape[0], k.shape[1], k.shape[1])
        H[n] = jnp.zeros(shp, jnp.float32)
    N = jax.tree_util.tree_leaves(batches)[0].shape[0]
    for i in range(N):
        b = jax.tree.map(lambda x: x[i:i + 1], batches)
        H = accumulate(H, b)
    if reduction == "mean":
        H = {n: v / N for n, v in H.items()}
    return H


def l2_hessians(model, params, batches):
    """Output-agnostic Hessians for all layers via forward captures."""
    @jax.jit
    def one(batch):
        _, aux = model.apply(params, batch, capture=True)
        return aux["xtx"]

    N = jax.tree_util.tree_leaves(batches)[0].shape[0]
    acc = None
    for i in range(N):
        b = jax.tree.map(lambda x: x[i:i + 1], batches)
        x = one(b)
        acc = x if acc is None else jax.tree.map(jnp.add, acc, x)
    return acc  # {capture_key: (L, d, d)}


@dataclasses.dataclass
class LayerResult:
    name: str
    layer: int
    calib: Optional[solver.CalibResult]
    binary: Optional[bl.BinaryResult]
    w_hat: np.ndarray


def _calibrate_kernel(W, H, qcfg: QuantConfig):
    if qcfg.method == "rtn":
        if W.ndim == 3:
            return jax.vmap(lambda w: solver.rtn_result(
                w, bits=qcfg.wbits, group_size=qcfg.group_size))(W)
        return solver.rtn_result(W, bits=qcfg.wbits, group_size=qcfg.group_size)
    if qcfg.method == "billm":
        fn = lambda w, h: bl.calibrate_binary(
            w, h, group_size=qcfg.group_size, alpha=qcfg.alpha)
        return jax.vmap(fn)(W, H) if W.ndim == 3 else fn(W, H)
    tau = qcfg.outlier_threshold if qcfg.method == "spqr" else 1e30
    cap = qcfg.outlier_capacity if qcfg.method == "spqr" else 1e-6
    fn = lambda w, h: solver.calibrate(
        w, h, bits=qcfg.wbits, group_size=qcfg.group_size, alpha=qcfg.alpha,
        tau=tau, outlier_capacity=cap, act_order=qcfg.act_order)
    return jax.vmap(fn)(W, H) if W.ndim == 3 else fn(W, H)


def quantize_model(model, params, batches, qcfg: QuantConfig, *,
                   sequential: bool = True, ckpt_dir: Optional[str] = None,
                   log: Callable = print):
    """Run Algorithm 1 over a uniform-stacked model.

    Returns (params with fake-quant weights, {(<layer>, <name>): LayerResult}).
    """
    params = jax.tree.map(lambda x: x, params)
    names = sorted(layer_kernel_paths(params))
    n_layers = layer_kernel_paths(params)[names[0]].shape[0]
    results: Dict = {}

    manifest_path = ckpt_dir and os.path.join(ckpt_dir, "pipeline.json")
    done = {}
    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
        if os.path.exists(manifest_path):
            done = json.load(open(manifest_path))
            log(f"[pipeline] resuming: {len(done)} layer-kernels done")

    l2_caps = None
    for j in range(n_layers):
        needs_h = qcfg.method != "rtn"
        H_blk = None
        todo = [n for n in names if f"{j}:{n}" not in done]
        if needs_h and qcfg.hessian == "oac" and todo:
            H_blk = oac_hessians_for_layer(
                model, params, batches, j, grad_dtype=qcfg.grad_dtype,
                reduction=qcfg.hessian_reduction)
        if needs_h and qcfg.hessian == "l2" and todo and (
                sequential or l2_caps is None):
            # sequential error propagation: captures reflect the already-
            # quantized earlier blocks (SpQR/OPTQ-faithful)
            l2_caps = l2_hessians(model, params, batches)
        for n in names:
            key = f"{j}:{n}"
            W = _get_layer_kernels(params, j)[n]
            if key in done:
                data = np.load(os.path.join(ckpt_dir, done[key]),
                               allow_pickle=False)
                w_hat = jnp.asarray(data["w_hat"])
                params = _set_layer_kernel(params, n, j, w_hat)
                results[(j, n)] = LayerResult(n, j, None, None,
                                              np.asarray(w_hat))
                continue
            if needs_h:
                if qcfg.hessian == "oac":
                    H = H_blk[n]
                elif qcfg.hessian == "l2":
                    ck = L2_KEY.get(n)
                    if ck is None:
                        raise ValueError(f"no l2 capture for kernel {n}")
                    H = l2_caps[ck][j]
                else:  # identity
                    d = W.shape[-2]
                    H = jnp.eye(d, dtype=jnp.float32)
                    if W.ndim == 3:
                        H = jnp.broadcast_to(H, (W.shape[0], d, d))
            else:
                H = None
            res = _calibrate_kernel(W, H, qcfg)
            w_hat = res.w_hat
            params = _set_layer_kernel(params, n, j, w_hat)
            lr = LayerResult(n, j,
                             res if isinstance(res, solver.CalibResult) else None,
                             res if isinstance(res, bl.BinaryResult) else None,
                             np.asarray(w_hat))
            results[(j, n)] = lr
            if ckpt_dir:
                fname = f"layer{j}_{n.replace('/', '_')}.npz"
                tmp = os.path.join(ckpt_dir, "tmp_" + fname)  # .npz suffix:
                np.savez(tmp, w_hat=np.asarray(w_hat))        # savez keeps it
                os.replace(tmp, os.path.join(ckpt_dir, fname))
                done[key] = fname
                with open(manifest_path + ".tmp", "w") as f:
                    json.dump(done, f)
                os.replace(manifest_path + ".tmp", manifest_path)
        log(f"[pipeline] layer {j + 1}/{n_layers} done "
            f"({qcfg.method}/{qcfg.hessian}, {qcfg.wbits}-bit)")
    return params, results


def pack_results(params, results, qcfg: QuantConfig):
    """Assemble packed QuantizedTensor stacks from per-layer CalibResults.

    Replaces each layers/<name>/kernel stack with a stacked QuantizedTensor
    (arrays gain a leading L dim; static meta shared)."""
    names = sorted(layer_kernel_paths(params))
    n_layers = layer_kernel_paths(params)[names[0]].shape[0]
    params = jax.tree.map(lambda x: x, params)
    for n in names:
        per_layer = []
        for j in range(n_layers):
            r = results[(j, n)].calib
            if r is None:
                raise ValueError(f"no packable CalibResult for {j}:{n}")
            qt = qformat.make_quantized(
                r.q, r.scales, r.zeros, qcfg.wbits, qcfg.group_size,
                (r.q.shape[0], r.q.shape[1]), r.out_rows, r.out_cols,
                r.out_vals.astype(jnp.bfloat16),
                stats_bits=qcfg.stats_bits, stats_group=qcfg.stats_group)
            per_layer.append(qt)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
        parts = n.split("/")
        node = params["layers"]
        for p in parts[:-1]:
            node = node[p]
        node[parts[-1]]["kernel"] = stacked
    return params
