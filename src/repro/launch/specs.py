"""ShapeDtypeStruct stand-ins for every model input (dry-run lowering).

No device allocation — everything here is abstract.  ``input_specs`` covers
the train/prefill batch; ``decode_specs`` covers the serve_step operands
(token, KV cache at seq_len occupancy, position scalar).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig, act_dtype=jnp.bfloat16):
    """Batch pytree of ShapeDtypeStructs for a train/prefill step."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        F = cfg.n_frontend_tokens
        return {"tokens": SDS((B, S - F), jnp.int32),
                "frontend": SDS((B, F, cfg.d_model), act_dtype)}
    if cfg.family == "audio":
        return {"tokens": SDS((B, S), jnp.int32),
                "frontend": SDS((B, S, cfg.d_model), act_dtype)}
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 cache_dtype=jnp.bfloat16, act_dtype=jnp.bfloat16,
                 paged: bool = False, block_size: int = 64,
                 stripes: int = 1, kv_bits: int = 16):
    """(tokens, cache, pos) ShapeDtypeStructs for one serve_step.

    The cache has capacity seq_len and is prefilled to seq_len-1; the step
    appends the incoming token and attends over the full window.  ``pos``
    is the (B,) per-row cache-clock vector the continuous-batching engine
    drives (a scalar clock also traces — lockstep fast path).

    ``paged=True`` swaps the dense KV rings for the block-pool layout
    (``PagedKVCache``): the abstract pool is sized at the dense worst case
    (B * seq_len/block_size blocks + one scratch per stripe) so the
    compiled cell bounds the same HBM; the serve step reads the
    cache-resident block tables (the engine overrides them per tick).
    ``stripes`` (= tp size for flash-mode cells) keeps the pool's block
    count divisible by the shard count.  ``kv_bits=8`` lowers the int8
    pool layout (codes + per-token scale planes)."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    if paged:
        bs = block_size
        while bs > 1 and S % bs:        # largest divisor of S <= block_size
            bs //= 2
        nb = B * (S // bs) + stripes
        nb += (-nb) % stripes
        cache = model.init_cache(B, S, dtype=cache_dtype, abstract=True,
                                 paged=True, block_size=bs, num_blocks=nb,
                                 kv_bits=kv_bits)
    else:
        cache = model.init_cache(B, S, dtype=cache_dtype, abstract=True)
    if cfg.family == "audio":
        tokens = SDS((B, 1, cfg.d_model), act_dtype)  # stub frame embedding
    else:
        tokens = SDS((B, 1), jnp.int32)
    pos = SDS((B,), jnp.int32)
    return tokens, cache, pos


def concrete_batch(cfg: ModelConfig, B: int, S: int, key=None, dtype=jnp.float32):
    """Small concrete batch for smoke tests."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.family == "vlm":
        F = cfg.n_frontend_tokens
        return {"tokens": jax.random.randint(key, (B, S - F), 0, cfg.vocab),
                "frontend": jax.random.normal(key, (B, F, cfg.d_model),
                                              dtype) * 0.02}
    if cfg.family == "audio":
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                "frontend": jax.random.normal(key, (B, S, cfg.d_model),
                                              dtype) * 0.02}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
