"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run driver must set XLA_FLAGS first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model); the ``pod`` axis
    extends data parallelism across the inter-pod DCN/ICI links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever local devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    assert n % model == 0, \
        f"model-parallel degree {model} must divide the {n} local devices"
    return jax.make_mesh((n // model, model), ("data", "model"))
