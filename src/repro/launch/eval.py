"""Quality-eval launcher: score a checkpoint (or in-memory method spec)
through the ``PagedEngine`` serving path and append to the scorecard.

``python -m repro.launch.eval --ckpt /tmp/oac_ckpt --scorecard
BENCH_quality.json --check`` loads an ``oac-qckpt`` directory, rebuilds
the fp16 reference its manifest ``extra`` describes (same seed /
train-steps / corpus — the ``launch/quantize.py`` recipe), scores both
through ``eval.runner.evaluate`` (held-out perplexity stream +
multiple-choice accuracy + greedy-match-rate), upserts the
``(arch, method, wbits, kv_bits)`` row, and — with ``--check`` — fails
(exit 1) if any row trips the per-bit-width perplexity-ratio bound.

Without ``--ckpt``, an in-memory spec (``--arch/--method/--wbits/...``)
quantizes on the fly: ``--method none`` scores the fp model against
itself (sanity row), ``rtn`` packs via ``quantize_params_rtn``, and the
calibrated methods run the full pipeline before scoring.
"""
import argparse
import sys
import tempfile

import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.configs.base import QuantConfig
from repro.core import pipeline
from repro.data import SyntheticCorpus, make_calib_set
from repro.eval import runner, scorecard
from repro.launch.quantize import HESSIANS, METHODS, prepare_params


def _eval_ckpt(args, log=print):
    """-> (cfg, qcfg, quantized params, fp reference params, source str)."""
    from repro.serving.qserve import ckpt as qckpt
    manifest = qckpt.load_manifest(args.ckpt)
    cfg = qckpt.resolve_config(manifest)
    qcfg = qckpt.quant_config(manifest)
    params = qckpt.load(args.ckpt, manifest=manifest)
    extra = manifest.get("extra") or {}
    seed = int(extra.get("seed", 0))
    train_steps = int(extra.get("train_steps", 0))
    calib_seq = int(extra.get("calib_seq", 128))
    log(f"[eval] ckpt {args.ckpt}: arch={cfg.name} "
        f"method={manifest.get('method')} "
        f"(rebuilding fp16 ref: seed={seed}, train_steps={train_steps})")
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=calib_seq, seed=7)
    _, ref = prepare_params(cfg, corpus, train_steps=train_steps, seed=seed,
                            work_dir=tempfile.mkdtemp(prefix="oac_eval_"),
                            log=log)
    return cfg, qcfg, params, ref, args.ckpt


def _eval_spec(args, log=print):
    """In-memory spec: init/train, then quantize with the chosen method."""
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=args.calib_seq, seed=7)
    m, ref = prepare_params(cfg, corpus, train_steps=args.train_steps,
                            seed=args.seed,
                            work_dir=tempfile.mkdtemp(prefix="oac_eval_"),
                            log=log)
    src = f"memory:{args.method}-w{args.wbits}"
    if args.method == "none":
        return cfg, None, ref, ref, "memory:fp"
    qcfg = QuantConfig(wbits=args.wbits, group_size=args.group_size,
                       method=args.method, hessian=args.hessian,
                       alpha=1.0 if args.hessian == "oac" else 0.1)
    if args.method == "rtn":
        from repro.serving.quantized import quantize_params_rtn
        qp, _ = quantize_params_rtn(ref, qcfg)
        return cfg, qcfg, qp, ref, src
    calib = {"tokens": jnp.asarray(
        make_calib_set(corpus, args.calib)["tokens"])}
    fq, results = pipeline.quantize_model(m, ref, calib, qcfg, log=log)
    return cfg, qcfg, pipeline.pack_results(fq, results, qcfg), ref, src


def run(args, log=print, obs=None) -> dict:
    """Score, upsert the scorecard row, return it."""
    if args.ckpt:
        cfg, qcfg, params, ref, src = _eval_ckpt(args, log)
    else:
        cfg, qcfg, params, ref, src = _eval_spec(args, log)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=args.eval_seq, seed=7)
    res = runner.evaluate(cfg, params, ref_params=ref, corpus=corpus,
                          n_seq=args.eval_seqs, kv_bits=args.kv_bits,
                          max_batch=args.max_batch, log=log, obs=obs)
    row = {
        "arch": cfg.name,
        "method": qcfg.method if qcfg is not None else "fp16",
        "hessian": qcfg.hessian
        if qcfg is not None and qcfg.method not in pipeline.HESSIAN_FREE
        else None,
        "wbits": qcfg.wbits if qcfg is not None else 16,
        "group_size": qcfg.group_size if qcfg is not None else None,
        "kv_bits": args.kv_bits,
        "ppl": round(res["ppl"], 4),
        "fp16_ppl": round(res["fp16_ppl"], 4),
        "ppl_ratio": round(res["ppl_ratio"], 4),
        "choice_acc": round(res["choice_acc"], 4),
        "fp16_choice_acc": round(res["fp16_choice_acc"], 4),
        "greedy_match": round(res["greedy_match"], 4),
        "n_tokens": res["n_tokens"],
        "source": src,
    }
    if args.scorecard:
        rows = scorecard.upsert(args.scorecard, row)
        log(f"[eval] scorecard {args.scorecard}: {len(rows)} rows "
            f"(updated {scorecard.row_key(row)})")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="score a packed oac-qckpt directory (the fp16 "
                         "reference is rebuilt from its manifest extra)")
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="rtn",
                    choices=("none",) + METHODS,
                    help="in-memory spec (no --ckpt): quantizer to apply "
                         "(none = score the fp model against itself)")
    ap.add_argument("--hessian", default="oac", choices=HESSIANS)
    ap.add_argument("--wbits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--calib", type=int, default=8)
    ap.add_argument("--calib-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-bits", type=int, default=16, choices=[16, 8],
                    help="KV-pool precision of the scoring engine")
    ap.add_argument("--eval-seqs", type=int, default=8,
                    help="held-out perplexity sequences")
    ap.add_argument("--eval-seq", type=int, default=128,
                    help="eval sequence length (= engine capacity)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--scorecard", default=None,
                    help="upsert the row into this BENCH_quality.json")
    ap.add_argument("--check", action="store_true",
                    help="after upserting, run the scorecard tripwires "
                         "and exit 1 on any perplexity regression")
    ap.add_argument("--metrics-out", default=None, metavar="metrics.prom",
                    help="write the scoring engines' metrics registry as "
                         "Prometheus text exposition")
    ap.add_argument("--trace-out", default=None, metavar="trace.json",
                    help="write the scoring trace as Chrome trace-event "
                         "JSON")
    args = ap.parse_args()

    from repro import obs as obs_mod
    ob = obs_mod.Obs.make() if (args.metrics_out or args.trace_out) \
        else None
    row = run(args, obs=ob)
    if ob is not None:
        if args.metrics_out:
            obs_mod.prom.write(args.metrics_out, ob.metrics)
            print(f"[eval] metrics -> {args.metrics_out}")
        if args.trace_out:
            ob.tracer.write(args.trace_out)
            print(f"[eval] trace -> {args.trace_out}")
    print(f"[eval] {row['arch']} {row['method']} w{row['wbits']} "
          f"kv{row['kv_bits']}: ppl {row['ppl']} "
          f"(x{row['ppl_ratio']} fp16), choice {row['choice_acc']}, "
          f"greedy match {row['greedy_match']}")
    if args.check:
        rows = scorecard.load(args.scorecard) if args.scorecard else [row]
        fails = scorecard.check(rows)
        for f in fails:
            print(f"[eval] TRIPWIRE: {f}")
        if fails:
            sys.exit(1)
        print(f"[eval] tripwires OK ({len(rows)} rows)")


if __name__ == "__main__":
    main()
