import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod, 2x16x16 multi-pod),
  2. builds the pjit'd step (train_step for train shapes, prefill/serve
     otherwise) with full sharding specs,
  3. ``.lower(*abstract_args).compile()`` — no device allocation,
  4. records ``compiled.memory_analysis()`` (proves it fits) and
     ``cost_analysis()`` + HLO collective bytes (feeds §Roofline).

Results land in artifacts/dryrun/<cell>.json; EXPERIMENTS.md §Dry-run and the
roofline benchmark read from there.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import SHAPES_BY_NAME, cells, get_config, skipped_cells  # noqa: E402
from repro.dist.steps import build_step                                     # noqa: E402
from repro.launch.mesh import make_production_mesh                          # noqa: E402
from repro.roofline.analysis import analyze_lowered                         # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def verify_ckpt(ckpt_dir: str, tp: int = 0, verbose: bool = True) -> dict:
    """Shape-verify a packed checkpoint from its manifest alone.

    No plane bytes are read.  Checks, per manifest tensor:
      1. quantized entries match the ``qformat.abstract_quantized``
         skeleton derived from their own static meta (bits/group/shape/
         stats/outlier count, incl. BiLLM residual planes and stack dims);
      2. the leaf exists in the recorded model config's abstract param
         tree with the matching logical (dequantized) shape — so the
         checkpoint actually loads into that architecture;
      3. with ``tp``, the packed per-device byte ratio under the plan's
         ``param_shardings`` (AbstractMesh — no devices needed).
    Returns the report dict; raises on any mismatch.
    """
    from repro import utils
    from repro.core import qformat
    from repro.serving.qserve import ckpt as qckpt
    from repro.serving.qserve.report import manifest_plane_bytes

    manifest = qckpt.load_manifest(ckpt_dir)
    cfg = qckpt.resolve_config(manifest)
    from repro.models import build_model
    model_sds = utils.tree_paths(build_model(cfg).abstract_params())
    # a checkpoint must be self-contained: every param of the recorded
    # arch present, nothing extra
    missing = set(model_sds) - set(manifest["tensors"])
    assert not missing, (f"checkpoint is missing {len(missing)} params of "
                         f"{cfg.name}: {sorted(missing)[:5]}...")
    n_quant = 0
    for path, t in manifest["tensors"].items():
        if path not in model_sds:
            raise AssertionError(f"{path}: not a param of {cfg.name}")
        want = tuple(model_sds[path].shape)
        if t["kind"] == "dense":
            got = tuple(t["planes"]["data"]["shape"])
            assert got == want, (path, got, want)
            continue
        n_quant += 1
        meta, stack = t["meta"], tuple(t["stack"])
        d_in, d_out = meta["shape"]
        assert stack + (d_in, d_out) == want, (path, stack, meta["shape"],
                                               want)
        ref = qformat.abstract_quantized(
            d_in, d_out, meta["bits"], meta["group_size"],
            stats_bits=meta["stats_bits"], stats_group=meta["stats_group"],
            dtype=meta["dtype"], residual="resid.0" in t["planes"],
            outlier_count=t["outlier_count"])
        ref_entries = dict(qformat.qt_entries(ref))
        assert set(t["planes"]) == set(ref_entries), (
            path, sorted(t["planes"]), sorted(ref_entries))
        for name, e in t["planes"].items():
            want_p = stack + tuple(ref_entries[name].shape)
            got_p = tuple(e["shape"])
            assert got_p == want_p, (path, name, got_p, want_p)
            assert e["dtype"] == jax.numpy.dtype(
                ref_entries[name].dtype).name, (path, name, e["dtype"])
    rep = {"arch": cfg.name, "tensors": len(manifest["tensors"]),
           "quantized": n_quant,
           "bytes": manifest_plane_bytes(manifest)}
    if tp > 1:
        from repro.dist.sharding import make_plan
        from repro.serving.qserve.report import abstract_tp_mesh
        plan = make_plan(cfg, abstract_tp_mesh(tp))
        rep["bytes_tp"] = manifest_plane_bytes(manifest, plan)
        rep["tp"] = tp
    if verbose:
        b = rep["bytes"]
        print(f"[dryrun] ckpt {ckpt_dir}: OK — {rep['tensors']} tensors "
              f"({n_quant} quantized), {b['total'] / 2**20:.2f} MiB packed "
              f"planes, arch {cfg.name}")
        if tp > 1:
            bt = rep["bytes_tp"]
            print(f"  tp={tp}: {bt['per_device'] / 2**20:.2f} MiB/device "
                  f"(ratio {bt['ratio']:.3f})")
    return rep


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             save: bool = True, verbose: bool = True, quantized: bool = False,
             paged: bool = False, kv_bits: int = 16):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    kw = {}
    packed = None
    if quantized and shape.kind == "decode":
        from repro.dist.sharding import make_plan
        from repro.serving.quantized import abstract_quantized_params
        from repro.serving.qserve.report import PACKED_SHARD_SLACK, \
            packed_plane_bytes
        qsds = abstract_quantized_params(cfg)
        kw["quantized_params_sds"] = qsds
        plan = make_plan(cfg, mesh)
        packed = packed_plane_bytes(qsds, plan.param_shardings(qsds))
        packed["tp"] = plan.tp_size
        # the whole point of plane sharding: per-device packed bytes must
        # track total/tp, not total (replicated planes would double-count
        # every shard).  Misaligned odd kernels may replicate, hence the
        # slack over the ideal ratio.
        assert packed["ratio"] <= PACKED_SHARD_SLACK / plan.tp_size, (
            f"QuantizedTensor planes look replicated, not tp-sharded: "
            f"per-device {packed['per_device']} vs total {packed['total']} "
            f"(ratio {packed['ratio']:.3f}, tp={plan.tp_size})")
    if paged and shape.kind == "decode":
        kw["paged"] = True
        kw["kv_bits"] = kv_bits
    with jax.set_mesh(mesh):
        jitted, abstract_args, ctx = build_step(cfg, shape, mesh, **kw)
        lowered = jitted.lower(*abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem_obj = compiled.memory_analysis()
    mem = {a: getattr(mem_obj, a) for a in dir(mem_obj)
           if a.endswith("_in_bytes") and isinstance(getattr(mem_obj, a), int)}
    cost = compiled.cost_analysis()
    if isinstance(cost, list):            # older jaxlibs: one dict per device
        cost = cost[0] if cost else {}
    roof = analyze_lowered(lowered, compiled, cfg, shape, mesh)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "quantized": quantized,
        "packed_plane_bytes": packed,
        "kv_bits": kv_bits if paged and shape.kind == "decode" else 16,
        "paged": paged and shape.kind == "decode",
        "attn_modes": [ctx.attn_train_mode, ctx.attn_decode_mode],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "cost": {k: cost[k] for k in sorted(cost)
                                if isinstance(cost[k], (int, float))},
        "roofline": roof,
    }
    if verbose:
        gb = mem.get("argument_size_in_bytes", 0) / 2**30
        tmp = mem.get("temp_size_in_bytes", 0) / 2**30
        total = (mem.get("argument_size_in_bytes", 0)
                 + mem.get("temp_size_in_bytes", 0)
                 + mem.get("output_size_in_bytes", 0)
                 - mem.get("alias_size_in_bytes", 0)) / 2**30
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}"
              f"{' [w2]' if quantized else ''}: OK "
              f"args={gb:.2f}GiB temp={tmp:.2f}GiB "
              f"total~{total:.2f}GiB/dev compile={t_compile:.0f}s "
              f"bottleneck={roof['bottleneck']}", flush=True)
        if packed is not None:
            print(f"  packed planes: {packed['total'] / 2**20:.1f} MiB "
                  f"total -> {packed['per_device'] / 2**20:.2f} MiB/device "
                  f"(tp={packed['tp']})", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  flops={roof['hlo_flops']:.3e} "
              f"bytes={roof['hlo_bytes']:.3e} "
              f"coll_bytes={roof['collective_bytes']:.3e}", flush=True)
    if save:
        os.makedirs(ART, exist_ok=True)
        tag = f"{arch}__{shape_name}__{rec['mesh']}" + \
            ("__w2" if quantized else "") + \
            ("__paged" if rec["paged"] else "") + \
            ("__kv8" if rec["paged"] and kv_bits == 8 else "")
        with open(os.path.join(ART, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quantized", action="store_true",
                    help="serve_step with 2-bit packed weights (decode cells)")
    ap.add_argument("--paged", action="store_true",
                    help="decode cells over the paged block-pool KV cache")
    ap.add_argument("--kv-bits", type=int, default=16, choices=[16, 8],
                    help="with --paged: int8 KV pool + scale planes")
    ap.add_argument("--ckpt", default=None, metavar="DIR",
                    help="verify a packed checkpoint's abstract shapes "
                         "against its manifest (no plane reads) and exit")
    ap.add_argument("--tp", type=int, default=0,
                    help="with --ckpt: also report per-device packed bytes "
                         "under a tp-way plan (AbstractMesh)")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    if args.ckpt:
        verify_ckpt(args.ckpt, tp=args.tp)
        return

    todo = []
    if args.all:
        todo = [(c.name, s.name) for c, s in cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape in todo:
        try:
            run_cell(arch, shape, multi_pod=args.multi_pod,
                     quantized=args.quantized, paged=args.paged,
                     kv_bits=args.kv_bits)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, repr(e)[:200]))
            if not args.continue_on_error:
                sys.exit(1)
    for a, s, r in skipped_cells():
        print(f"[dryrun] SKIP {a} x {s}: {r}")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        sys.exit(1)
    print(f"[dryrun] all {len(todo)} cells compiled OK "
          f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})")


if __name__ == "__main__":
    main()
