"""Reference client for the HTTP serving front end (stdlib only).

``python -m repro.launch.client --port P`` streams one completion from a
``serve --http`` server and prints the tokens; ``--check`` turns it into
the CI api-smoke assertion: tokens arrived, client-measured decode rate
is positive, and the server's ``/metrics`` scrape records at least one
finished request lifecycle.  The helpers (``complete``, ``scrape``,
``wait_ready``) are plain functions over ``http.client`` so the
integration tests drive the same code path as the CLI.

There is no tokenizer in this repo: prompts are token-id lists.  By
default the prompt is ``--shared-prefix N`` deterministic tokens (the
same chain ``serve --save-warmup --shared-prefix N`` persisted, so a
warmed server skips its prefill) followed by ``--suffix-tokens`` fixed
suffix tokens.
"""
import argparse
import http.client
import json
import sys
import time


def wait_ready(port: int, host: str = "127.0.0.1",
               timeout: float = 60.0) -> dict:
    """Poll /healthz until the server answers; returns the health dict."""
    deadline = time.monotonic() + timeout
    last = None
    while time.monotonic() < deadline:
        try:
            conn = http.client.HTTPConnection(host, port, timeout=5)
            conn.request("GET", "/healthz")
            resp = conn.getresponse()
            body = json.loads(resp.read())
            conn.close()
            if resp.status == 200:
                return body
            last = body
        except (OSError, json.JSONDecodeError) as e:
            last = repr(e)
        time.sleep(0.2)
    raise TimeoutError(f"server on :{port} not ready: {last}")


def complete(port: int, prompt, *, host: str = "127.0.0.1",
             max_tokens: int = 16, temperature: float = 0.0,
             seed=None, slo: str = "interactive", timeout: float = 120.0):
    """POST a streaming completion; yields ``(token_id, finish_reason)``
    pairs — finish_reason is None until the final chunk."""
    body = {"prompt": [int(t) for t in prompt], "max_tokens": max_tokens,
            "temperature": temperature, "slo": slo, "stream": True}
    if seed is not None:
        body["seed"] = int(seed)
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    conn.request("POST", "/v1/completions", body=json.dumps(body),
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    if resp.status != 200:
        raise RuntimeError(f"HTTP {resp.status}: {resp.read().decode()}")
    try:
        for raw in resp:
            line = raw.strip()
            if not line.startswith(b"data: "):
                continue
            payload = line[len(b"data: "):]
            if payload == b"[DONE]":
                return
            chunk = json.loads(payload)
            if "error" in chunk:
                raise RuntimeError(chunk["error"]["message"])
            choice = chunk["choices"][0]
            yield choice["token_id"], choice["finish_reason"]
    finally:
        conn.close()


def scrape(port: int, host: str = "127.0.0.1") -> str:
    """GET /metrics -> Prometheus 0.0.4 text."""
    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("GET", "/metrics")
    resp = conn.getresponse()
    text = resp.read().decode()
    conn.close()
    if resp.status != 200:
        raise RuntimeError(f"/metrics returned HTTP {resp.status}")
    return text


def metric_value(text: str, name: str, labels: str = "") -> float:
    """Sum of all samples of ``name`` whose label block contains
    ``labels`` (crude but sufficient for smoke assertions)."""
    total, seen = 0.0, False
    for line in text.splitlines():
        if line.startswith("#") or not line.startswith(name):
            continue
        rest = line[len(name):]
        if rest[:1] not in ("{", " "):
            continue                       # a longer metric name
        if labels and labels not in rest:
            continue
        total += float(line.rsplit(None, 1)[1])
        seen = True
    return total if seen else float("nan")


def shared_prefix(n: int, vocab: int):
    """The deterministic prefix ``serve --shared-prefix n`` uses."""
    return [(i % vocab) for i in range(1, n + 1)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the deterministic N-token prefix the "
                         "server's warmup file was built from")
    ap.add_argument("--suffix-tokens", type=int, default=8,
                    help="fixed suffix tokens after the shared prefix")
    ap.add_argument("--slo", default="interactive",
                    choices=["interactive", "batch"])
    ap.add_argument("--check", action="store_true",
                    help="assert ≥1 token streamed, tokens/sec > 0, and "
                         "≥1 finished request in the /metrics scrape")
    ap.add_argument("--expect-warm", action="store_true",
                    help="with --check: also assert the server skipped "
                         "prefill via the warmed prefix cache")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the final /metrics scrape to FILE")
    args = ap.parse_args(argv)

    health = wait_ready(args.port, args.host)
    print(f"[client] server ready: {health}")
    vocab_probe = http.client.HTTPConnection(args.host, args.port,
                                             timeout=10)
    vocab_probe.request("GET", "/v1/models")
    models = json.loads(vocab_probe.getresponse().read())
    vocab_probe.close()
    info = models["data"][0]
    print(f"[client] model: {info}")
    cap = int(health["capacity"])
    vocab = int(info["vocab"])

    total_tokens = 0
    t0 = time.monotonic()
    for i in range(args.requests):
        prompt = shared_prefix(args.shared_prefix, vocab)
        prompt += [(7 * i + j) % 13 + 1 for j in range(args.suffix_tokens)]
        prompt = prompt[:cap - args.max_tokens - 1]
        toks = []
        for tok, fin in complete(args.port, prompt, host=args.host,
                                 max_tokens=args.max_tokens, slo=args.slo):
            if tok is not None:
                toks.append(tok)
        total_tokens += len(toks)
        print(f"[client] req {i}: {len(toks)} tokens: {toks}")
    dt = time.monotonic() - t0
    rate = total_tokens / max(dt, 1e-9)
    print(f"[client] {total_tokens} tokens in {dt:.2f}s "
          f"({rate:.1f} tok/s)")

    text = scrape(args.port, args.host)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(text)
        print(f"[client] metrics scrape -> {args.metrics_out}")
    if args.check:
        finished = metric_value(text, "engine_requests_finished_total")
        skipped = metric_value(text, "engine_prefill_tokens_total",
                               'kind="skipped"')
        print(f"[client] check: finished={finished} "
              f"prefill_skipped={skipped} rate={rate:.1f}")
        assert total_tokens > 0, "no tokens streamed"
        assert rate > 0, "tokens/sec not positive"
        assert finished >= 1, \
            f"metrics report {finished} finished requests"
        if args.expect_warm:
            assert skipped > 0, \
                "warmed server skipped no prefill tokens"
        print("[client] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
