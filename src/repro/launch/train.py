"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Drives the fault-tolerant loop (``train/loop.py``: auto-resume, watchdog)
with the production step from ``dist.steps.build_train_step``: a host mesh
+ ``ShardingPlan`` lay the params out (FSDP over data axes, TP over the
model axis) and the step donates its buffers — the same lowering the
dry-run driver validates for the production mesh.  CPU smoke and a real
TPU slice are the same code path; ``--grad-compression int8_ef`` falls
back to the single-host step (error-feedback state is not threaded through
the dist step).  Reduced configs via --smoke.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data import DataIterator, SyntheticCorpus
from repro.models import build_model
from repro.train.loop import train


def dist_step_fn(cfg, tcfg: TrainConfig, shape: ShapeConfig, mesh):
    """Wrap ``build_train_step`` into the loop's step contract.

    Returns ``(step_fn, shard_params)``: the adapter threads the loop's
    (unused) compression residuals through and reports loss/lr, and
    ``shard_params`` lays a param tree out per the plan so the donated jit
    aliases buffers instead of resharding every step."""
    from repro.dist.sharding import make_plan
    from repro.dist.steps import build_train_step
    from repro.train import optimizer as opt

    plan = make_plan(cfg, mesh)
    step, _, _ = build_train_step(cfg, shape, plan, tcfg)
    # logging-only mirror of the schedule build_train_step applies
    # internally (same tcfg -> same curve); the dist step itself reports
    # only the loss
    sched = opt.warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.steps)

    def step_fn(params, opt_state, residuals, batch):
        params, opt_state, loss = step(params, opt_state, batch)
        return params, opt_state, residuals, \
            {"loss": loss, "lr": sched(opt_state.step)}

    def shard_params(params):
        return jax.device_put(params, plan.param_shardings(params))

    return step_fn, shard_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--compute-dtype", default="bfloat16",
                    choices=["bfloat16", "float32"])
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree over local devices")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=args.seq, seed=7)
    it = DataIterator(corpus, "train", args.batch)
    tcfg = TrainConfig(steps=args.steps, lr=args.lr, ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(args.steps // 4, 1),
                       grad_compression=args.grad_compression,
                       compute_dtype=args.compute_dtype)

    if args.grad_compression != "none":
        # error-feedback residuals only thread through the single-host step
        params, losses = train(m, params, it, tcfg)
    else:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.tp)
        shape = ShapeConfig("train_cli", args.seq, args.batch, "train")
        with jax.set_mesh(mesh):
            step_fn, shard = dist_step_fn(cfg, tcfg, shape, mesh)
            params, losses = train(m, shard(params), it, tcfg,
                                   step_fn=step_fn)
    print(f"[train] done: first={losses[0]:.4f} last={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
