"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop on the local devices (CPU smoke / a
real TPU slice — the same code path; the dry-run driver validates the
production-mesh lowering).  Reduced configs via --smoke.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.configs.base import TrainConfig
from repro.data import DataIterator, SyntheticCorpus
from repro.models import build_model
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=args.seq, seed=7)
    it = DataIterator(corpus, "train", args.batch)
    tcfg = TrainConfig(steps=args.steps, lr=args.lr, ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(args.steps // 4, 1),
                       grad_compression=args.grad_compression)
    params, losses = train(m, params, it, tcfg)
    print(f"[train] done: first={losses[0]:.4f} last={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
