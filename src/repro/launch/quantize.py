"""Calibration launcher: quantize a model and save a packed checkpoint.

``python -m repro.launch.quantize --arch toy-llama --method spqr
--hessian oac --wbits 2 --out /tmp/oac_ckpt`` runs the paper's Algorithm 1
(``core.pipeline.quantize_model``) on a (optionally briefly trained) model,
packs the per-layer results into stacked ``QuantizedTensor`` planes
(``pack_results``), and writes the on-disk packed-checkpoint format
(``serving.qserve.ckpt.save``) that ``launch/serve.py --ckpt`` loads.

Calibration is resumable: per-layer results persist under ``<out>/calib``
(the pipeline's existing manifest), so a preempted run re-invoked with the
same arguments skips finished layer-kernels and still packs the full tree.

``--method rtn`` is the zero-calibration path; ``spqr``/``optq`` calibrate
with ``--hessian oac`` (paper) / ``l2`` / ``identity``; ``billm`` packs via
the 1-bit residual carrier; ``adpq`` (arXiv 2405.13358) is the zero-shot
adaptive-outlier rival and ``quantease`` (arXiv 2309.01885) the
coordinate-descent one — all six emit the same ``oac-qckpt`` container.
Calibration data comes from the synthetic corpus (the repo's offline
stand-in for C4/WikiText2).
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.configs.base import QuantConfig, TrainConfig
from repro.core import pipeline
from repro.core.qformat import QuantizedTensor
from repro.data import DataIterator, SyntheticCorpus, make_calib_set
from repro.models import build_model
from repro.serving.qserve import ckpt as qckpt

METHODS = ("rtn", "optq", "spqr", "billm", "adpq", "quantease")
HESSIANS = ("oac", "l2", "identity")


def prepare_params(cfg, corpus, *, train_steps: int = 0, seed: int = 0,
                   work_dir: str = "/tmp/oac_prep", log=print):
    """init (+ optional brief training) -> (model, params).

    This is the deterministic fp-reference recipe: given the same
    (cfg, corpus, seed, train_steps), any process rebuilds the exact
    params a checkpoint was quantized from — ``launch/eval.py`` uses it
    to reconstruct the fp16 baseline a ckpt's manifest ``extra`` names.
    """
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(seed))
    if train_steps > 0:
        from repro.train.loop import train
        tcfg = TrainConfig(steps=train_steps, lr=2e-3,
                           warmup=min(30, train_steps // 2),
                           ckpt_dir=os.path.join(work_dir, "train"))
        params, _ = train(m, params, DataIterator(corpus, "train", 16),
                          tcfg, log_every=max(train_steps // 4, 1))
    return m, params


def parse_draft(spec):
    """``--draft rtn-w4`` -> the zero-calibration QuantConfig drafting
    runs with (None/"none" disables)."""
    if spec in (None, "", "none"):
        return None
    if not spec.startswith("rtn-w"):
        raise ValueError(f"unsupported draft spec {spec!r} "
                         "(expected rtn-w<bits>, e.g. rtn-w4)")
    wbits = int(spec[len("rtn-w"):])
    return QuantConfig(wbits=wbits, group_size=32, method="rtn")


def run(cfg, qcfg: QuantConfig, out_dir: str, *, train_steps: int = 0,
        n_calib: int = 8, calib_seq: int = 128, seed: int = 0,
        draft: str = None, dist_ctx=None, log=print, obs=None,
        save_workers: int = 0) -> dict:
    """Train (optionally) -> calibrate -> pack -> save; returns the manifest.

    ``draft="rtn-w4"`` additionally RTN-packs the *same* prepared fp params
    at the given width and stores the planes beside the target in one
    checkpoint — the self-speculative serving pair (``launch/serve.py
    --draft``): zero-shot quantization tracks the calibrated model's
    distribution closely enough to propose for it (AdpQ, arXiv 2405.13358),
    at zero extra calibration cost.

    Callable from examples/tests with a concrete ModelConfig; the CLI is a
    thin argv wrapper around this.
    """
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=calib_seq, seed=7)
    m, params = prepare_params(cfg, corpus, train_steps=train_steps,
                               seed=seed, work_dir=out_dir, log=log)
    calib = {"tokens": jnp.asarray(make_calib_set(corpus, n_calib)["tokens"])}

    qp, results = pipeline.quantize_model(
        m, params, calib, qcfg, ckpt_dir=os.path.join(out_dir, "calib"),
        dist_ctx=dist_ctx, log=log, obs=obs)
    packed = pipeline.pack_results(qp, results, qcfg)
    dq = parse_draft(draft)
    dpacked = None
    if dq is not None:
        from repro.serving.quantized import quantize_params_rtn
        dpacked, skipped = quantize_params_rtn(params, dq)
        log(f"[quantize] draft pack {draft}: "
            f"{len(skipped)} kernels left fp")
    manifest = qckpt.save(out_dir, packed, cfg, qcfg,
                          draft=dpacked, draft_qcfg=dq,
                          workers=save_workers,
                          extra={"seed": seed, "train_steps": train_steps,
                                 "n_calib": n_calib, "calib_seq": calib_seq})

    bits = [float(np.mean(v.storage_bits()))
            for v in jax.tree.leaves(
                packed, is_leaf=lambda x: isinstance(x, QuantizedTensor))
            if isinstance(v, QuantizedTensor)]
    pf = manifest["plane_file"]
    log(f"[quantize] saved {len(manifest['tensors'])} tensors "
        f"({sum(1 for t in manifest['tensors'].values() if t['kind'] == 'quantized')} packed, "
        f"avg {np.mean(bits):.2f} bits/weight) -> {out_dir} "
        f"({pf['bytes'] / 1e6:.2f} MB planes)")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family smoke config")
    ap.add_argument("--method", default="spqr", choices=METHODS)
    ap.add_argument("--hessian", default="oac", choices=HESSIANS)
    ap.add_argument("--wbits", type=int, default=2)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--alpha", type=float, default=None,
                    help="Hessian regularization (default: 1.0 for oac, "
                         "0.1 otherwise — paper App. C.2)")
    ap.add_argument("--out", required=True, help="checkpoint directory")
    ap.add_argument("--train-steps", type=int, default=0,
                    help="briefly pre-train on the synthetic corpus "
                         "(0 = quantize the random init; fine for smoke)")
    ap.add_argument("--calib", type=int, default=8,
                    help="calibration sequences (paper: 128)")
    ap.add_argument("--calib-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--draft", default=None,
                    help="also pack a zero-calibration speculative draft "
                         "of the same weights into the checkpoint "
                         "(e.g. rtn-w4)")
    ap.add_argument("--save-workers", type=int, default=0,
                    help="write planes.bin with N parallel per-shard "
                         "writers (byte-identical to the default single "
                         "streaming writer)")
    ap.add_argument("--metrics-out", default=None, metavar="metrics.prom",
                    help="write pipeline_* metrics (per-layer wall, "
                         "hessian/solve split, quant error) as Prometheus "
                         "text exposition")
    ap.add_argument("--trace-out", default=None, metavar="trace.json",
                    help="write the calibration trace (layer/solve spans) "
                         "as Chrome trace-event JSON")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    alpha = args.alpha if args.alpha is not None else \
        (1.0 if args.hessian == "oac" else 0.1)
    qcfg = QuantConfig(wbits=args.wbits, group_size=args.group_size,
                       method=args.method, hessian=args.hessian, alpha=alpha)
    from repro import obs as obs_mod
    ob = obs_mod.Obs.make() if (args.metrics_out or args.trace_out) \
        else None
    run(cfg, qcfg, args.out, train_steps=args.train_steps,
        n_calib=args.calib, calib_seq=args.calib_seq, seed=args.seed,
        draft=args.draft, obs=ob, save_workers=args.save_workers)
    if ob is not None:
        if args.metrics_out:
            obs_mod.prom.write(args.metrics_out, ob.metrics)
            print(f"[quantize] metrics -> {args.metrics_out}")
        if args.trace_out:
            ob.tracer.write(args.trace_out)
            print(f"[quantize] trace -> {args.trace_out}")
        print("[quantize] calibration summary:")
        print(obs_mod.summary_table(ob.metrics, prefix="pipeline_"))


if __name__ == "__main__":
    main()
