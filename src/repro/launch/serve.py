"""Serving launcher: ``python -m repro.launch.serve [--ckpt DIR | --arch ID]``.

Serves a demo batch of requests through the engine (continuous-batching
slot pool by default; ``--engine paged`` adds the block-pool KV with prefix
sharing, ``--engine static`` runs the cohort baseline), or — with
``--http PORT`` — exposes the engine as a network service: OpenAI-style
``POST /v1/completions`` with SSE streaming, live ``GET /metrics``,
``/healthz`` and ``/v1/models`` (see ``docs/http_api.md``;
``launch/client.py`` is the matching reference client).  Weights come
from one of:

  * ``--ckpt DIR`` — a packed checkpoint written by ``launch/quantize.py``
    (or ``serving.qserve.ckpt.save``): the manifest names the model config
    and the planes are memmap-loaded; under ``--tp N`` each plane shard is
    placed directly per the ShardingPlan (the calibrated-OAC serving path).
  * ``--quant {rtn-w4,rtn-w3,rtn-w2}`` — RTN-pack a fresh init in memory
    (the zero-calibration fast path).
  * neither — full-precision weights.

``--kv-bits 8`` (paged engine) stores the KV pool as int8 codes +
per-token scale planes.  ``--check-quant rtn-wN`` (with ``--ckpt``) also
serves the same requests from an equivalent in-memory RTN tree and asserts
the greedy tokens match — the CI ckpt-smoke tripwire.

Latency-shaped scheduling (paged engine): ``--draft rtn-w4`` turns on
self-speculative decode (checkpoint draft planes when the ckpt packs
them, else an in-memory RTN pack of the same weights; greedy output is
bit-identical to target-only decode), ``--prefill-chunk N`` admits long
prompts in fixed chunks interleaved with decode ticks, and ``--slo``
assigns SLO classes that order admission and preemption.

Fleet ops (paged engine + ``--ckpt``): ``--save-warmup`` persists the
prefix cache populated by the demo batch beside the weight planes (use
``--shared-prefix N`` to give the demo prompts a deterministic common
prefix worth caching); ``--warmup`` pre-seeds a fresh replica's prefix
cache from that file at boot, so restarted servers skip the shared
prefill from tick one.
"""
import argparse
import contextlib
import sys

import jax
import numpy as np

from repro import obs as obs_mod
from repro.configs import get_config, get_smoke
from repro.configs.base import QuantConfig
from repro.dist.sharding import make_plan
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serving.engine import Engine, PagedEngine, StaticEngine
from repro.serving.quantized import quantize_params_rtn

QUANT_CHOICES = ("none", "rtn-w4", "rtn-w3", "rtn-w2")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="serve a packed checkpoint directory (overrides "
                         "--arch/--smoke/--quant from its manifest)")
    ap.add_argument("--quant", default="none", choices=QUANT_CHOICES,
                    help="pack weights to rtn-w{4,3,2} QuantizedTensors "
                         "(the zero-calibration serving fast path)")
    ap.add_argument("--check-quant", default=None,
                    choices=QUANT_CHOICES[1:], metavar="rtn-wN",
                    help="with --ckpt: also serve the same requests from an "
                         "in-memory rtn tree and assert greedy tokens match")
    ap.add_argument("--kv-bits", type=int, default=16, choices=[16, 8],
                    help="paged engine: KV pool precision (8 = int8 codes "
                         "+ per-token scale planes, ~2x less KV HBM)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=128,
                    help="per-request KV capacity in tokens")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree over local devices")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "paged", "static"],
                    help="slot-pool continuous batching (default), paged "
                         "block-pool KV with prefix sharing, or the "
                         "static-cohort baseline")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--draft", default=None, metavar="rtn-wN",
                    help="paged engine: self-speculative decode — draft "
                         "with the checkpoint's co-packed draft planes "
                         "(--ckpt) or an in-memory rtn-wN pack of the same "
                         "weights, verify with the target model")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="draft tokens proposed per speculative tick "
                         "(requires --draft; default 4)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged engine: admit prompts longer than this in "
                         "fixed chunks interleaved with decode ticks "
                         "(0 = blocking admission)")
    ap.add_argument("--slo", default="interactive",
                    choices=["interactive", "batch", "mixed"],
                    help="SLO class(es) for the demo requests (mixed "
                         "alternates; interactive admits first and is "
                         "preempted last)")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve over HTTP on this port instead of running "
                         "the demo batch (0 = ephemeral port; see "
                         "docs/http_api.md)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="give every demo prompt the same deterministic "
                         "N-token prefix (exercises prefix sharing; "
                         "launch/client.py --shared-prefix rebuilds it)")
    ap.add_argument("--warmup", action="store_true",
                    help="paged engine + --ckpt: pre-seed the prefix cache "
                         "from the checkpoint's warmup file at boot")
    ap.add_argument("--save-warmup", action="store_true",
                    help="paged engine + --ckpt: after the demo batch, "
                         "persist the populated prefix cache beside the "
                         "weight planes (warmup.json + warmup.npz)")
    ap.add_argument("--metrics-out", default=None, metavar="metrics.prom",
                    help="write the engine's metrics registry as "
                         "Prometheus text exposition after serving")
    ap.add_argument("--trace-out", default=None, metavar="trace.json",
                    help="write the request-lifecycle trace as Chrome "
                         "trace-event JSON (open in ui.perfetto.dev)")
    return ap


def validate_args(ap: argparse.ArgumentParser, args: argparse.Namespace):
    """All flag cross-checks in one testable place (``ap.error`` exits 2).
    Combinations that would silently no-op are hard errors — a flag the
    user typed must either take effect or fail loudly."""
    if args.kv_bits != 16 and args.engine != "paged":
        ap.error("--kv-bits 8 requires --engine paged (the int8 pool is "
                 "a block-pool layout)")
    if args.draft and args.engine != "paged":
        ap.error("--draft requires --engine paged (speculative decode "
                 "runs on the block-pool scheduler)")
    if args.spec_k is not None and not args.draft:
        ap.error("--spec-k without a draft source silently no-ops; add "
                 "--draft rtn-wN (or quantize with draft planes and pass "
                 "--ckpt + --draft)")
    if args.prefill_chunk and args.engine != "paged":
        ap.error(f"--prefill-chunk is a paged-engine feature; --engine "
                 f"{args.engine} would silently ignore it")
    if args.check_quant and not args.ckpt:
        ap.error("--check-quant only makes sense with --ckpt")
    if args.ckpt and args.quant != "none":
        ap.error("--ckpt already carries packed weights; drop --quant")
    if args.engine == "paged" and args.capacity % args.block_size:
        ap.error(f"--capacity {args.capacity} must be a multiple of "
                 f"--block-size {args.block_size}")
    if args.warmup or args.save_warmup:
        if args.engine != "paged":
            ap.error("--warmup/--save-warmup operate on the paged "
                     "engine's prefix cache; add --engine paged")
        if not args.ckpt:
            ap.error("--warmup/--save-warmup need a checkpoint directory "
                     "to hold the warmup file; add --ckpt DIR")
    if args.http is not None:
        if not 0 <= args.http <= 65535:
            ap.error(f"--http {args.http} is not a valid port")
        if args.engine == "static":
            ap.error("--http requires a continuous engine (the static "
                     "cohort baseline has no streaming surface)")
        if args.check_quant:
            ap.error("--check-quant runs the demo batch; drop --http")
        if args.save_warmup:
            ap.error("--save-warmup persists the demo batch's prefix "
                     "cache; run it without --http, then boot the server "
                     "with --warmup")
        if args.tp > 1:
            ap.error("--http currently serves tp=1 (the driver thread "
                     "does not re-enter the launcher's mesh context)")
    return args


def _demo_prompts(cfg, args):
    """The demo workload: 12 random tokens per request, optionally behind
    a shared deterministic prefix (same construction as launch/client.py
    --shared-prefix, so a warmed server recognizes client prompts)."""
    rng = np.random.default_rng(0)
    pre = (np.arange(1, args.shared_prefix + 1) % cfg.vocab).astype(np.int32)
    return [np.concatenate([pre, rng.integers(0, cfg.vocab,
                                              size=12).astype(np.int32)])
            for _ in range(args.requests)]


def _build_engine(cfg, params, args, plan, draft=None, obs=None):
    if args.engine == "paged":
        return PagedEngine(cfg, params, max_batch=args.requests,
                           capacity=args.capacity, plan=plan,
                           block_size=args.block_size, kv_bits=args.kv_bits,
                           draft=draft,
                           spec_k=4 if args.spec_k is None else args.spec_k,
                           prefill_chunk=args.prefill_chunk, obs=obs)
    cls = Engine if args.engine == "continuous" else StaticEngine
    return cls(cfg, params, max_batch=args.requests,
               capacity=args.capacity, plan=plan, obs=obs)


def _serve_requests(cfg, params, args, plan, draft=None, obs=None):
    """Build the chosen engine, serve the demo batch, return the requests."""
    eng = _build_engine(cfg, params, args, plan, draft=draft, obs=obs)
    if args.warmup:
        from repro.serving.qserve import ckpt as qckpt
        n = qckpt.load_warmup(args.ckpt, eng)
        print(f"[serve] prefix cache warmed: {n} blocks from {args.ckpt}")
    slos = {"interactive": ["interactive"], "batch": ["batch"],
            "mixed": ["interactive", "batch"]}[args.slo]
    rs = [eng.submit(p, max_tokens=args.max_tokens,
                     slo=slos[i % len(slos)])
          for i, p in enumerate(_demo_prompts(cfg, args))]
    eng.run()
    return eng, rs


def _model_info(cfg, manifest, args) -> dict:
    """What /v1/models and /healthz report about the served model."""
    qcfg = None
    if manifest is not None:
        from repro.serving.qserve import ckpt as qckpt
        qcfg = qckpt.quant_config(manifest)
    if qcfg is not None:
        method, wbits = qcfg.method, qcfg.wbits
    elif args.quant != "none":
        method, wbits = "rtn", int(args.quant.rsplit("w", 1)[1])
    else:
        method, wbits = "fp", None
    return {"arch": cfg.name, "method": method, "wbits": wbits,
            "vocab": cfg.vocab,
            "kv_bits": args.kv_bits if args.engine == "paged" else 16,
            "engine": args.engine, "capacity": args.capacity,
            "spec_decode": bool(args.draft),
            "prefill_chunk": args.prefill_chunk}


def _serve_http(cfg, params, args, plan, draft, ob, manifest):
    """Run the HTTP front end until interrupted (Ctrl-C)."""
    from repro.serving.api import ApiServer, EngineBridge
    eng = _build_engine(cfg, params, args, plan, draft=draft, obs=ob)
    if args.warmup:
        from repro.serving.qserve import ckpt as qckpt
        n = qckpt.load_warmup(args.ckpt, eng)
        print(f"[serve] prefix cache warmed: {n} blocks from {args.ckpt}")
    bridge = EngineBridge(eng).start()
    server = ApiServer(bridge, model_info=_model_info(cfg, manifest, args),
                       port=args.http)
    port = server.start()
    print(f"[serve] http on 127.0.0.1:{port} — POST /v1/completions, "
          "GET /metrics /healthz /v1/models (Ctrl-C to stop)", flush=True)
    try:
        server.join()
    except KeyboardInterrupt:
        print("\n[serve] shutting down")
    finally:
        server.stop()
        bridge.stop()


def main(argv=None):
    ap = build_parser()
    args = validate_args(ap, ap.parse_args(argv))

    manifest = None
    if args.ckpt:
        from repro.serving.qserve import ckpt as qckpt
        manifest = qckpt.load_manifest(args.ckpt)
        cfg = qckpt.resolve_config(manifest)
        qcfg = qckpt.quant_config(manifest)
        print(f"[serve] ckpt {args.ckpt}: arch={cfg.name}"
              + (f" {qcfg.method}/{qcfg.hessian} w{qcfg.wbits}"
                 f"g{qcfg.group_size}" if qcfg else ""))
    else:
        cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)

    plan, mesh = None, None
    if args.tp > 1:
        mesh = make_host_mesh(model=args.tp)
        plan = make_plan(cfg, mesh)
        print(f"[serve] mesh {dict(mesh.shape)} "
              f"(decode mode: {plan.ctx().attn_decode_mode})")

    def mesh_ctx():
        return jax.set_mesh(mesh) if mesh is not None \
            else contextlib.nullcontext()

    with mesh_ctx():
        draft = None
        if args.ckpt:
            from repro.serving.qserve import ckpt as qckpt
            params = qckpt.load(args.ckpt, plan, manifest=manifest)
            if args.draft:
                if not qckpt.has_draft(manifest):
                    print(f"[serve] checkpoint {args.ckpt} has no draft "
                          "planes — re-quantize with --draft "
                          f"{args.draft} to pack them")
                    sys.exit(2)
                draft = qckpt.load(args.ckpt, plan, manifest=manifest,
                                   which="draft")
                print("[serve] speculative draft: checkpoint draft planes "
                      f"(k={4 if args.spec_k is None else args.spec_k})")
        else:
            params = build_model(cfg).init(jax.random.PRNGKey(0))
            if args.quant != "none":
                wbits = int(args.quant.rsplit("w", 1)[1])
                params, skipped = quantize_params_rtn(
                    params, QuantConfig(wbits=wbits, group_size=32))
                print(f"[serve] packed weights to w{wbits}"
                      + (f" ({len(skipped)} kernels left fp: {skipped})"
                         if skipped else ""))
            if args.draft:
                wbits = int(args.draft.rsplit("w", 1)[1])
                draft, _ = quantize_params_rtn(
                    build_model(cfg).init(jax.random.PRNGKey(0)),
                    QuantConfig(wbits=wbits, group_size=32))
                print(f"[serve] speculative draft: in-memory {args.draft} "
                      f"pack of the same weights "
                      f"(k={4 if args.spec_k is None else args.spec_k})")
        ob = obs_mod.Obs.make()
        if args.http is not None:
            _serve_http(cfg, params, args, plan, draft, ob, manifest)
            return
        eng, rs = _serve_requests(cfg, params, args, plan, draft=draft,
                                  obs=ob)
    for r in rs:
        print(f"[serve] req {r.rid}: {r.out}")
    if args.save_warmup:
        from repro.serving.qserve import ckpt as qckpt
        n = qckpt.save_warmup(args.ckpt, eng)
        print(f"[serve] warmup saved: {n} prefix blocks -> {args.ckpt}")
    if args.metrics_out:
        obs_mod.prom.write(args.metrics_out, ob.metrics)
        print(f"[serve] metrics -> {args.metrics_out}")
    if args.trace_out:
        ob.tracer.write(args.trace_out)
        print(f"[serve] trace -> {args.trace_out} "
              "(open in https://ui.perfetto.dev)")
    print("[serve] run summary:")
    print(obs_mod.summary_table(ob.metrics, prefix="engine_"))
    if args.engine == "paged":
        print(f"[serve] prefill tokens skipped (prefix sharing): "
              f"{eng.prefill_tokens_skipped}, peak blocks: "
              f"{eng.peak_blocks_in_use}/{eng.num_blocks}"
              + (f", kv pool int8" if args.kv_bits == 8 else ""))
        if eng.spec_drafted:
            tok = sum(len(r.out) for r in rs)
            print(f"[serve] speculative: {eng.spec_accepted}/"
                  f"{eng.spec_drafted} drafts accepted "
                  f"({eng.spec_accepted / eng.spec_drafted:.0%}), "
                  f"{tok / max(eng.ticks, 1):.2f} tokens/tick "
                  f"over {eng.ticks} ticks")
        if eng.chunk_steps or eng.preemptions:
            print(f"[serve] scheduler: {eng.chunk_steps} prefill chunks, "
                  f"{eng.preemptions} preemptions, {eng.swap_ins} swap-ins, "
                  f"{eng.requeues} requeues")
    if plan is not None and (args.ckpt or args.quant != "none"):
        from repro.serving.qserve.report import (device_plane_bytes,
                                                 packed_plane_bytes)
        rep = packed_plane_bytes(eng.params,
                                 plan.param_shardings(eng.params))
        print(f"[serve] packed planes: {rep['total']} B total, "
              f"{rep['per_device']} B/device "
              f"(ratio {rep['ratio']:.3f}, tp={plan.tp_size}, "
              f"resident max {device_plane_bytes(eng.params)} B/device)")

    if args.check_quant:
        from repro.serving.qserve import ckpt as qckpt
        qcfg = qckpt.quant_config(manifest)
        wbits = int(args.check_quant.rsplit("w", 1)[1])
        extra = manifest.get("extra") or {}
        # the check's contract is "ckpt == packing the same init in memory":
        # it is only meaningful for untrained rtn checkpoints of matching
        # bit-width — anything else would report a false MISMATCH
        if extra.get("train_steps", 0):
            print("[serve] --check-quant requires an untrained checkpoint "
                  f"(this one trained {extra['train_steps']} steps)")
            sys.exit(2)
        if qcfg is not None and (qcfg.method != "rtn"
                                 or qcfg.wbits != wbits):
            print(f"[serve] --check-quant {args.check_quant} cannot verify "
                  f"a {qcfg.method} w{qcfg.wbits} checkpoint")
            sys.exit(2)
        gs = qcfg.group_size if qcfg is not None else 32
        ref = build_model(cfg).init(jax.random.PRNGKey(extra.get("seed", 0)))
        ref, _ = quantize_params_rtn(ref, QuantConfig(wbits=wbits,
                                                      group_size=gs))
        with mesh_ctx():
            _, ref_rs = _serve_requests(cfg, ref, args, plan)
        for a, b in zip(rs, ref_rs):
            if a.out != b.out:
                print(f"[serve] MISMATCH req {a.rid}: ckpt {a.out} vs "
                      f"in-memory {args.check_quant} {b.out}")
                sys.exit(1)
        print(f"[serve] OK: ckpt greedy tokens match in-memory "
              f"{args.check_quant} serving ({len(rs)} requests)")


if __name__ == "__main__":
    main()
