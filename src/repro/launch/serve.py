"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--quant rtn-w4]``.

Builds a (reduced) model, optionally RTN-quantizes it to packed low-bit
storage (``--quant {none,rtn-w4,rtn-w3,rtn-w2}``), and serves a demo batch
of requests through the engine (continuous-batching slot pool by default;
``--engine paged`` adds the block-pool KV with prefix sharing, ``--engine
static`` runs the cohort baseline).  ``--kv-bits 8`` (paged engine) stores
the KV pool as int8 codes + per-token scale planes.  With ``--tp N`` the
engine runs under a local (devices/N, N) mesh and a ``repro.dist``
ShardingPlan — quantized decode then runs with the packed planes TP-sharded
(``qserve``) on the same tensor-parallel layout the production mesh uses.
"""
import argparse
import contextlib

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.configs.base import QuantConfig
from repro.dist.sharding import make_plan
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serving.engine import Engine, PagedEngine, StaticEngine
from repro.serving.quantized import quantize_params_rtn

QUANT_CHOICES = ("none", "rtn-w4", "rtn-w3", "rtn-w2")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none", choices=QUANT_CHOICES,
                    help="pack weights to rtn-w{4,3,2} QuantizedTensors "
                         "(the zero-calibration serving fast path)")
    ap.add_argument("--kv-bits", type=int, default=16, choices=[16, 8],
                    help="paged engine: KV pool precision (8 = int8 codes "
                         "+ per-token scale planes, ~2x less KV HBM)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree over local devices")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "paged", "static"],
                    help="slot-pool continuous batching (default), paged "
                         "block-pool KV with prefix sharing, or the "
                         "static-cohort baseline")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: tokens per KV block")
    args = ap.parse_args()

    if args.kv_bits != 16 and args.engine != "paged":
        ap.error("--kv-bits 8 requires --engine paged (the int8 pool is "
                 "a block-pool layout)")

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    if args.quant != "none":
        wbits = int(args.quant.rsplit("w", 1)[1])
        params, skipped = quantize_params_rtn(
            params, QuantConfig(wbits=wbits, group_size=32))
        print(f"[serve] packed weights to w{wbits}"
              + (f" ({len(skipped)} kernels left fp: {skipped})"
                 if skipped else ""))

    plan, mesh_ctx = None, contextlib.nullcontext()
    if args.tp > 1:
        mesh = make_host_mesh(model=args.tp)
        plan = make_plan(cfg, mesh)
        mesh_ctx = jax.set_mesh(mesh)
        print(f"[serve] mesh {dict(mesh.shape)} "
              f"(decode mode: {plan.ctx().attn_decode_mode})")

    with mesh_ctx:
        if args.engine == "paged":
            eng = PagedEngine(cfg, params, max_batch=args.requests,
                              capacity=128, plan=plan,
                              block_size=args.block_size,
                              kv_bits=args.kv_bits)
        else:
            cls = Engine if args.engine == "continuous" else StaticEngine
            eng = cls(cfg, params, max_batch=args.requests, capacity=128,
                      plan=plan)
        rng = np.random.default_rng(0)
        rs = [eng.submit(rng.integers(0, cfg.vocab, size=12),
                         max_tokens=args.max_tokens)
              for _ in range(args.requests)]
        eng.run()
    for r in rs:
        print(f"[serve] req {r.rid}: {r.out}")
    if args.engine == "paged":
        print(f"[serve] prefill tokens skipped (prefix sharing): "
              f"{eng.prefill_tokens_skipped}, peak blocks: "
              f"{eng.peak_blocks_in_use}/{eng.num_blocks}"
              + (f", kv pool int8" if args.kv_bits == 8 else ""))
    if args.quant != "none" and plan is not None:
        from repro.serving.qserve.report import packed_plane_bytes
        rep = packed_plane_bytes(params, plan.param_shardings(params))
        print(f"[serve] packed planes: {rep['total']} B total, "
              f"{rep['per_device']} B/device "
              f"(ratio {rep['ratio']:.3f}, tp={plan.tp_size})")


if __name__ == "__main__":
    main()
