"""Serving launcher: ``python -m repro.launch.serve [--ckpt DIR | --arch ID]``.

Serves a demo batch of requests through the engine (continuous-batching
slot pool by default; ``--engine paged`` adds the block-pool KV with prefix
sharing, ``--engine static`` runs the cohort baseline).  Weights come from
one of:

  * ``--ckpt DIR`` — a packed checkpoint written by ``launch/quantize.py``
    (or ``serving.qserve.ckpt.save``): the manifest names the model config
    and the planes are memmap-loaded; under ``--tp N`` each plane shard is
    placed directly per the ShardingPlan (the calibrated-OAC serving path).
  * ``--quant {rtn-w4,rtn-w3,rtn-w2}`` — RTN-pack a fresh init in memory
    (the zero-calibration fast path).
  * neither — full-precision weights.

``--kv-bits 8`` (paged engine) stores the KV pool as int8 codes +
per-token scale planes.  ``--check-quant rtn-wN`` (with ``--ckpt``) also
serves the same requests from an equivalent in-memory RTN tree and asserts
the greedy tokens match — the CI ckpt-smoke tripwire.

Latency-shaped scheduling (paged engine): ``--draft rtn-w4`` turns on
self-speculative decode (checkpoint draft planes when the ckpt packs
them, else an in-memory RTN pack of the same weights; greedy output is
bit-identical to target-only decode), ``--prefill-chunk N`` admits long
prompts in fixed chunks interleaved with decode ticks, and ``--slo``
assigns SLO classes that order admission and preemption.
"""
import argparse
import contextlib
import sys

import jax
import numpy as np

from repro import obs as obs_mod
from repro.configs import get_config, get_smoke
from repro.configs.base import QuantConfig
from repro.dist.sharding import make_plan
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serving.engine import Engine, PagedEngine, StaticEngine
from repro.serving.quantized import quantize_params_rtn

QUANT_CHOICES = ("none", "rtn-w4", "rtn-w3", "rtn-w2")


def _serve_requests(cfg, params, args, plan, draft=None, obs=None):
    """Build the chosen engine, serve the demo batch, return the requests."""
    if args.engine == "paged":
        eng = PagedEngine(cfg, params, max_batch=args.requests,
                          capacity=128, plan=plan,
                          block_size=args.block_size, kv_bits=args.kv_bits,
                          draft=draft, spec_k=args.spec_k,
                          prefill_chunk=args.prefill_chunk, obs=obs)
    else:
        cls = Engine if args.engine == "continuous" else StaticEngine
        eng = cls(cfg, params, max_batch=args.requests, capacity=128,
                  plan=plan, obs=obs)
    rng = np.random.default_rng(0)
    slos = {"interactive": ["interactive"], "batch": ["batch"],
            "mixed": ["interactive", "batch"]}[args.slo]
    rs = [eng.submit(rng.integers(0, cfg.vocab, size=12),
                     max_tokens=args.max_tokens,
                     slo=slos[i % len(slos)])
          for i in range(args.requests)]
    eng.run()
    return eng, rs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="serve a packed checkpoint directory (overrides "
                         "--arch/--smoke/--quant from its manifest)")
    ap.add_argument("--quant", default="none", choices=QUANT_CHOICES,
                    help="pack weights to rtn-w{4,3,2} QuantizedTensors "
                         "(the zero-calibration serving fast path)")
    ap.add_argument("--check-quant", default=None,
                    choices=QUANT_CHOICES[1:], metavar="rtn-wN",
                    help="with --ckpt: also serve the same requests from an "
                         "in-memory rtn tree and assert greedy tokens match")
    ap.add_argument("--kv-bits", type=int, default=16, choices=[16, 8],
                    help="paged engine: KV pool precision (8 = int8 codes "
                         "+ per-token scale planes, ~2x less KV HBM)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree over local devices")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "paged", "static"],
                    help="slot-pool continuous batching (default), paged "
                         "block-pool KV with prefix sharing, or the "
                         "static-cohort baseline")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--draft", default=None, metavar="rtn-wN",
                    help="paged engine: self-speculative decode — draft "
                         "with the checkpoint's co-packed draft planes "
                         "(--ckpt) or an in-memory rtn-wN pack of the same "
                         "weights, verify with the target model")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative tick")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged engine: admit prompts longer than this in "
                         "fixed chunks interleaved with decode ticks "
                         "(0 = blocking admission)")
    ap.add_argument("--slo", default="interactive",
                    choices=["interactive", "batch", "mixed"],
                    help="SLO class(es) for the demo requests (mixed "
                         "alternates; interactive admits first and is "
                         "preempted last)")
    ap.add_argument("--metrics-out", default=None, metavar="metrics.prom",
                    help="write the engine's metrics registry as "
                         "Prometheus text exposition after serving")
    ap.add_argument("--trace-out", default=None, metavar="trace.json",
                    help="write the request-lifecycle trace as Chrome "
                         "trace-event JSON (open in ui.perfetto.dev)")
    args = ap.parse_args()

    if args.kv_bits != 16 and args.engine != "paged":
        ap.error("--kv-bits 8 requires --engine paged (the int8 pool is "
                 "a block-pool layout)")
    if args.draft and args.engine != "paged":
        ap.error("--draft requires --engine paged (speculative decode "
                 "runs on the block-pool scheduler)")
    if args.check_quant and not args.ckpt:
        ap.error("--check-quant only makes sense with --ckpt")
    if args.ckpt and args.quant != "none":
        ap.error("--ckpt already carries packed weights; drop --quant")

    manifest = None
    if args.ckpt:
        from repro.serving.qserve import ckpt as qckpt
        manifest = qckpt.load_manifest(args.ckpt)
        cfg = qckpt.resolve_config(manifest)
        qcfg = qckpt.quant_config(manifest)
        print(f"[serve] ckpt {args.ckpt}: arch={cfg.name}"
              + (f" {qcfg.method}/{qcfg.hessian} w{qcfg.wbits}"
                 f"g{qcfg.group_size}" if qcfg else ""))
    else:
        cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)

    plan, mesh = None, None
    if args.tp > 1:
        mesh = make_host_mesh(model=args.tp)
        plan = make_plan(cfg, mesh)
        print(f"[serve] mesh {dict(mesh.shape)} "
              f"(decode mode: {plan.ctx().attn_decode_mode})")

    def mesh_ctx():
        return jax.set_mesh(mesh) if mesh is not None \
            else contextlib.nullcontext()

    with mesh_ctx():
        draft = None
        if args.ckpt:
            from repro.serving.qserve import ckpt as qckpt
            params = qckpt.load(args.ckpt, plan, manifest=manifest)
            if args.draft:
                if not qckpt.has_draft(manifest):
                    print(f"[serve] checkpoint {args.ckpt} has no draft "
                          "planes — re-quantize with --draft "
                          f"{args.draft} to pack them")
                    sys.exit(2)
                draft = qckpt.load(args.ckpt, plan, manifest=manifest,
                                   which="draft")
                print("[serve] speculative draft: checkpoint draft planes "
                      f"(k={args.spec_k})")
        else:
            params = build_model(cfg).init(jax.random.PRNGKey(0))
            if args.quant != "none":
                wbits = int(args.quant.rsplit("w", 1)[1])
                params, skipped = quantize_params_rtn(
                    params, QuantConfig(wbits=wbits, group_size=32))
                print(f"[serve] packed weights to w{wbits}"
                      + (f" ({len(skipped)} kernels left fp: {skipped})"
                         if skipped else ""))
            if args.draft:
                wbits = int(args.draft.rsplit("w", 1)[1])
                draft, _ = quantize_params_rtn(
                    build_model(cfg).init(jax.random.PRNGKey(0)),
                    QuantConfig(wbits=wbits, group_size=32))
                print(f"[serve] speculative draft: in-memory {args.draft} "
                      f"pack of the same weights (k={args.spec_k})")
        ob = obs_mod.Obs.make()
        eng, rs = _serve_requests(cfg, params, args, plan, draft=draft,
                                  obs=ob)
    for r in rs:
        print(f"[serve] req {r.rid}: {r.out}")
    if args.metrics_out:
        obs_mod.prom.write(args.metrics_out, ob.metrics)
        print(f"[serve] metrics -> {args.metrics_out}")
    if args.trace_out:
        ob.tracer.write(args.trace_out)
        print(f"[serve] trace -> {args.trace_out} "
              "(open in https://ui.perfetto.dev)")
    print("[serve] run summary:")
    print(obs_mod.summary_table(ob.metrics, prefix="engine_"))
    if args.engine == "paged":
        print(f"[serve] prefill tokens skipped (prefix sharing): "
              f"{eng.prefill_tokens_skipped}, peak blocks: "
              f"{eng.peak_blocks_in_use}/{eng.num_blocks}"
              + (f", kv pool int8" if args.kv_bits == 8 else ""))
        if eng.spec_drafted:
            tok = sum(len(r.out) for r in rs)
            print(f"[serve] speculative: {eng.spec_accepted}/"
                  f"{eng.spec_drafted} drafts accepted "
                  f"({eng.spec_accepted / eng.spec_drafted:.0%}), "
                  f"{tok / max(eng.ticks, 1):.2f} tokens/tick "
                  f"over {eng.ticks} ticks")
        if eng.chunk_steps or eng.preemptions:
            print(f"[serve] scheduler: {eng.chunk_steps} prefill chunks, "
                  f"{eng.preemptions} preemptions, {eng.swap_ins} swap-ins, "
                  f"{eng.requeues} requeues")
    if plan is not None and (args.ckpt or args.quant != "none"):
        from repro.serving.qserve.report import (device_plane_bytes,
                                                 packed_plane_bytes)
        rep = packed_plane_bytes(eng.params,
                                 plan.param_shardings(eng.params))
        print(f"[serve] packed planes: {rep['total']} B total, "
              f"{rep['per_device']} B/device "
              f"(ratio {rep['ratio']:.3f}, tp={plan.tp_size}, "
              f"resident max {device_plane_bytes(eng.params)} B/device)")

    if args.check_quant:
        from repro.serving.qserve import ckpt as qckpt
        qcfg = qckpt.quant_config(manifest)
        wbits = int(args.check_quant.rsplit("w", 1)[1])
        extra = manifest.get("extra") or {}
        # the check's contract is "ckpt == packing the same init in memory":
        # it is only meaningful for untrained rtn checkpoints of matching
        # bit-width — anything else would report a false MISMATCH
        if extra.get("train_steps", 0):
            print("[serve] --check-quant requires an untrained checkpoint "
                  f"(this one trained {extra['train_steps']} steps)")
            sys.exit(2)
        if qcfg is not None and (qcfg.method != "rtn"
                                 or qcfg.wbits != wbits):
            print(f"[serve] --check-quant {args.check_quant} cannot verify "
                  f"a {qcfg.method} w{qcfg.wbits} checkpoint")
            sys.exit(2)
        gs = qcfg.group_size if qcfg is not None else 32
        ref = build_model(cfg).init(jax.random.PRNGKey(extra.get("seed", 0)))
        ref, _ = quantize_params_rtn(ref, QuantConfig(wbits=wbits,
                                                      group_size=gs))
        with mesh_ctx():
            _, ref_rs = _serve_requests(cfg, ref, args, plan)
        for a, b in zip(rs, ref_rs):
            if a.out != b.out:
                print(f"[serve] MISMATCH req {a.rid}: ckpt {a.out} vs "
                      f"in-memory {args.check_quant} {b.out}")
                sys.exit(1)
        print(f"[serve] OK: ckpt greedy tokens match in-memory "
              f"{args.check_quant} serving ({len(rs)} requests)")


if __name__ == "__main__":
    main()
