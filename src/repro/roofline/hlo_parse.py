"""HLO text analyzer with while-loop trip-count multipliers.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, which under-counts
scan-over-layers models by ~n_layers x (verified empirically).  This module
walks the optimized, SPMD-partitioned HLO text, multiplies loop bodies by
their trip counts (XLA's ``known_trip_count`` backend config, with a
condition-constant fallback), and reports:

  * matmul FLOPs (dot) — the MFU-convention compute count,
  * HBM bytes (operand + output sizes of top-level instructions; post-fusion
    this approximates true traffic),
  * collective bytes by op kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), from output sizes.

Operand shapes are resolved through a per-computation symbol table (the
jax-0.8 HLO printer does not inline operand types).  All numbers are PER
DEVICE (the partitioned module is the per-device program); multiply by chip
count for global figures.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = ("parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "iota", "while",
               "conditional", "call")


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(shapes) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


class Instr:
    __slots__ = ("name", "op", "out_shapes", "args", "line")

    def __init__(self, name, op, out_shapes, args, line):
        self.name, self.op = name, op
        self.out_shapes, self.args, self.line = out_shapes, args, line


_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z]\d*[a-z0-9]*\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")


def parse(hlo: str):
    """-> (computations {name: [Instr]}, entry_name)."""
    comps: Dict[str, List[Instr]] = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        ls = raw.strip()
        if ls.endswith("{") and "->" in ls and not ls.startswith(("%ROOT",)):
            head = ls.split("(")[0].strip()
            toks = head.split()
            if toks and (toks[0] == "ENTRY" or toks[0].startswith("%")
                         or len(toks) == 1):
                name = toks[1] if toks[0] == "ENTRY" else toks[0]
                cur = name.lstrip("%")
                comps[cur] = []
                if toks[0] == "ENTRY":
                    entry = cur
                continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(ls)
        if m:
            name, type_str, op, rest = m.groups()
            args = rest.split("), ")[0] if "), " in rest else rest.rstrip(")")
            comps[cur].append(Instr(name, op, _shape_list(type_str),
                                    _NAME_RE.findall(args), ls))
    return comps, entry


def _fusion_is_inplace_update(line: str, comps, out_shapes) -> bool:
    """True when the fusion is an in-place buffer update: its body contains a
    dynamic-update-slice/scatter whose result has the fusion's output dims
    (converts may wrap the root — compare dims, not dtypes)."""
    m = re.search(r"calls=%?([\w\.\-]+)", line)
    if not m or not out_shapes:
        return False
    out_dims = out_shapes[0][1]
    for ins in comps.get(m.group(1), []):
        if ins.op in ("dynamic-update-slice", "scatter") and \
                ins.out_shapes and ins.out_shapes[0][1] == out_dims:
            return True
    return False


def _trip_count(line: str, cond_instrs) -> int:
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    best = 1
    for ins in cond_instrs or []:
        for mm in re.finditer(r"constant\((\d+)\)", ins.line):
            v = int(mm.group(1))
            if 1 < v < 10_000_000:
                best = max(best, v)
    return best


def analyze(hlo: str) -> Dict[str, float]:
    comps, entry = parse(hlo)
    result = defaultdict(float)
    on_stack = set()

    def visit(comp: str, mult: float, count_bytes: bool):
        if comp not in comps or comp in on_stack:
            return
        on_stack.add(comp)
        sym = {i.name: i.out_shapes for i in comps[comp]}
        for ins in comps[comp]:
            op, line = ins.op, ins.line
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                trips = _trip_count(line, comps.get(mc.group(1)) if mc else [])
                if mb:
                    visit(mb.group(1), mult * trips, count_bytes)
                continue
            if op in ("call", "fusion"):
                m = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", line)
                if m:
                    visit(m.group(1), mult, False)   # FLOPs only
            if op == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", line)
                if mbr:
                    for b in mbr.group(1).split(","):
                        visit(b.strip().lstrip("%"), mult, count_bytes)
                continue
            if op == "dot":
                out_n = 1.0
                for dt, dims in ins.out_shapes[:1]:
                    for d in dims:
                        out_n *= d
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                contract = 1.0
                if cm and ins.args:
                    lhs = sym.get(ins.args[0])
                    if lhs:
                        dims = lhs[0][1]
                        for idx in (cm.group(1).split(",")
                                    if cm.group(1) else []):
                            i = int(idx)
                            if i < len(dims):
                                contract *= dims[i]
                result["flops"] += mult * 2.0 * out_n * contract
            for ck in COLLECTIVES:
                if op == ck or op == ck + "-start":
                    b = _bytes_of(ins.out_shapes)
                    result["collective_bytes"] += mult * b
                    result[f"coll::{ck}"] += mult * b
                    break
            if count_bytes and op not in _SKIP_BYTES:
                if op in ("dynamic-update-slice", "scatter"):
                    # in-place updates: traffic ~= 2x the update payload
                    # (read-modify-write of the touched slice), NOT the full
                    # buffer the HLO type suggests
                    upd = ins.args[1] if len(ins.args) > 1 else None
                    b = 2 * _bytes_of(sym.get(upd, [])) if upd else 0.0
                elif op == "fusion" and _fusion_is_inplace_update(
                        line, comps, ins.out_shapes):
                    # fusion whose root is a DUS: skip the pass-through
                    # buffer (largest operand) and the full-size output
                    opb = sorted((_bytes_of(sym[a]) for a in ins.args
                                  if a in sym), reverse=True)
                    b = 2.0 * sum(opb[1:]) if len(opb) > 1 else 0.0
                else:
                    b = _bytes_of(ins.out_shapes)
                    for a in ins.args:
                        if a in sym:
                            b += _bytes_of(sym[a])
                result["hbm_bytes"] += mult * b
        on_stack.discard(comp)

    if entry:
        visit(entry, 1.0, True)
    result.setdefault("flops", 0.0)
    result.setdefault("hbm_bytes", 0.0)
    result.setdefault("collective_bytes", 0.0)
    return dict(result)
