"""Three-term roofline model from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes / collective_bytes are GLOBAL (per-device counts from
the partitioned module x chip count; counted by roofline.hlo_parse with
while-loop trip multipliers).  Hardware: TPU v5e — 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd-only), N = (active) params,
D = tokens processed; the ratio MODEL_FLOPS/HLO_FLOPs measures how much of
compiled compute is useful (catches remat/redundancy waste — remat is VISIBLE
here by design: a rematerialized train step legitimately recomputes ~1 fwd).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline import hlo_parse

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link (ICI)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Paper-convention useful FLOPs for the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_lowered(lowered, compiled, cfg: ModelConfig, shape: ShapeConfig,
                    mesh) -> Dict:
    chips = int(np.prod(list(mesh.shape.values())))
    hlo = compiled.as_text()
    per_dev = hlo_parse.analyze(hlo)
    flops_g = per_dev["flops"] * chips
    bytes_g = per_dev["hbm_bytes"] * chips
    coll_g = per_dev["collective_bytes"] * chips

    t_compute = flops_g / (chips * PEAK_FLOPS)
    t_memory = bytes_g / (chips * HBM_BW)
    t_coll = coll_g / (chips * LINK_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    t_bound = max(terms.values())
    return {
        "chips": chips,
        "hlo_flops": flops_g,
        "hlo_bytes": bytes_g,
        "collective_bytes": coll_g,
        "coll_breakdown": {k[6:]: v * chips for k, v in per_dev.items()
                           if k.startswith("coll::")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_ratio": mf / flops_g if flops_g else 0.0,
        # fraction of roofline-ideal step time the dominant term allows,
        # assuming perfect overlap of the other two terms
        "roofline_fraction": (mf / (chips * PEAK_FLOPS)) / t_bound
        if t_bound else 0.0,
    }
