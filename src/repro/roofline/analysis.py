"""Three-term roofline model from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes / collective_bytes are GLOBAL (per-device counts from
the partitioned module x chip count; counted by roofline.hlo_parse with
while-loop trip multipliers).  Hardware: TPU v5e — 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd-only), N = (active) params,
D = tokens processed; the ratio MODEL_FLOPS/HLO_FLOPs measures how much of
compiled compute is useful (catches remat/redundancy waste — remat is VISIBLE
here by design: a rematerialized train step legitimately recomputes ~1 fwd).
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline import hlo_parse

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link (ICI)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Paper-convention useful FLOPs for the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze_lowered(lowered, compiled, cfg: ModelConfig, shape: ShapeConfig,
                    mesh) -> Dict:
    chips = int(np.prod(list(mesh.shape.values())))
    hlo = compiled.as_text()
    per_dev = hlo_parse.analyze(hlo)
    flops_g = per_dev["flops"] * chips
    bytes_g = per_dev["hbm_bytes"] * chips
    coll_g = per_dev["collective_bytes"] * chips

    t_compute = flops_g / (chips * PEAK_FLOPS)
    t_memory = bytes_g / (chips * HBM_BW)
    t_coll = coll_g / (chips * LINK_BW)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    t_bound = max(terms.values())
    return {
        "chips": chips,
        "hlo_flops": flops_g,
        "hlo_bytes": bytes_g,
        "collective_bytes": coll_g,
        "coll_breakdown": {k[6:]: v * chips for k, v in per_dev.items()
                           if k.startswith("coll::")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_ratio": mf / flops_g if flops_g else 0.0,
        # fraction of roofline-ideal step time the dominant term allows,
        # assuming perfect overlap of the other two terms
        "roofline_fraction": (mf / (chips * PEAK_FLOPS)) / t_bound
        if t_bound else 0.0,
    }


# ---------------------------------------------------------------------------
# Per-kernel byte models for the Pallas serving kernels (kernels/paged_attn,
# kernels/moe_dequant).  "fused" is the table-walking / packed-plane kernel:
# it streams only the bytes that physically exist in HBM.  "unfused" is the
# XLA fallback it replaces: gather (or dequant) materializes a dense
# intermediate that is written out and read back.  Ratios are analytic and
# backend-independent; ``achieved_bytes`` measures what the *current*
# lowering actually compiles to (hlo_parse on the optimized module), so
# benchmarks can report achieved-vs-predicted side by side.
# ---------------------------------------------------------------------------

def paged_attn_bytes(B, live_blocks, block_size, n_kv, d_head, n_heads,
                     kv_bits=16) -> Dict[str, float]:
    """Predicted HBM bytes per decode step: fused table-walk vs dense gather.

    ``live_blocks`` is the bounded table width the engine passes (logical
    blocks actually mapped), not ``max_blocks``.  At ``kv_bits=8`` the fused
    kernel reads int8 code planes + bf16 per-(block, slot, head) scales and
    dequantizes in VREGs; the fallback materializes the dequantized bf16
    pool view before attending.
    """
    el = 1 if kv_bits == 8 else 2
    rows = B * live_blocks * block_size * n_kv            # gathered KV slots
    pool = 2 * rows * d_head * el                         # K + V code reads
    scales = 2 * rows * 2 if kv_bits == 8 else 0          # k_scale + v_scale
    q = B * n_heads * d_head * 2
    out = B * n_heads * d_head * 2
    tables = B * live_blocks * 4
    fused = pool + scales + q + out + tables
    # fallback: the gathered (and, for int8, dequantized) dense (B, L, KV, Dh)
    # K and V views are written to HBM and read back by the attention einsums
    dense = 2 * rows * d_head * 2
    unfused = pool + scales + tables + 2 * dense + q + out
    return {"fused": fused, "unfused": unfused, "ratio": fused / unfused}


def moe_dequant_bytes(n_routed, n_experts, T, K, N, bits, group_size,
                      resid=False) -> Dict[str, float]:
    """Predicted HBM bytes per MoE layer step: fused packed-plane contraction
    over the ``n_routed`` compacted experts vs the dense path that
    reconstructs all ``n_experts`` bf16 weight stacks before the einsum."""
    def packed(e):
        b = e * K * N * bits / 8.0                        # code planes
        b += 2 * e * (K // group_size) * N                # uint8 stats codes
        if resid:
            b += e * K * N / 8.0 + e * K * N * 2.0        # sign + |w_hat|
        return b

    x = n_routed * T * K * 2
    out = n_routed * T * N * 4
    fused = x + packed(n_routed) + out
    dense = n_experts * K * N * 2
    unfused = x + packed(n_experts) + 2 * dense + out
    return {"fused": fused, "unfused": unfused, "ratio": fused / unfused}


def achieved_bytes(fn, *args) -> float:
    """Per-device HBM bytes of ``fn``'s compiled lowering on this backend
    (hlo_parse over the optimized module — post-fusion operand+output
    traffic, the same count ``analyze_lowered`` uses)."""
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_parse.analyze(hlo)["hbm_bytes"]


def record_achieved_bytes(registry, kernel: str, fn, *args) -> float:
    """``achieved_bytes`` measured AND published: the value lands in the
    ``kernel_achieved_bytes{kernel=...}`` gauge family of ``registry``
    (a ``repro.obs.MetricsRegistry``) — one source of truth shared by
    BENCH_kernels.json rows and a live metrics endpoint."""
    b = achieved_bytes(fn, *args)
    registry.gauge("kernel_achieved_bytes",
                   "per-device HBM bytes of the compiled lowering",
                   labels=("kernel",)).labels(kernel=kernel).set(b)
    return b
