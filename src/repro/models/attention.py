"""GQA attention: chunked-causal (train/prefill) and cached decode.

Memory discipline: the (Sq x Skv) score matrix is never fully materialized —
queries are processed in chunks via ``lax.scan`` (TPU: each chunk's scores
fit VMEM; XLA pipelines the chunks).  GQA is computed grouped
(``q (B,S,KV,rep,Dh)``) so KV heads are never repeated in memory.

Decode supports two layouts:
  * dense: scores over the full cache (KV-head-sharded when divisible);
  * partial: returns (unnormalized out, max, sumexp) per KV shard so the
    distribution layer can combine across a KV-length-sharded cache
    (flash-decoding style) — used when head counts don't divide the TP axis
    and for long-context cells.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attn import ops as PA
from repro.models import layers as L

NEG_INF = -1e30


def attn_init(key, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.linear_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias,
                            dtype=dtype),
        "wk": L.linear_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias,
                            dtype=dtype),
        "wv": L.linear_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias,
                            dtype=dtype),
        "wo": L.linear_init(ks[3], cfg.n_heads * hd, d, dtype=dtype),
    }


def qkv_project(p, x, cfg, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = L.linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = L.linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.pos == "rope":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _pick_chunk(S, target=512):
    if S <= target:
        return S
    c = target
    while S % c:
        c //= 2
    return max(c, 1)


def causal_attention(q, k, v, *, window: int = 0, q_offset=0,
                     q_chunk: int = 256, kv_chunk: int = 1024):
    """Flash-style double-blocked causal attention (online softmax).

    q (B,Sq,H,Dh); k,v (B,Skv,KV,Dh).  Query i attends keys j with
    j <= i + q_offset (and i+q_offset-j < window when window>0).  Scores
    exist only per (q_chunk x kv_chunk) block — the O(Sq*Skv) matrix never
    reaches HBM, which turns 32k-prefill from score-traffic-bound to
    compute-bound (EXPERIMENTS.md §Perf).  Off-causal blocks are masked, not
    skipped (block-skipping needs dynamic trip counts that break reverse-mode
    AD; the Pallas splash kernel is the real-TPU answer).
    """
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    KV = k.shape[2]
    rep = H // KV
    scale = Dh ** -0.5
    qg = (q * scale).reshape(B, Sq, KV, rep, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    Cq = _pick_chunk(Sq, q_chunk)
    Ck = _pick_chunk(Skv, kv_chunk)

    def q_body(_, qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * Cq, Cq, axis=1)
        qpos = q_offset + qi * Cq + jnp.arange(Cq)

        def kv_body(carry, kj):
            o, m, l = carry
            kc = jax.lax.dynamic_slice_in_dim(kf, kj * Ck, Ck, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, kj * Ck, Ck, axis=1)
            kpos = kj * Ck + jnp.arange(Ck)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qc, kc)
            msk = kpos[None, :] <= qpos[:, None]
            if window:
                msk &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            o = o * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p, vc.astype(jnp.float32))
            return (o, m_new, l), None

        # derive carries from qc so they inherit shard_map's varying-axis
        # typing (fresh jnp.zeros is "unvarying" and fails the scan carry
        # check when this runs inside the seq_shard shard_map)
        o0 = jnp.moveaxis(qc, 1, 3) * 0.0             # (B,KV,rep,Cq,Dh)
        m0 = o0[..., 0] + NEG_INF
        l0 = o0[..., 0]
        (o, m, l), _ = jax.lax.scan(kv_body, (o0, m0, l0),
                                    jnp.arange(Skv // Ck))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return None, o.astype(v.dtype)                # (B,KV,rep,Cq,Dh)

    _, outs = jax.lax.scan(q_body, None, jnp.arange(Sq // Cq))
    # outs (nq, B, KV, rep, Cq, Dh) -> (B, Sq, H, Dh)
    o = jnp.moveaxis(outs, 0, 3)                      # (B,KV,rep,nq,Cq,Dh)
    o = jnp.moveaxis(o.reshape(B, KV, rep, Sq, Dh), 3, 1)
    return o.reshape(B, Sq, H, Dh)


def chunk_attention(q, k_ctx, v_ctx, ctx_valid, k_new, v_new):
    """Prefill-chunk attention: one joint softmax over [pool prefix || chunk].

    ``q`` (B,C,H,Dh) are the chunk's queries at positions start..start+C-1.
    ``k_ctx``/``v_ctx`` (B,Lctx,KV,Dh) are the row's pool blocks gathered in
    logical order, with ``ctx_valid`` (B,Lctx) marking real prefix positions
    (pos < start and block mapped) — every valid context position precedes
    every query, so no causal test is needed there.  ``k_new``/``v_new``
    (B,C,KV,Dh) are the chunk's own KV, attended causally by chunk-local
    index.  Both score halves share one softmax (single max/normalizer), so
    splitting a prompt into chunks changes only which tile materializes:
    the (C, Lctx+C) score block is the memory ceiling — bounding that tile
    regardless of prompt length is the point of chunked prefill."""
    B, C, H, Dh = q.shape
    KV = k_new.shape[2]
    rep = H // KV
    qg = (q * Dh ** -0.5).reshape(B, C, KV, rep, Dh).astype(jnp.float32)
    s_ctx = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_ctx.astype(jnp.float32))
    s_ctx = jnp.where(ctx_valid[:, None, None, None, :], s_ctx, NEG_INF)
    s_new = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_new.astype(jnp.float32))
    ii = jnp.arange(C)
    s_new = jnp.where((ii[None, :] <= ii[:, None])[None, None, None],
                      s_new, NEG_INF)
    s = jnp.concatenate([s_ctx, s_new], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    vals = jnp.concatenate([v_ctx, v_new], axis=1).astype(jnp.float32)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, vals)
    return o.reshape(B, C, H, Dh).astype(v_new.dtype)


# ------------------------------------------------------------------ decode

class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, Lc, KV, Dh)
    v: jnp.ndarray
    # (B, Lc) absolute position stored in each slot (-1 empty).  Per-row so
    # every batch row carries its own cache clock (continuous batching:
    # rows prefilled at different times decode at independent positions).
    slot_pos: jnp.ndarray


class PagedKVCache(NamedTuple):
    """Paged KV pool: one global block pool shared by every batch slot.

    Addressing is linear, not a ring: absolute position ``p`` of row ``b``
    lives at ``(block_tables[b, p // bs], p % bs)`` in the pool.  Because
    positions are implicit in the layout, no per-slot ``slot_pos`` array is
    needed — validity during decode is ``j <= pos[b]`` (the same ``(B,)``
    vector clock every decode path already threads) plus "the logical block
    is mapped".  Physical block 0 is RESERVED as a write scratch: rows whose
    target block is unmapped (free slots in the engine's pool) land their
    appends there, and no table ever references it, so the scatter stays
    branch-free without corrupting live blocks.  Block tables are shared
    across the layer stack (one logical->physical mapping; each layer has
    its own pool slab indexed by the same physical ids).

    ``k_scale``/``v_scale`` are present iff the pool is int8-quantized
    (``kv_bits=8``): one symmetric grid scale per (block slot, kv-head),
    laid out block-parallel with the code pool so scatter/gather, COW
    copies, and tp stripe sharding treat codes and scales uniformly.
    Writes quantize (``qserve.kvquant``), reads dequantize inside the
    attention math; fp pools carry ``None`` and keep their exact
    pre-quantization lowering.
    """
    k: jnp.ndarray            # (num_blocks, block_size, KV, Dh) pool
    v: jnp.ndarray
    block_tables: jnp.ndarray  # (B, max_blocks) int32 physical ids, -1 free
    k_scale: Optional[jnp.ndarray] = None  # (num_blocks, block_size, KV)
    v_scale: Optional[jnp.ndarray] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def init_cache(B, capacity, kv_heads, head_dim, dtype=jnp.bfloat16):
    return KVCache(
        k=jnp.zeros((B, capacity, kv_heads, head_dim), dtype),
        v=jnp.zeros((B, capacity, kv_heads, head_dim), dtype),
        slot_pos=jnp.full((B, capacity), -1, jnp.int32))


def init_paged_cache(B, num_blocks, block_size, max_blocks, kv_heads,
                     head_dim, dtype=jnp.bfloat16, kv_bits=16):
    ksc = vsc = None
    if kv_bits == 8:
        from repro.serving.qserve.kvquant import SCALE_DTYPE
        dtype = jnp.int8
        ksc = jnp.zeros((num_blocks, block_size, kv_heads), SCALE_DTYPE)
        vsc = jnp.zeros((num_blocks, block_size, kv_heads), SCALE_DTYPE)
    return PagedKVCache(
        k=jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        v=jnp.zeros((num_blocks, block_size, kv_heads, head_dim), dtype),
        block_tables=jnp.full((B, max_blocks), -1, jnp.int32),
        k_scale=ksc, v_scale=vsc)


def _pos_rows(pos, B):
    """Normalize ``pos`` (scalar or (B,)) to a (B,) int32 row-clock vector."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jnp.broadcast_to(pos.astype(jnp.int32), (B,))
    return pos.astype(jnp.int32)


def _paged_cache_write(cache: PagedKVCache, k_new, v_new, pos):
    """Paged append: row ``b`` writes ``(bt[b, pos[b]//bs], pos[b]%bs)``.

    Rows whose target logical block is unmapped (-1) write to the reserved
    scratch block 0 (never referenced by any table, so never read); live
    rows own their write block exclusively (allocator invariant), so the
    scatter indices never collide on a live block.  int8 pools quantize the
    incoming token on write (codes + per-(token, head) scale scatter)."""
    bt = cache.block_tables
    B = bt.shape[0]
    bs = cache.k.shape[1]
    posr = _pos_rows(pos, B)
    lb = posr // bs
    off = posr % bs
    rows = jnp.arange(B)
    pb = bt[rows, jnp.clip(lb, 0, bt.shape[1] - 1)]
    ok = (lb < bt.shape[1]) & (pb >= 0)
    pbs = jnp.where(ok, pb, 0)                        # scratch block 0
    # unconditional scatter: duplicate indices only ever land on the
    # scratch block (never read), so no read-back select is needed
    if cache.quantized:
        from repro.serving.qserve import kvquant as KQ
        kq, ks = KQ.quantize_kv(k_new[:, 0])          # (B,KV,Dh),(B,KV)
        vq, vs = KQ.quantize_kv(v_new[:, 0])
        return PagedKVCache(
            cache.k.at[pbs, off].set(kq), cache.v.at[pbs, off].set(vq), bt,
            cache.k_scale.at[pbs, off].set(ks),
            cache.v_scale.at[pbs, off].set(vs))
    k = cache.k.at[pbs, off].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[pbs, off].set(v_new[:, 0].astype(cache.v.dtype))
    return PagedKVCache(k, v, bt)


def _paged_cache_prefill(cache: PagedKVCache, k_all, v_all, start=0):
    """Bulk-write S tokens (a multiple of block_size, block-aligned start)
    into each row's mapped blocks.  Unmapped target blocks (rows shorter
    than S, or tables truncated at capacity) spill to the scratch block."""
    B, S = k_all.shape[:2]
    bs = cache.k.shape[1]
    assert S % bs == 0 and start % bs == 0, (S, start, bs)
    nblk = S // bs
    first = start // bs
    mb = cache.block_tables.shape[1]
    idx = jnp.clip(first + jnp.arange(nblk), 0, mb - 1)
    pb = cache.block_tables[:, idx]                   # (B, nblk)
    ok = (first + jnp.arange(nblk) < mb)[None] & (pb >= 0)
    pbs = jnp.where(ok, pb, 0).reshape(-1)            # (B*nblk,) 0=scratch

    def scat(pool, vals):
        # unmapped targets collapse onto the never-read scratch block, so
        # the scatter needs no read-back select
        vals = vals.reshape(B * nblk, bs, *vals.shape[2:]).astype(pool.dtype)
        return pool.at[pbs].set(vals)

    if cache.quantized:
        from repro.serving.qserve import kvquant as KQ
        kq, ks = KQ.quantize_kv(k_all)                # (B,S,KV,Dh),(B,S,KV)
        vq, vs = KQ.quantize_kv(v_all)

        def scat_q(pool, vals):
            vals = vals.reshape(B * nblk, bs, *vals.shape[2:])
            return pool.at[pbs].set(vals.astype(pool.dtype))
        return PagedKVCache(scat_q(cache.k, kq), scat_q(cache.v, vq),
                            cache.block_tables,
                            scat_q(cache.k_scale, ks),
                            scat_q(cache.v_scale, vs))
    return PagedKVCache(scat(cache.k, k_all), scat(cache.v, v_all),
                        cache.block_tables)


def cache_write(cache, k_new, v_new, pos):
    """Append KV for one token per row at absolute position ``pos``.

    ``pos`` is a scalar (all rows share one clock — the lockstep fast path:
    a single dynamic-update-slice, no scatter) or a (B,) vector (per-row
    clocks: each row writes its own ring slot).  Paged caches dispatch to
    the block-table scatter; the dense lowering below is unchanged."""
    if isinstance(cache, PagedKVCache):
        return _paged_cache_write(cache, k_new, v_new, pos)
    cap = cache.k.shape[1]
    B = cache.k.shape[0]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        slot = pos % cap
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
        sp = jax.lax.dynamic_update_slice_in_dim(
            cache.slot_pos,
            jnp.broadcast_to(pos.astype(jnp.int32), (B, 1)), slot, axis=1)
        return KVCache(k, v, sp)
    posr = _pos_rows(pos, B)
    slot = posr % cap                                 # (B,) per-row slots
    rows = jnp.arange(B)
    k = cache.k.at[rows, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[rows, slot].set(v_new[:, 0].astype(cache.v.dtype))
    sp = cache.slot_pos.at[rows, slot].set(posr)
    return KVCache(k, v, sp)


def cache_prefill(cache, k_all, v_all, start=0, valid_len=None):
    """Bulk-write S tokens (positions start..start+S-1); S <= capacity.

    ``valid_len`` (optional, traced): only the first ``valid_len`` of the S
    tokens are real — the rest are bucket padding whose slots stay marked
    empty (slot_pos -1) so decode masks never see them.  ``None`` keeps the
    exact pre-bucketing lowering."""
    if isinstance(cache, PagedKVCache):
        return _paged_cache_prefill(cache, k_all, v_all, start)
    S = k_all.shape[1]
    cap = cache.k.shape[1]
    B = cache.k.shape[0]
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_all.astype(cache.k.dtype), start % cap, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_all.astype(cache.v.dtype), start % cap, axis=1)
    pos_row = (start + jnp.arange(S)).astype(jnp.int32)
    if valid_len is not None:
        pos_row = jnp.where(jnp.arange(S) < valid_len, pos_row, -1)
    sp = jax.lax.dynamic_update_slice_in_dim(
        cache.slot_pos, jnp.broadcast_to(pos_row, (B, S)),
        start % cap, axis=1)
    return KVCache(k, v, sp)


def _paged_scales(cache: PagedKVCache):
    return (cache.k_scale, cache.v_scale) if cache.quantized else (None, None)


def _decode_scores(q, cache, pos, window):
    if isinstance(cache, PagedKVCache):
        ks, _ = _paged_scales(cache)
        k, mapped = PA.paged_view(cache.k, cache.block_tables, ks)
        return PA.paged_scores(q, k, mapped, _pos_rows(pos, q.shape[0]),
                               window)
    B, one, H, Dh = q.shape
    KV = cache.k.shape[2]
    rep = H // KV
    qg = (q[:, 0] * Dh ** -0.5).reshape(B, KV, rep, Dh)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg.astype(jnp.float32),
                   cache.k.astype(jnp.float32))
    posr = _pos_rows(pos, B)[:, None]                 # (B,1) row clocks
    valid = (cache.slot_pos >= 0) & (cache.slot_pos <= posr)
    if window:
        valid &= (posr - cache.slot_pos) < window
    return jnp.where(valid[:, None, None], s, NEG_INF)


def decode_attention(q, cache, pos, window: int = 0):
    """Dense decode: q (B,1,H,Dh) against the full cache -> (B,1,H,Dh).
    ``pos`` is a scalar clock or a (B,) per-row clock vector.  Paged caches
    dispatch to ``kernels.paged_attn`` (table-walking Pallas kernel on TPU,
    the exact pre-kernel XLA gather lowering elsewhere); the dense lowering
    is unchanged.
    """
    B, _, H, Dh = q.shape
    if isinstance(cache, PagedKVCache):
        ks, vs = _paged_scales(cache)
        return PA.paged_decode(q, cache.k, cache.v, cache.block_tables,
                               _pos_rows(pos, B), window=window,
                               k_scale=ks, v_scale=vs)
    v = cache.v
    s = _decode_scores(q, cache, pos, window)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p.astype(v.dtype), v)
    return o.reshape(B, 1, H, Dh)


def decode_attention_partial(q, cache, pos, window: int = 0):
    """Flash-decoding partial: softmax stats for cross-shard combination.

    Returns (o_unnorm (B,H,Dh) f32, m (B,H), l (B,H)); combine as
    ``o = psum(o_unnorm * exp(m - M)) / psum(l * exp(m - M))`` with
    ``M = pmax(m)``.
    """
    B, _, H, Dh = q.shape
    KV = cache.k.shape[2]
    rep = H // KV
    if isinstance(cache, PagedKVCache):
        ks, vs = _paged_scales(cache)
        return PA.paged_decode_partial(
            q, cache.k, cache.v, cache.block_tables, _pos_rows(pos, B),
            window=window, k_scale=ks, v_scale=vs)
    v = cache.v
    s = _decode_scores(q, cache, pos, window)        # (B,KV,rep,Lc)
    m = s.max(axis=-1)
    e = jnp.exp(s - m[..., None])
    l = e.sum(axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", e, v.astype(jnp.float32))
    return (o.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))


# --------------------------------------------------------------------------
# distribution-aware dispatchers (consult repro.dist.ctx; see DESIGN.md §4)
# --------------------------------------------------------------------------

def train_attention(q, k, v, *, window: int = 0):
    """Mode-dispatched causal attention for train/prefill.

    grouped   : KV heads divide tp -> shard KV heads (GQA-grouped einsum).
    repeated  : Q heads divide tp (KV doesn't) -> materialize repeated KV,
                shard flat Q heads (shard boundaries stay KV-group aligned).
    seq_shard : neither divides (qwen2 12H/2KV, qwen2.5 40H/8KV) ->
                shard_map: queries sequence-sharded over tp, KV all-gathered;
                zero redundant FLOPs, collectives = one KV all-gather/layer.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist import ctx as dctx
    c = dctx.get()
    if c is None:
        return causal_attention(q, k, v, window=window)
    b = c.batch_spec

    def wsc(t):
        return jax.lax.with_sharding_constraint(
            t, NamedSharding(c.mesh, P(b, None, c.tp, None)))

    if c.attn_train_mode == "grouped":
        return causal_attention(wsc(q), wsc(k), wsc(v), window=window)
    if c.attn_train_mode == "repeated":
        rep = q.shape[2] // k.shape[2]
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        return causal_attention(wsc(q), wsc(k), wsc(v), window=window)
    # seq_shard
    B, Sq = q.shape[:2]

    def local(ql, kl, vl):
        off = jax.lax.axis_index(c.tp) * ql.shape[1]
        kf = jax.lax.all_gather(kl, c.tp, axis=1, tiled=True)
        vf = jax.lax.all_gather(vl, c.tp, axis=1, tiled=True)
        return causal_attention(ql, kf, vf, window=window, q_offset=off)

    bspec = b if (B % c.dp_size == 0 and b is not None) else None
    return jax.shard_map(
        local, mesh=c.mesh,
        in_specs=(P(bspec, c.tp, None, None),) * 3,
        out_specs=P(bspec, c.tp, None, None))(q, k, v)


def serve_attention_write(q, k_new, v_new, cache, pos, *,
                          window: int = 0):
    """Mode-dispatched decode attention WITH the cache append fused in.

    ``pos`` is the per-batch cache clock: a scalar (lockstep decode) or a
    (B,) vector (continuous batching — every row at its own position).

    dense : KV heads divide tp -> cache sharded on KV heads, plain softmax;
            the append is a (local) dynamic-update-slice / row scatter.
    flash : KV-length-parallel (flash-decoding): cache sharded on the length
            dim over tp; the owning shard appends locally inside the
            shard_map (keeps the update in-place — a GSPMD-level DUS on the
            length-sharded cache was measured to copy the whole cache), then
            per-shard partial softmax + logsumexp combine.  Used when head
            counts don't divide tp, and for long-context cells.

    ``PagedKVCache`` inputs dispatch on the same modes: dense keeps the
    pool KV-head-sharded with the plain gather/scatter math; flash shards
    the pool's *block* dim over tp (contiguous logical stripes — shard t
    owns logical blocks [t*mb/T, (t+1)*mb/T), matching the dense flash
    path's contiguous length split) with the same partial-softmax combine.

    Returns (o (B,1,H,Dh), new cache of the input's kind).
    """
    from jax.sharding import PartitionSpec as P
    from repro.dist import ctx as dctx
    c = dctx.get()
    if c is None or c.attn_decode_mode == "dense":
        cache = cache_write(cache, k_new, v_new, pos)
        return decode_attention(q, cache, pos, window), cache
    if isinstance(cache, PagedKVCache):
        return _paged_flash_write(q, k_new, v_new, cache, pos, window, c)
    B = q.shape[0]
    bspec = c.batch_spec if B % c.dp_size == 0 else None
    posv = _pos_rows(pos, B)                          # (B,) row clocks

    def local(ql, knl, vnl, kl, vl, spl, posl):
        Bl, cap_l = spl.shape
        cap_total = cap_l * c.tp_size
        slot = posl % cap_total                       # (Bl,)
        my = jax.lax.axis_index(c.tp)
        start = my * cap_l
        mine = (slot >= start) & (slot < start + cap_l)
        off = jnp.clip(slot - start, 0, cap_l - 1)    # (Bl,)
        rows = jnp.arange(Bl)
        kl = kl.at[rows, off].set(
            jnp.where(mine[:, None, None], knl[:, 0].astype(kl.dtype),
                      kl[rows, off]))
        vl = vl.at[rows, off].set(
            jnp.where(mine[:, None, None], vnl[:, 0].astype(vl.dtype),
                      vl[rows, off]))
        spl = spl.at[rows, off].set(
            jnp.where(mine, posl.astype(jnp.int32), spl[rows, off]))
        o, m, l = decode_attention_partial(
            ql, KVCache(kl, vl, spl), posl, window)
        M = jax.lax.pmax(m, c.tp)
        w = jnp.exp(m - M)
        o = jax.lax.psum(o * w[..., None], c.tp)
        l = jax.lax.psum(l * w, c.tp)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out[:, None].astype(vl.dtype), kl, vl, spl

    o, kk, vv, sp = jax.shard_map(
        local, mesh=c.mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec, c.tp, None, None), P(bspec, c.tp, None, None),
                  P(bspec, c.tp), P(bspec)),
        out_specs=(P(bspec, None, None, None),
                   P(bspec, c.tp, None, None), P(bspec, c.tp, None, None),
                   P(bspec, c.tp)))(
        q, k_new, v_new, cache.k, cache.v, cache.slot_pos, posv)
    return o, KVCache(kk, vv, sp)


def _paged_flash_write(q, k_new, v_new, cache: PagedKVCache, pos, window, c):
    """Block-parallel flash decoding over a tp-sharded paged pool.

    The pool's block dim and the table's logical-block dim are both split
    contiguously over tp, and the allocator guarantees the *stripe
    invariant*: the physical block backing logical block ``lb`` is drawn
    from pool partition ``lb // (max_blocks/T)``, so every shard's table
    slice references only its local pool slab.  Each shard appends the
    incoming token if it owns the target block (local physical block 0 is
    its reserved scratch otherwise), gathers only its own stripe, and the
    partial softmax stats combine with the same logsumexp psum as the dense
    flash path.  Per-shard HBM, gather traffic, and score FLOPs all drop by
    T (the trade: early blocks — short rows — concentrate on low shards,
    exactly like the dense flash path's contiguous length split).
    """
    from jax.sharding import PartitionSpec as P
    B, _, H, Dh = q.shape
    KV = cache.k.shape[2]
    rep = H // KV
    bspec = c.batch_spec if B % c.dp_size == 0 else None
    posv = _pos_rows(pos, B)
    quant = cache.quantized

    def local(ql, knl, vnl, kl, vl, btl, posl, *sc):
        Bl, mbl = btl.shape
        nbl, bs = kl.shape[0], kl.shape[1]
        my = jax.lax.axis_index(c.tp)
        blk0 = my * nbl                   # my physical-id range start
        pos0 = my * mbl * bs              # absolute position of my stripe
        rows = jnp.arange(Bl)
        # ---- append: only the shard owning logical block pos//bs writes
        lb = posl // bs - my * mbl        # logical block, stripe-local
        off = posl % bs
        pb = btl[rows, jnp.clip(lb, 0, mbl - 1)] - blk0
        ok = (lb >= 0) & (lb < mbl) & (pb >= 0) & (pb < nbl)
        pbs = jnp.where(ok, pb, 0)        # local block 0 = shard scratch
        # non-owner rows collapse onto the shard's scratch block (never
        # read), so the scatter needs no read-back select
        if quant:
            from repro.serving.qserve import kvquant as KQ
            kscl, vscl = sc
            kq, ks = KQ.quantize_kv(knl[:, 0])
            vq, vs = KQ.quantize_kv(vnl[:, 0])
            kl = kl.at[pbs, off].set(kq)
            vl = vl.at[pbs, off].set(vq)
            kscl = kscl.at[pbs, off].set(ks)
            vscl = vscl.at[pbs, off].set(vs)
        else:
            kscl = vscl = None
            kl = kl.at[pbs, off].set(knl[:, 0].astype(kl.dtype))
            vl = vl.at[pbs, off].set(vnl[:, 0].astype(vl.dtype))
        # ---- partials over my stripe only: localize the table (foreign
        # blocks -> -1) and shift the row clocks by my stripe's base
        # position; integer masks make the shifted form exact, and fully
        # foreign garbage is nulled bit-exactly by the psum combine weights
        btl_local = jnp.where((btl >= blk0) & (btl < blk0 + nbl),
                              btl - blk0, -1)
        o, m, l = PA.paged_decode_partial(
            ql, kl, vl, btl_local, posl, window=window,
            k_scale=kscl, v_scale=vscl, pos_offset=pos0)
        M = jax.lax.pmax(m, c.tp)
        w = jnp.exp(m - M)
        o = jax.lax.psum(o * w[..., None], c.tp)
        l = jax.lax.psum(l * w, c.tp)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(Bl, 1, H, Dh)
        out = out.astype(q.dtype if quant else vl.dtype)
        return (out, kl, vl) + ((kscl, vscl) if quant else ())

    pool = P(c.tp, None, None, None)
    in_specs = (P(bspec, None, None, None),
                P(bspec, None, None, None), P(bspec, None, None, None),
                pool, pool, P(bspec, c.tp), P(bspec))
    out_specs = (P(bspec, None, None, None), pool, pool)
    args = (q, k_new, v_new, cache.k, cache.v, cache.block_tables, posv)
    if quant:
        scp = P(c.tp, None, None)
        in_specs += (scp, scp)
        out_specs += (scp, scp)
        args += (cache.k_scale, cache.v_scale)
    res = jax.shard_map(local, mesh=c.mesh, in_specs=in_specs,
                        out_specs=out_specs)(*args)
    o, kk, vv = res[:3]
    sc = res[3:] if quant else (None, None)
    return o, PagedKVCache(kk, vv, cache.block_tables, *sc)
