"""Unified model: one class covering all 10 assigned families.

Layer stacks are scanned (``jax.lax.scan`` over stacked params) so compile
time is depth-independent — required for 96-layer nemotron on the dry-run.

Stack layouts:
  * uniform: every layer identical -> single scan.
  * grouped-local (gemma3): groups of (global_every-1) sliding-window layers
    + 1 global layer; local layers get ring-buffer KV caches of length
    ``local_window`` (a large serving-memory win), globals get full caches.
  * hybrid (zamba2): groups of ``shared_attn_every`` Mamba2 layers + one
    invocation of a weight-shared attention block (per-invocation input
    projection concatenates the residual stream with the embedding stream).
  * ssm (rwkv6): uniform RWKV6 blocks.

Batch dict: {"tokens": (B,S) int32[, "frontend": (B,F,d) or (B,S,d),
"loss_mask": (B,S)]}.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rwkv6 as R
from repro.models import ssm_mamba2 as S


def _layer_init(key, cfg, dtype, is_global=True):
    ks = jax.random.split(key, 4)
    p = {"ln1": L.norm_init(cfg.norm, cfg.d_model, dtype),
         "ln2": L.norm_init(cfg.norm, cfg.d_model, dtype),
         "attn": A.attn_init(ks[0], cfg, dtype)}
    if cfg.family == "moe" and cfg.moe is not None:
        p["moe"] = M.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg, dtype=dtype)
    return p


def _constrain_hidden(x):
    """Residual-stream sharding constraint (batch over dp; optionally the
    sequence dim over tp = Megatron-style sequence parallelism, which also
    bounds the remat-saved activations)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist import ctx as dctx
    c = dctx.get()
    if c is None:
        return x
    spec = [c.batch_spec] + [None] * (x.ndim - 1)
    if c.hidden_seq_shard and x.ndim == 3 and x.shape[1] % c.tp_size == 0 \
            and x.shape[1] > 1:
        spec[1] = c.tp
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(c.mesh, P(*spec)))


def _maybe_remat(fn):
    from repro.dist import ctx as dctx
    c = dctx.get()
    return jax.checkpoint(fn) if (c is not None and c.remat) else fn


def _scan_with_state(body, x, params_stack, state_stack, length):
    """Scan over a layer stack, carrying `state_stack` (KV caches / SSM
    states) through the loop CARRY with in-place dynamic updates.

    Passing caches as scan xs/ys double-buffers them (ys is a fresh stacked
    allocation — 2x cache memory per decode step); carry buffers alias
    in-place through the while loop.  body(x, layer_params, state_i) ->
    (x, new_state_i)."""
    if length == 0:
        return x, state_stack

    def f(carry, inp):
        xc, st = carry
        lp, i = inp
        st_i = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
            st)
        xc, st_new = body(xc, lp, st_i)
        st = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), i, 0), st, st_new)
        return (xc, st), None

    (x, state_stack), _ = jax.lax.scan(
        f, (x, state_stack), (params_stack, jnp.arange(length)))
    return x, state_stack


# Paged KV caches scan their pool slabs as a plain (k, v[, k_scale,
# v_scale]) carry while the (shared, host-managed) block table rides
# outside the loop; these helpers express that rebinding rule once for
# every decode path.  int8 pools (``kv_bits=8``) carry their per-token
# scale planes as two extra tuple entries — the tuple length is static per
# trace, so both layouts lower through the same scan.
# ``bt is None`` means "this cache is dense" throughout.
def _paged_kv_state(kvc):
    """Cache node -> scan-carry state."""
    if not isinstance(kvc, A.PagedKVCache):
        return kvc
    if kvc.quantized:
        return (kvc.k, kvc.v, kvc.k_scale, kvc.v_scale)
    return (kvc.k, kvc.v)


def _paged_kv_in(st, bt):
    """Scan carry -> the per-layer cache view _layer_apply consumes."""
    return A.PagedKVCache(st[0], st[1], bt, *st[2:]) if bt is not None \
        else st


def _paged_kv_out(kv, bt):
    """_layer_apply's new cache -> scan carry."""
    return _paged_kv_state(kv) if bt is not None else kv


def _paged_kv_rebuild(kvs, bt):
    """Post-scan stacked carry -> the cache node handed back to callers."""
    return A.PagedKVCache(kvs[0], kvs[1], bt, *kvs[2:]) if bt is not None \
        else kvs


def _paged_tables(kvc, block_tables):
    """The table to thread this step: the per-tick override when given,
    else the cache-resident fallback; None for dense caches."""
    if not isinstance(kvc, A.PagedKVCache):
        return None
    return kvc.block_tables if block_tables is None else block_tables


def _paged_store_tables(kvc):
    """The table to store in the cache handed back to callers: always the
    cache-resident one.  The per-tick override is a compute-only view — the
    serving engine narrows it to the live-block bucket (fewer gathered
    blocks per decode step), so persisting it would shrink the cache leaf
    shapes across jit ticks and break donation."""
    return kvc.block_tables if isinstance(kvc, A.PagedKVCache) else None


def _layer_apply(p, x, cfg, *, positions, window, kv=None, pos=None,
                 mode="train"):
    """One transformer layer.  mode: train/prefill use full-seq attention;
    decode uses the cache.  Returns (x, new_kv or (k,v))."""
    x = _constrain_hidden(x)
    h = L.norm(p["ln1"], x)
    q, k, v = A.qkv_project(p["attn"], h, cfg, positions)
    if mode == "decode":
        o, kv = A.serve_attention_write(q, k, v, kv, pos, window=window)
        new_kv = kv
    else:
        o = A.train_attention(q, k, v, window=window)
        new_kv = (k, v)
    B, Sq = x.shape[:2]
    o = o.reshape(B, Sq, -1)
    x = x + L.linear(p["attn"]["wo"], o, kind="row")
    h = L.norm(p["ln2"], x)
    if "moe" in p:
        x = x + M.moe_apply(p["moe"], h, cfg)
    else:
        x = x + L.mlp(p["mlp"], h, cfg.mlp)
    return x, new_kv


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------------------------------------------------------- init
    def init(self, key, dtype=jnp.float32):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params: Dict[str, Any] = {"embed": L.embed_init(ks[0], cfg.vocab,
                                                        cfg.d_model, dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = L.linear_init(ks[1], cfg.d_model, cfg.vocab,
                                              dtype=dtype)
        params["final_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)

        if cfg.family == "ssm":
            def one(k):
                return R.rwkv_init(k, cfg, dtype)
            params["layers"] = jax.vmap(one)(
                jax.random.split(ks[2], cfg.n_layers))
        elif cfg.family == "hybrid":
            k = cfg.shared_attn_every
            ng, tail = cfg.n_layers // k, cfg.n_layers % k
            def one(kk):
                return S.mamba_init(kk, cfg, dtype)
            params["groups"] = jax.vmap(
                lambda kk: jax.vmap(one)(jax.random.split(kk, k)))(
                    jax.random.split(ks[2], ng))
            if tail:
                params["tail"] = jax.vmap(one)(jax.random.split(ks[3], tail))
            # weight-shared attention block + per-invocation in-proj
            params["shared"] = _layer_init(ks[4], cfg, dtype)
            params["shared_in"] = jax.vmap(
                lambda kk: L.linear_init(kk, 2 * cfg.d_model, cfg.d_model,
                                         dtype=dtype))(
                jax.random.split(ks[5], ng))
        elif self._grouped_local():
            ge = cfg.global_every
            ng, tail = cfg.n_layers // ge, cfg.n_layers % ge
            def one(kk, g):
                return _layer_init(kk, cfg, dtype, is_global=g)
            params["groups"] = {
                "local": jax.vmap(lambda kk: jax.vmap(
                    lambda k2: one(k2, False))(jax.random.split(kk, ge - 1)))(
                        jax.random.split(ks[2], ng)),
                "global": jax.vmap(lambda kk: one(kk, True))(
                    jax.random.split(ks[3], ng)),
            }
            if tail:
                params["tail"] = jax.vmap(lambda kk: one(kk, False))(
                    jax.random.split(ks[4], tail))
        else:
            params["layers"] = jax.vmap(
                lambda kk: _layer_init(kk, cfg, dtype))(
                jax.random.split(ks[2], cfg.n_layers))
        return params

    def abstract_params(self, dtype=jnp.float32):
        return jax.eval_shape(lambda k: self.init(k, dtype),
                              jax.random.PRNGKey(0))

    def _grouped_local(self):
        return self.cfg.local_window > 0 and self.cfg.global_every > 0

    # ------------------------------------------------------------- embed/out
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio":
            x = batch["frontend"]                        # (B,S,d) frames
        elif cfg.family == "vlm":
            fe = batch["frontend"]                       # (B,F,d)
            te = L.embed(params["embed"], batch["tokens"])  # (B,S-F,d)
            x = jnp.concatenate([fe.astype(te.dtype), te], axis=1)
        else:
            x = L.embed(params["embed"], batch["tokens"])
        return x

    def _logits(self, params, h):
        from repro.dist import ctx as dctx
        cfg = self.cfg
        h = L.norm(params["final_norm"], h)
        logits = L.unembed(params["embed"], params.get("lm_head"), h,
                           cfg.tie_embeddings)
        # keep logits vocab-sharded over tp — without this, GSPMD replicates
        # the (huge) unembedding and the CE-loss intermediates (measured on
        # gemma3: 5.25 GiB table x31 copies; see EXPERIMENTS.md §Perf).
        # Non-divisible vocabs (granite 49155) shard the sequence dim instead.
        vspec = dctx.tp_if(cfg.vocab)
        sspec = dctx.tp_if(logits.shape[1]) if vspec is None else None
        logits = dctx.wsc(logits, "b", sspec, vspec)
        return L.softcap(logits, cfg.logit_softcap)

    # ---------------------------------------------------------------- apply
    def apply(self, params, batch, capture: bool = False):
        """Full-sequence forward -> (logits (B,S,V), aux)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        B, Stot, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(Stot)[None], (B, Stot))
        if cfg.pos == "sinusoidal":
            x = x + L.sinusoidal(positions, cfg.d_model, x.dtype)
        aux: Dict[str, Any] = {}

        if cfg.family == "ssm":
            st0 = R.init_state(B, cfg, x.dtype)

            def body(xc, lp):
                xc = _constrain_hidden(xc)
                xc, _ = R.rwkv_block(lp, xc, cfg, st0)
                return xc, None
            x, _ = jax.lax.scan(_maybe_remat(body), x, params["layers"])
        elif cfg.family == "hybrid":
            x = self._hybrid_forward(params, x, positions, mode="train")[0]
        elif self._grouped_local():
            x = self._grouped_forward(params, x, positions)
        else:
            def body(xc, lp):
                xc2, _ = _layer_apply(lp, xc, cfg, positions=positions,
                                      window=0)
                ys = self._capture_grams(lp, xc, positions) if capture else None
                return xc2, ys

            x, caps = jax.lax.scan(_maybe_remat(body), x, params["layers"])
            if capture:
                aux["xtx"] = caps
        logits = self._logits(params, x)
        return logits, aux

    def _capture_grams(self, lp, x_in, positions):
        """Gram matrices sum x x^T of every linear input in this layer
        (output-agnostic Hessians for the OPTQ/SpQR baselines).  Recomputes
        the layer's intermediates from x_in (toy-scale calibration only)."""
        cfg = self.cfg

        def gram(t):
            f = t.reshape(-1, t.shape[-1]).astype(jnp.float32)
            return f.T @ f

        h1 = L.norm(lp["ln1"], x_in)
        caps = {"attn_in": gram(h1)}
        q, k, v = A.qkv_project(lp["attn"], h1, cfg, positions)
        o = A.causal_attention(q, k, v, window=0)
        B, Sq = x_in.shape[:2]
        o = o.reshape(B, Sq, -1)
        caps["wo_in"] = gram(o)
        x_mid = x_in + L.linear(lp["attn"]["wo"], o, kind="row")
        h2 = L.norm(lp["ln2"], x_mid)
        caps["mlp_in"] = gram(h2)
        if "mlp" in lp:
            if "wg" in lp["mlp"]:
                act = jax.nn.silu if cfg.mlp == "swiglu" else \
                    (lambda t: jax.nn.gelu(t, approximate=True))
                hmid = act(L.linear(lp["mlp"]["wg"], h2)) * \
                    L.linear(lp["mlp"]["wi"], h2)
            else:
                hm = L.linear(lp["mlp"]["wi"], h2)
                hmid = jnp.square(jax.nn.relu(hm)) if cfg.mlp == "relu2" \
                    else jax.nn.gelu(hm, approximate=True)
            caps["mlp_out_in"] = gram(hmid)
        return caps

    # ---------------------------------------------- grouped-local forward
    def _grouped_forward(self, params, x, positions):
        cfg = self.cfg
        w = cfg.local_window

        def local_body(xc, lp):
            xc, _ = _layer_apply(lp, xc, cfg, positions=positions, window=w)
            return xc, None

        def group_body(xc, gp):
            xc, _ = jax.lax.scan(_maybe_remat(local_body), xc, gp["local"])
            xc, _ = _layer_apply(gp["global"], xc, cfg, positions=positions,
                                 window=0)
            return xc, None

        x, _ = jax.lax.scan(_maybe_remat(group_body), x, params["groups"])
        if "tail" in params:
            x, _ = jax.lax.scan(_maybe_remat(local_body), x, params["tail"])
        return x

    # ---------------------------------------------------- hybrid forward
    def _hybrid_forward(self, params, x, positions, mode, caches=None,
                        pos=None, block_tables=None):
        cfg = self.cfg
        x0 = x  # embedding stream fed to every shared-attn invocation

        def mamba_train(xc, lp):
            xc = _constrain_hidden(xc)
            y, st2 = S.mamba_apply(lp, xc, cfg)
            return xc + y, st2

        def mamba_decode(xc, lp, st):
            y, st2 = S.mamba_step(lp, xc, st, cfg)
            return xc + y, st2

        new_states = {}
        k = cfg.shared_attn_every

        if mode == "decode":
            bt = _paged_tables(caches["kv"], block_tables)

            def group_body(xc, gpin, st):
                gp, gin = gpin
                mst, kv = st
                xc, msts = _scan_with_state(mamba_decode, xc, gp, mst, k)
                a_in = L.linear(gin, jnp.concatenate([xc, x0], axis=-1))
                a_out, kv2 = _layer_apply(params["shared"], a_in, cfg,
                                          positions=positions, window=0,
                                          kv=_paged_kv_in(kv, bt),
                                          pos=pos, mode="decode")
                xc = xc + (a_out - a_in)  # _layer_apply adds its residual
                return xc, (msts, _paged_kv_out(kv2, bt))

            ng = cfg.n_layers // k
            x, (mg, kvs) = _scan_with_state(
                group_body, x, (params["groups"], params["shared_in"]),
                (caches["mamba_g"], _paged_kv_state(caches["kv"])), ng)
            new_states["mamba_g"] = mg
            new_states["kv"] = _paged_kv_rebuild(
                kvs, _paged_store_tables(caches["kv"]))
            if "tail" in params:
                x, mt = _scan_with_state(mamba_decode, x, params["tail"],
                                         caches["mamba_t"],
                                         cfg.n_layers % k)
                new_states["mamba_t"] = mt
        else:
            def group_body(xc, inp):
                gp, gin = inp
                xc, _ = jax.lax.scan(mamba_train, xc, gp)
                a_in = L.linear(gin, jnp.concatenate([xc, x0], axis=-1))
                a_out, _ = _layer_apply(params["shared"], a_in, cfg,
                                        positions=positions, window=0)
                xc = xc + (a_out - a_in)
                return xc, None

            x, _ = jax.lax.scan(_maybe_remat(group_body), x,
                                (params["groups"], params["shared_in"]))
            if "tail" in params:
                def tail_body(xc, lp):
                    return mamba_train(xc, lp)
                x, _ = jax.lax.scan(_maybe_remat(tail_body), x,
                                    params["tail"])
        return x, new_states

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch):
        """Next-token CE (the paper's L_CE; frontend positions masked)."""
        cfg = self.cfg
        logits, _ = self.apply(params, batch)
        tokens = batch["tokens"]
        if cfg.family == "vlm":
            F = logits.shape[1] - tokens.shape[1]
            logits = logits[:, F:]                     # text positions only
        # sharding-friendly CE: no gather over the (tp-sharded) vocab dim —
        # the one-hot mask fuses into the reduction (no (B,S,V) materializes)
        lg = logits[:, :-1].astype(jnp.float32)
        tgt = tokens[:, 1:]
        lse = jax.nn.logsumexp(lg, axis=-1)
        vocab_iota = jnp.arange(lg.shape[-1], dtype=tgt.dtype)
        tgt_logit = jnp.sum(
            jnp.where(vocab_iota[None, None, :] == tgt[..., None], lg, 0.0),
            axis=-1)
        nll = lse - tgt_logit
        mask = batch.get("loss_mask")
        if mask is not None:
            m = mask[:, 1:].astype(jnp.float32)
            return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
        return nll.mean()

    # ---------------------------------------------------------------- cache
    def init_cache(self, B, capacity, dtype=jnp.bfloat16, abstract=False,
                   paged=False, block_size=16, num_blocks=None, kv_bits=16):
        """Decode-state pytree.  ``paged=True`` swaps every *full-context*
        KV cache for a ``PagedKVCache`` pool (``num_blocks`` physical blocks
        of ``block_size`` tokens; block 0 reserved as the write scratch)
        with a shared ``(B, capacity // block_size)`` block table.
        ``kv_bits=8`` (paged only) stores the pool as int8 codes plus
        per-(token, kv-head) scale planes (``qserve.kvquant``) — writes
        quantize, attention dequantizes on read, KV HBM drops ~2x vs fp16.

        What stays dense under ``paged``:
          * SSM / RWKV / Mamba state — it is O(1) per row (a fixed-size
            recurrent summary, not a per-token log), so there is nothing to
            page: block tables map *positions* to storage, and recurrent
            state has no position axis.
          * grouped-local sliding-window rings — bounded at ``local_window``
            tokens per row by construction; paging a fixed small ring buys
            no memory and costs a gather per layer.
        Only the unbounded full-attention caches (the actual O(context)
        memory) go through the pool."""
        if kv_bits != 16 and not paged:
            raise ValueError("kv_bits=8 requires the paged block pool "
                             "(dense rings keep their fp lowering)")
        cfg = self.cfg
        hd = cfg.resolved_head_dim

        def mk(*shape, dt=dtype):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dt)
            return jnp.zeros(shape, dt)

        def kv(n, cap):
            # slot_pos carries a per-row cache clock (see attention.KVCache)
            sp = jnp.full((n, B, cap), -1, jnp.int32) if not abstract else \
                jax.ShapeDtypeStruct((n, B, cap), jnp.int32)
            return A.KVCache(mk(n, B, cap, cfg.n_kv_heads, hd),
                             mk(n, B, cap, cfg.n_kv_heads, hd), sp)

        if paged:
            assert capacity % block_size == 0, (capacity, block_size)
            assert kv_bits in (16, 8), kv_bits
            mb = capacity // block_size
            nb = num_blocks if num_blocks is not None else B * mb + 1
            pool_dt = dtype if kv_bits == 16 else jnp.int8

            def paged_kv(n):
                bt = jnp.full((B, mb), -1, jnp.int32) if not abstract else \
                    jax.ShapeDtypeStruct((B, mb), jnp.int32)
                ksc = vsc = None
                if kv_bits == 8:
                    from repro.serving.qserve.kvquant import SCALE_DTYPE
                    ksc = mk(n, nb, block_size, cfg.n_kv_heads,
                             dt=SCALE_DTYPE)
                    vsc = mk(n, nb, block_size, cfg.n_kv_heads,
                             dt=SCALE_DTYPE)
                return A.PagedKVCache(
                    mk(n, nb, block_size, cfg.n_kv_heads, hd, dt=pool_dt),
                    mk(n, nb, block_size, cfg.n_kv_heads, hd, dt=pool_dt),
                    bt, ksc, vsc)

        if cfg.family == "ssm":
            Lh = cfg.n_layers
            H = cfg.d_model // cfg.rwkv.head_size
            return {"state": R.RWKVState(
                mk(Lh, B, H, cfg.rwkv.head_size, cfg.rwkv.head_size,
                   dt=jnp.float32),
                mk(Lh, B, cfg.d_model), mk(Lh, B, cfg.d_model))}
        if cfg.family == "hybrid":
            k = cfg.shared_attn_every
            ng, tail = cfg.n_layers // k, cfg.n_layers % k
            d_in, nH, conv_ch = S.dims(cfg)
            s = cfg.ssm
            out = {"mamba_g": S.MambaState(
                mk(ng, k, B, s.d_conv - 1, conv_ch),
                mk(ng, k, B, nH, s.head_dim, s.d_state, dt=jnp.float32)),
                "kv": paged_kv(ng) if paged else kv(ng, capacity)}
            if tail:
                out["mamba_t"] = S.MambaState(
                    mk(tail, B, s.d_conv - 1, conv_ch),
                    mk(tail, B, nH, s.head_dim, s.d_state, dt=jnp.float32))
            return out
        if self._grouped_local():
            ge = cfg.global_every
            ng, tail = cfg.n_layers // ge, cfg.n_layers % ge
            wcap = min(capacity, cfg.local_window)
            lsp = jnp.full((ng, ge - 1, B, wcap), -1, jnp.int32) \
                if not abstract \
                else jax.ShapeDtypeStruct((ng, ge - 1, B, wcap), jnp.int32)
            out = {"local": A.KVCache(
                mk(ng, ge - 1, B, wcap, cfg.n_kv_heads, hd),
                mk(ng, ge - 1, B, wcap, cfg.n_kv_heads, hd), lsp),
                "global": paged_kv(ng) if paged else kv(ng, capacity)}
            if tail:
                out["tail"] = kv(tail, wcap)
            return out
        if paged:
            return {"kv": paged_kv(cfg.n_layers)}
        return {"kv": kv(cfg.n_layers, capacity)}

    # --------------------------------------------------------------- decode
    def decode_step(self, params, tokens, cache, pos, block_tables=None):
        """One serving step: tokens (B,1) -> (logits (B,1,V), new cache).

        ``pos`` is the absolute position of the incoming token (cache holds
        positions < pos) — a scalar when the whole batch decodes in lockstep,
        or a (B,) vector clock when every row runs at its own position
        (continuous batching).

        ``block_tables`` (optional, (B, max_blocks) int32) overrides the
        table leaf of every paged cache in the pytree: the serving engine's
        allocator is host-side, so it passes the current logical->physical
        mapping per tick (the cache-resident table is a self-contained
        fallback for direct callers and the dry-run decode cells).  One
        table serves the whole layer stack."""
        cfg = self.cfg
        if cfg.family == "audio":
            # frames arrive as embeddings even in decode (stub frontend)
            x = tokens if tokens.ndim == 3 else \
                L.embed(params["embed"], tokens)
        else:
            x = L.embed(params["embed"], tokens)
        B = x.shape[0]
        pos_arr = jnp.asarray(pos)
        positions = jnp.broadcast_to(pos_arr, (B, 1)) if pos_arr.ndim == 0 \
            else pos_arr[:, None]                      # (B,1) row clocks
        if cfg.pos == "sinusoidal":
            x = x + L.sinusoidal(positions, cfg.d_model, x.dtype)

        if cfg.family == "ssm":
            def body(xc, lp, st):
                return R.rwkv_block(lp, xc, cfg, st)
            # rwkv_block consumes (B,S,d); S=1 works through the scan
            x, states = _scan_with_state(body, x, params["layers"],
                                         cache["state"], cfg.n_layers)
            new_cache = {"state": states}
        elif cfg.family == "hybrid":
            x, ns = self._hybrid_forward(params, x, positions, mode="decode",
                                         caches=cache, pos=pos,
                                         block_tables=block_tables)
            new_cache = ns
        elif self._grouped_local():
            x, new_cache = self._grouped_decode(params, x, positions, cache,
                                                pos, block_tables)
        else:
            bt = _paged_tables(cache["kv"], block_tables)

            def body(xc, lp, st):
                xc, kv2 = _layer_apply(
                    lp, xc, cfg, positions=positions, window=0,
                    kv=_paged_kv_in(st, bt), pos=pos, mode="decode")
                return xc, _paged_kv_out(kv2, bt)
            x, kvs = _scan_with_state(body, x, params["layers"],
                                      _paged_kv_state(cache["kv"]),
                                      cfg.n_layers)
            new_cache = {"kv": _paged_kv_rebuild(
                kvs, _paged_store_tables(cache["kv"]))}
        return self._logits(params, x), new_cache

    def _grouped_decode(self, params, x, positions, cache, pos,
                        block_tables=None):
        cfg = self.cfg
        w = cfg.local_window
        ge = cfg.global_every
        bt = _paged_tables(cache["global"], block_tables)

        def local_body(xc, lp, kvc):
            return _layer_apply(lp, xc, cfg, positions=positions,
                                window=w, kv=kvc, pos=pos, mode="decode")

        def group_body(xc, gp, st):
            lkv, gkv = st
            xc, lkv2 = _scan_with_state(local_body, xc, gp["local"], lkv,
                                        ge - 1)
            xc, gkv2 = _layer_apply(gp["global"], xc, cfg,
                                    positions=positions, window=0,
                                    kv=_paged_kv_in(gkv, bt),
                                    pos=pos, mode="decode")
            return xc, (lkv2, _paged_kv_out(gkv2, bt))

        ng = cfg.n_layers // ge
        x, (lkvs, gkvs) = _scan_with_state(
            group_body, x, params["groups"],
            (cache["local"], _paged_kv_state(cache["global"])), ng)
        new_cache = {"local": lkvs, "global": _paged_kv_rebuild(
            gkvs, _paged_store_tables(cache["global"]))}
        if "tail" in params:
            x, tkv = _scan_with_state(local_body, x, params["tail"],
                                      cache["tail"], cfg.n_layers % ge)
            new_cache["tail"] = tkv
        return x, new_cache

    # -------------------------------------------------------------- prefill
    def prefill(self, params, batch, cache, valid_len=None):
        """Full-prompt forward that also fills the KV caches.

        Implemented as apply() for the hidden states plus bulk cache writes;
        returns (logits of last valid position, cache, n_prompt).

        ``valid_len`` (traced scalar) enables *bucketed* prefill: the batch
        is padded to a bucket length, only the first ``valid_len`` tokens
        are real.  Causal masking makes every valid position's output
        bit-identical to an unpadded run (pad keys are never attended by
        valid queries, and the online-softmax accumulates exact zeros for
        masked slots), pad cache slots stay marked empty, and the returned
        logits are taken at ``valid_len - 1``.  Rejected for recurrent
        families (ssm/hybrid): their prefill threads state *through* every
        position, so pad tokens would poison the carried state."""
        cfg = self.cfg
        if valid_len is not None and cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"bucketed prefill (valid_len) is unsupported for the "
                f"recurrent-state family {cfg.family!r}: padding corrupts "
                f"the carried SSM/RWKV state")
        x = self._embed_in(params, batch)
        B, Stot, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(Stot)[None], (B, Stot))
        if cfg.pos == "sinusoidal":
            x = x + L.sinusoidal(positions, cfg.d_model, x.dtype)

        if cfg.family == "ssm":
            def body(xc, lp, st):
                return R.rwkv_block(lp, xc, cfg, st)
            x, states = _scan_with_state(body, x, params["layers"],
                                         cache["state"], cfg.n_layers)
            new_cache = {"state": states}
        elif cfg.family == "hybrid":
            x, ns = self._hybrid_prefill(params, x, positions, cache)
            new_cache = ns
        elif self._grouped_local():
            x, new_cache = self._grouped_prefill(params, x, positions,
                                                 cache, valid_len)
        else:
            def body(xc, lp, kvc):
                h = L.norm(lp["ln1"], xc)
                q, k, v = A.qkv_project(lp["attn"], h, cfg, positions)
                kv2 = A.cache_prefill(kvc, k, v, valid_len=valid_len)
                o = A.train_attention(q, k, v, window=0)
                xc = xc + L.linear(lp["attn"]["wo"],
                                   o.reshape(B, Stot, -1), kind="row")
                h = L.norm(lp["ln2"], xc)
                if "moe" in lp:
                    xc = xc + M.moe_apply(lp["moe"], h, cfg)
                else:
                    xc = xc + L.mlp(lp["mlp"], h, cfg.mlp)
                return xc, kv2
            x, kvs = _scan_with_state(body, x, params["layers"],
                                      cache["kv"], cfg.n_layers)
            new_cache = {"kv": kvs}
        if valid_len is None:
            xl = x[:, -1:]
        else:
            xl = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
        logits = self._logits(params, xl)
        return logits, new_cache, Stot

    def _grouped_prefill(self, params, x, positions, cache, valid_len=None):
        cfg = self.cfg
        B, Stot, _ = x.shape
        w = cfg.local_window

        def fill_local(lp, xc, kvc):
            h = L.norm(lp["ln1"], xc)
            q, k, v = A.qkv_project(lp["attn"], h, cfg, positions)
            # ring cache keeps only the last min(valid, wcap) positions at
            # slot = pos % wcap (matching cache_write's ring discipline)
            wcap = kvc.k.shape[1]
            if valid_len is None:
                n = min(Stot, wcap)
                start = Stot - n
                parr = (start + jnp.arange(n)).astype(jnp.int32)
                slots = parr % wcap
                kv2 = A.KVCache(
                    kvc.k.at[:, slots].set(k[:, -n:].astype(kvc.k.dtype)),
                    kvc.v.at[:, slots].set(v[:, -n:].astype(kvc.v.dtype)),
                    kvc.slot_pos.at[:, slots].set(parr[None]))
            else:
                # traced valid_len: take the wcap positions ending at
                # valid_len-1 (idx < 0 -> slot marked empty); idx covers
                # wcap consecutive ints so idx % wcap is a permutation
                idx = valid_len - wcap + jnp.arange(wcap)
                kw = jnp.take(k, jnp.clip(idx, 0, Stot - 1), axis=1)
                vw = jnp.take(v, jnp.clip(idx, 0, Stot - 1), axis=1)
                slots = idx % wcap
                sp = jnp.where(idx >= 0, idx, -1).astype(jnp.int32)
                kv2 = A.KVCache(
                    kvc.k.at[:, slots].set(kw.astype(kvc.k.dtype)),
                    kvc.v.at[:, slots].set(vw.astype(kvc.v.dtype)),
                    kvc.slot_pos.at[:, slots].set(
                        jnp.broadcast_to(sp, (B, wcap))))
            o = A.train_attention(q, k, v, window=w)
            xc = xc + L.linear(lp["attn"]["wo"], o.reshape(B, Stot, -1),
                               kind="row")
            h = L.norm(lp["ln2"], xc)
            xc = xc + L.mlp(lp["mlp"], h, cfg.mlp)
            return xc, kv2

        def fill_global(lp, xc, kvc):
            h = L.norm(lp["ln1"], xc)
            q, k, v = A.qkv_project(lp["attn"], h, cfg, positions)
            kv2 = A.cache_prefill(kvc, k, v, valid_len=valid_len)
            o = A.train_attention(q, k, v, window=0)
            xc = xc + L.linear(lp["attn"]["wo"], o.reshape(B, Stot, -1),
                               kind="row")
            h = L.norm(lp["ln2"], xc)
            xc = xc + L.mlp(lp["mlp"], h, cfg.mlp)
            return xc, kv2

        def local_body2(xc, lp, kvc):
            return fill_local(lp, xc, kvc)

        ge = cfg.global_every

        def group_body(xc, gp, st):
            lkv, gkv = st
            xc, lkv2 = _scan_with_state(local_body2, xc, gp["local"], lkv,
                                        ge - 1)
            xc, gkv2 = fill_global(gp["global"], xc, gkv)
            return xc, (lkv2, gkv2)

        x, (lkvs, gkvs) = _scan_with_state(
            group_body, x, params["groups"],
            (cache["local"], cache["global"]), cfg.n_layers // ge)
        new_cache = {"local": lkvs, "global": gkvs}
        if "tail" in params:
            x, tkv = _scan_with_state(local_body2, x, params["tail"],
                                      cache["tail"], cfg.n_layers % ge)
            new_cache["tail"] = tkv
        return x, new_cache

    def _hybrid_prefill(self, params, x, positions, cache):
        cfg = self.cfg
        x0 = x

        def mamba_body(xc, lp):
            y, st = S.mamba_apply(lp, xc, cfg)
            return xc + y, st

        kk = cfg.shared_attn_every

        def group_body(xc, gpin, st):
            gp, gin = gpin
            _, gkv = st
            xc, msts = jax.lax.scan(mamba_body, xc, gp)
            a_in = L.linear(gin, jnp.concatenate([xc, x0], axis=-1))
            h = L.norm(params["shared"]["ln1"], a_in)
            q, k, v = A.qkv_project(params["shared"]["attn"], h, cfg,
                                    positions)
            kv2 = A.cache_prefill(gkv, k, v)
            o = A.train_attention(q, k, v, window=0)
            a = a_in + L.linear(params["shared"]["attn"]["wo"],
                                o.reshape(x.shape[0], x.shape[1], -1),
                                kind="row")
            h = L.norm(params["shared"]["ln2"], a)
            a = a + L.mlp(params["shared"]["mlp"], h, cfg.mlp)
            return xc + (a - a_in), (msts, kv2)

        x, (mg, kvs) = _scan_with_state(
            group_body, x, (params["groups"], params["shared_in"]),
            (cache["mamba_g"], cache["kv"]), cfg.n_layers // kk)
        new_cache = {"mamba_g": mg, "kv": kvs}
        if "tail" in params:
            x, mt = jax.lax.scan(mamba_body, x, params["tail"])
            new_cache["mamba_t"] = mt
        return x, new_cache

    # ------------------------------------------------ speculative decoding
    @staticmethod
    def _is_paged(n):
        return isinstance(n, A.PagedKVCache)

    def spec_state(self, cache):
        """The rollback-sensitive slice of a decode cache: every leaf that
        is NOT a paged pool — window rings, recurrent SSM/RWKV state, dense
        slot clocks.  Paged pools need no snapshot to rewind: addressing is
        linear-positional, so stale speculative entries are clock-masked
        (``j <= pos``) and overwritten in place by the next real write.
        Ring/recurrent leaves have no such discipline (a ring write
        *destroys* the entry ``window`` positions back; recurrent state has
        no position axis at all), so speculation snapshots them and selects
        the accepted step's copy per row on rollback."""
        nodes, _ = jax.tree.flatten(cache, is_leaf=self._is_paged)
        return [n for n in nodes if not self._is_paged(n)]

    def with_spec_state(self, cache, state):
        """Rebuild ``cache`` with its rollback-sensitive leaves replaced by
        ``state`` (a ``spec_state`` list); paged pools pass through."""
        nodes, td = jax.tree.flatten(cache, is_leaf=self._is_paged)
        it = iter(state)
        out = [n if self._is_paged(n) else next(it) for n in nodes]
        return jax.tree.unflatten(td, out)

    def decode_steps(self, params, tokens, cache, pos, block_tables=None):
        """Scanned multi-token decode (the speculative *verify* pass):
        ``tokens`` (B,K) are fed sequentially at positions pos..pos+K-1
        through exactly ``decode_step``'s per-token math — same ops, same
        order, so step i's logits are bit-identical to i separate
        ``decode_step`` calls — in a single trace/dispatch.

        Returns ``(logits (B,K,V), cache, snaps)`` where ``snaps`` stacks
        every ``spec_state`` leaf after each step (axis 0 = step index):
        the rollback record a speculative scheduler selects per-row
        accepted states from.  ``pos`` is a scalar or (B,) vector clock,
        as in ``decode_step``."""
        def step(carry, tk):
            c, p = carry
            logits, c = self.decode_step(params, tk[:, None], c, p,
                                         block_tables)
            return (c, p + 1), (logits[:, 0], self.spec_state(c))

        toks = jnp.moveaxis(tokens, 0, 1)                 # (K, B)
        (cache, _), (lgs, snaps) = jax.lax.scan(
            step, (cache, jnp.asarray(pos)), toks)
        return jnp.moveaxis(lgs, 0, 1), cache, snaps

    # ------------------------------------------------------ chunked prefill
    def prefill_chunk(self, params, tokens, cache, bt_row, start, valid_len):
        """One fixed-size chunk of a long-prompt prefill against a paged
        cache row whose first ``start`` positions are already populated.

        ``tokens`` (1, C) are prompt positions start..start+C-1 (C static,
        a multiple of block_size; the tail past ``valid_len`` is pad),
        ``bt_row`` (w,) the row's block table truncated to a static
        power-of-two bucket covering the whole prompt, and ``start``
        (traced, block-aligned) the chunk's absolute offset — so every
        chunk of every prompt lowers through ONE compile per (w, C) pair,
        which is what lets the engine interleave decode ticks between
        chunks instead of stalling the batch for a monolithic prefill.

        Chunk queries attend [gathered pool prefix (pos < start) || the
        chunk itself, causal] via ``A.chunk_attention``; chunk KV scatters
        into the row's mapped blocks (unmapped pad blocks spill to the
        scratch block).  Returns (logits at valid_len-1, new cache).
        Uniform-attention families only, like ``prefill_suffix``."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe") or self._grouped_local():
            raise ValueError(
                f"chunked prefill requires a uniform full-attention "
                f"stack, not family {cfg.family!r}")
        pk = cache["kv"]
        bs = pk.k.shape[2]
        KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        x = L.embed(params["embed"], tokens)
        B, C, _ = x.shape
        assert B == 1 and C % bs == 0, (B, C, bs)
        w = bt_row.shape[0]
        positions = start + jnp.broadcast_to(jnp.arange(C)[None], (B, C))
        if cfg.pos == "sinusoidal":
            x = x + L.sinusoidal(positions, cfg.d_model, x.dtype)
        okb = bt_row >= 0
        safe_ids = jnp.where(okb, bt_row, 0)              # 0 = scratch block
        jpos = jnp.arange(w * bs)
        ctx_valid = ((jpos < start) & jnp.repeat(okb, bs))[None]   # (1, w*bs)
        # the chunk's own write blocks (traced ids; pad region -> scratch)
        cb = start // bs + jnp.arange(C // bs)
        wok = (cb < w) & (jnp.take(bt_row, jnp.clip(cb, 0, w - 1)) >= 0)
        wids = jnp.where(wok, jnp.take(bt_row, jnp.clip(cb, 0, w - 1)), 0)
        quant = pk.quantized

        def body(xc, lp, st):
            kp, vp = st[0], st[1]                         # (nb, bs, KV, hd)
            h = L.norm(lp["ln1"], xc)
            q, k, v = A.qkv_project(lp["attn"], h, cfg, positions)
            if quant:
                from repro.serving.qserve import kvquant as KQ
                kctx = KQ.dequantize_kv(kp[safe_ids], st[2][safe_ids],
                                        k.dtype)
                vctx = KQ.dequantize_kv(vp[safe_ids], st[3][safe_ids],
                                        v.dtype)
            else:
                kctx = kp[safe_ids].astype(k.dtype)
                vctx = vp[safe_ids].astype(v.dtype)
            o = A.chunk_attention(q, kctx.reshape(1, w * bs, KV, hd),
                                  vctx.reshape(1, w * bs, KV, hd),
                                  ctx_valid, k, v)
            if quant:
                from repro.serving.qserve import kvquant as KQ
                kq, ksn = KQ.quantize_kv(k[0].reshape(C // bs, bs, KV, hd))
                vq, vsn = KQ.quantize_kv(v[0].reshape(C // bs, bs, KV, hd))
                st_new = (kp.at[wids].set(kq), vp.at[wids].set(vq),
                          st[2].at[wids].set(ksn), st[3].at[wids].set(vsn))
            else:
                st_new = (
                    kp.at[wids].set(
                        k[0].reshape(C // bs, bs, KV, hd).astype(kp.dtype)),
                    vp.at[wids].set(
                        v[0].reshape(C // bs, bs, KV, hd).astype(vp.dtype)))
            xc = xc + L.linear(lp["attn"]["wo"], o.reshape(B, C, -1),
                               kind="row")
            h = L.norm(lp["ln2"], xc)
            if "moe" in lp:
                xc = xc + M.moe_apply(lp["moe"], h, cfg)
            else:
                xc = xc + L.mlp(lp["mlp"], h, cfg.mlp)
            return xc, st_new

        x, kvs = _scan_with_state(body, x, params["layers"],
                                  _paged_kv_state(pk), cfg.n_layers)
        xl = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
        logits = self._logits(params, xl)
        return logits, {"kv": A.PagedKVCache(kvs[0], kvs[1],
                                             pk.block_tables, *kvs[2:])}

    # ------------------------------------------------- paged suffix prefill
    def prefill_suffix(self, params, tokens, cache, bt_row, valid_len, *,
                       n_shared):
        """Prefill a prompt *suffix* against ``n_shared`` already-populated
        prefix blocks of a paged cache (prefix sharing: the shared blocks'
        KV is reused, their prefill FLOPs are skipped entirely).

        ``tokens`` (1, S_pad) is the suffix padded to a bucket length
        (S_pad a multiple of block_size), ``bt_row`` (max_blocks,) the
        row's block table, ``valid_len`` (traced) the real suffix length;
        ``n_shared`` is static — each (n_shared, S_pad) pair compiles once.
        Suffix queries attend [shared prefix || suffix] via the causal
        ``q_offset`` path; suffix KV (pad garbage included — masked by the
        ``j <= pos`` clock until decode overwrites it) scatters into the
        row's private blocks.  Returns (logits at valid_len-1, new cache).

        Uniform-attention families only: grouped-local rings and SSM/hybrid
        recurrent state are per-row and unshareable, so those families
        admit through the full dense-row prefill + block pack instead."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe") or self._grouped_local():
            raise ValueError(
                f"prefix-shared suffix prefill requires a uniform "
                f"full-attention stack, not family {cfg.family!r}")
        pk = cache["kv"]
        bs = pk.k.shape[2]
        start = n_shared * bs
        KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        x = L.embed(params["embed"], tokens)
        B, Spad, _ = x.shape
        assert B == 1 and Spad % bs == 0, (B, Spad, bs)
        nsb = Spad // bs
        assert n_shared + nsb <= bt_row.shape[0], (n_shared, nsb)
        positions = start + jnp.broadcast_to(jnp.arange(Spad)[None],
                                             (B, Spad))
        if cfg.pos == "sinusoidal":
            x = x + L.sinusoidal(positions, cfg.d_model, x.dtype)
        sfx_ids = bt_row[n_shared:n_shared + nsb]         # (nsb,) static slice
        ok = sfx_ids >= 0
        safe = jnp.where(ok, sfx_ids, 0)                  # 0 = scratch block
        quant = pk.quantized

        def body(xc, lp, st):
            kp, vp = st[0], st[1]                         # (nb, bs, KV, hd)
            h = L.norm(lp["ln1"], xc)
            q, k, v = A.qkv_project(lp["attn"], h, cfg, positions)
            if n_shared:
                pre_ids = bt_row[:n_shared]
                if quant:
                    from repro.serving.qserve import kvquant as KQ
                    kpre = KQ.dequantize_kv(kp[pre_ids], st[2][pre_ids],
                                            k.dtype)
                    vpre = KQ.dequantize_kv(vp[pre_ids], st[3][pre_ids],
                                            v.dtype)
                else:
                    kpre = kp[pre_ids].astype(k.dtype)
                    vpre = vp[pre_ids].astype(v.dtype)
                kf = jnp.concatenate(
                    [kpre.reshape(1, start, KV, hd), k], axis=1)
                vf = jnp.concatenate(
                    [vpre.reshape(1, start, KV, hd), v], axis=1)
            else:
                kf, vf = k, v
            o = A.causal_attention(q, kf, vf, window=0, q_offset=start)
            # unmapped (pad-region) blocks collapse onto the never-read
            # scratch block, so the scatter needs no read-back select
            if quant:
                from repro.serving.qserve import kvquant as KQ
                kq, ksn = KQ.quantize_kv(k[0].reshape(nsb, bs, KV, hd))
                vq, vsn = KQ.quantize_kv(v[0].reshape(nsb, bs, KV, hd))
                st_new = (kp.at[safe].set(kq), vp.at[safe].set(vq),
                          st[2].at[safe].set(ksn), st[3].at[safe].set(vsn))
            else:
                st_new = (
                    kp.at[safe].set(
                        k[0].reshape(nsb, bs, KV, hd).astype(kp.dtype)),
                    vp.at[safe].set(
                        v[0].reshape(nsb, bs, KV, hd).astype(vp.dtype)))
            xc = xc + L.linear(lp["attn"]["wo"], o.reshape(B, Spad, -1),
                               kind="row")
            h = L.norm(lp["ln2"], xc)
            if "moe" in lp:
                xc = xc + M.moe_apply(lp["moe"], h, cfg)
            else:
                xc = xc + L.mlp(lp["mlp"], h, cfg.mlp)
            return xc, st_new

        x, kvs = _scan_with_state(body, x, params["layers"],
                                  _paged_kv_state(pk), cfg.n_layers)
        xl = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
        logits = self._logits(params, xl)
        return logits, {"kv": A.PagedKVCache(kvs[0], kvs[1],
                                             pk.block_tables, *kvs[2:])}
