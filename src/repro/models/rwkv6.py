"""RWKV6 "Finch" block: data-dependent decay linear attention + channel mix.

Time-mix: ddlerp token-shift (per-stream mu + LoRA), decay
``w_t = exp(-exp(w0 + lora(x)))`` per channel, wkv recurrence per head
(head_size K=V): ``S_t = diag(w_t) S_{t-1} + k_t^T v_t``,
``y_t = r_t (diag(u) k_t^T v_t + S_{t-1})``.

Decode state per layer: wkv state (B,H,K,V) + two token-shift carries.
Long-context decode is O(1) in sequence length — rwkv6-3b runs long_500k.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

STREAMS = ("r", "k", "v", "g", "w")


class RWKVState(NamedTuple):
    wkv: jnp.ndarray      # (B, H, K, V) fp32
    shift_att: jnp.ndarray  # (B, d) last input of time-mix
    shift_ffn: jnp.ndarray  # (B, d) last input of channel-mix


def rwkv_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    r = cfg.rwkv
    H = d // r.head_size
    ks = jax.random.split(key, 16)
    p = {"ln_att": L.layernorm_init(d, dtype), "ln_ffn": L.layernorm_init(d, dtype)}
    # ddlerp token shift: shared lora A, per-stream base mu and lora B
    p["mix_base"] = jnp.zeros((len(STREAMS), d), dtype) + 0.5
    p["mix_A"] = L.uniform_init(ks[0], (d, len(STREAMS) * r.mix_lora), dtype=dtype)
    p["mix_B"] = L.uniform_init(ks[1], (len(STREAMS), r.mix_lora, d),
                                scale=0.1, dtype=dtype)
    for i, s in enumerate(("r", "k", "v", "g")):
        p[f"w{s}"] = L.linear_init(ks[2 + i], d, d, dtype=dtype)
    p["wo"] = L.linear_init(ks[6], d, d, dtype=dtype)
    p["w0"] = jnp.zeros((d,), dtype) - 0.6          # decay base
    p["decay_A"] = L.uniform_init(ks[7], (d, r.decay_lora), dtype=dtype)
    p["decay_B"] = L.uniform_init(ks[8], (r.decay_lora, d), scale=0.1, dtype=dtype)
    p["u"] = jnp.zeros((H, r.head_size), dtype) + 0.1   # "bonus"
    p["ln_x"] = L.layernorm_init(d, dtype)              # per-head group norm
    # channel mix
    p["cm_mu_k"] = jnp.zeros((d,), dtype) + 0.5
    p["cm_mu_r"] = jnp.zeros((d,), dtype) + 0.5
    p["cm_key"] = L.linear_init(ks[9], d, cfg.d_ff, dtype=dtype)
    p["cm_value"] = L.linear_init(ks[10], cfg.d_ff, d, dtype=dtype)
    p["cm_recept"] = L.linear_init(ks[11], d, d, dtype=dtype)
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift mix -> one tensor per stream."""
    dxp = x_prev - x
    lora = jnp.tanh(x @ p["mix_A"])                        # (B,S,5*r)
    lora = lora.reshape(*x.shape[:-1], len(STREAMS), -1)
    adj = jnp.einsum("bsnr,nrd->nbsd", lora, p["mix_B"])
    mixed = x[None] + dxp[None] * (p["mix_base"][:, None, None, :] + adj)
    return mixed                                           # (5, B, S, d)


def _wkv_scan(r, k, v, w, u, state0, chunk: int = 128):
    """Linear-attention recurrence.  r,k,w (B,S,H,K); v (B,S,H,V); u (H,K).
    Returns y (B,S,H,V), final state (B,H,K,V).

    Chunked + rematerialized: a plain scan's backward saves the (B,H,K,V)
    state for EVERY timestep (64 GiB/device at 4k x rwkv6-3b); checkpointing
    each chunk bounds saved state to the chunk boundaries."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                # (B,H,K) etc.
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, u[None, :, :, None] * kv + S)
        S = w_t[..., :, None] * S + kv
        return S, y

    B, S, H, K = r.shape
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    def to_chunks(t):
        # (B,S,...) -> (nc, Q, B, ...)
        return jnp.moveaxis(t, 1, 0).reshape(nc, Q, *t.shape[:1],
                                             *t.shape[2:])

    xs = (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w))

    @jax.checkpoint
    def chunk_fn(S0, inp):
        Sn, ys = jax.lax.scan(step, S0, inp)
        return Sn, ys

    S_fin, ys = jax.lax.scan(chunk_fn, state0, xs)   # ys (nc, Q, B, H, V)
    y = ys.reshape(nc * Q, B, H, -1)
    return jnp.moveaxis(y, 0, 1), S_fin


def _wkv_chunked(r, k, v, w, u, state0, chunk: int = 16):
    """Chunked matmul-form wkv (EXPERIMENTS.md §Perf rwkv hillclimb).

    The per-step scan streams the (B,H,K,V) state through HBM 4096x per
    layer (measured 2197s memory term on train_4k); chunking passes state
    between chunks only (S/chunk steps) and computes intra-chunk outputs via
    the pairwise-decay tensor E[t,j] = exp(cum_{t-1} - cum_j) (exponent <= 0:
    numerically safe for any data-dependent decay, unlike the factorized
    k/P_j form).  Exact — validated against the scan oracle in tests.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    C = min(chunk, S)
    while S % C:
        C //= 2
    nc = S // C

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, C, H, -1), 1, 0)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))      # (nc,B,C,H,*)
    tri = jnp.tril(jnp.ones((C, C), bool), -1)          # strict lower: j<t

    @jax.checkpoint
    def chunk_fn(S_in, inp):
        rt, kt, vt, wt = inp                            # (B,C,H,K/V)
        lw = jnp.log(jnp.maximum(wt, 1e-38))
        cum = jnp.cumsum(lw, axis=1)                    # inclusive over C
        cum_ex = cum - lw                               # exclusive (cum_{t-1})
        # intra-chunk pairwise decays (exponent <= 0 for j < t)
        E = jnp.exp(jnp.where(tri[None, :, :, None, None],
                              cum_ex[:, :, None] - cum[:, None, :], -1e30))
        score = jnp.einsum("bthk,btjhk,bjhk->btjh", rt, E, kt)
        y = jnp.einsum("btjh,bjhv->bthv", score, vt)
        # diagonal (bonus u) term: (r_t . u*k_t) v_t
        coeff = (rt * u[None, None] * kt).sum(-1, keepdims=True)  # (B,C,H,1)
        y += coeff * vt
        # carried-state term
        y += jnp.einsum("bthk,bhkv->bthv", rt * jnp.exp(cum_ex), S_in)
        # chunk state update
        dte = jnp.exp(cum[:, -1:] - cum)                # decay-to-end <= 1
        S_out = S_in * jnp.exp(cum[:, -1])[:, :, :, None] + \
            jnp.einsum("bthk,bthv->bhkv", kt * dte, vt)
        return S_out, y

    S_fin, ys = jax.lax.scan(chunk_fn, state0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, V)
    return y, S_fin


def time_mix(p, x, cfg, x_prev, state0):
    """x (B,S,d); x_prev (B,d) carry; returns (out, last_x, new wkv state)."""
    B, S, d = x.shape
    hs = cfg.rwkv.head_size
    H = d // hs
    xp = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mixed = _ddlerp(p, x, xp)                              # (5,B,S,d)
    xr, xk, xv, xg, xw = mixed
    r = L.linear(p["wr"], xr).reshape(B, S, H, hs)
    k = L.linear(p["wk"], xk).reshape(B, S, H, hs)
    v = L.linear(p["wv"], xv).reshape(B, S, H, hs)
    g = L.linear(p["wg"], xg)
    w = jnp.exp(-jnp.exp(
        (p["w0"] + jnp.tanh(xw @ p["decay_A"]) @ p["decay_B"]
         ).astype(jnp.float32)))
    w = w.reshape(B, S, H, hs)
    wkv_fn = _wkv_chunked if S > 1 else _wkv_scan
    y, S_fin = wkv_fn(r.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), w, p["u"].astype(jnp.float32),
                      state0)
    y = y.reshape(B, S, d).astype(x.dtype)
    y = L.norm(p["ln_x"], y)
    y = y * jax.nn.silu(g)
    return L.linear(p["wo"], y, kind="row"), x[:, -1], S_fin


def channel_mix(p, x, x_prev):
    xp = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xk = x + (xp - x) * p["cm_mu_k"]
    xr = x + (xp - x) * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(L.linear(p["cm_key"], xk)))
    vv = L.linear(p["cm_value"], kk, kind="row")
    return jax.nn.sigmoid(L.linear(p["cm_recept"], xr)) * vv, x[:, -1]


def rwkv_block(p, x, cfg, state: RWKVState):
    h, sa, wkv = time_mix(p, L.norm(p["ln_att"], x), cfg,
                          state.shift_att, state.wkv)
    x = x + h
    h, sf = channel_mix(p, L.norm(p["ln_ffn"], x), state.shift_ffn)
    x = x + h
    return x, RWKVState(wkv, sa, sf)


def init_state(B, cfg, dtype=jnp.float32):
    d = cfg.d_model
    hs = cfg.rwkv.head_size
    H = d // hs
    return RWKVState(jnp.zeros((B, H, hs, hs), jnp.float32),
                     jnp.zeros((B, d), dtype), jnp.zeros((B, d), dtype))
