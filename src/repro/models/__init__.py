"""Model zoo public API."""
from repro.models.transformer import Model


def build_model(cfg) -> Model:
    return Model(cfg)


__all__ = ["Model", "build_model"]
