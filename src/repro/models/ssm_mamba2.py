"""Mamba2 block (SSD chunked scan) — the zamba2-7b backbone layer.

Training/prefill uses the SSD block decomposition (intra-chunk quadratic +
inter-chunk state recurrence, chunk length cfg.ssm.chunk); decode is the O(1)
recurrent step carrying (conv_state, ssm_state).  n_groups=1: B/C shared
across heads (zamba2).

Sharding note (DESIGN.md §4): the canonical fused ``in_proj`` is split into
separate z/x/B/C/dt projections so the big ones (z, x: d_model -> expand*d)
TP-shard head-aligned over the ``model`` axis while the tiny B/C/dt
projections stay replicated; the depthwise conv is likewise split into a
head-sharded ``conv_x`` and a replicated ``conv_bc``.  SSM state is then
sharded over heads with zero cross-shard traffic inside the scan.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


class MambaState(NamedTuple):
    conv: jnp.ndarray   # (B, d_conv-1, conv_ch)  [x | B | C] pre-activation
    ssm: jnp.ndarray    # (B, nH, P, N) fp32


def dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nH = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return d_in, nH, conv_ch


def mamba_init(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nH, conv_ch = dims(cfg)
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    return {
        "in_z": L.linear_init(ks[0], d, d_in, dtype=dtype),
        "in_x": L.linear_init(ks[1], d, d_in, dtype=dtype),
        "in_B": L.linear_init(ks[2], d, gn, dtype=dtype),
        "in_C": L.linear_init(ks[3], d, gn, dtype=dtype),
        "in_dt": L.linear_init(ks[4], d, nH, dtype=dtype),
        "conv_x": jax.random.normal(ks[5], (s.d_conv, d_in), dtype) * 0.1,
        "conv_bc": jax.random.normal(ks[6], (s.d_conv, 2 * gn), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nH).astype(dtype)),
        "D": jnp.ones((nH,), dtype),
        "dt_bias": jnp.zeros((nH,), dtype) + 0.5,
        "norm": L.rmsnorm_init(d_in, dtype),
        "out_proj": L.linear_init(ks[7], d_in, d, dtype=dtype),
    }


def _conv_scan(xBC, w, b):
    """Causal depthwise conv (small window) via shifted sums; xBC (B,S,C)."""
    K = w.shape[0]
    out = xBC * w[-1]
    for i in range(1, K):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, :xBC.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, A, Bc, Cc, chunk):
    """SSD scan.  x (B,S,nH,P); dt (B,S,nH); A (nH)<0; Bc/Cc (B,S,N) (groups
    broadcast).  Returns y (B,S,nH,P) and final state (B,nH,P,N)."""
    from repro.dist import ctx as dctx
    Bsz, S, nH, P = x.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q
    # pin the head dim to tp so the big (B,nc,Q,Q,H) intra-chunk tensors stay
    # head-sharded (112 heads / 16 = 7 local for zamba2; measured 30 GiB
    # replicated otherwise)
    htp = dctx.tp_if(nH)
    x = dctx.wsc(x, "b", None, htp, None)
    dt = dctx.wsc(dt, "b", None, htp)
    xc = x.reshape(Bsz, nc, Q, nH, P)
    dtc = dt.reshape(Bsz, nc, Q, nH)
    Bcc = Bc.reshape(Bsz, nc, Q, N)
    Ccc = Cc.reshape(Bsz, nc, Q, N)

    dA = dtc * A[None, None, None, :]                  # (B,nc,Q,H) (negative)
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    # intra-chunk: Lmat[i,j] = exp(cum_i - cum_j) for i >= j.  The mask goes
    # INSIDE the exp (where around exp(+big) poisons gradients with NaN)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -1e30))
    cb = jnp.einsum("bcin,bcjn->bcij", Ccc, Bcc)       # (B,nc,Q,Q)
    w_ij = cb[..., None] * Lmat * dtc[:, :, None, :, :]  # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w_ij, xc)

    # chunk summary states: sum_j exp(cum_last - cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)    # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqh,bcqn,bcqhp->bchpn",
                        decay_to_end, dtc, Bcc, xc)    # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B,nc,H)

    def scan_fn(h, inp):
        st, dec = inp                                  # (B,H,P,N), (B,H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h                                # emit state BEFORE chunk

    h0 = jnp.zeros((Bsz, nH, P, N), jnp.float32)
    h_fin, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)              # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Ccc, jnp.exp(cum), h_prevs.astype(Ccc.dtype))
    y = (y_intra + y_inter).reshape(Bsz, S, nH, P)
    return y, h_fin


def _project(p, u, cfg):
    """z, x, B, C, dt projections (the split-TP layout)."""
    z = L.linear(p["in_z"], u)
    xr = L.linear(p["in_x"], u)
    Bc = L.linear(p["in_B"], u)
    Cc = L.linear(p["in_C"], u)
    dt = L.linear(p["in_dt"], u)
    return z, xr, Bc, Cc, dt


def mamba_apply(p, u, cfg):
    """Train/prefill forward.  u (B,S,D) -> (y (B,S,D), final MambaState)."""
    s = cfg.ssm
    d_in, nH, conv_ch = dims(cfg)
    B, S, D = u.shape
    gn = s.n_groups * s.d_state
    z, xr, Bc, Cc, dt = _project(p, u, cfg)
    pre_x, pre_bc = xr, jnp.concatenate([Bc, Cc], axis=-1)
    xr = _conv_scan(xr, p["conv_x"], p["conv_b"][:d_in])
    BCc = _conv_scan(pre_bc, p["conv_bc"], p["conv_b"][d_in:])
    Bc, Cc = jnp.split(BCc, [gn], axis=-1)
    x = xr.reshape(B, S, nH, s.head_dim)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_fin = _ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32),
                            A, Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                            s.chunk)
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(u.dtype)
    y = L.norm(p["norm"], y * jax.nn.silu(z))
    out = L.linear(p["out_proj"], y, kind="row")
    # conv state holds the PRE-activation inputs of the last K-1 steps
    pre = jnp.concatenate([pre_x, pre_bc], axis=-1)
    K = s.d_conv
    if S >= K - 1:
        conv_state = pre[:, S - (K - 1):, :]
    else:
        conv_state = jnp.pad(pre, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, MambaState(conv_state, h_fin)


def mamba_step(p, u, state: MambaState, cfg):
    """Decode step.  u (B,1,D) -> (y (B,1,D), new state)."""
    s = cfg.ssm
    d_in, nH, conv_ch = dims(cfg)
    B = u.shape[0]
    gn = s.n_groups * s.d_state
    z, xr, Bc, Cc, dt = _project(p, u[:, 0:1], cfg)
    z, xr, Bc, Cc, dt = z[:, 0], xr[:, 0], Bc[:, 0], Cc[:, 0], dt[:, 0]
    pre = jnp.concatenate([xr, Bc, Cc], axis=-1)       # (B, conv_ch)
    window = jnp.concatenate([state.conv, pre[:, None]], axis=1)  # (B,K,ch)
    w_full = jnp.concatenate([p["conv_x"], p["conv_bc"]], axis=-1)
    xBC = jnp.einsum("bkc,kc->bc", window, w_full) + p["conv_b"]
    xBC = jax.nn.silu(xBC)
    xr, Bc, Cc = jnp.split(xBC, [d_in, d_in + gn], axis=-1)
    x = xr.reshape(B, nH, s.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None])                          # (B,nH)
    h = state.ssm * dA[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bc.astype(jnp.float32), x)
    y = jnp.einsum("bn,bhpn->bhp", Cc.astype(jnp.float32), h)
    y = y + x * p["D"][None, :, None]
    y = y.reshape(B, d_in).astype(u.dtype)
    y = L.norm(p["norm"], y * jax.nn.silu(z))
    out = L.linear(p["out_proj"], y, kind="row")[:, None]
    new_conv = window[:, 1:]
    return out, MambaState(new_conv, h)
