"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Design for GSPMD (see DESIGN.md §4): routing groups are **batch rows**, which
are data-sharded, so all dispatch index math is shard-local; expert weights
``(E, d, f)`` are FSDP-sharded on ``d`` and TP-sharded on ``f``, so the expert
einsum all-gathers weights (per layer, overlapped by XLA) instead of
all-to-all-ing tokens.  A shard_map EP variant is the grok-1 hillclimb lever
(see EXPERIMENTS.md §Perf).

Dispatch is one-hot-cumsum based (no sort): slot_j = #earlier assignments to
the same expert in the group; assignments beyond capacity are dropped (their
tokens fall through via the residual connection, Switch-style).

Quantized expert stacks (``serving.quantized`` packs them as one stacked
``QuantizedTensor`` per projection) never dense-dequantize all ``E`` experts
off-mesh: routing first *compacts* the expert axis to the <= B*S*k experts
actually routed this step, then ``kernels.moe_dequant`` contracts the
dispatch buffers against the packed planes directly (Pallas fused kernel on
TPU, per-expert scan elsewhere).  On a tensor-parallel mesh the GSPMD einsum
lowering is kept, so the dense reconstruction only survives where the
collective schedule depends on it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_init(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    glu = cfg.mlp in ("swiglu", "geglu")
    p = {"router": L.linear_init(ks[0], d, E, dtype=dtype),
         "wi": {"kernel": L.uniform_init(ks[1], (E, d, f), dtype=dtype)},
         "wo": {"kernel": L.uniform_init(ks[2], (E, f, d), dtype=dtype)}}
    if glu:
        p["wg"] = {"kernel": L.uniform_init(ks[3], (E, d, f), dtype=dtype)}
    return p


def capacity(S, top_k, n_experts, cf):
    c = int(S * top_k * cf / n_experts) + 1
    c = max(8 if S >= 8 else 1, c)
    return -(-c // 8) * 8 if S >= 8 else c  # lane-align capacity


def _act(h, g, kind):
    if kind == "swiglu":
        return jax.nn.silu(g) * h
    if kind == "geglu":
        return jax.nn.gelu(g, approximate=True) * h
    if kind == "relu2":
        return jnp.square(jax.nn.relu(h))
    return jax.nn.gelu(h, approximate=True)


def moe_apply(p, x, cfg):
    """x (B, S, d) -> (B, S, d).  Routing groups = batch rows."""
    from repro.core.qformat import QuantizedTensor, dequantize_any
    from repro.dist import ctx as dctx
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    C = capacity(S, k, E, m.capacity_factor)
    C = min(C, S * k)

    c = dctx.get()
    quant = isinstance(p["wi"]["kernel"], QuantizedTensor)
    # packed expert stacks stay packed off-mesh (compaction + fused op
    # below); everywhere else reconstruct upfront as before
    fused = quant and m.moe_impl != "dense" and (c is None or c.tp_size <= 1)
    if not fused:
        p = {n: ({"kernel": dequantize_any(v["kernel"])}
                 if isinstance(v, dict) and "kernel" in v else v)
             for n, v in p.items()}

    logits = L.linear(p["router"], x)                       # (B,S,E)
    topv, topi = jax.lax.top_k(logits, k)                   # (B,S,k)
    gates = jax.nn.softmax(topv.astype(jnp.float32), axis=-1).astype(x.dtype)

    if m.moe_impl == "dense":                               # smoke-scale only
        h = jnp.einsum("bsd,edf->bsef", x, p["wi"]["kernel"])
        if "wg" in p:
            g = jnp.einsum("bsd,edf->bsef", x, p["wg"]["kernel"])
            h = _act(h, g, cfg.mlp)
        else:
            h = _act(h, None, cfg.mlp)
        y = jnp.einsum("bsef,efd->bsed", h, p["wo"]["kernel"])
        sel = jax.nn.one_hot(topi, E, dtype=x.dtype) * gates[..., None]
        return jnp.einsum("bsed,bske->bsd", y, sel)

    # ---- capacity-based gather/scatter dispatch ----
    # explicit batch-dim constraints throughout: GSPMD does not partition
    # batched scatter/gather reliably and otherwise replicates the (B,E,C,*)
    # buffers over the data axes (measured on grok-1: 5 GiB x182 copies)
    flat_e = topi.reshape(B, S * k)                         # expert of each slot
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (B,S*k,E)
    slot = jnp.cumsum(onehot, axis=1) - 1                   # position in expert
    slot = jnp.take_along_axis(slot, flat_e[..., None], axis=-1)[..., 0]
    slot = dctx.wsc(slot, "b", None)
    keep = slot < C                                         # drop overflow
    tok = jnp.repeat(jnp.arange(S)[None, :, None], k, axis=2).reshape(1, S * k)
    tok = jnp.broadcast_to(tok, (B, S * k))

    # off-mesh packed path: compact the expert axis to the routed set — at
    # most B*S*k distinct experts receive tokens, so the top-Eh by count
    # provably covers every routed expert; unrouted experts' packed bytes
    # are never touched
    Eh, flat_ec = E, flat_e
    wsel = None
    if fused and E > B * S * k:
        Eh = B * S * k
        _, eidx = jax.lax.top_k(onehot.sum(axis=(0, 1)), Eh)
        inv = jnp.zeros((E,), jnp.int32).at[eidx].set(
            jnp.arange(Eh, dtype=jnp.int32))
        flat_ec = inv[flat_e]
        wsel = lambda qt: jax.tree.map(lambda a: a[eidx], qt)  # noqa: E731

    # scatter tokens into (B, Eh, C, d); out-of-capacity assignments drop via
    # out-of-bounds scatter mode
    dst = jnp.where(keep, flat_ec * C + slot, Eh * C)       # Eh*C -> dropped
    buf = jnp.zeros((B, Eh * C, d), x.dtype)
    buf = dctx.wsc(buf, "b", None, None)
    xi = jnp.take_along_axis(
        x, tok[..., None].astype(jnp.int32), axis=1)        # (B,S*k,d)
    buf = jax.vmap(lambda b, i, u: b.at[i].set(u, mode="drop"))(buf, dst, xi)
    xe = buf.reshape(B, Eh, C, d)

    if fused:
        from repro.kernels.moe_dequant import ops as mops
        sel = wsel if wsel is not None else (lambda qt: qt)
        xef = xe.transpose(1, 0, 2, 3).reshape(Eh, B * C, d)
        h = mops.moe_dequant_matmul(xef, sel(p["wi"]["kernel"]))
        if "wg" in p:
            g = mops.moe_dequant_matmul(xef, sel(p["wg"]["kernel"]))
            h = _act(h, g, cfg.mlp)
        else:
            h = _act(h, None, cfg.mlp)
        ye = mops.moe_dequant_matmul(h, sel(p["wo"]["kernel"]))
        ye = ye.reshape(Eh, B, C, d).transpose(1, 0, 2, 3)  # (B,Eh,C,d)
    else:
        # expert dim shards over tp when divisible (granite 32e); else the
        # buffers stay tp-replicated and only the ffn dim is tp-sharded
        # (grok 8e)
        etp = dctx.tp_if(E)
        xe = dctx.wsc(xe, "b", etp, None, None)
        ftp = "tp" if etp is None else None
        h = jnp.einsum("becd,edf->becf", xe, p["wi"]["kernel"])
        h = dctx.wsc(h, "b", etp, None, ftp)
        if "wg" in p:
            g = jnp.einsum("becd,edf->becf", xe, p["wg"]["kernel"])
            h = _act(h, dctx.wsc(g, "b", etp, None, ftp), cfg.mlp)
        else:
            h = _act(h, None, cfg.mlp)
        ye = jnp.einsum("becf,efd->becd", h, p["wo"]["kernel"])  # (B,E,C,d)
        ye = dctx.wsc(ye, "b", etp, None, None)

    # gather back, weighted by gates
    ye_flat = ye.reshape(B, Eh * C, d)
    src = jnp.where(keep, flat_ec * C + slot, 0)
    yo = jnp.take_along_axis(ye_flat, src[..., None].astype(jnp.int32), axis=1)
    yo = yo * (keep[..., None] * gates.reshape(B, S * k)[..., None]).astype(x.dtype)
    yo = dctx.wsc(yo, "b", None, None)
    return yo.reshape(B, S, k, d).sum(axis=2)
