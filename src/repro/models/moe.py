"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Design for GSPMD (see DESIGN.md §4): routing groups are **batch rows**, which
are data-sharded, so all dispatch index math is shard-local; expert weights
``(E, d, f)`` are FSDP-sharded on ``d`` and TP-sharded on ``f``, so the expert
einsum all-gathers weights (per layer, overlapped by XLA) instead of
all-to-all-ing tokens.  A shard_map EP variant is the grok-1 hillclimb lever
(see EXPERIMENTS.md §Perf).

Dispatch is one-hot-cumsum based (no sort): slot_j = #earlier assignments to
the same expert in the group; assignments beyond capacity are dropped (their
tokens fall through via the residual connection, Switch-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_init(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    glu = cfg.mlp in ("swiglu", "geglu")
    p = {"router": L.linear_init(ks[0], d, E, dtype=dtype),
         "wi": {"kernel": L.uniform_init(ks[1], (E, d, f), dtype=dtype)},
         "wo": {"kernel": L.uniform_init(ks[2], (E, f, d), dtype=dtype)}}
    if glu:
        p["wg"] = {"kernel": L.uniform_init(ks[3], (E, d, f), dtype=dtype)}
    return p


def capacity(S, top_k, n_experts, cf):
    c = int(S * top_k * cf / n_experts) + 1
    c = max(8 if S >= 8 else 1, c)
    return -(-c // 8) * 8 if S >= 8 else c  # lane-align capacity


def _act(h, g, kind):
    if kind == "swiglu":
        return jax.nn.silu(g) * h
    if kind == "geglu":
        return jax.nn.gelu(g, approximate=True) * h
    if kind == "relu2":
        return jnp.square(jax.nn.relu(h))
    return jax.nn.gelu(h, approximate=True)


def moe_apply(p, x, cfg):
    """x (B, S, d) -> (B, S, d).  Routing groups = batch rows."""
    from repro.core.qformat import dequantize_any
    p = {k: ({"kernel": dequantize_any(v["kernel"])}
             if isinstance(v, dict) and "kernel" in v else v)
         for k, v in p.items()}
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    C = capacity(S, k, E, m.capacity_factor)
    C = min(C, S * k)

    logits = L.linear(p["router"], x)                       # (B,S,E)
    topv, topi = jax.lax.top_k(logits, k)                   # (B,S,k)
    gates = jax.nn.softmax(topv.astype(jnp.float32), axis=-1).astype(x.dtype)

    if m.moe_impl == "dense":                               # smoke-scale only
        h = jnp.einsum("bsd,edf->bsef", x, p["wi"]["kernel"])
        if "wg" in p:
            g = jnp.einsum("bsd,edf->bsef", x, p["wg"]["kernel"])
            h = _act(h, g, cfg.mlp)
        else:
            h = _act(h, None, cfg.mlp)
        y = jnp.einsum("bsef,efd->bsed", h, p["wo"]["kernel"])
        sel = jax.nn.one_hot(topi, E, dtype=x.dtype) * gates[..., None]
        return jnp.einsum("bsed,bske->bsd", y, sel)

    # ---- capacity-based gather/scatter dispatch ----
    # explicit batch-dim constraints throughout: GSPMD does not partition
    # batched scatter/gather reliably and otherwise replicates the (B,E,C,*)
    # buffers over the data axes (measured on grok-1: 5 GiB x182 copies)
    from repro.dist import ctx as dctx
    flat_e = topi.reshape(B, S * k)                         # expert of each slot
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (B,S*k,E)
    slot = jnp.cumsum(onehot, axis=1) - 1                   # position in expert
    slot = jnp.take_along_axis(slot, flat_e[..., None], axis=-1)[..., 0]
    slot = dctx.wsc(slot, "b", None)
    keep = slot < C                                         # drop overflow
    tok = jnp.repeat(jnp.arange(S)[None, :, None], k, axis=2).reshape(1, S * k)
    tok = jnp.broadcast_to(tok, (B, S * k))

    # scatter tokens into (B, E, C, d); out-of-capacity assignments drop via
    # out-of-bounds scatter mode
    dst = jnp.where(keep, flat_e * C + slot, E * C)         # E*C -> dropped
    buf = jnp.zeros((B, E * C, d), x.dtype)
    buf = dctx.wsc(buf, "b", None, None)
    xi = jnp.take_along_axis(
        x, tok[..., None].astype(jnp.int32), axis=1)        # (B,S*k,d)
    buf = jax.vmap(lambda b, i, u: b.at[i].set(u, mode="drop"))(buf, dst, xi)
    # expert dim shards over tp when divisible (granite 32e); else the
    # buffers stay tp-replicated and only the ffn dim is tp-sharded (grok 8e)
    etp = dctx.tp_if(E)
    xe = buf.reshape(B, E, C, d)
    xe = dctx.wsc(xe, "b", etp, None, None)

    ftp = "tp" if etp is None else None
    h = jnp.einsum("becd,edf->becf", xe, p["wi"]["kernel"])
    h = dctx.wsc(h, "b", etp, None, ftp)
    if "wg" in p:
        g = jnp.einsum("becd,edf->becf", xe, p["wg"]["kernel"])
        h = _act(h, dctx.wsc(g, "b", etp, None, ftp), cfg.mlp)
    else:
        h = _act(h, None, cfg.mlp)
    ye = jnp.einsum("becf,efd->becd", h, p["wo"]["kernel"])  # (B,E,C,d)
    ye = dctx.wsc(ye, "b", etp, None, None)

    # gather back, weighted by gates
    ye_flat = ye.reshape(B, E * C, d)
    src = jnp.where(keep, flat_e * C + slot, 0)
    yo = jnp.take_along_axis(ye_flat, src[..., None].astype(jnp.int32), axis=1)
    yo = yo * (keep[..., None] * gates.reshape(B, S * k)[..., None]).astype(x.dtype)
    yo = dctx.wsc(yo, "b", None, None)
    return yo.reshape(B, S, k, d).sum(axis=2)
