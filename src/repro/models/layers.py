"""Shared neural layers: norms, linear (quantization-aware), RoPE, embeddings.

Parameter convention: nested dicts of arrays; every dense projection is a
``{"kernel": (d_in, d_out)[, "bias": (d_out,)]}`` dict applied as
``y = x @ kernel + bias``.  A kernel leaf may be replaced by a
``repro.core.qformat.QuantizedTensor`` — ``linear()`` dispatches to the fused
dequant matmul, which is how OAC-quantized checkpoints are served.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qformat import QuantizedTensor


# ---------------------------------------------------------------- init utils

def uniform_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * scale


def linear_init(key, d_in, d_out, bias=False, dtype=jnp.float32, scale=None):
    p = {"kernel": uniform_init(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


# ---------------------------------------------------------------- apply fns

def linear(p, x, compute_dtype=None, kind="col"):
    """y = x @ kernel (+ bias); kernel may be a QuantizedTensor.

    ``kind`` ("col" | "row") names the kernel's tensor-parallel layout for
    the quantized fast path: "row" marks the contraction-sharded
    projections (``wo``/``out_proj``-style, the plan's ``_ROW_SHARDED``
    set) so ``qserve.linear`` splits the fused dequant matmul the same way
    the fp kernel is split.  Ignored for fp kernels (GSPMD reads the
    layout off the param sharding directly)."""
    k = p["kernel"]
    if isinstance(k, QuantizedTensor):
        from repro.serving.qserve.linear import quantized_linear
        y = quantized_linear(x, k, kind=kind)
    else:
        if compute_dtype is not None:
            k = k.astype(compute_dtype)
            x = x.astype(compute_dtype)
        y = x @ k
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_init(kind, d, dtype=jnp.float32):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:            # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------- positions

def rope(x, positions, theta: float):
    """x (..., S, H, Dh); positions (..., S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal(positions, d, dtype=jnp.float32):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------- mlps

def mlp_init(key, cfg, d_ff=None, dtype=jnp.float32):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {"wi": linear_init(ks[0], d, f, dtype=dtype),
                "wg": linear_init(ks[1], d, f, dtype=dtype),
                "wo": linear_init(ks[2], f, d, dtype=dtype)}
    return {"wi": linear_init(ks[0], d, f, dtype=dtype),
            "wo": linear_init(ks[2], f, d, dtype=dtype)}


def mlp(p, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x)
    elif kind == "geglu":
        h = jax.nn.gelu(linear(p["wg"], x), approximate=True) * linear(p["wi"], x)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(linear(p["wi"], x)))
    else:  # gelu
        h = jax.nn.gelu(linear(p["wi"], x), approximate=True)
    return linear(p["wo"], h, kind="row")


# ---------------------------------------------------------------- embedding

def embed_init(key, vocab, d, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p, tokens):
    return p["table"][tokens]


def unembed(p_embed, p_head, h, tied: bool):
    if tied:
        return h @ p_embed["table"].T.astype(h.dtype)
    return linear(p_head, h)
