"""Gradient compression: int8 all-reduce with error feedback.

At 1000+ node scale the gradient all-reduce competes with FSDP all-gathers
for ICI/DCN bandwidth; 4x compression of the gradient reduce is a standard
mitigation.  Implementation: per-leaf max-abs int8 quantization, all-gather
of int8 shards + local dequant-sum (overflow-safe, unlike int8 ring
all-reduce), with an error-feedback residual carried in the optimizer state
so the compression bias vanishes over steps (Karimireddy et al., 2019).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.dist.compat  # noqa: F401  (top-level jax.shard_map/set_mesh
#                           aliases for callers driving this under a mesh)


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str):
    """int8-compressed sum over a shard_map axis.

    all-gathers int8 payloads (N*d bytes vs ring-psum's ~2*d*4 bytes when
    N <= 8; for larger N, combine with a reduce-scatter first — documented
    trade-off) and dequant-sums locally."""
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)              # (N, ...) int8
    ss = jax.lax.all_gather(scale, axis_name)          # (N,)
    return jnp.tensordot(ss, qs.astype(jnp.float32), axes=1)


def ef_compress(grads, residuals):
    """Error feedback: g' = Q(g + e); e' = (g + e) - g'. Returns (g', e')."""
    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_e = jax.tree_util.tree_leaves(residuals)
    gs, es = [], []
    for g, e in zip(leaves_g, leaves_e):
        t = g + e
        q, s = quantize_int8(t)
        dq = dequantize_int8(q, s).astype(g.dtype)
        gs.append(dq)
        es.append((t - dq).astype(g.dtype))
    return (jax.tree_util.tree_unflatten(treedef, gs),
            jax.tree_util.tree_unflatten(treedef, es))


def init_residuals(params):
    return jax.tree.map(jnp.zeros_like, params)
