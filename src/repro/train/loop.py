"""Training loop: jit'd step, auto-resume, straggler watchdog, grad compression.

Fault-tolerance contract:
  * checkpoints every `ckpt_every` steps (atomic, see checkpoint.py);
  * on (re)start, `train()` resumes from the latest checkpoint including the
    data-iterator state — restart-safe under preemption (tests simulate a
    mid-run kill);
  * the data pipeline is statelessly indexable, so a restore onto a
    different mesh/host-count replays the exact global batch sequence
    (elastic scaling);
  * a per-step watchdog flags stragglers (steps slower than
    `straggler_factor` x the running median get logged for the operator —
    on real fleets this feeds the reschedule signal).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.data.pipeline import DataIterator
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import optimizer as opt


def make_train_step(model, tcfg: TrainConfig, total_steps: int):
    sched = opt.warmup_cosine(tcfg.lr, tcfg.warmup, total_steps)
    use_ef = tcfg.grad_compression == "int8_ef"

    def step(params, opt_state, residuals, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if use_ef:
            grads, residuals = comp.ef_compress(grads, residuals)
        params, opt_state, info = opt.adamw_update(
            grads, opt_state, params, lr_sched=sched, b1=tcfg.b1, b2=tcfg.b2,
            eps=tcfg.eps, weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip)
        info["loss"] = loss
        return params, opt_state, residuals, info

    return jax.jit(step, donate_argnums=(0, 1, 2))


def train(model, params, data_it: DataIterator, tcfg: TrainConfig, *,
          step_fn: Optional[Callable] = None,
          log: Callable = print, log_every: int = 20,
          fault_injector: Optional[Callable] = None,
          straggler_factor: float = 3.0):
    """Run tcfg.steps steps, resuming from tcfg.ckpt_dir if present.

    ``step_fn(params, opt_state, residuals, batch) -> (params, opt_state,
    residuals, info)`` overrides the default jit'd step — the production
    path wraps ``repro.dist.steps.build_train_step`` (plan-sharded, donated
    buffers) this way; the default remains the single-host step."""
    opt_state = opt.adamw_init(params)
    residuals = comp.init_residuals(params) \
        if tcfg.grad_compression == "int8_ef" else ()
    start = 0
    try:
        latest = ckpt.latest_step(tcfg.ckpt_dir)
    except Exception:
        latest = None
    if latest is not None:
        (params, opt_state, residuals), meta = ckpt.restore(
            tcfg.ckpt_dir, (params, opt_state, residuals))
        start = meta["step"]
        data_it.restore(meta["extra"]["data"])
        log(f"[train] resumed from step {start}")

    if step_fn is None:
        step_fn = make_train_step(model, tcfg, tcfg.steps)
    durations = []
    losses = []
    for s in range(start, tcfg.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in next(data_it).items()}
        if fault_injector is not None:
            fault_injector(s)
        params, opt_state, residuals, info = step_fn(
            params, opt_state, residuals, batch)
        loss = float(info["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        durations.append(dt)
        med = float(np.median(durations[-50:]))
        if len(durations) > 5 and dt > straggler_factor * med:
            log(f"[train][watchdog] step {s} straggled: {dt:.3f}s "
                f"vs median {med:.3f}s")
        if (s + 1) % log_every == 0:
            log(f"[train] step {s + 1}/{tcfg.steps} loss={loss:.4f} "
                f"lr={float(info['lr']):.2e} {dt * 1e3:.0f}ms")
        if (s + 1) % tcfg.ckpt_every == 0 or s + 1 == tcfg.steps:
            ckpt.save(tcfg.ckpt_dir, s + 1, (params, opt_state, residuals),
                      extra={"data": data_it.state}, keep=tcfg.keep)
    return params, losses
