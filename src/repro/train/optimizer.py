"""Optimizers (AdamW / SGD) with grad clipping — self-contained (no optax)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object


def adamw_init(params):
    z = lambda: jax.tree.map(jnp.zeros_like, params)
    return AdamState(jnp.zeros((), jnp.int32), z(), z())


def clip_by_global_norm(grads, max_norm):
    g2 = sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def warmup_cosine(lr, warmup, total):
    def sched(step):
        s = step.astype(jnp.float32)
        wu = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        return lr * wu * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return sched


def adamw_update(grads, state: AdamState, params, *, lr_sched, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.01, grad_clip=1.0):
    grads, gnorm = clip_by_global_norm(grads, grad_clip)
    step = state.step + 1
    lr = lr_sched(step)
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, mi, vi):
        u = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        return p - lr * (u + weight_decay * p)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamState(step, m, v), {"grad_norm": gnorm, "lr": lr}


def sgd_update(grads, params, lr):
    """Paper App. G: plain SGD is used for the gradient computations — the
    OAC pipeline itself never steps the optimizer; provided for completeness."""
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)
