"""Atomic, mesh-agnostic checkpoints (npz + JSON manifest).

Checkpoints store full (unsharded) arrays keyed by pytree path, so a restore
can re-shard onto ANY mesh — this is what makes elastic scaling work: a job
that loses a pod restarts on the smaller mesh and `restore` lays the same
arrays out under the new sharding (see train/loop.py and tests).

Write protocol: write to `<dir>/tmp.<step>`, fsync, atomic-rename to
`step_<n>`, update `latest` marker last.  A crash at any point leaves either
the old or the new checkpoint intact, never a torn one.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro import utils


def _flatten(tree) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in utils.tree_paths(tree).items()}


def _unflatten_into(template, flat: Dict[str, np.ndarray], strict=True):
    paths = utils.tree_paths(template)
    missing = set(paths) - set(flat)
    if missing and strict:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    flat_tpl, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = [flat.get(utils.path_str(p), tpl) for p, tpl in flat_tpl]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
         keep: int = 3):
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k.replace("/", "|"): v for k, v in flat.items()})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "extra": extra or {}}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(marker):
        return None
    name = open(marker).read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template, step: Optional[int] = None,
            shardings=None, strict: bool = True) -> Tuple[Any, dict]:
    """Restore into `template`'s structure; lay out per `shardings` if given
    (a pytree of NamedSharding matching template) — the elastic-rescale path.
    strict=False keeps template leaves for keys absent from the checkpoint
    (schema-evolution tolerance)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    flat = {k.replace("|", "/"): data[k] for k in data.files}
    tree = _unflatten_into(template, flat, strict=strict)
    meta = json.load(open(os.path.join(d, "meta.json")))
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta
