"""HTTP front-end benchmark: what does the network surface cost?

Serves the same shared-prefix workload twice from identical engines —
once by driving ``PagedEngine.run`` in process, once streaming through
``serving/api`` over a real loopback socket (driver thread + SSE framing
+ per-token asyncio hops) — and reports tokens/sec for both plus the
ratio.  A third cell fans the HTTP requests out over concurrent client
threads, the shape a load-balancer actually delivers.

The interesting number is the ratio: the engine tick is jit'd model
work, so the bridge/HTTP machinery should cost a modest constant per
token, not a multiple.  ``--check`` trips (exit 1) when single-client
HTTP throughput falls below ``HTTP_FLOOR`` x in-process — at toy scale
the per-token model work is tiny and absorbs the whole framing cost, so
a deep regression here means the bridge is stalling the driver (e.g. a
blocking hop on the token path), not that SSE got slower.

    python benchmarks/bench_api.py [--smoke] [--check]
                                   [--out BENCH_api.json]
"""
import argparse
import json
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import numpy as np              # noqa: E402

from repro.configs import get_smoke                         # noqa: E402
from repro.launch import client as cl                       # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.serving.api import ApiServer, EngineBridge       # noqa: E402
from repro.serving.engine import PagedEngine                # noqa: E402

# single-client HTTP tokens/sec vs in-process on the same engine; the
# asyncio hop per token is microseconds against a millisecond-scale tick,
# so falling below this means the driver is being stalled, not framed
HTTP_FLOOR = 0.5


def _workload(cfg, requests, prefix_len=32, suffix_len=12):
    rng = np.random.default_rng(3)
    pre = cl.shared_prefix(prefix_len, cfg.vocab)
    return [pre + [int(t) for t in rng.integers(0, cfg.vocab,
                                                size=suffix_len)]
            for _ in range(requests)]


def _engine(cfg, params, args):
    return PagedEngine(cfg, params, max_batch=args.max_batch,
                       capacity=args.capacity,
                       block_size=args.block_size)


def _run_inprocess(cfg, params, args, prompts):
    eng = _engine(cfg, params, args)
    rs = [eng.submit(np.asarray(p, np.int32), max_tokens=args.max_tokens)
          for p in prompts]
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    return sum(len(r.out) for r in rs), wall


def _run_http(cfg, params, args, prompts, client_threads):
    eng = _engine(cfg, params, args)
    bridge = EngineBridge(eng, idle_wait=0.002).start()
    server = ApiServer(bridge, model_info={"arch": cfg.name,
                                           "vocab": cfg.vocab})
    port = server.start()
    counts = [0] * len(prompts)
    errs = []

    def worker(idxs):
        for i in idxs:
            try:
                counts[i] = sum(
                    1 for t, _ in cl.complete(port, prompts[i],
                                              max_tokens=args.max_tokens)
                    if t is not None)
            except Exception as e:
                errs.append(repr(e))

    try:
        t0 = time.perf_counter()
        ts = [threading.Thread(
            target=worker, args=(range(w, len(prompts), client_threads),))
            for w in range(client_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        wall = time.perf_counter() - t0
    finally:
        server.stop()
        bridge.stop()
    if errs:
        raise RuntimeError(f"client errors: {errs[:3]}")
    return sum(counts), wall


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells (CI-sized)")
    ap.add_argument("--check", action="store_true",
                    help=f"exit 1 if single-client HTTP tokens/sec "
                         f"< {HTTP_FLOOR}x in-process")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--clients", type=int, default=4,
                    help="threads for the concurrent-client cell")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_api.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.max_tokens = 8, 8

    cfg = get_smoke(args.arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    prompts = _workload(cfg, args.requests)

    # warmup pass compiles the tick outside every timed wall
    _run_inprocess(cfg, params, args, prompts[:2])

    cells = {}
    toks, wall = _run_inprocess(cfg, params, args, prompts)
    cells["inprocess"] = {"tokens": toks, "wall_s": round(wall, 4),
                          "tokens_per_s": round(toks / wall, 1)}
    toks, wall = _run_http(cfg, params, args, prompts, 1)
    cells["http_1_client"] = {"tokens": toks, "wall_s": round(wall, 4),
                              "tokens_per_s": round(toks / wall, 1)}
    toks, wall = _run_http(cfg, params, args, prompts, args.clients)
    cells[f"http_{args.clients}_clients"] = {
        "tokens": toks, "wall_s": round(wall, 4),
        "tokens_per_s": round(toks / wall, 1)}

    ratio = cells["http_1_client"]["tokens_per_s"] \
        / cells["inprocess"]["tokens_per_s"]
    report = {"arch": cfg.name, "requests": args.requests,
              "max_tokens": args.max_tokens, "cells": cells,
              "http_over_inprocess": round(ratio, 3)}
    for name, c in cells.items():
        print(f"[bench_api] {name:>22}: {c['tokens']:4d} tokens "
              f"in {c['wall_s']:.2f}s = {c['tokens_per_s']:.0f} tok/s")
    print(f"[bench_api] http/in-process ratio: {ratio:.2f}")
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"[bench_api] -> {args.out}")
    if args.check and ratio < HTTP_FLOOR:
        print(f"[bench_api] TRIPWIRE: http {ratio:.2f}x in-process "
              f"< {HTTP_FLOOR}x floor")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
