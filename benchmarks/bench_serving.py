"""Serving benchmark: static cohorts vs continuous batching vs paged KV
vs quantized serving (packed weights + int8 paged KV).

Replays two workloads through the engines:

  * uniform: mixed prompt lengths + uneven budgets (the shape that makes
    static batching burn decode steps into the discard buffer) — run
    through ``StaticEngine``, continuous ``Engine``, and ``PagedEngine``,
    dense and RTN-quantized.  The paged engine must not regress below the
    continuous-dense engine here (CI tripwire): block tables buy memory,
    not throughput, and must not cost throughput either.
  * shared_prefix: every request carries the same system prompt (the
    dominant million-user traffic shape) — continuous vs paged (fp and
    int8-KV, plus an RTN-w4 paged row), reporting tokens/sec, KV bytes per
    request, and prefill tokens skipped by prefix sharing (CI tripwires:
    >= 30% of prefill tokens skipped; int8 paged KV bytes/request <= 0.6x
    the fp16-equivalent paged baseline).

The quantized section also reports **packed-weight bytes per device under
tp** (over a device-free AbstractMesh, via ``qserve.report``): sharded
planes report ~total/tp, replicated planes would report ~total — the
tripwire that proves plane sharding is real.

Each cell gets one untimed warmup pass so jit compilation does not pollute
the walls.

    python benchmarks/bench_serving.py [--smoke | --quant-smoke]
                                       [--out BENCH_serving.json]

Emits ``BENCH_serving.json``; CI runs the --smoke and --quant-smoke
invocations on the tiny config as regression tripwires.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.configs import get_smoke                         # noqa: E402
from repro.configs.base import QuantConfig                  # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.models.attention import KVCache, PagedKVCache    # noqa: E402
from repro.serving.engine import (Engine, PagedEngine,      # noqa: E402
                                  StaticEngine, _cache_nodes)
from repro.serving.quantized import quantize_params_rtn     # noqa: E402

# paged must stay within this factor of continuous-dense tokens/sec on the
# uniform workload (the gather/table overhead budget; <1.0 only to absorb
# wall-clock noise at toy scale — the CI cell runs single-digit seconds
# and repeat runs land 0.93-1.04x; a real gather pessimization shows up
# far below this)
PAGED_UNIFORM_FLOOR = 0.85
MIN_PREFIX_SKIP_FRACTION = 0.30
# int8 paged KV bytes/request vs the fp16-equivalent paged baseline
# (pool blocks only -- window rings / recurrent state stay dense fp by
# design and are excluded from both sides): the analytic ratio is
# (head_dim + 2) / (2 * head_dim) -- 0.5625 at the toy head_dim=16,
# 0.508 at head_dim=128 -- so 0.6 trips on any layout regression
# (scale-plane bloat, codes stored wider than int8)
MAX_INT8_KV_RATIO = 0.60


def workload(cfg, n_requests, seed=0):
    """Mixed prompt lengths + uneven max_tokens: the continuous engine's
    home turf (a static cohort drains at the slowest member's budget)."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([8, 12, 16], size=n_requests)
    budgets = rng.integers(4, 33, size=n_requests)
    return [(rng.integers(1, cfg.vocab, size=int(s)).astype(np.int32),
             int(b)) for s, b in zip(lens, budgets)]


def workload_shared_prefix(cfg, n_requests, prefix_len=48, seed=0):
    """One shared system prompt + short unique tails: the prefix-sharing
    target shape.  ``prefix_len`` is chosen so full blocks dominate."""
    rng = np.random.default_rng(seed)
    sysp = rng.integers(1, cfg.vocab, size=prefix_len).astype(np.int32)
    out = []
    for _ in range(n_requests):
        tail = rng.integers(1, cfg.vocab,
                            size=int(rng.choice([3, 5, 8]))).astype(np.int32)
        out.append((np.concatenate([sysp, tail]), int(rng.integers(4, 17))))
    return out


def kv_bytes_split(eng):
    """(dense bytes/request, paged-pool bytes/request).  The paged engine
    counts blocks actually held at retirement (pool bytes scale with live
    tokens); dense engines reserve a full-capacity slot per request.
    int8 pools count their code bytes plus the per-token scale planes."""
    cache = getattr(eng, "_cache", None)
    if cache is None:                 # static engine: per-cohort allocation
        cache = eng.model.init_cache(eng.max_batch, eng.capacity,
                                     dtype=jnp.float32, abstract=True)
    nodes, _ = _cache_nodes(cache)
    dense_per_slot = 0.0
    block_bytes = 0.0
    for n in nodes:
        if isinstance(n, PagedKVCache):
            itm = np.dtype(n.k.dtype).itemsize
            # (stack, nb, bs, KV, hd) -> bytes of one block across the
            # layer stack, k + v
            block_bytes += 2 * itm * n.k.shape[0] * int(
                np.prod(n.k.shape[2:]))
            if n.k_scale is not None:   # int8 pool: scale planes ride along
                sitm = np.dtype(n.k_scale.dtype).itemsize
                block_bytes += 2 * sitm * n.k_scale.shape[0] * int(
                    np.prod(n.k_scale.shape[2:]))
        elif isinstance(n, KVCache):
            itm = np.dtype(n.k.dtype).itemsize
            B = n.k.shape[-4]
            dense_per_slot += 2 * itm * int(np.prod(n.k.shape)) / B
    held = getattr(eng, "blocks_held_at_retire", None)
    paged = block_bytes * float(np.mean(held)) if held else 0.0
    return dense_per_slot, paged


def kv_bytes_per_request(eng):
    dense, paged = kv_bytes_split(eng)
    return dense + paged


def run_workload(eng, reqs):
    ticks0 = getattr(eng, "ticks", 0)
    skip0 = getattr(eng, "prefill_tokens_skipped", 0)
    comp0 = getattr(eng, "prefill_tokens_computed", 0)
    handles = [eng.submit(p, max_tokens=b) for p, b in reqs]
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in handles)
    lats = sorted(r.finish_wall for r in handles)
    kv_dense, kv_paged = kv_bytes_split(eng)
    return {
        "kv_paged_bytes_per_request": kv_paged,
        "wall_s": wall,
        "generated_tokens": toks,
        "tokens_per_s": toks / wall,
        "latency_mean_s": float(np.mean(lats)),
        "latency_p99_s": float(np.quantile(lats, 0.99)),
        "ticks": getattr(eng, "ticks", 0) - ticks0 or None,
        "prefill_tokens_skipped":
            getattr(eng, "prefill_tokens_skipped", 0) - skip0,
        "prefill_tokens_computed":
            getattr(eng, "prefill_tokens_computed", 0) - comp0,
        "kv_bytes_per_request": kv_dense + kv_paged,
    }


def bench_cell(name, make_engine, reqs):
    # warmup and timed pass reuse ONE engine instance: the jit caches live
    # on the instance's closures, so a fresh engine would recompile every
    # shape during the timed pass and the walls would measure XLA, not
    # serving throughput
    eng = make_engine()
    run_workload(eng, reqs)                                 # warmup/compile
    res = run_workload(eng, reqs)
    print(f"[bench_serving] {name:28s} {res['tokens_per_s']:8.1f} tok/s  "
          f"mean {res['latency_mean_s'] * 1e3:7.1f} ms  "
          f"p99 {res['latency_p99_s'] * 1e3:7.1f} ms  "
          f"kv/req {res['kv_bytes_per_request'] / 1024:7.1f} KiB  "
          f"skip {res['prefill_tokens_skipped']:4d}")
    return res


def bench_quantized(cfg, params, args, results, regressed, quantized=None):
    """Quantized serving cells: int8 paged KV vs fp paged on the
    shared-prefix workload, an RTN-w4 paged row, and the packed-weight
    bytes-per-device report under virtual tp.  ``quantized`` is an
    already-packed (params, skipped) pair when the caller has one (the
    full run), else packed here."""
    n = 8 if args.quant_smoke else args.requests
    shared_reqs = workload_shared_prefix(cfg, n)
    cells = results["cells"]

    def paged(p, kv_bits=16):
        return PagedEngine(cfg, p, max_batch=args.max_batch,
                           capacity=args.capacity,
                           block_size=args.block_size, kv_bits=kv_bits)

    fp = bench_cell("shared/paged/fp-kv", lambda: paged(params), shared_reqs)
    i8 = bench_cell("shared/paged/int8-kv", lambda: paged(params, 8),
                    shared_reqs)
    cells["shared_paged_fp_kv"] = fp
    cells["shared_paged_int8_kv"] = i8
    # pool blocks only (window rings / recurrent state stay dense fp by
    # design); the engine stores fp pools in f32, so halve for the
    # fp16-equivalent baseline the paper-level claim is against
    fp16_equiv = fp["kv_paged_bytes_per_request"] / 2.0
    ratio = i8["kv_paged_bytes_per_request"] / fp16_equiv
    cells["int8_kv_bytes_ratio_vs_fp16"] = ratio
    print(f"[bench_serving] int8 paged KV pool: "
          f"{i8['kv_paged_bytes_per_request'] / 1024:.1f} KiB/req vs "
          f"{fp16_equiv / 1024:.1f} KiB/req fp16-equiv "
          f"({1 - ratio:.0%} reduction)")
    if ratio > MAX_INT8_KV_RATIO:
        regressed.append("int8_kv_bytes")
        print(f"[bench_serving] FAIL: int8 paged KV bytes/request "
              f"{ratio:.2f}x fp16 paged (> {MAX_INT8_KV_RATIO})")

    # rtn-w4 packed weights through the paged engine (the quantized row)
    if quantized is None:
        quantized = quantize_params_rtn(
            params, QuantConfig(wbits=args.wbits, group_size=32))
    qp, skipped = quantized
    cells[f"shared_paged_rtn_w{args.wbits}"] = bench_cell(
        f"shared/paged/rtn-w{args.wbits}", lambda: paged(qp), shared_reqs)
    cells["rtn_skipped_kernels"] = skipped

    # packed-weight bytes per device under tp (AbstractMesh: layout-only)
    from repro.dist.sharding import make_plan
    from repro.serving.qserve.report import PACKED_SHARD_SLACK, \
        abstract_tp_mesh, packed_plane_bytes
    mesh = abstract_tp_mesh(args.tp)
    plan = make_plan(cfg, mesh)
    rep = packed_plane_bytes(qp, plan.param_shardings(qp))
    rep["tp"] = plan.tp_size
    cells["packed_plane_bytes"] = rep
    print(f"[bench_serving] packed planes: {rep['total']} B total -> "
          f"{rep['per_device']} B/device under tp={rep['tp']} "
          f"(ratio {rep['ratio']:.3f})")
    if rep["ratio"] > PACKED_SHARD_SLACK / rep["tp"]:
        regressed.append("packed_planes_replicated")
        print(f"[bench_serving] FAIL: packed planes look replicated under "
              f"tp={rep['tp']} (per-device/total = {rep['ratio']:.3f})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI cell: fewer requests, no quantized runs")
    ap.add_argument("--quant-smoke", action="store_true",
                    help="tiny CI cell: ONLY the quantized-serving section "
                         "(rtn-w4 paged, int8 KV, packed bytes/device)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--wbits", type=int, default=4)
    ap.add_argument("--tp", type=int, default=4,
                    help="virtual tp degree for the packed bytes/device "
                         "report (AbstractMesh; no devices needed)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n = 8 if args.smoke else args.requests
    reqs = workload(cfg, n)
    shared_reqs = workload_shared_prefix(cfg, n)

    results = {"arch": cfg.name, "requests": n, "max_batch": args.max_batch,
               "capacity": args.capacity, "block_size": args.block_size,
               "cells": {}}

    if args.quant_smoke:
        regressed = []
        bench_quantized(cfg, params, args, results, regressed)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[bench_serving] wrote {os.path.normpath(args.out)}")
        if regressed:
            sys.exit(1)
        return results

    variants = [("dense", params)]
    quantized = None
    if not args.smoke:
        quantized = quantize_params_rtn(
            params, QuantConfig(wbits=args.wbits, group_size=32))
        variants.append((f"rtn_w{args.wbits}", quantized[0]))

    def makers(p):
        return (("static", lambda: StaticEngine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity)),
                ("continuous", lambda: Engine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity)),
                ("paged", lambda: PagedEngine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity, block_size=args.block_size)))

    # ---- uniform workload: all three engines
    for vname, p in variants:
        for ename, mk in makers(p):
            results["cells"][f"{ename}_{vname}"] = bench_cell(
                f"{ename}/{vname}", mk, reqs)

    # ---- shared-prefix workload: continuous-dense vs paged
    for ename, mk in makers(params)[1:]:
        results["cells"][f"shared_{ename}_dense"] = bench_cell(
            f"shared/{ename}/dense", mk, shared_reqs)

    regressed = []
    for vname, _ in variants:
        s = results["cells"][f"static_{vname}"]["tokens_per_s"]
        c = results["cells"][f"continuous_{vname}"]["tokens_per_s"]
        g = results["cells"][f"paged_{vname}"]["tokens_per_s"]
        results["cells"][f"speedup_{vname}"] = c / s
        results["cells"][f"paged_vs_continuous_{vname}"] = g / c
        print(f"[bench_serving] continuous/{vname} speedup over static: "
              f"{c / s:.2f}x; paged vs continuous: {g / c:.2f}x")
        if c <= s:
            regressed.append(f"continuous_{vname}")
            print(f"[bench_serving] FAIL: continuous did not beat static "
                  f"on {vname}")
        if g < PAGED_UNIFORM_FLOOR * c:
            regressed.append(f"paged_{vname}")
            print(f"[bench_serving] FAIL: paged regressed below "
                  f"continuous-dense on the uniform workload ({g / c:.2f}x "
                  f"< {PAGED_UNIFORM_FLOOR})")

    sp = results["cells"]["shared_paged_dense"]
    sc = results["cells"]["shared_continuous_dense"]
    skip_frac = sp["prefill_tokens_skipped"] / max(
        1, sp["prefill_tokens_skipped"] + sp["prefill_tokens_computed"])
    results["cells"]["shared_prefix_skip_fraction"] = skip_frac
    results["cells"]["shared_kv_bytes_ratio"] = \
        sp["kv_bytes_per_request"] / sc["kv_bytes_per_request"]
    print(f"[bench_serving] shared-prefix: {skip_frac:.0%} prefill tokens "
          f"skipped; kv bytes/request {sp['kv_bytes_per_request'] / 1024:.1f}"
          f" KiB paged vs {sc['kv_bytes_per_request'] / 1024:.1f} KiB dense")
    if skip_frac < MIN_PREFIX_SKIP_FRACTION:
        regressed.append("shared_prefix_skip")
        print(f"[bench_serving] FAIL: prefix sharing skipped only "
              f"{skip_frac:.0%} of prefill tokens "
              f"(< {MIN_PREFIX_SKIP_FRACTION:.0%})")

    if not args.smoke:   # full run: quantized serving section too
        bench_quantized(cfg, params, args, results, regressed, quantized)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_serving] wrote {os.path.normpath(args.out)}")
    if regressed:                     # the CI tripwire: fail the step
        sys.exit(1)
    return results


if __name__ == "__main__":
    main()
