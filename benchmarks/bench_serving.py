"""Serving benchmark: static cohorts vs continuous batching.

Replays the same mixed-length, uneven-budget workload (the shape that makes
static batching burn decode steps into the discard buffer) through
``StaticEngine`` and the continuous ``Engine``, dense and RTN-quantized,
and reports tokens/sec plus mean/p99 request latency.  Each cell gets one
untimed warmup pass so jit compilation does not pollute the walls.

    python benchmarks/bench_serving.py [--smoke] [--out BENCH_serving.json]

Emits ``BENCH_serving.json``; CI runs the --smoke invocation on the tiny
config as a regression tripwire (continuous must beat static on tokens/sec
for this workload).
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import numpy as np              # noqa: E402

from repro.configs import get_smoke                         # noqa: E402
from repro.configs.base import QuantConfig                  # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.serving.engine import Engine, StaticEngine       # noqa: E402
from repro.serving.quantized import quantize_params_rtn     # noqa: E402


def workload(cfg, n_requests, seed=0):
    """Mixed prompt lengths + uneven max_tokens: the continuous engine's
    home turf (a static cohort drains at the slowest member's budget)."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([8, 12, 16], size=n_requests)
    budgets = rng.integers(4, 33, size=n_requests)
    return [(rng.integers(1, cfg.vocab, size=int(s)).astype(np.int32),
             int(b)) for s, b in zip(lens, budgets)]


def run_workload(eng, reqs):
    ticks0 = getattr(eng, "ticks", 0)
    handles = [eng.submit(p, max_tokens=b) for p, b in reqs]
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in handles)
    lats = sorted(r.finish_wall for r in handles)
    return {
        "wall_s": wall,
        "generated_tokens": toks,
        "tokens_per_s": toks / wall,
        "latency_mean_s": float(np.mean(lats)),
        "latency_p99_s": float(np.quantile(lats, 0.99)),
        "ticks": getattr(eng, "ticks", 0) - ticks0 or None,
    }


def bench_cell(name, cls, cfg, params, reqs, max_batch, capacity):
    # warmup and timed pass reuse ONE engine instance: the jit caches live
    # on the instance's closures, so a fresh engine would recompile every
    # shape during the timed pass and the walls would measure XLA, not
    # serving throughput
    eng = cls(cfg, params, max_batch=max_batch, capacity=capacity)
    run_workload(eng, reqs)                                 # warmup/compile
    res = run_workload(eng, reqs)
    print(f"[bench_serving] {name:28s} {res['tokens_per_s']:8.1f} tok/s  "
          f"mean {res['latency_mean_s'] * 1e3:7.1f} ms  "
          f"p99 {res['latency_p99_s'] * 1e3:7.1f} ms")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI cell: fewer requests, no quantized runs")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--wbits", type=int, default=4)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n = 8 if args.smoke else args.requests
    reqs = workload(cfg, n)

    results = {"arch": cfg.name, "requests": n, "max_batch": args.max_batch,
               "capacity": args.capacity, "cells": {}}
    variants = [("dense", params)]
    if not args.smoke:
        qp = quantize_params_rtn(
            params, QuantConfig(wbits=args.wbits, group_size=32))
        variants.append((f"rtn_w{args.wbits}", qp))

    for vname, p in variants:
        for ename, cls in (("static", StaticEngine), ("continuous", Engine)):
            results["cells"][f"{ename}_{vname}"] = bench_cell(
                f"{ename}/{vname}", cls, cfg, p, reqs,
                args.max_batch, args.capacity)

    regressed = []
    for vname, _ in variants:
        s = results["cells"][f"static_{vname}"]["tokens_per_s"]
        c = results["cells"][f"continuous_{vname}"]["tokens_per_s"]
        results["cells"][f"speedup_{vname}"] = c / s
        print(f"[bench_serving] continuous/{vname} speedup over static: "
              f"{c / s:.2f}x")
        if c <= s:
            regressed.append(vname)
            print(f"[bench_serving] FAIL: continuous did not beat static "
                  f"on {vname}")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_serving] wrote {os.path.normpath(args.out)}")
    if regressed:                     # the CI tripwire: fail the step
        sys.exit(1)
    return results


if __name__ == "__main__":
    main()
