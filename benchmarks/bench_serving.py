"""Serving benchmark: static cohorts vs continuous batching vs paged KV.

Replays two workloads through the engines:

  * uniform: mixed prompt lengths + uneven budgets (the shape that makes
    static batching burn decode steps into the discard buffer) — run
    through ``StaticEngine``, continuous ``Engine``, and ``PagedEngine``,
    dense and RTN-quantized.  The paged engine must not regress below the
    continuous-dense engine here (CI tripwire): block tables buy memory,
    not throughput, and must not cost throughput either.
  * shared_prefix: every request carries the same system prompt (the
    dominant million-user traffic shape) — continuous vs paged, reporting
    tokens/sec, KV bytes per request, and prefill tokens skipped by
    prefix sharing (CI tripwire: >= 30% of prefill tokens skipped).

Each cell gets one untimed warmup pass so jit compilation does not pollute
the walls.

    python benchmarks/bench_serving.py [--smoke] [--out BENCH_serving.json]

Emits ``BENCH_serving.json``; CI runs the --smoke invocation on the tiny
config as a regression tripwire.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.configs import get_smoke                         # noqa: E402
from repro.configs.base import QuantConfig                  # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.models.attention import KVCache, PagedKVCache    # noqa: E402
from repro.serving.engine import (Engine, PagedEngine,      # noqa: E402
                                  StaticEngine, _cache_nodes)
from repro.serving.quantized import quantize_params_rtn     # noqa: E402

# paged must stay within this factor of continuous-dense tokens/sec on the
# uniform workload (the gather/table overhead budget; <1.0 only to absorb
# wall-clock noise at toy scale — the CI cell runs single-digit seconds
# and repeat runs land 0.93-1.04x; a real gather pessimization shows up
# far below this)
PAGED_UNIFORM_FLOOR = 0.85
MIN_PREFIX_SKIP_FRACTION = 0.30


def workload(cfg, n_requests, seed=0):
    """Mixed prompt lengths + uneven max_tokens: the continuous engine's
    home turf (a static cohort drains at the slowest member's budget)."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([8, 12, 16], size=n_requests)
    budgets = rng.integers(4, 33, size=n_requests)
    return [(rng.integers(1, cfg.vocab, size=int(s)).astype(np.int32),
             int(b)) for s, b in zip(lens, budgets)]


def workload_shared_prefix(cfg, n_requests, prefix_len=48, seed=0):
    """One shared system prompt + short unique tails: the prefix-sharing
    target shape.  ``prefix_len`` is chosen so full blocks dominate."""
    rng = np.random.default_rng(seed)
    sysp = rng.integers(1, cfg.vocab, size=prefix_len).astype(np.int32)
    out = []
    for _ in range(n_requests):
        tail = rng.integers(1, cfg.vocab,
                            size=int(rng.choice([3, 5, 8]))).astype(np.int32)
        out.append((np.concatenate([sysp, tail]), int(rng.integers(4, 17))))
    return out


def kv_bytes_per_request(eng):
    """Resident KV bytes attributable to one request: the paged engine
    counts blocks actually held at retirement (pool bytes scale with live
    tokens); dense engines reserve a full-capacity slot per request."""
    cache = getattr(eng, "_cache", None)
    if cache is None:                 # static engine: per-cohort allocation
        cache = eng.model.init_cache(eng.max_batch, eng.capacity,
                                     dtype=jnp.float32, abstract=True)
    nodes, _ = _cache_nodes(cache)
    dense_per_slot = 0.0
    block_bytes = 0.0
    for n in nodes:
        if isinstance(n, PagedKVCache):
            itm = np.dtype(n.k.dtype).itemsize
            # (stack, nb, bs, KV, hd) -> bytes of one block across the
            # layer stack, k + v
            block_bytes += 2 * itm * n.k.shape[0] * int(
                np.prod(n.k.shape[2:]))
        elif isinstance(n, KVCache):
            itm = np.dtype(n.k.dtype).itemsize
            B = n.k.shape[-4]
            dense_per_slot += 2 * itm * int(np.prod(n.k.shape)) / B
    held = getattr(eng, "blocks_held_at_retire", None)
    if held:
        return dense_per_slot + block_bytes * float(np.mean(held))
    return dense_per_slot


def run_workload(eng, reqs):
    ticks0 = getattr(eng, "ticks", 0)
    skip0 = getattr(eng, "prefill_tokens_skipped", 0)
    comp0 = getattr(eng, "prefill_tokens_computed", 0)
    handles = [eng.submit(p, max_tokens=b) for p, b in reqs]
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in handles)
    lats = sorted(r.finish_wall for r in handles)
    return {
        "wall_s": wall,
        "generated_tokens": toks,
        "tokens_per_s": toks / wall,
        "latency_mean_s": float(np.mean(lats)),
        "latency_p99_s": float(np.quantile(lats, 0.99)),
        "ticks": getattr(eng, "ticks", 0) - ticks0 or None,
        "prefill_tokens_skipped":
            getattr(eng, "prefill_tokens_skipped", 0) - skip0,
        "prefill_tokens_computed":
            getattr(eng, "prefill_tokens_computed", 0) - comp0,
        "kv_bytes_per_request": kv_bytes_per_request(eng),
    }


def bench_cell(name, make_engine, reqs):
    # warmup and timed pass reuse ONE engine instance: the jit caches live
    # on the instance's closures, so a fresh engine would recompile every
    # shape during the timed pass and the walls would measure XLA, not
    # serving throughput
    eng = make_engine()
    run_workload(eng, reqs)                                 # warmup/compile
    res = run_workload(eng, reqs)
    print(f"[bench_serving] {name:28s} {res['tokens_per_s']:8.1f} tok/s  "
          f"mean {res['latency_mean_s'] * 1e3:7.1f} ms  "
          f"p99 {res['latency_p99_s'] * 1e3:7.1f} ms  "
          f"kv/req {res['kv_bytes_per_request'] / 1024:7.1f} KiB  "
          f"skip {res['prefill_tokens_skipped']:4d}")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI cell: fewer requests, no quantized runs")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--wbits", type=int, default=4)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n = 8 if args.smoke else args.requests
    reqs = workload(cfg, n)
    shared_reqs = workload_shared_prefix(cfg, n)

    results = {"arch": cfg.name, "requests": n, "max_batch": args.max_batch,
               "capacity": args.capacity, "block_size": args.block_size,
               "cells": {}}
    variants = [("dense", params)]
    if not args.smoke:
        qp = quantize_params_rtn(
            params, QuantConfig(wbits=args.wbits, group_size=32))
        variants.append((f"rtn_w{args.wbits}", qp))

    def makers(p):
        return (("static", lambda: StaticEngine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity)),
                ("continuous", lambda: Engine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity)),
                ("paged", lambda: PagedEngine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity, block_size=args.block_size)))

    # ---- uniform workload: all three engines
    for vname, p in variants:
        for ename, mk in makers(p):
            results["cells"][f"{ename}_{vname}"] = bench_cell(
                f"{ename}/{vname}", mk, reqs)

    # ---- shared-prefix workload: continuous-dense vs paged
    for ename, mk in makers(params)[1:]:
        results["cells"][f"shared_{ename}_dense"] = bench_cell(
            f"shared/{ename}/dense", mk, shared_reqs)

    regressed = []
    for vname, _ in variants:
        s = results["cells"][f"static_{vname}"]["tokens_per_s"]
        c = results["cells"][f"continuous_{vname}"]["tokens_per_s"]
        g = results["cells"][f"paged_{vname}"]["tokens_per_s"]
        results["cells"][f"speedup_{vname}"] = c / s
        results["cells"][f"paged_vs_continuous_{vname}"] = g / c
        print(f"[bench_serving] continuous/{vname} speedup over static: "
              f"{c / s:.2f}x; paged vs continuous: {g / c:.2f}x")
        if c <= s:
            regressed.append(f"continuous_{vname}")
            print(f"[bench_serving] FAIL: continuous did not beat static "
                  f"on {vname}")
        if g < PAGED_UNIFORM_FLOOR * c:
            regressed.append(f"paged_{vname}")
            print(f"[bench_serving] FAIL: paged regressed below "
                  f"continuous-dense on the uniform workload ({g / c:.2f}x "
                  f"< {PAGED_UNIFORM_FLOOR})")

    sp = results["cells"]["shared_paged_dense"]
    sc = results["cells"]["shared_continuous_dense"]
    skip_frac = sp["prefill_tokens_skipped"] / max(
        1, sp["prefill_tokens_skipped"] + sp["prefill_tokens_computed"])
    results["cells"]["shared_prefix_skip_fraction"] = skip_frac
    results["cells"]["shared_kv_bytes_ratio"] = \
        sp["kv_bytes_per_request"] / sc["kv_bytes_per_request"]
    print(f"[bench_serving] shared-prefix: {skip_frac:.0%} prefill tokens "
          f"skipped; kv bytes/request {sp['kv_bytes_per_request'] / 1024:.1f}"
          f" KiB paged vs {sc['kv_bytes_per_request'] / 1024:.1f} KiB dense")
    if skip_frac < MIN_PREFIX_SKIP_FRACTION:
        regressed.append("shared_prefix_skip")
        print(f"[bench_serving] FAIL: prefix sharing skipped only "
              f"{skip_frac:.0%} of prefill tokens "
              f"(< {MIN_PREFIX_SKIP_FRACTION:.0%})")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_serving] wrote {os.path.normpath(args.out)}")
    if regressed:                     # the CI tripwire: fail the step
        sys.exit(1)
    return results


if __name__ == "__main__":
    main()
