"""Serving benchmark: static cohorts vs continuous batching vs paged KV
vs quantized serving (packed weights + int8 paged KV).

Replays two workloads through the engines:

  * uniform: mixed prompt lengths + uneven budgets (the shape that makes
    static batching burn decode steps into the discard buffer) — run
    through ``StaticEngine``, continuous ``Engine``, and ``PagedEngine``,
    dense and RTN-quantized.  The paged engine must not regress below the
    continuous-dense engine here (CI tripwire): block tables buy memory,
    not throughput, and must not cost throughput either.
  * shared_prefix: every request carries the same system prompt (the
    dominant million-user traffic shape) — continuous vs paged (fp and
    int8-KV, plus an RTN-w4 paged row), reporting tokens/sec, KV bytes per
    request, and prefill tokens skipped by prefix sharing (CI tripwires:
    >= 30% of prefill tokens skipped; int8 paged KV bytes/request <= 0.6x
    the fp16-equivalent paged baseline).

The quantized section also reports **packed-weight bytes per device under
tp** (over a device-free AbstractMesh, via ``qserve.report``): sharded
planes report ~total/tp, replicated planes would report ~total — the
tripwire that proves plane sharding is real.

The scheduling section (``--sched-smoke`` for the CI cell) adds two
latency-shaped workloads:

  * adversarial: one very long prompt dropped mid-stream of 64 short chat
    sessions (alternating interactive/batch SLO classes).  Reports
    per-token inter-tick latency p50/p99 per SLO class from
    ``Request.token_times``; blocking admission stalls every co-resident
    chat for the full prefill, chunked admission bounds the stall at one
    chunk (CI tripwire: chunked interactive p99 <= 0.5x blocking p99).
  * shared-prefix speculative: target-only decode vs self-speculative
    decode from a draft of the same weights.  CI tripwires: greedy output
    bit-identical for both the perfect draft and the rtn-w4 draft
    (rollback-heavy), and perfect-draft tokens/sec >= 1.2x target-only.

Each cell gets one untimed warmup pass so jit compilation does not pollute
the walls.

    python benchmarks/bench_serving.py [--smoke | --quant-smoke |
                                        --sched-smoke]
                                       [--out BENCH_serving.json]

Emits ``BENCH_serving.json``; CI runs the --smoke, --quant-smoke and
--sched-smoke invocations on the tiny config as regression tripwires.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro import obs as obs_mod                            # noqa: E402
from repro.configs import get_smoke                         # noqa: E402
from repro.configs.base import QuantConfig                  # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.models.attention import KVCache, PagedKVCache    # noqa: E402
from repro.serving.engine import (Engine, PagedEngine,      # noqa: E402
                                  StaticEngine, _cache_nodes)
from repro.serving.quantized import quantize_params_rtn     # noqa: E402

# paged must stay within this factor of continuous-dense tokens/sec on the
# uniform workload (the gather/table overhead budget; <1.0 only to absorb
# wall-clock noise at toy scale — the CI cell runs single-digit seconds
# and repeat runs land 0.93-1.04x; a real gather pessimization shows up
# far below this)
PAGED_UNIFORM_FLOOR = 0.85
MIN_PREFIX_SKIP_FRACTION = 0.30
# int8 paged KV bytes/request vs the fp16-equivalent paged baseline
# (pool blocks only -- window rings / recurrent state stay dense fp by
# design and are excluded from both sides): the analytic ratio is
# (head_dim + 2) / (2 * head_dim) -- 0.5625 at the toy head_dim=16,
# 0.508 at head_dim=128 -- so 0.6 trips on any layout regression
# (scale-plane bloat, codes stored wider than int8)
MAX_INT8_KV_RATIO = 0.60
# chunked prefill must cut the interactive-class inter-token p99 on the
# adversarial workload to at most this fraction of blocking admission's
# (the long prompt's one-shot prefill IS the blocking p99; a chunk costs
# well under half of it)
MAX_CHUNKED_P99_RATIO = 0.50
# perfect-draft speculative decode must beat target-only tokens/sec by at
# least this factor on the shared-prefix workload (each tick emits up to
# spec_k+1 tokens per row for one fused dispatch + one host sync)
MIN_SPEC_SPEEDUP = 1.20
# telemetry must be ~free: the full-obs paged engine must keep at least
# this fraction of the no-op-obs engine's tokens/sec on the uniform
# workload (interleaved best-of rounds; the instrumented path costs a few
# dict lookups and float ops per tick, far under toy-scale wall noise)
OBS_OVERHEAD_FLOOR = 0.95


def workload(cfg, n_requests, seed=0):
    """Mixed prompt lengths + uneven max_tokens: the continuous engine's
    home turf (a static cohort drains at the slowest member's budget)."""
    rng = np.random.default_rng(seed)
    lens = rng.choice([8, 12, 16], size=n_requests)
    budgets = rng.integers(4, 33, size=n_requests)
    return [(rng.integers(1, cfg.vocab, size=int(s)).astype(np.int32),
             int(b)) for s, b in zip(lens, budgets)]


def workload_shared_prefix(cfg, n_requests, prefix_len=48, seed=0):
    """One shared system prompt + short unique tails: the prefix-sharing
    target shape.  ``prefix_len`` is chosen so full blocks dominate."""
    rng = np.random.default_rng(seed)
    sysp = rng.integers(1, cfg.vocab, size=prefix_len).astype(np.int32)
    out = []
    for _ in range(n_requests):
        tail = rng.integers(1, cfg.vocab,
                            size=int(rng.choice([3, 5, 8]))).astype(np.int32)
        out.append((np.concatenate([sysp, tail]), int(rng.integers(4, 17))))
    return out


def workload_adversarial(cfg, n_chat=64, long_len=2048, seed=0):
    """The ROADMAP adversarial shape: one very long prompt dropped
    mid-stream of ``n_chat`` short chat sessions.  Chats alternate
    interactive/batch SLO classes; the long prompt is interactive so
    SLO-ordered FIFO admission lands it mid-stream, where its prefill
    stalls every co-resident chat decode unless chunked.  Returns
    ``(prompt, max_tokens, slo)`` triples."""
    rng = np.random.default_rng(seed)

    def chat(i):
        p = rng.integers(1, cfg.vocab, size=int(rng.choice([6, 10, 14])))
        return (p.astype(np.int32), int(rng.integers(4, 10)),
                "interactive" if i % 2 == 0 else "batch")

    reqs = [chat(i) for i in range(n_chat)]
    longp = (rng.integers(1, cfg.vocab, size=long_len).astype(np.int32),
             8, "interactive")
    reqs.insert(n_chat // 2, longp)
    return reqs


def _fam_total(m, name, **sel):
    """Sum of a counter family's child values, optionally filtered to the
    children whose labels match ``sel``.  0 when the family is absent or
    never got children (e.g. spec counters on a non-speculative engine)."""
    fam = m.get(name)
    if fam is None:
        return 0
    total = 0.0
    for vals, c in fam.children().items():
        d = dict(zip(fam.label_names, vals))
        if all(d.get(k) == str(v) for k, v in sel.items()):
            total += c.value
    return int(total)


def _latency_stats(m):
    """(mean_s, p99_s) across the request-latency histogram's SLO children
    (exact quantiles: at bench scale the sample buffer holds every
    observation)."""
    kids = [h for h in
            m.get("engine_request_latency_seconds").children().values()
            if h.count]
    n = sum(h.count for h in kids)
    mean = sum(h.sum for h in kids) / max(1, n)
    p99 = max((h.quantile(0.99) for h in kids), default=0.0)
    return mean, p99


def token_gap_stats(metrics):
    """Per-SLO-class inter-token latency from the engine's
    ``engine_inter_token_seconds`` histogram family — the same gaps the
    engine observes as it stamps ``Request.token_times``, read back as
    exact sample quantiles instead of re-diffed by hand here."""
    out = {}
    for (slo,), h in sorted(
            metrics.get("engine_inter_token_seconds").children().items()):
        if h.count:
            out[slo] = {"n_gaps": int(h.count),
                        "p50_ms": h.quantile(0.50) * 1e3,
                        "p99_ms": h.quantile(0.99) * 1e3,
                        "max_ms": h.max * 1e3}
    return out


def run_sched(eng, reqs):
    """Serve ``(prompt, max_tokens, slo)`` triples; return (stats, outs).
    All accounting reads the engine's own MetricsRegistry: the registry
    is reset going in, so every counter/histogram reads as this pass's
    delta — no attribute-diff bookkeeping."""
    m = eng.obs.metrics
    m.reset()
    handles = [eng.submit(p, max_tokens=b, slo=s) for p, b, s in reqs]
    eng.run()
    wall = m.get("engine_run_seconds").value
    toks = int(m.get("engine_tokens_total").value)
    return {
        "wall_s": wall,
        "generated_tokens": toks,
        "tokens_per_s": toks / wall,
        "ticks": _fam_total(m, "engine_ticks_total"),
        "chunk_steps": _fam_total(m, "engine_sched_events_total",
                                  event="chunk"),
        "preemptions": _fam_total(m, "engine_sched_events_total",
                                  event="preempt"),
        "spec_drafted": _fam_total(m, "engine_spec_tokens_total",
                                   kind="drafted"),
        "spec_accepted": _fam_total(m, "engine_spec_tokens_total",
                                    kind="accepted"),
        "token_gap_ms": token_gap_stats(m),
    }, [list(r.out) for r in handles]


def sched_cell(name, make_engine, reqs, warm_reqs=None, repeats=1):
    """One warmup pass (``warm_reqs`` when the timed pass must not hit the
    prefix cache the warmup populated — the adversarial cells) + ``repeats``
    timed passes on the same engine instance (shared jit caches), keeping
    the fastest (OS noise only ever inflates a wall)."""
    eng = make_engine()
    run_sched(eng, warm_reqs if warm_reqs is not None else reqs)
    res, outs = run_sched(eng, reqs)
    for _ in range(repeats - 1):
        r2, outs = run_sched(eng, reqs)
        if r2["tokens_per_s"] > res["tokens_per_s"]:
            res = r2
    gaps = "  ".join(
        f"{slo[:5]} p50 {g['p50_ms']:6.2f} p99 {g['p99_ms']:7.2f} ms"
        for slo, g in res["token_gap_ms"].items())
    acc = (f"  acc {res['spec_accepted']}/{res['spec_drafted']}"
           if res["spec_drafted"] else "")
    print(f"[bench_serving] {name:28s} {res['tokens_per_s']:8.1f} tok/s  "
          f"{gaps}{acc}")
    return res, outs


def bench_sched(cfg, params, args, results, regressed):
    """Latency-shaped scheduling cells: blocking vs chunked admission on
    the adversarial workload (per-SLO inter-token histograms), and
    target-only vs self-speculative decode on the shared-prefix one."""
    smoke = args.sched_smoke
    n_chat = 16 if smoke else 64
    long_len = 1024 if smoke else 2048
    chunk = 128
    mb = 4 if smoke else 8
    cap = long_len + 64
    cells = results["cells"]

    adv = workload_adversarial(cfg, n_chat=n_chat, long_len=long_len)
    adv_warm = workload_adversarial(cfg, n_chat=n_chat, long_len=long_len,
                                    seed=1)

    def paged(capacity, **kw):
        return PagedEngine(cfg, params, max_batch=mb, capacity=capacity,
                           block_size=args.block_size, **kw)

    blk, _ = sched_cell("adv/blocking-prefill",
                        lambda: paged(cap), adv, warm_reqs=adv_warm)
    chk, _ = sched_cell(f"adv/chunked-{chunk}",
                        lambda: paged(cap, prefill_chunk=chunk),
                        adv, warm_reqs=adv_warm)
    cells["adversarial_blocking"] = blk
    cells["adversarial_chunked"] = chk
    bp = blk["token_gap_ms"]["interactive"]["p99_ms"]
    cp = chk["token_gap_ms"]["interactive"]["p99_ms"]
    cells["chunked_p99_ratio"] = cp / bp
    print(f"[bench_serving] chunked prefill interactive p99: {cp:.2f} ms "
          f"vs {bp:.2f} ms blocking ({cp / bp:.2f}x)")
    if cp > MAX_CHUNKED_P99_RATIO * bp:
        regressed.append("chunked_prefill_p99")
        print(f"[bench_serving] FAIL: chunked prefill interactive p99 "
              f"{cp / bp:.2f}x blocking (> {MAX_CHUNKED_P99_RATIO})")

    # ---- speculative decode: shared-prefix workload, greedy.  Budgets
    # are stretched so the decode phase dominates the wall (the speedup
    # under test is a decode-loop property), and each cell keeps the
    # fastest of 3 timed passes — at toy scale a single ~0.3s pass is
    # scheduler-noise-bound and the ratio swings either way
    n = 6 if smoke else 12
    sreqs = [(p, b + (8 if smoke else 32), "interactive")
             for p, b in workload_shared_prefix(cfg, n)]
    tgt, tgt_out = sched_cell("shared/target-only",
                              lambda: paged(128), sreqs, repeats=3)
    spec, spec_out = sched_cell(
        "shared/spec-perfect-draft",
        lambda: paged(128, draft=params, spec_k=4), sreqs, repeats=3)
    qd, _ = quantize_params_rtn(params, QuantConfig(wbits=4, group_size=32))
    rtn, rtn_out = sched_cell(
        "shared/spec-rtn-w4-draft",
        lambda: paged(128, draft=qd, spec_k=4), sreqs, repeats=3)
    cells["shared_target_only"] = tgt
    cells["shared_spec_perfect"] = spec
    cells["shared_spec_rtn_w4"] = rtn
    speedup = spec["tokens_per_s"] / tgt["tokens_per_s"]
    cells["spec_speedup_perfect_draft"] = speedup
    print(f"[bench_serving] speculative speedup (perfect draft): "
          f"{speedup:.2f}x target-only; rtn-w4 draft acceptance "
          f"{rtn['spec_accepted']}/{rtn['spec_drafted']}")
    for label, outs in (("perfect", spec_out), ("rtn_w4", rtn_out)):
        if outs != tgt_out:
            regressed.append(f"spec_bit_identity_{label}")
            print(f"[bench_serving] FAIL: speculative greedy output "
                  f"({label} draft) diverged from target-only decode")
    if speedup < MIN_SPEC_SPEEDUP:
        regressed.append("spec_speedup")
        print(f"[bench_serving] FAIL: perfect-draft speculation only "
              f"{speedup:.2f}x target-only (< {MIN_SPEC_SPEEDUP})")


def kv_bytes_split(eng):
    """(dense bytes/request, paged-pool bytes/request).  The paged engine
    counts blocks actually held at retirement (pool bytes scale with live
    tokens); dense engines reserve a full-capacity slot per request.
    int8 pools count their code bytes plus the per-token scale planes."""
    cache = getattr(eng, "_cache", None)
    if cache is None:                 # static engine: per-cohort allocation
        cache = eng.model.init_cache(eng.max_batch, eng.capacity,
                                     dtype=jnp.float32, abstract=True)
    nodes, _ = _cache_nodes(cache)
    dense_per_slot = 0.0
    block_bytes = 0.0
    for n in nodes:
        if isinstance(n, PagedKVCache):
            itm = np.dtype(n.k.dtype).itemsize
            # (stack, nb, bs, KV, hd) -> bytes of one block across the
            # layer stack, k + v
            block_bytes += 2 * itm * n.k.shape[0] * int(
                np.prod(n.k.shape[2:]))
            if n.k_scale is not None:   # int8 pool: scale planes ride along
                sitm = np.dtype(n.k_scale.dtype).itemsize
                block_bytes += 2 * sitm * n.k_scale.shape[0] * int(
                    np.prod(n.k_scale.shape[2:]))
        elif isinstance(n, KVCache):
            itm = np.dtype(n.k.dtype).itemsize
            B = n.k.shape[-4]
            dense_per_slot += 2 * itm * int(np.prod(n.k.shape)) / B
    held = getattr(eng, "blocks_held_at_retire", None)
    paged = block_bytes * float(np.mean(held)) if held else 0.0
    return dense_per_slot, paged


def kv_bytes_per_request(eng):
    dense, paged = kv_bytes_split(eng)
    return dense + paged


def run_workload(eng, reqs):
    """One timed pass; accounting comes from the engine's MetricsRegistry
    (reset going in, so every value is this pass's delta)."""
    m = eng.obs.metrics
    m.reset()
    for p, b in reqs:
        eng.submit(p, max_tokens=b)
    eng.run()
    wall = m.get("engine_run_seconds").value
    toks = int(m.get("engine_tokens_total").value)
    lat_mean, lat_p99 = _latency_stats(m)
    kv_dense, kv_paged = kv_bytes_split(eng)
    return {
        "kv_paged_bytes_per_request": kv_paged,
        "wall_s": wall,
        "generated_tokens": toks,
        "tokens_per_s": toks / wall,
        "latency_mean_s": lat_mean,
        "latency_p99_s": lat_p99,
        "ticks": _fam_total(m, "engine_ticks_total") or None,
        "prefill_tokens_skipped":
            _fam_total(m, "engine_prefill_tokens_total", kind="skipped"),
        "prefill_tokens_computed":
            _fam_total(m, "engine_prefill_tokens_total", kind="computed"),
        "kv_bytes_per_request": kv_dense + kv_paged,
    }


def _print_cell(name, res):
    print(f"[bench_serving] {name:28s} {res['tokens_per_s']:8.1f} tok/s  "
          f"mean {res['latency_mean_s'] * 1e3:7.1f} ms  "
          f"p99 {res['latency_p99_s'] * 1e3:7.1f} ms  "
          f"kv/req {res['kv_bytes_per_request'] / 1024:7.1f} KiB  "
          f"skip {res['prefill_tokens_skipped']:4d}")


def bench_cell(name, make_engine, reqs):
    # warmup and timed passes reuse ONE engine instance: the jit caches
    # live on the instance's closures, so a fresh engine would recompile
    # every shape during the timed pass and the walls would measure XLA,
    # not serving throughput.  Best of two timed passes: a toy-scale pass
    # is ~100ms, and OS scheduler noise only ever inflates a wall
    eng = make_engine()
    run_workload(eng, reqs)                                 # warmup/compile
    res = run_workload(eng, reqs)
    r2 = run_workload(eng, reqs)
    if r2["tokens_per_s"] > res["tokens_per_s"]:
        res = r2
    _print_cell(name, res)
    return res


def bench_group(named_makers, reqs, rounds=3):
    """Benchmark cells whose walls get *ratioed* against each other (the
    static/continuous/paged tripwires): every engine warms up once, then
    timed passes run in interleaved rounds (A, B, C, A, B, C, ...) and
    each cell keeps its fastest.  Machine drift between rounds hits every
    cell of the group equally instead of biasing whichever cell happened
    to run in the slow minute — cells measured minutes apart cannot give
    a trustworthy ~0.9x ratio on ~100 ms toy-scale walls."""
    engines = [(name, mk()) for name, mk in named_makers]
    for _, eng in engines:
        run_workload(eng, reqs)                             # warmup/compile
    best = {}
    for _ in range(rounds):
        for name, eng in engines:
            r = run_workload(eng, reqs)
            if name not in best or \
                    r["tokens_per_s"] > best[name]["tokens_per_s"]:
                best[name] = r
    for name, _ in engines:
        _print_cell(name, best[name])
    return best


def bench_obs_overhead(cfg, params, args, results, regressed, reqs):
    """The no-op-mode tripwire: the same paged engine with full telemetry
    (default obs) vs the shared no-op bundle (``obs_mod.OFF``).  The off
    engine's registry is the null object, so both cells count tokens from
    the request handles and time ``run()`` directly — an identical
    measurement that depends on neither registry."""
    def mk(obs=None):
        return PagedEngine(cfg, params, max_batch=args.max_batch,
                           capacity=args.capacity,
                           block_size=args.block_size, obs=obs)

    def raw_pass(eng):
        handles = [eng.submit(p, max_tokens=b) for p, b in reqs]
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        return sum(len(r.out) for r in handles) / wall

    engines = [("obs_on", mk()), ("obs_off", mk(obs_mod.OFF))]
    for _, eng in engines:
        raw_pass(eng)                                       # warmup/compile
    best = {name: 0.0 for name, _ in engines}
    for _ in range(3):            # interleaved rounds, like bench_group
        for name, eng in engines:
            best[name] = max(best[name], raw_pass(eng))
    ratio = best["obs_on"] / best["obs_off"]
    results["cells"]["obs_overhead_ratio"] = ratio
    print(f"[bench_serving] obs overhead: {best['obs_on']:8.1f} tok/s on vs "
          f"{best['obs_off']:8.1f} tok/s off ({ratio:.2f}x)")
    if ratio < OBS_OVERHEAD_FLOOR:
        regressed.append("obs_overhead")
        print(f"[bench_serving] FAIL: obs-on tokens/sec {ratio:.2f}x "
              f"obs-off (< {OBS_OVERHEAD_FLOOR})")


def bench_quantized(cfg, params, args, results, regressed, quantized=None):
    """Quantized serving cells: int8 paged KV vs fp paged on the
    shared-prefix workload, an RTN-w4 paged row, and the packed-weight
    bytes-per-device report under virtual tp.  ``quantized`` is an
    already-packed (params, skipped) pair when the caller has one (the
    full run), else packed here."""
    n = 8 if args.quant_smoke else args.requests
    shared_reqs = workload_shared_prefix(cfg, n)
    cells = results["cells"]

    def paged(p, kv_bits=16):
        return PagedEngine(cfg, p, max_batch=args.max_batch,
                           capacity=args.capacity,
                           block_size=args.block_size, kv_bits=kv_bits)

    fp = bench_cell("shared/paged/fp-kv", lambda: paged(params), shared_reqs)
    i8 = bench_cell("shared/paged/int8-kv", lambda: paged(params, 8),
                    shared_reqs)
    cells["shared_paged_fp_kv"] = fp
    cells["shared_paged_int8_kv"] = i8
    # pool blocks only (window rings / recurrent state stay dense fp by
    # design); the engine stores fp pools in f32, so halve for the
    # fp16-equivalent baseline the paper-level claim is against
    fp16_equiv = fp["kv_paged_bytes_per_request"] / 2.0
    ratio = i8["kv_paged_bytes_per_request"] / fp16_equiv
    cells["int8_kv_bytes_ratio_vs_fp16"] = ratio
    print(f"[bench_serving] int8 paged KV pool: "
          f"{i8['kv_paged_bytes_per_request'] / 1024:.1f} KiB/req vs "
          f"{fp16_equiv / 1024:.1f} KiB/req fp16-equiv "
          f"({1 - ratio:.0%} reduction)")
    if ratio > MAX_INT8_KV_RATIO:
        regressed.append("int8_kv_bytes")
        print(f"[bench_serving] FAIL: int8 paged KV bytes/request "
              f"{ratio:.2f}x fp16 paged (> {MAX_INT8_KV_RATIO})")

    # rtn-w4 packed weights through the paged engine (the quantized row)
    if quantized is None:
        quantized = quantize_params_rtn(
            params, QuantConfig(wbits=args.wbits, group_size=32))
    qp, skipped = quantized
    cells[f"shared_paged_rtn_w{args.wbits}"] = bench_cell(
        f"shared/paged/rtn-w{args.wbits}", lambda: paged(qp), shared_reqs)
    cells["rtn_skipped_kernels"] = skipped

    # packed-weight bytes per device under tp (AbstractMesh: layout-only)
    from repro.dist.sharding import make_plan
    from repro.serving.qserve.report import PACKED_SHARD_SLACK, \
        abstract_tp_mesh, packed_plane_bytes
    mesh = abstract_tp_mesh(args.tp)
    plan = make_plan(cfg, mesh)
    rep = packed_plane_bytes(qp, plan.param_shardings(qp))
    rep["tp"] = plan.tp_size
    cells["packed_plane_bytes"] = rep
    print(f"[bench_serving] packed planes: {rep['total']} B total -> "
          f"{rep['per_device']} B/device under tp={rep['tp']} "
          f"(ratio {rep['ratio']:.3f})")
    if rep["ratio"] > PACKED_SHARD_SLACK / rep["tp"]:
        regressed.append("packed_planes_replicated")
        print(f"[bench_serving] FAIL: packed planes look replicated under "
              f"tp={rep['tp']} (per-device/total = {rep['ratio']:.3f})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="toy-llama")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI cell: fewer requests, no quantized runs")
    ap.add_argument("--quant-smoke", action="store_true",
                    help="tiny CI cell: ONLY the quantized-serving section "
                         "(rtn-w4 paged, int8 KV, packed bytes/device)")
    ap.add_argument("--sched-smoke", action="store_true",
                    help="tiny CI cell: ONLY the scheduling section "
                         "(chunked vs blocking prefill, speculative decode)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--wbits", type=int, default=4)
    ap.add_argument("--tp", type=int, default=4,
                    help="virtual tp degree for the packed bytes/device "
                         "report (AbstractMesh; no devices needed)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serving.json"))
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n = 8 if args.smoke else args.requests
    reqs = workload(cfg, n)
    shared_reqs = workload_shared_prefix(cfg, n)

    results = {"arch": cfg.name, "requests": n, "max_batch": args.max_batch,
               "capacity": args.capacity, "block_size": args.block_size,
               "cells": {}}

    if args.quant_smoke or args.sched_smoke:
        regressed = []
        if args.quant_smoke:
            bench_quantized(cfg, params, args, results, regressed)
        else:
            bench_sched(cfg, params, args, results, regressed)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[bench_serving] wrote {os.path.normpath(args.out)}")
        if regressed:
            sys.exit(1)
        return results

    variants = [("dense", params)]
    quantized = None
    if not args.smoke:
        quantized = quantize_params_rtn(
            params, QuantConfig(wbits=args.wbits, group_size=32))
        variants.append((f"rtn_w{args.wbits}", quantized[0]))

    def makers(p):
        return (("static", lambda: StaticEngine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity)),
                ("continuous", lambda: Engine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity)),
                ("paged", lambda: PagedEngine(
                    cfg, p, max_batch=args.max_batch,
                    capacity=args.capacity, block_size=args.block_size)))

    # ---- uniform workload: all three engines, interleaved timed rounds
    for vname, p in variants:
        group = bench_group([(f"{ename}/{vname}", mk)
                             for ename, mk in makers(p)], reqs)
        for ename, _ in makers(p):
            results["cells"][f"{ename}_{vname}"] = group[f"{ename}/{vname}"]

    # ---- shared-prefix workload: continuous-dense vs paged
    group = bench_group([(f"shared/{ename}/dense", mk)
                         for ename, mk in makers(params)[1:]], shared_reqs)
    for ename, _ in makers(params)[1:]:
        results["cells"][f"shared_{ename}_dense"] = \
            group[f"shared/{ename}/dense"]

    regressed = []
    for vname, _ in variants:
        s = results["cells"][f"static_{vname}"]["tokens_per_s"]
        c = results["cells"][f"continuous_{vname}"]["tokens_per_s"]
        g = results["cells"][f"paged_{vname}"]["tokens_per_s"]
        results["cells"][f"speedup_{vname}"] = c / s
        results["cells"][f"paged_vs_continuous_{vname}"] = g / c
        print(f"[bench_serving] continuous/{vname} speedup over static: "
              f"{c / s:.2f}x; paged vs continuous: {g / c:.2f}x")
        if c <= s:
            regressed.append(f"continuous_{vname}")
            print(f"[bench_serving] FAIL: continuous did not beat static "
                  f"on {vname}")
        if g < PAGED_UNIFORM_FLOOR * c:
            regressed.append(f"paged_{vname}")
            print(f"[bench_serving] FAIL: paged regressed below "
                  f"continuous-dense on the uniform workload ({g / c:.2f}x "
                  f"< {PAGED_UNIFORM_FLOOR})")

    sp = results["cells"]["shared_paged_dense"]
    sc = results["cells"]["shared_continuous_dense"]
    skip_frac = sp["prefill_tokens_skipped"] / max(
        1, sp["prefill_tokens_skipped"] + sp["prefill_tokens_computed"])
    results["cells"]["shared_prefix_skip_fraction"] = skip_frac
    results["cells"]["shared_kv_bytes_ratio"] = \
        sp["kv_bytes_per_request"] / sc["kv_bytes_per_request"]
    print(f"[bench_serving] shared-prefix: {skip_frac:.0%} prefill tokens "
          f"skipped; kv bytes/request {sp['kv_bytes_per_request'] / 1024:.1f}"
          f" KiB paged vs {sc['kv_bytes_per_request'] / 1024:.1f} KiB dense")
    if skip_frac < MIN_PREFIX_SKIP_FRACTION:
        regressed.append("shared_prefix_skip")
        print(f"[bench_serving] FAIL: prefix sharing skipped only "
              f"{skip_frac:.0%} of prefill tokens "
              f"(< {MIN_PREFIX_SKIP_FRACTION:.0%})")

    bench_obs_overhead(cfg, params, args, results, regressed, reqs)

    if not args.smoke:   # full run: quantized + scheduling sections too
        bench_quantized(cfg, params, args, results, regressed, quantized)
        bench_sched(cfg, params, args, results, regressed)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_serving] wrote {os.path.normpath(args.out)}")
    if regressed:                     # the CI tripwire: fail the step
        sys.exit(1)
    return results


if __name__ == "__main__":
    main()
