"""Train the toy LLaMa-family LM used by the quality benchmarks (cached)."""
import os
import sys

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import TrainConfig                     # noqa: E402
from repro.configs.paper_models import TOY_LM                  # noqa: E402
from repro.data import DataIterator, SyntheticCorpus           # noqa: E402
from repro.models import build_model                           # noqa: E402
from repro.train.loop import train                             # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "toy_lm")
SEQ = 128


def main(steps=400):
    cfg = TOY_LM
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=SEQ, seed=7)
    it = DataIterator(corpus, "train", batch_size=16)
    tcfg = TrainConfig(steps=steps, ckpt_every=50, ckpt_dir=ART,
                       lr=2e-3, warmup=30, keep=1)
    params, losses = train(m, params, it, tcfg, log_every=25)
    print("final loss:", losses[-1])


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 400)
