"""Paper-table reproductions (quality orderings at toy scale).

One function per table; each prints ``name,us_per_call,derived`` CSV rows
(us_per_call = quantization wall time; derived = the quality metrics).
"""
import dataclasses
import time

import numpy as np

from benchmarks import common
from repro.configs.base import QuantConfig


def _row(m, params, calib, test, name, qcfg, tag=""):
    t0 = time.time()
    qp, dt = common.quantize_cached(m, params, calib, qcfg, tag)
    wall = (dt if dt is not None else 0.0) * 1e6
    met = common.metrics(m, qp, params, test)
    bits = common.avg_bits_of(qcfg)
    common.emit(
        f"{name}", wall,
        f"avg_bits={bits:.2f};ppl={met['ppl']:.3f};dCE={met['dce']:.4f};"
        f"kl={met['kl']:.4f};base_ppl={met['base_ppl']:.3f}")
    return met


def _tuned_row(m, params, calib, valid, test, name, qcfg,
               alphas=(0.1, 1.0)):
    """Paper App. C.2: tune the Hessian regularization per method on a
    validation split, report the test metrics of the winner."""
    best = (None, 1e9, None)
    for a in alphas:
        q = dataclasses.replace(qcfg, alpha=a)
        qp, dt = common.quantize_cached(m, params, calib, q)
        ce_v = float(m.loss(qp, valid))
        if ce_v < best[1]:
            best = (q, ce_v, qp)
    met = common.metrics(m, best[2], params, test)
    bits = common.avg_bits_of(best[0])
    common.emit(
        name, 0,
        f"avg_bits={bits:.2f};ppl={met['ppl']:.3f};dCE={met['dce']:.4f};"
        f"kl={met['kl']:.4f};alpha={best[0].alpha}")
    return met


def table1_2bit(ctx):
    """Table 1/11/12: 2-bit PTQ — RTN vs OPTQ vs SpQR(l2) vs OAC.
    alpha is tuned per method on a validation split (paper App. C.2)."""
    m, params, calib, test, valid = ctx
    g = 32
    out = {"table1/rtn_w2": _row(m, params, calib, test, "table1/rtn_w2",
                                 QuantConfig(wbits=2, group_size=g,
                                             method="rtn"))}
    for name, method, h in (("table1/optq_l2_w2", "optq", "l2"),
                            ("table1/spqr_l2_w2", "spqr", "l2"),
                            ("table1/oac_spqr_w2", "spqr", "oac")):
        out[name] = _tuned_row(m, params, calib, valid, test, name,
                               QuantConfig(wbits=2, group_size=g,
                                           method=method, hessian=h))
    order = [out["table1/oac_spqr_w2"]["dce"],
             out["table1/spqr_l2_w2"]["dce"],
             out["table1/rtn_w2"]["dce"]]
    ok = order[0] <= order[1] * 1.05 and order[1] < order[2]
    common.emit("table1/ordering_oac<=spqr<rtn", 0, f"holds={ok}")
    return out


def table2_binary(ctx):
    """Table 2/10: binarization — BiLLM(l2 H) vs OAC_BiLLM."""
    m, params, calib, test, valid = ctx
    rows = {
        "table2/billm_l2_w1": QuantConfig(wbits=1, group_size=64,
                                          method="billm", hessian="l2"),
        "table2/oac_billm_w1": QuantConfig(wbits=1, group_size=64,
                                           method="billm", hessian="oac"),
    }
    out = {k: _tuned_row(m, params, calib, valid, test, k, q)
           for k, q in rows.items()}
    ok = out["table2/oac_billm_w1"]["dce"] <= \
        out["table2/billm_l2_w1"]["dce"] * 1.05
    common.emit("table2/ordering_oac_billm<=billm", 0, f"holds={ok}")
    return out


def table3_grad_dtype(ctx):
    """Table 3 / App C.1: bf16 vs fp32 gradient Hessians (cost vs quality)."""
    m, params, calib, test, valid = ctx
    for name, dt in (("fp32", "float32"), ("bf16", "bfloat16")):
        q = QuantConfig(wbits=2, group_size=32, method="spqr",
                        hessian="oac", grad_dtype=dt)
        _row(m, params, calib, test, f"table3/oac_grad_{name}", q)


def table4_alpha(ctx):
    """Table 4 / App C.2: Hessian regularization sweep."""
    m, params, calib, test, valid = ctx
    best = (None, 1e9)
    for a in (0.001, 0.01, 0.1, 1.0):
        q = QuantConfig(wbits=2, group_size=32, method="spqr",
                        hessian="oac", alpha=a)
        met = _row(m, params, calib, test, f"table4/oac_alpha_{a}", q)
        if met["dce"] < best[1]:
            best = (a, met["dce"])
    common.emit("table4/best_alpha", 0, f"alpha={best[0]}")


def table5_reduction(ctx):
    """Table 5 / App C.3: sum (eq.22) vs mean (eq.14) Hessian reduction."""
    m, params, calib, test, valid = ctx
    for red in ("sum", "mean"):
        q = QuantConfig(wbits=2, group_size=32, method="spqr",
                        hessian="oac", hessian_reduction=red)
        _row(m, params, calib, test, f"table5/oac_{red}", q)


def table13_3bit(ctx):
    """Table 13: 3-bit PTQ."""
    m, params, calib, test, valid = ctx
    for name, q in {
        "table13/rtn_w3": QuantConfig(wbits=3, group_size=32, method="rtn"),
        "table13/spqr_l2_w3": QuantConfig(wbits=3, group_size=32,
                                          method="spqr", hessian="l2"),
        "table13/oac_spqr_w3": QuantConfig(wbits=3, group_size=32,
                                           method="spqr", hessian="oac"),
    }.items():
        _row(m, params, calib, test, name, q)


def table14_ablation(ctx):
    """Table 14 / App I: OAC_X improves every base calibrator X."""
    m, params, calib, test, valid = ctx
    pairs = {}
    for base in ("optq", "spqr"):
        for h in ("l2", "oac"):
            q = QuantConfig(wbits=2, group_size=32, method=base, hessian=h)
            met = _tuned_row(m, params, calib, valid, test,
                             f"table14/{base}_{h}", q)
            pairs[(base, h)] = met["dce"]
    for base in ("optq", "spqr"):
        ok = pairs[(base, "oac")] <= pairs[(base, "l2")] * 1.05
        common.emit(f"table14/oac_improves_{base}", 0, f"holds={ok}")


ALL = [table1_2bit, table2_binary, table3_grad_dtype, table4_alpha,
       table5_reduction, table13_3bit, table14_ablation]
