"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline source).

Reads artifacts/dryrun/<cell>.json and emits one CSV row per (arch x shape):
terms in seconds, dominant bottleneck, useful-FLOP ratio, roofline fraction.
"""
import glob
import json
import os

from benchmarks import common

ART = os.path.join(common.ROOT, "artifacts", "dryrun")


def load_cells(mesh="16x16"):
    cells = {}
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def bench_roofline(ctx=None):
    cells = load_cells()
    if not cells:
        common.emit("roofline/missing", 0,
                    "run: python -m repro.launch.dryrun --all")
        return
    for (arch, shape), rec in sorted(cells.items()):
        r = rec["roofline"]
        dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        common.emit(
            f"roofline/{arch}/{shape}", dom * 1e6,
            f"bottleneck={r['bottleneck']};tc={r['t_compute_s']:.4f}"
            f";tm={r['t_memory_s']:.4f};tcoll={r['t_collective_s']:.4f}"
            f";useful={r['useful_ratio']:.3f}"
            f";roofline_frac={r['roofline_fraction']:.3f}"
            f";attn={rec['attn_modes']}")


ALL = [bench_roofline]
