"""Shared benchmark infrastructure: trained toy LM, calib/test sets, metrics.

The quality benchmarks reproduce the paper's TABLE ORDERINGS at toy scale
(CPU container; see DESIGN.md §7): a trained 4L/256d LLaMa-family model on
the synthetic Markov corpus, quantized by each method, evaluated by held-out
perplexity and KL(original ‖ quantized) — the paper's C4/WikiText2 metrics
stand-ins.  Results cache under artifacts/bench_cache.
"""
import hashlib
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.configs.base import QuantConfig, TrainConfig     # noqa: E402
from repro.configs.paper_models import TOY_LM               # noqa: E402
from repro.core import pipeline                             # noqa: E402
from repro.data import SyntheticCorpus, make_calib_set      # noqa: E402
from repro.models import build_model                        # noqa: E402
from repro.train import checkpoint as ckpt                  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts")
TOY_DIR = os.path.join(ART, "toy_lm")
CACHE = os.path.join(ART, "bench_cache")
SEQ = 128
N_CALIB = 24
N_TEST = 16


def load_toy():
    """(model, trained params, calib batch, test batch). Trains on demand."""
    cfg = TOY_LM
    m = build_model(cfg)
    params0 = m.init(jax.random.PRNGKey(0))
    if ckpt.latest_step(TOY_DIR) is None:
        from benchmarks import prep_toy_lm
        prep_toy_lm.main(500)
    from repro.train import optimizer as opt
    from repro.train import compression as comp
    tpl = (params0, opt.adamw_init(params0), ())
    (params, _, _), _ = ckpt.restore(TOY_DIR, tpl, strict=False)
    params = jax.tree.map(jnp.asarray, params)
    corpus = SyntheticCorpus(vocab=cfg.vocab, seq_len=SEQ, seed=7)
    calib = {"tokens": jnp.asarray(make_calib_set(corpus, N_CALIB)["tokens"])}
    test = {"tokens": jnp.asarray(
        np.concatenate([corpus.batch("test", i, 8)["tokens"]
                        for i in range(N_TEST // 8)], 0))}
    valid = {"tokens": jnp.asarray(corpus.batch("valid", 0, 8)["tokens"])}
    return m, params, calib, test, valid


def metrics(m, params_q, params_orig, test):
    """(ppl, delta_ce, kl) of quantized vs original on held-out data."""
    ce_q = float(m.loss(params_q, test))
    ce_o = float(m.loss(params_orig, test))
    lq, _ = m.apply(params_q, test)
    lo, _ = m.apply(params_orig, test)
    po = jax.nn.log_softmax(lo.astype(jnp.float32), -1)
    pq = jax.nn.log_softmax(lq.astype(jnp.float32), -1)
    kl = float(jnp.sum(jnp.exp(po) * (po - pq), -1).mean())
    return {"ppl": float(np.exp(ce_q)), "ce": ce_q, "dce": ce_q - ce_o,
            "kl": kl, "base_ppl": float(np.exp(ce_o))}


def quantize_cached(m, params, calib, qcfg: QuantConfig, tag=""):
    """Run (or load) the Algorithm-1 pipeline for one quant config."""
    os.makedirs(CACHE, exist_ok=True)
    key = hashlib.md5((repr(qcfg) + tag).encode()).hexdigest()[:16]
    path = os.path.join(CACHE, f"q_{key}.npz")
    if os.path.exists(path):
        data = np.load(path)
        flat, treedef = jax.tree_util.tree_flatten(params)
        leaves = [jnp.asarray(data[f"l{i}"]) for i in range(len(flat))]
        return jax.tree_util.tree_unflatten(treedef, leaves), None
    t0 = time.time()
    qp, results = pipeline.quantize_model(m, params, calib, qcfg,
                                          log=lambda *a: None)
    dt = time.time() - t0
    flat, _ = jax.tree_util.tree_flatten(qp)
    np.savez(path, **{f"l{i}": np.asarray(v) for i, v in enumerate(flat)})
    with open(path + ".meta", "w") as f:
        json.dump({"seconds": dt, "qcfg": repr(qcfg)}, f)
    return qp, dt


def avg_bits_of(qcfg: QuantConfig) -> float:
    """Analytic average bits for the config (storage accounting)."""
    b = qcfg.wbits
    if qcfg.method == "rtn":
        return b + 2 * 16 / qcfg.group_size
    if qcfg.method == "billm":
        return 1.09  # reported per BiLLM's own convention; see core/billm.py
    stats = 2 * qcfg.stats_bits / qcfg.group_size + \
        4 * 16 / (qcfg.group_size * qcfg.stats_group)
    outl = qcfg.outlier_capacity * 48
    return b + stats + outl


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
