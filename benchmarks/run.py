"""Benchmark driver: one function per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only tables|kernels|roofline]
"""
import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "tables", "kernels", "roofline"])
    args = ap.parse_args()

    from benchmarks import bench_kernels, bench_roofline, bench_tables
    from benchmarks import common

    print("name,us_per_call,derived")
    suites = []
    if args.only in (None, "tables"):
        suites.append(("tables", bench_tables.ALL, True))
    if args.only in (None, "kernels"):
        suites.append(("kernels", bench_kernels.ALL, False))
    if args.only in (None, "roofline"):
        suites.append(("roofline", bench_roofline.ALL, False))

    ctx = None
    failures = 0
    for name, fns, needs_ctx in suites:
        if needs_ctx and ctx is None:
            ctx = common.load_toy()
        for fn in fns:
            try:
                fn(ctx)
            except Exception:
                traceback.print_exc()
                failures += 1
    if failures:
        print(f"# {failures} benchmark(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
