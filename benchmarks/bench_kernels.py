"""Kernel microbenchmarks (CPU wall time of the jnp paths + interpret-mode
checks; BlockSpec sweeps report the tiling chosen for TPU)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import utils
from repro.core import hessian as hess
from repro.core import qformat
from repro.kernels.dequant_matmul import ops as dq_ops
from repro.kernels.hessian_gg import ops as gg_ops


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        utils.block_all(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_dequant(ctx=None):
    rng = np.random.default_rng(0)
    for (M, K, N, bits) in [(64, 1024, 1024, 2), (64, 1024, 1024, 4),
                            (8, 2048, 2048, 2)]:
        gs = 64
        codes = jnp.asarray(rng.integers(0, 2 ** bits, (K, N)), jnp.uint8)
        from repro.core import quantizers as qz
        W = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
        q, s, z, _ = qz.rtn_quantize(W, bits, gs)
        cap = 8
        zr = jnp.zeros(cap, jnp.int32)
        qt = qformat.make_quantized(q, s, z, bits, gs, (K, N), zr, zr,
                                    jnp.zeros(cap, jnp.bfloat16))
        x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
        f = jax.jit(lambda xx: dq_ops.dequant_matmul(xx, qt))
        us = _time(f, x)
        dense = jax.jit(lambda xx: xx @ W)
        us_d = _time(dense, x)
        common.emit(f"kernels/dequant_matmul_M{M}_K{K}_N{N}_w{bits}", us,
                    f"dense_us={us_d:.0f};packed_bytes={sum(p.size for p in qt.planes)}")


def bench_hessian_gg(ctx=None):
    rng = np.random.default_rng(1)
    for (D, dout) in [(512, 512), (1024, 512)]:
        G = jnp.asarray(rng.normal(size=(D, dout)).astype(np.float32))
        f = jax.jit(lambda g: gg_ops.gg_update(g))
        us = _time(f, G)
        tri_flops = D * (D + 1) / 2 * dout * 2
        full_flops = D * D * dout * 2
        common.emit(f"kernels/hessian_gg_D{D}_dout{dout}", us,
                    f"tri_flop_saving={full_flops / tri_flops:.2f}x")


def bench_calib_blocks(ctx=None):
    rng = np.random.default_rng(2)
    from repro.core import solver
    for (d_in, d_out) in [(512, 512), (1024, 1024)]:
        W = jnp.asarray(rng.normal(size=(d_in, d_out)).astype(np.float32))
        X = jnp.asarray(rng.normal(size=(512, d_in)).astype(np.float32))
        H = X.T @ X
        f = jax.jit(lambda w, h: solver.calibrate(
            w, h, bits=2, group_size=64, alpha=0.1, tau=3.5,
            outlier_capacity=0.005).w_hat)
        us = _time(f, W, H, reps=2)
        common.emit(f"kernels/solver_calibrate_{d_in}x{d_out}_w2", us,
                    f"cols_per_s={d_in / (us / 1e6):.0f}")


ALL = [bench_dequant, bench_hessian_gg, bench_calib_blocks]
